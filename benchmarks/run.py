"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract).  ``--full``
adds the slow sweeps (all array sizes, all macro budgets, grouped-conv
accuracy training).
"""
from __future__ import annotations

import argparse

from . import (fig14_speedup, fig15_grouped_speedup, fig17_18_system,
               fig19_ablation, fig20_macro_parallel, fleet_bench,
               kernels_bench, mobilenet_depthwise, plan_bench,
               search_bench, serve_bench, table1_mapping, table2_grouped,
               transformer_bench)

MODULES = [table1_mapping, table2_grouped, fig14_speedup,
           fig15_grouped_speedup, fig17_18_system, fig19_ablation,
           fig20_macro_parallel, mobilenet_depthwise, kernels_bench,
           plan_bench, search_bench, serve_bench, fleet_bench,
           transformer_bench]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="slow sweeps: all sizes/budgets + accuracy runs")
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for mod in MODULES:
        if args.only and args.only not in mod.__name__:
            continue
        for row in mod.run(full=args.full):
            print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
