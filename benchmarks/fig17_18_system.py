"""Fig 17/18: system-level latency / dynamic energy / EDAP on the CIM
simulator (NeuroSim-style analytical model, 22nm/1GHz/512x512 SRAM).
Paper: TetrisG vs VWC latency/energy 2.4x/1.7x (CNN8), 1.3x/1.2x
(Inception), 1.3x/1.6x (DenseNet40); EDAP 4.27x/1.54x/2.06x."""
from __future__ import annotations

from repro.core import ArrayConfig, map_net, memo, networks
from repro.core.simulator import simulate

from .common import Row, timed


def run(full: bool = False):
    arr = ArrayConfig(512, 512)
    rows = []
    for net in ("cnn8", "inception", "densenet40"):
        layers = networks.NETWORKS[net]()
        sims = {}
        us_tot = 0.0
        for alg in ("img2col", "VWC-SDK", "TetrisG-SDK"):
            kw = ({"groups": (1, 2)} if
                  (alg == "TetrisG-SDK" and net != "cnn8") else {})
            # search timed under memo.disabled() so us_per_call is the
            # real (uncached scalar) search cost, independent of what an
            # earlier module left in the in-process cache — the same
            # convention search_bench.py uses; simulate timed separately.
            with memo.disabled():
                (nm, us_map) = timed(map_net, net, layers, arr, alg, **kw)
            (m, us_sim) = timed(simulate, nm)
            sims[alg] = m
            us_tot += us_map + us_sim
        g, v, i = sims["TetrisG-SDK"], sims["VWC-SDK"], sims["img2col"]
        rows.append(Row(
            f"fig17/{net}", us_tot,
            f"lat_x_vwc={v.latency_s/g.latency_s:.2f};"
            f"en_x_vwc={v.energy_j/g.energy_j:.2f}"))
        rows.append(Row(
            f"fig18/{net}", us_tot,
            f"edap_x_vwc={v.edap/g.edap:.2f};"
            f"edap_x_img2col={i.edap/g.edap:.2f}"))
    return rows
