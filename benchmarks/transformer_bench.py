"""Transformer serving throughput through the operator-generic plan path.

The ISSUE 8 acceptance artifact: both transformer smoke configs
(`launch.transformer.TRANSFORMERS`) lower block-by-block into matmul
specs + GlueSpec glue, compile through the SAME `exec.compile_plan` the
CNN serve path uses, and run steady-state forwards through
`execute_plan` — tokens/s is reported next to images/s (a "request" is
one ``seq``-token frame, `launch.transformer.tokens_per_row`).

Medians over interleaved steady-state rounds; ``--smoke`` keeps one
block per config so the CPU CI job compiles in seconds.

    python -m benchmarks.transformer_bench --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ArrayConfig, MacroGrid, memo
from repro.exec import compile_plan, execute_plan
from repro.launch.transformer import (TRANSFORMERS, tokens_per_row,
                                      transformer_mapping)

from .common import Row, median

SEQ = 16
BATCH = 2
ARRAY = ArrayConfig(64, 64)
GRID = MacroGrid(2, 2)
ROUNDS = 3
STEPS = 4


def _serve_rate(plan, kernels, x) -> float:
    """Steady-state seconds per forward (one warmup outside the clock)."""
    import jax
    jax.block_until_ready(execute_plan(plan, kernels, x))
    t0 = time.perf_counter()
    for _ in range(STEPS):
        jax.block_until_ready(execute_plan(plan, kernels, x))
    return (time.perf_counter() - t0) / STEPS


def run(full: bool = False):
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    rows = []
    for name in sorted(TRANSFORMERS):
        memo.clear()
        t0 = time.perf_counter()
        net = transformer_mapping(name, seq=SEQ, array=ARRAY, grid=GRID,
                                  blocks=None if full else 1)
        search_s = time.perf_counter() - t0
        plan = compile_plan(net, executor_policy="mapped", batch=BATCH)
        assert plan.total_steps == net.total_cycles
        kernels = [jnp.asarray(
            rng.randn(1, 1, m.layer.ic // m.group, m.layer.oc) * 0.1,
            jnp.float32) for m in net.layers]
        d_model = net.layers[0].layer.ic
        x = jnp.asarray(rng.randn(BATCH, d_model, SEQ, 1) * 0.5,
                        jnp.float32)
        s = median([_serve_rate(plan, kernels, x) for _ in range(ROUNDS)])
        toks = BATCH * tokens_per_row(net)
        rows.append(Row(
            f"transformer/{name}", s * 1e6,
            f"tokens_per_s={toks / s:.1f};"
            f"images_per_s={BATCH / s:.1f};"
            f"seq={SEQ};batch={BATCH};layers={len(net.layers)};"
            f"total_cycles={net.total_cycles};"
            f"search_ms={search_s * 1e3:.1f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one block per config (the CI artifact)")
    ap.add_argument("--full", action="store_true",
                    help="all blocks of each smoke config")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(full=args.full and not args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
