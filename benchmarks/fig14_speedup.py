"""Fig 14: Tetris-SDK speed-up vs img2col/SDK/VW-SDK across array sizes
(64x64 .. 512x512) for the three benchmark networks."""
from __future__ import annotations

from repro.core import ArrayConfig, map_net, networks

from .common import Row, timed


def run(full: bool = False):
    rows = []
    sizes = (64, 128, 256, 512) if full else (128, 512)
    for net in ("cnn8", "inception", "densenet40"):
        layers = networks.NETWORKS[net]()
        for s in sizes:
            arr = ArrayConfig(s, s)
            base = {}
            for alg in ("img2col", "SDK", "VW-SDK", "Tetris-SDK"):
                m, us = timed(map_net, net, layers, arr, alg)
                base[alg] = m.total_cycles
            der = (f"tetris_cycles={base['Tetris-SDK']};"
                   f"x_img2col={base['img2col']/base['Tetris-SDK']:.2f};"
                   f"x_sdk={base['SDK']/base['Tetris-SDK']:.2f};"
                   f"x_vw={base['VW-SDK']/base['Tetris-SDK']:.2f}")
            rows.append(Row(f"fig14/{net}/{s}x{s}", us, der))
    return rows
