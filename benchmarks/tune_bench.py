"""Autotuner acceptance bench: tuned vs the "auto" default, measured.

For each bench network (cnn8, inception, densenet40 — a prefix in smoke
mode to keep CI compile time sane) this runs the measured-feedback
search (`repro.tune.autotune`) and reports the winner's interleaved-
median wall-clock against the auto-policy baseline FROM THE SAME FINAL
ROUNDS — the ISSUE 6 acceptance quantity: tuned must beat or tie auto
(the baseline candidate survives every halving cut, so a winner slower
than the default cannot exist by construction; the rows make the margin
visible).

    python -m benchmarks.tune_bench --smoke           # CI: tiny budget
    python -m benchmarks.tune_bench --full            # whole densenet40
    python -m benchmarks.tune_bench --smoke --json out.json \
        --trajectory BENCH_autotune.json --pr "PR 6"

Prints the harness CSV (``name,usec,extras``) to stdout — CI tees it
into ``bench-out/tune_bench.csv``.  Exposes ``run(full)`` returning
`benchmarks.common.Row`s like every other bench module, though it is
not in run.py's default MODULES: a measured search is minutes, not the
seconds budget ``python -m benchmarks.run`` holds to.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional

from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro import tune

from .common import Row

BATCH = 4
GRID = MacroGrid(2, 2)


def _nets(full: bool):
    return [("cnn8", networks.cnn8()),
            ("inception", networks.inception()),
            ("densenet40" if full else "densenet40[:12]",
             networks.densenet40() if full else
             networks.densenet40()[:12])]


def tune_all(*, full: bool = False, budget: Optional[tune.TuneBudget] = None,
             force: bool = False) -> Dict[str, tune.TuneResult]:
    """Autotune every bench net; smoke mode uses the tiny CI budget."""
    budget = budget or (tune.TuneBudget() if full else tune.SMOKE_BUDGET)
    arr = ArrayConfig(64, 64)
    results = {}
    for label, layers in _nets(full):
        nm = map_net(label, layers, arr, "TetrisG-SDK", GRID,
                     groups=(1, 2))
        results[label] = tune.autotune(nm, batch=BATCH, budget=budget,
                                       force=force)
    return results


def run(full: bool = False):
    """Harness-shaped entry: one Row per net (summary only — trial rows
    stay in the CSV artifact the CLI writes)."""
    results = tune_all(full=full)
    return [Row(name, us, extras)
            for name, us, extras in tune.report.csv_rows(results)
            if "/trial" not in name]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="tiny budget + densenet40 prefix (the CI run)")
    mode.add_argument("--full", action="store_true",
                      help="default budget + whole densenet40")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even with a persisted winner")
    ap.add_argument("--csv", default=None,
                    help="also write the CSV to this path")
    ap.add_argument("--json", default=None,
                    help="write the full results (every trial) as JSON")
    ap.add_argument("--trajectory", default=None,
                    help="append a BENCH_autotune.json ledger entry here")
    ap.add_argument("--pr", default="",
                    help="ledger entry tag for --trajectory")
    args = ap.parse_args(argv)

    results = tune_all(full=args.full, force=args.force)
    print(tune.write_csv(results, args.csv), end="")
    if args.json:
        tune.write_json(results, args.json)
    if args.trajectory:
        tune.append_trajectory(
            args.trajectory,
            tune.trajectory_entry(results, pr=args.pr,
                                  note="smoke" if args.smoke else "full"))


if __name__ == "__main__":
    main()
