"""Shared-fleet vs dedicated-slice serving on the SAME mixed stream.

The ISSUE 7 acceptance row: one `launch/fleet.FleetScheduler` serving a
mixed cnn8+inception+densenet40 Poisson stream must achieve at least
the aggregate effective images/s of serving each model alone on a
dedicated fleet slice.  Both paths face an identical tagged trace:

* ``shared``    — `fleet.serve_fleet`: per-model coalescers + plan
  ladders behind the cross-model drain policy, one serving span — a
  model's idle arrival gaps are filled with the other models' work;
* ``dedicated`` — each model's sub-trace (absolute arrival times
  preserved) replayed alone through `serve_cnn.serve_dynamic`; the
  slices run independently, so the baseline's wall is the SUM of the
  per-slice walls — each slice still waits out its own arrival span,
  which is the whole trace's span.

Rounds are interleaved (plan_bench-style) so machine noise hits both
paths equally; medians are reported.  Per-model and aggregate SLO
attainment come from the shared run.  Layer SLICES of the three nets
keep CPU compile time in check (densenet40's full 38-layer program
compiles for minutes); the scheduling comparison is unchanged.

    python -m benchmarks.fleet_bench --smoke
"""
from __future__ import annotations

import argparse

from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.launch import fleet, serve_cnn

from .common import Row, interleaved_rounds, median

NETS = ("cnn8", "inception", "densenet40")
SLICES = {"cnn8": 3, "inception": 2, "densenet40": 4}
MAX_BATCH = 4
MAX_DELAY_MS = 2.0
SLO_MS = 100.0
RATE_PER_S = 150.0
ROUNDS = 3


def _mappings():
    out = {}
    for name in NETS:
        layers = networks.NETWORKS[name]()[:SLICES[name]]
        out[name] = fleet.chainable_prefix(map_net(
            name, layers, ArrayConfig(64, 64), "TetrisG-SDK",
            MacroGrid(2, 2), groups=(1, 2)))
    return out


def run(full: bool = False):
    n_requests = 60 if full else 24
    mappings = _mappings()
    config = fleet.FleetConfig(models=tuple(
        fleet.ModelSpec(n, max_batch=MAX_BATCH,
                        max_delay_s=MAX_DELAY_MS / 1e3, slo_ms=SLO_MS)
        for n in NETS))
    trace = fleet.mixed_poisson_trace(NETS, n_requests, RATE_PER_S,
                                      MAX_BATCH, seed=0)

    def shared_round():
        stats, _ = fleet.serve_fleet(mappings, config, trace, warmup=1)
        return (stats.images_per_s, stats.padded_images_per_s,
                stats.slo_attainment,
                {n: m.slo_attainment for n, m in stats.models.items()})

    def dedicated_round():
        # each slice serves ONLY its model but still spans the whole
        # trace (absolute arrival times preserved); slices are
        # independent, so the baseline wall is the sum
        images = padded = wall = 0.0
        for name in NETS:
            sub = tuple((t, r) for t, m, r in trace if m == name)
            s = serve_cnn.serve_dynamic(
                mappings[name], sub, max_batch=MAX_BATCH,
                max_delay_ms=MAX_DELAY_MS, warmup=1)
            images += s.request_images
            padded += s.padded_images
            wall += s.wall_s
        return images / wall, padded / wall

    outs = interleaved_rounds([shared_round, dedicated_round], ROUNDS,
                              warmup=1)
    sh_eff = median([o[0] for o in outs[0]])
    sh_pad = median([o[1] for o in outs[0]])
    sh_slo = median([o[2] for o in outs[0]])
    per_model = outs[0][len(outs[0]) // 2][3]     # the median round's
    de_eff = median([o[0] for o in outs[1]])
    de_pad = median([o[1] for o in outs[1]])
    slo_tag = "/".join(f"{n}:{per_model[n]:.3f}" for n in NETS)
    return [
        Row("fleet/dedicated", 1e6 / de_eff,
            f"images_per_s={de_eff:.1f};padded_images_per_s={de_pad:.1f};"
            f"models={'/'.join(NETS)};requests={n_requests}"),
        Row("fleet/shared", 1e6 / sh_eff,
            f"images_per_s={sh_eff:.1f};padded_images_per_s={sh_pad:.1f};"
            f"speedup={sh_eff / de_eff:.2f};"
            f"slo_attainment={sh_slo:.3f};per_model_slo={slo_tag};"
            f"max_batch={MAX_BATCH};max_delay_ms={MAX_DELAY_MS};"
            f"slo_ms={SLO_MS}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (the acceptance smoke)")
    ap.add_argument("--full", action="store_true",
                    help="longer trace / more rounds")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(full=args.full and not args.smoke):
        print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
