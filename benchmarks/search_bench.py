"""Search-speed benchmark: map_net + Alg 2 grid_search wall time, cached
(memoized/vectorized, core/memo.py) vs uncached (scalar reference loops),
so search-cost regressions surface in the BENCH trajectory alongside
kernel numbers.

The headline row is ``search/grid_search/densenet40/p16`` — the repo's
acceptance anchor is cached >= 5x faster than uncached with identical
chosen grids and cycle counts.  The full uncached densenet40 sweep takes
minutes, so quick mode measures the uncached side on a reduced budget
and reports the extrapolated ratio; ``--full`` times the real thing and
asserts result identity.
"""
from __future__ import annotations

import time

from repro.core import ArrayConfig, grid_search, map_net, networks
from repro.core import memo
from repro.core.macro_grid import candidate_grids

from .common import Row, timed

NETS = ("cnn8", "inception", "densenet40")
P_MAX = 16


def _grid_search_pair(net: str, p_max_uncached: int):
    """(cached us, uncached us/grid, results) for one network."""
    layers = networks.NETWORKS[net]()
    arr = ArrayConfig(512, 512)
    memo.clear()
    cached, us_cached = timed(grid_search, net, layers, arr, P_MAX)
    t0 = time.perf_counter()
    with memo.disabled():
        uncached = grid_search(net, layers, arr, p_max_uncached)
    us_unc = (time.perf_counter() - t0) * 1e6
    return cached, us_cached, uncached, us_unc


def run(full: bool = False):
    # this module measures the *in-memory* memoization ratio; detach any
    # REPRO_MAPPING_CACHE disk layer so cold timings aren't disk reads
    # and warm timings aren't disk writes (the persistent layer has its
    # own acceptance test in tests/test_search_cache.py)
    prev_disk = memo.disk_cache_dir()
    memo.set_disk_cache(None)
    try:
        return _run(full)
    finally:
        memo.set_disk_cache(prev_disk)


def _run(full: bool = False):
    arr = ArrayConfig(512, 512)
    rows = []
    for net in NETS:
        layers = networks.NETWORKS[net]()
        memo.clear()
        m, us = timed(map_net, net, layers, arr)
        rows.append(Row(f"search/map_net/{net}", us,
                        f"layers={len(layers)};cycles={m.total_cycles}"))

    n_grids = len(candidate_grids(P_MAX))
    for net in NETS:
        # uncached budget: full mode pays the whole scalar sweep on every
        # net; quick mode samples a 3-grid sweep and extrapolates
        p_unc = P_MAX if full else 2
        cached, us_c, uncached, us_u = _grid_search_pair(net, p_unc)
        if full:
            identical = (cached.best == uncached.best
                         and cached.per_grid == uncached.per_grid)
            speedup = us_u / us_c
            tag = (f"grid={cached.best.grid.r}x{cached.best.grid.c}"
                   f";cycles={cached.best.total_cycles}"
                   f";speedup={speedup:.1f}x;identical={identical}")
        else:
            est_unc = us_u / len(candidate_grids(p_unc)) * n_grids
            tag = (f"grid={cached.best.grid.r}x{cached.best.grid.c}"
                   f";cycles={cached.best.total_cycles}"
                   f";est_speedup={est_unc / us_c:.1f}x")
        rows.append(Row(f"search/grid_search/{net}/p{P_MAX}", us_c, tag))
    return rows
