"""Pallas kernel micro-bench (interpret mode on CPU — numbers are for
plumbing sanity, not TPU perf; TPU perf is the roofline analysis)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import Row, timed


def run(full: bool = False):
    rng = np.random.RandomState(0)
    rows = []
    x = jnp.asarray(rng.randn(256, 256), jnp.float32)
    w = jnp.asarray(rng.randn(256, 256), jnp.float32)
    y, _ = timed(lambda: ops.matmul(x, w).block_until_ready())
    _, us = timed(lambda: ops.matmul(x, w).block_until_ready(), repeats=3)
    rows.append(Row("kernel/tetris_matmul/256", us, "interpret=cpu"))

    xg = jnp.asarray(rng.randn(4, 128, 64), jnp.float32)
    wg = jnp.asarray(rng.randn(4, 64, 128), jnp.float32)
    timed(lambda: ops.gmm(xg, wg).block_until_ready())
    _, us = timed(lambda: ops.gmm(xg, wg).block_until_ready(), repeats=3)
    rows.append(Row("kernel/grouped_matmul/4x128", us, "interpret=cpu"))

    xc = jnp.asarray(rng.randn(1, 18, 18, 24), jnp.float32)
    wc = jnp.asarray(rng.randn(3, 3, 24, 32) * 0.1, jnp.float32)
    timed(lambda: ops.conv2d(xc, wc).block_until_ready())
    _, us = timed(lambda: ops.conv2d(xc, wc).block_until_ready(),
                  repeats=3)
    rows.append(Row("kernel/im2win_conv/18x18x24", us, "interpret=cpu"))
    return rows
