"""Fig 19: stepwise ablation on CNN8 — VWC baseline, +square-inclined
(SI), +marginal windows (MW), +depth-optimal (DO), +grouping (G).

Implemented by degrading the Tetris search: SI only = tetris without
marginal handling or remainder re-opt; +MW adds marginal windows; +DO
adds the depth-optimal remainder; +G adds grouping (the full TetrisG)."""
from __future__ import annotations

import math

from repro.core import ArrayConfig, LayerMapping, networks
from repro.core import baselines, cycles as cyc, grouped, tetris
from repro.core.simulator import simulate
from repro.core.types import MacroGrid, NetworkMapping, TileMapping

from .common import Row, timed

ARR = ArrayConfig(512, 512)


def _si_only(layer, array, grid=MacroGrid(), **kw):
    """Square-inclined windows, ceil counts, no marginal/DO windows."""
    best = None
    for w in cyc.candidate_windows(layer, array):
        ic_t = cyc.ic_t_for(w, layer.ic, array)
        oc_t = cyc.oc_t_for(w, layer, array)
        if ic_t < 1 or oc_t < 1:
            continue
        n, _ = cyc.n_windows(layer, w, marginal=False)
        t = TileMapping(window=w, depth=layer.ic, ic_t=ic_t, oc_t=oc_t,
                        ar_c=math.ceil(layer.ic / ic_t),
                        ac_c=math.ceil(layer.oc / oc_t), n_regular=n)
        m = LayerMapping(layer=layer, array=array, algorithm="SI",
                         tiles=(t,), grid=grid)
        # square preference as tie-break (Alg 3)
        key = (m.cycles, abs(w.pw_w - w.pw_h))
        if best is None or key < (best.cycles,
                                  abs(best.tiles[0].window.pw_w
                                      - best.tiles[0].window.pw_h)):
            best = m
    return best


def _mw(layer, array, grid=MacroGrid(), **kw):
    """SI + marginal windows (no depth-optimal remainder)."""
    return tetris.tetris_layer(layer, array, grid, max_prune=0)


def _do(layer, array, grid=MacroGrid(), **kw):
    return tetris.tetris_layer(layer, array, grid, max_prune=1)


def run(full: bool = False):
    layers = networks.cnn8()
    steps = [
        ("vwc", lambda ly, a, g: baselines.vwc_sdk(ly, a, g)),
        ("+SI", _si_only),
        ("+MW", _mw),
        ("+DO", _do),
        ("+G", lambda ly, a, g: grouped.tetrisg_layer(ly, a, g)),
    ]
    rows = []
    for name, mapper in steps:
        def netmap():
            ms = tuple(mapper(ly, ARR, MacroGrid()) for ly in layers)
            return NetworkMapping(name="cnn8", algorithm=name, array=ARR,
                                  layers=ms)
        net, us = timed(netmap)
        sim = simulate(net)
        der = (f"cycles={net.total_cycles};energy={sim.energy_j:.2e};"
               f"latency={sim.latency_s:.2e}")
        rows.append(Row(f"fig19/cnn8/{name}", us, der))
    return rows
