"""Table II: grouped-convolution accuracy + cycles.

Cycles: all four networks/algorithms (fast).  Accuracy: MNIST/CIFAR/Tiny
ImageNet are unavailable offline, so the near-lossless claim is tested on
the seeded synthetic classification task (--full; ~5 min CPU) — the
deltas G=1 vs G=2 are the reproduction target, not absolute accuracy."""
from __future__ import annotations

from repro.core import ArrayConfig, map_net, networks

from .common import Row, timed


def run(full: bool = False):
    arr = ArrayConfig(512, 512)
    rows = []
    for net in ("cnn8", "densenet40", "inception"):
        layers = networks.NETWORKS[net]()
        for alg in ("VW-SDK", "Tetris-SDK", "TetrisG-SDK"):
            # accuracy-constrained group sets (SIV-C1): CNN8 tolerates up
            # to G=8 on the proxy task; Inception/DenseNet kept at G<=2
            kw = ({"groups": (1, 2)} if alg == "TetrisG-SDK"
                  and net != "cnn8" else {})
            m, us = timed(map_net, net, layers, arr, alg, **kw)
            rows.append(Row(f"table2/{net}/{alg}", us,
                            f"cycles={m.total_cycles}"))
    if full:
        from repro.cnn.models import cnn8_config
        from repro.cnn.train import train_cnn
        for g in (1, 2, 4):
            # accuracy measured through the macro-parallel mapped executor:
            # every conv of every step runs as its TetrisG LayerMapping
            # prescribes, so the reported accuracy and the reported cycles
            # come from the same execution path (DESIGN.md §3)
            r, us = timed(train_cnn, cnn8_config(group=g), steps=150,
                          n_train=1024, n_test=256, executor="mapped")
            rows.append(Row(f"table2/accuracy/cnn8-G{g}", us,
                            f"test_acc={r.test_acc:.3f};executor=mapped"))
    return rows
