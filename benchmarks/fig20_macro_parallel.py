"""Fig 20: EDAP of TetrisG-SDK normalized to Tetris-SDK across macro
budgets P (64x64 macros, Alg 2 grid search).  Paper: best reductions
70 % (CNN8, P=8), 68 % (Inception, P=2), 36 % (DenseNet40, P=32)."""
from __future__ import annotations

from repro.core import ArrayConfig, grid_search, networks
from repro.core.simulator import simulate

from .common import Row, timed


def run(full: bool = False):
    arr = ArrayConfig(64, 64)
    budgets = (1, 2, 4, 8, 16, 32) if full else (2, 8)
    rows = []
    nets = ("cnn8", "inception", "densenet40") if full \
        else ("cnn8", "inception")
    for net in nets:
        layers = networks.NETWORKS[net]()
        for p in budgets:
            def both():
                g = grid_search(net, layers, arr, p_max=p,
                                algorithm="TetrisG-SDK", groups=(1, 2, 4))
                t = grid_search(net, layers, arr, p_max=p,
                                algorithm="Tetris-SDK")
                return simulate(g.best), simulate(t.best), g.best
            (sg, st, best), us = timed(both)
            rows.append(Row(
                f"fig20/{net}/P{p}", us,
                f"edap_reduction={1 - sg.edap/st.edap:.0%};"
                f"grid={best.grid.r}x{best.grid.c};"
                f"active={sg.active_macros}"))
    return rows
