"""Fig 20: EDAP of TetrisG-SDK normalized to Tetris-SDK across macro
budgets P (64x64 macros, Alg 2 grid search).  Paper: best reductions
70 % (CNN8, P=8), 68 % (Inception, P=2), 36 % (DenseNet40, P=32).

Since PR 2 this benchmark also *executes* the macro parallelism it
accounts for: the mapped-network executor (cnn/mapped_net.py) runs the
best grid's NetworkMapping with the macro grid realized as
vmap/shard_map super-steps, and we report measured wall-clock speed-up
at p_max in {1, 4, 16} next to the analytical cycle ratio.  Since the
NetworkPlan refactor the measured forward goes through a compiled plan
(`repro.exec`, DESIGN.md §8): every row reports the fused one-dispatch
wall-clock next to the per-layer loop's.  Per-layer executed step counts
are asserted equal to ``LayerMapping.cycles`` for every mapping this
file touches (at plan-compile time, and for all four bench networks in
the steps-equal-cycles row).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ArrayConfig, MacroGrid, grid_search, map_net,
                        networks)
from repro.core.simulator import simulate
from repro.cnn.mapped_net import assert_steps_match, zero_pruned_kernels
from repro.exec import (apply_layer, compile_plan, execute_layerwise)

from .common import Row, timed

EXEC_BUDGETS = (1, 4, 16)


def _mapped_walltime(net, reps: int = 3):
    """(loop_us, fused_us, n_layers) per full mapped-network forward —
    the same layerwise plan through per-layer jit dispatch vs one fused
    program (the bench nets are representative layer sets; chained
    forwards are covered by benchmarks/plan_bench.py)."""
    plan = compile_plan(net, executor_policy="mapped", chained=False)
    rng = np.random.RandomState(0)
    ks = zero_pruned_kernels(net, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc),
                    jnp.float32) for m in net.layers])
    xs = [jnp.asarray(rng.randn(1, m.layer.ic, m.layer.i_h, m.layer.i_w),
                      jnp.float32) for m in net.layers]
    n = len(net.layers)

    def loop():
        jax.block_until_ready(
            [apply_layer(plan, i, xs[i], ks[i]) for i in range(n)])

    def fused():
        jax.block_until_ready(execute_layerwise(plan, ks, xs))

    out = []
    for fn in (loop, fused):
        fn()                                    # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        out.append((time.perf_counter() - t0) / reps * 1e6)
    return out[0], out[1], n


def run(full: bool = False):
    arr = ArrayConfig(64, 64)
    budgets = (1, 2, 4, 8, 16, 32) if full else (2, 8)
    rows = []
    nets = ("cnn8", "inception", "densenet40") if full \
        else ("cnn8", "inception")
    for net in nets:
        layers = networks.NETWORKS[net]()
        for p in budgets:
            def both():
                g = grid_search(net, layers, arr, p_max=p,
                                algorithm="TetrisG-SDK", groups=(1, 2, 4))
                t = grid_search(net, layers, arr, p_max=p,
                                algorithm="Tetris-SDK")
                return simulate(g.best), simulate(t.best), g.best
            (sg, st, best), us = timed(both)
            rows.append(Row(
                f"fig20/{net}/P{p}", us,
                f"edap_reduction={1 - sg.edap/st.edap:.0%};"
                f"grid={best.grid.r}x{best.grid.c};"
                f"active={sg.active_macros}"))

    # --- measured macro parallelism: the executor, not just the count ----
    exec_nets = ("cnn8", "inception") if full else ("cnn8",)
    for name in exec_nets:
        layers = networks.NETWORKS[name]()
        base_cycles = base_us = None
        for p in EXEC_BUDGETS:
            best = grid_search(name, layers, arr, p_max=p,
                               algorithm="TetrisG-SDK",
                               groups=(1, 2, 4)).best
            assert_steps_match(best)            # executed steps == cycles
            loop_us, us, n = _mapped_walltime(best)
            if p == 1:
                base_cycles, base_us = best.total_cycles, us
            rows.append(Row(
                f"fig20/mapped-exec/{name}/P{p}", us,
                f"speedup={base_us / us:.2f};"
                f"cycle_ratio={base_cycles / best.total_cycles:.2f};"
                f"grid={best.grid.r}x{best.grid.c};"
                f"cycles={best.total_cycles};"
                f"loop_us={loop_us:.1f};"
                f"dispatches_loop={n};dispatches_plan=1"))

    # --- executed-schedule contract on all bench networks ----------------
    def check_all():
        n_layers = 0
        for name, fn in networks.NETWORKS.items():
            m = map_net(name, fn(), arr, "TetrisG-SDK", MacroGrid(4, 4),
                        groups=(1, 2))
            assert_steps_match(m)
            n_layers += len(m.layers)
        return n_layers
    n, us = timed(check_all)
    rows.append(Row("fig20/steps-equal-cycles", us,
                    f"networks={len(networks.NETWORKS)};layers={n};ok=1"))
    return rows
