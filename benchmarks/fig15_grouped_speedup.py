"""Fig 15: TetrisG-SDK speed-up with grouped convolutions per network.
Paper: ~1.5x CNN8, ~1.3x Inception, ~2x DenseNet40 vs VW-SDK @512x512."""
from __future__ import annotations

from repro.core import ArrayConfig, map_net, networks

from .common import Row, timed


def run(full: bool = False):
    arr = ArrayConfig(512, 512)
    rows = []
    paper = {"cnn8": 1.5, "inception": 1.3, "densenet40": 2.0}
    for net in ("cnn8", "inception", "densenet40"):
        layers = networks.NETWORKS[net]()
        vw = map_net(net, layers, arr, "VW-SDK").total_cycles
        kw = {"groups": (1, 2)} if net == "inception" else {}
        m, us = timed(map_net, net, layers, arr, "TetrisG-SDK", **kw)
        rows.append(Row(
            f"fig15/{net}", us,
            f"x_vw={vw/m.total_cycles:.2f};paper~{paper[net]}"))
    return rows
