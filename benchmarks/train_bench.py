"""Training memory/throughput frontier: remat off vs ``"auto"``.

The ISSUE 10 acceptance quantity: on densenet40 — the bench net whose
concat-heavy forward carries the deepest live-activation stack — train
through the compiled plan (`repro.cnn.train.train_plan`) with
rematerialization off and with ``remat="auto"``, and report both sides
of the trade:

* ``mem_mb`` — the memory pass's peak-live estimate of the plan that
  actually ran (exec/memory.py, `NetworkPlan.peak_bytes`);
* ``steps_s`` — measured optimizer steps/s, median over the post-warmup
  steps (the first step holds the jit compile and is dropped).

The auto row must show peak-estimate ``reduction >= 2`` against its own
``unremat_mb`` and ``slowdown < 2`` against the off row: recompute buys
the memory back for less than one extra forward per step.

    python -m benchmarks.train_bench --smoke          # the CI run
    python -m benchmarks.train_bench --full
    python -m benchmarks.train_bench --smoke --ledger BENCH_train.json \
        --pr "PR 10"

Prints the harness CSV (``name,usec,extras``) to stdout — CI tees it
into ``bench-out/train_bench.csv``.  Exposes ``run(full)`` returning
`benchmarks.common.Row`s like every bench module, though (like
replica_bench) it is not in run.py's default MODULES: two full
densenet40 train compiles are minutes, not the seconds budget
``python -m benchmarks.run`` holds to.
"""
from __future__ import annotations

import argparse
import os
import statistics

from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.train import train_plan
from repro.exec.remat import ENV_BUDGET

from .common import Row

NET = "densenet40"
ARRAY = ArrayConfig(64, 64)
GRID = MacroGrid(2, 2)


def _config(full: bool) -> dict:
    # batch >= 4 so activations (not the shifted-weight constants)
    # dominate the estimate — below that the 3-segment split cannot
    # reach the 2x reduction the frontier exists to show
    return (dict(steps=6, batch=8, accum=2, lr=1e-3) if full
            else dict(steps=4, batch=4, accum=1, lr=1e-3))


def _train(net, remat, cfg: dict):
    times: list = []
    losses: list = []
    r = train_plan(net, steps=cfg["steps"], batch=cfg["batch"],
                   accum=cfg["accum"], lr=cfg["lr"], remat=remat,
                   losses=losses, step_times=times)
    steady = times[1:] or times     # times[0] holds the jit compile
    return r, statistics.median(steady), losses


def run(full: bool = False):
    """Harness-shaped entry: one row per remat mode, auto carrying the
    frontier numbers (reduction vs its own unremat estimate, slowdown
    vs the off row)."""
    cfg = _config(full)
    net = map_net(NET, networks.NETWORKS[NET](), ARRAY, "TetrisG-SDK",
                  GRID)
    # the trainer's forced-budget refusal (REPRO_TRAIN_MEM_BUDGET) would
    # abort the off leg — the bench measures the frontier itself, so it
    # runs budget-free and restores the caller's env after
    forced = os.environ.pop(ENV_BUDGET, None)
    try:
        rows = []
        base_s = None
        for tag, remat in (("off", None), ("auto", "auto")):
            r, step_s, losses = _train(net, remat, cfg)
            extras = (f"mem_mb={r.peak_mb:.1f};"
                      f"unremat_mb={r.unremat_peak_mb:.1f};"
                      f"segments={r.segments};"
                      f"steps_s={1.0 / step_s:.3f};"
                      f"steps={r.steps};batch={r.batch};"
                      f"accum={r.accum};donated={int(r.donated)};"
                      f"loss={losses[0]:.3f}->{losses[-1]:.3f}")
            if tag == "off":
                base_s = step_s
            else:
                extras += (f";reduction="
                           f"{r.unremat_peak_mb / r.peak_mb:.2f}"
                           f";slowdown={step_s / base_s:.2f}")
            rows.append(Row(f"train/{NET}/remat_{tag}", step_s * 1e6,
                            extras))
        return rows
    finally:
        if forced is not None:
            os.environ[ENV_BUDGET] = forced


def ledger_entry(rows, *, pr: str, note: str) -> dict:
    """BENCH_train.json entry: the frontier as plain numbers — peak
    estimates, measured steps/s, and the reduction/slowdown ratios the
    acceptance bar reads."""
    def kv(row):
        return dict(p.split("=", 1) for p in row.derived.split(";"))
    off = next(r for r in rows if r.name.endswith("/remat_off"))
    auto = next(r for r in rows if r.name.endswith("/remat_auto"))
    return {
        "pr": pr,
        "note": note,
        "net": NET,
        "batch": int(kv(off)["batch"]),
        "accum": int(kv(off)["accum"]),
        "steps": int(kv(off)["steps"]),
        "unremat_peak_mb": float(kv(auto)["unremat_mb"]),
        "off_peak_mb": float(kv(off)["mem_mb"]),
        "auto_peak_mb": float(kv(auto)["mem_mb"]),
        "auto_segments": int(kv(auto)["segments"]),
        "off_steps_per_s": float(kv(off)["steps_s"]),
        "auto_steps_per_s": float(kv(auto)["steps_s"]),
        "peak_reduction": float(kv(auto)["reduction"]),
        "slowdown": float(kv(auto)["slowdown"]),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="batch 4, 4 steps per mode (the CI run)")
    mode.add_argument("--full", action="store_true",
                      help="batch 8, accum 2, 6 steps per mode")
    ap.add_argument("--csv", default=None,
                    help="also write the CSV to this path")
    ap.add_argument("--ledger", default=None,
                    help="append a BENCH_train.json ledger entry here")
    ap.add_argument("--pr", default="",
                    help="ledger entry tag for --ledger")
    args = ap.parse_args(argv)

    rows = run(full=args.full)
    text = "\n".join(r.csv() for r in rows) + "\n"
    print(text, end="")
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(text)
    if args.ledger:
        from repro.tune.report import append_trajectory
        append_trajectory(args.ledger, ledger_entry(
            rows, pr=args.pr, note="smoke" if args.smoke else "full"))


if __name__ == "__main__":
    main()
