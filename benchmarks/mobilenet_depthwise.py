"""SIV-C3 MobileNet finding: TetrisG == VWC on depthwise/pointwise mixes
(no cross-channel reuse to exploit); large win vs img2col."""
from __future__ import annotations

from repro.core import ArrayConfig, map_net, networks

from .common import Row, timed


def run(full: bool = False):
    arr = ArrayConfig(512, 512)
    layers = networks.mobilenet()
    cc = {}
    us_tot = 0.0
    for alg in ("img2col", "VWC-SDK", "Tetris-SDK", "TetrisG-SDK"):
        m, us = timed(map_net, "mobilenet", layers, arr, alg)
        cc[alg] = m.total_cycles
        us_tot += us
    return [Row("mobilenet/depthwise", us_tot,
                f"tetrisg={cc['TetrisG-SDK']};"
                f"x_img2col={cc['img2col']/cc['TetrisG-SDK']:.1f};"
                f"eq_vwc={cc['TetrisG-SDK'] == cc['VWC-SDK']}")]
