"""Fixed-batch vs dynamic-batching serving on the SAME ragged stream.

The ISSUE 5 acceptance row: arrival-driven coalescing must achieve at
least the fixed-batch driver's *effective* images/s on cnn8.  Both
drivers face an identical backlogged sequence of ragged requests
(1..BATCH rows each):

* ``fixed``   — the pre-dynamic serve_cnn behavior: every ragged request
  is padded-and-masked to the one fixed plan batch and served ALONE, so
  the plan executes ``BATCH`` rows to deliver ``rows`` useful ones;
* ``dynamic`` — `serve_cnn.serve_dynamic`: the max-delay coalescer
  drains the backlog into full ladder tiers, so padding collapses and
  the effective rate approaches the padded rate.

Rounds are interleaved (plan_bench-style) so CI machine noise hits both
paths equally; medians are reported.  The same compiled plan backs the
fixed path and the dynamic top tier — the comparison isolates the
batching policy, not the executor.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.exec import compile_plan, execute_plan
from repro.launch import serve_cnn

from .common import Row, interleaved_rounds, median

BATCH = 4                          # fixed plan batch == top ladder tier
SIZES = (1, 3, 2, 1, 4, 2, 3, 1)   # ragged request rows (backlogged)
ROUNDS = 5


def run(full: bool = False):
    layers = networks.cnn8() if full else networks.cnn8()[:4]
    net = map_net("cnn8", layers, ArrayConfig(64, 64), "TetrisG-SDK",
                  MacroGrid(2, 2), groups=(1, 2))
    plan = compile_plan(net, executor_policy="mapped", batch=BATCH)
    rng, ks = serve_cnn._serving_kernels(net, 0)
    first = net.layers[0].layer
    shape = (first.ic, first.i_h, first.i_w)
    pool = rng.randn(BATCH, *shape).astype(np.float32)
    reqs = tuple((0.0, r) for r in SIZES)

    def fixed_round():
        t0 = time.perf_counter()
        for _, rows in reqs:        # one padded-and-masked plan forward
            x = np.zeros((BATCH,) + shape, np.float32)   # per request
            x[:rows] = pool[:rows]
            y = execute_plan(plan, ks, jax.device_put(x))
            jax.block_until_ready(y[:rows])
        dt = time.perf_counter() - t0
        return sum(SIZES) / dt, len(reqs) * BATCH / dt

    def dynamic_round():
        s = serve_cnn.serve_dynamic(net, reqs, max_batch=BATCH,
                                    max_delay_ms=1.0, warmup=1)
        return s.images_per_s, s.padded_images_per_s

    # interleaved rounds (shared primitive): noise hits both equally;
    # the measured quantity is each round's (effective, padded) rates,
    # so medians are taken per component over the returned values
    outs = interleaved_rounds([fixed_round, dynamic_round], ROUNDS,
                              warmup=1)
    (f_eff, f_pad), (d_eff, d_pad) = (
        (median([e for e, _ in o]), median([p for _, p in o]))
        for o in outs)
    return [
        Row("serve_dyn/cnn8/fixed-ragged", 1e6 / f_eff,
            f"images_per_s={f_eff:.1f};padded_images_per_s={f_pad:.1f};"
            f"batch={BATCH};requests={len(SIZES)}"),
        Row("serve_dyn/cnn8/dynamic", 1e6 / d_eff,
            f"images_per_s={d_eff:.1f};padded_images_per_s={d_pad:.1f};"
            f"speedup={d_eff / f_eff:.2f};max_batch={BATCH};"
            f"max_delay_ms=1.0"),
    ]
