"""Compiled-plan execution vs the per-layer dispatch loop.

For each bench network: lower the mapping once (`repro.exec.compile_plan`)
and measure the SAME plan through both dispatch shapes —

* ``loop``  — one jit launch per layer, eager glue between
  (`execute_looped`, the pre-plan behavior);
* ``fused`` — the whole forward as one jitted program with bounded
  one-layer-lookahead pipelining (`execute_plan`).

The fused rows must show the per-forward host dispatch count dropping to
1 and wall-clock no worse than the loop (DESIGN.md §8).  CNN8 and
DenseNet40 execute as real chains; Inception's spec list is a
representative layer *set*, so it runs layerwise (`execute_layerwise`
vs an `apply_layer` loop — same dispatch comparison).  The default run
uses a DenseNet40 prefix to keep CI compile time sane; ``--full``
compiles the whole 38-layer chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import (apply_layer, compile_plan, execute_layerwise,
                        execute_looped, execute_plan)

from .common import Row, interleaved_medians

BATCH = 4
GRID = MacroGrid(2, 2)


def _kernels(net, rng):
    return zero_pruned_kernels(net, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net.layers])


def _time_pair(fn_a, fn_b, rounds: int = 5):
    """Median us of two warm paths via the shared interleaved-rounds
    primitive (`repro.tune.measure`), so machine noise (2-core CI
    boxes) hits both equally."""
    a, b = interleaved_medians([fn_a, fn_b], rounds=rounds, warmup=1)
    return a * 1e6, b * 1e6


def _rows(label: str, plan, us_loop: float, us_fused: float):
    n = len(plan.layers)
    # memory-pass estimate (exec/memory.py) of training through this
    # plan unremat'd, scaled to the bench batch — the frontier's x-axis,
    # inspectable without running the trainer (train_bench measures it)
    mem_mb = plan.unremat_peak_bytes * BATCH / 1e6
    return [
        Row(f"plan/{label}/loop", us_loop,
            f"dispatches={n};batch={BATCH};mem_mb={mem_mb:.1f}"),
        Row(f"plan/{label}/fused", us_fused,
            f"dispatches={plan.host_dispatches};"
            f"speedup={us_loop / us_fused:.2f};"
            f"steps={plan.total_steps};batch={BATCH};"
            f"mem_mb={mem_mb:.1f}"),
    ]


def run(full: bool = False):
    arr = ArrayConfig(64, 64)
    rng = np.random.RandomState(0)
    rows = []

    chained = [("cnn8", networks.cnn8()),
               ("densenet40" if full else "densenet40[:12]",
                networks.densenet40() if full else
                networks.densenet40()[:12])]
    for label, layers in chained:
        net = map_net(label, layers, arr, "TetrisG-SDK", GRID,
                      groups=(1, 2))
        plan = compile_plan(net, executor_policy="mapped")
        ks = _kernels(net, rng)
        first = net.layers[0].layer
        x = jnp.asarray(rng.randn(BATCH, first.ic, first.i_h, first.i_w),
                        jnp.float32)
        us_loop, us_fused = _time_pair(
            lambda: jax.block_until_ready(execute_looped(plan, ks, x)),
            lambda: jax.block_until_ready(execute_plan(plan, ks, x)))
        rows += _rows(label, plan, us_loop, us_fused)

    # inception: representative layer set, not a chain -> layerwise plan
    net = map_net("inception", networks.inception(), arr, "TetrisG-SDK",
                  GRID, groups=(1, 2))
    plan = compile_plan(net, executor_policy="mapped", chained=False)
    ks = _kernels(net, rng)
    xs = [jnp.asarray(rng.randn(BATCH, m.layer.ic, m.layer.i_h,
                                m.layer.i_w), jnp.float32)
          for m in net.layers]
    n = len(net.layers)
    us_loop, us_fused = _time_pair(
        lambda: jax.block_until_ready(
            [apply_layer(plan, i, xs[i], ks[i]) for i in range(n)]),
        lambda: jax.block_until_ready(execute_layerwise(plan, ks, xs)))
    rows += _rows("inception", plan, us_loop, us_fused)
    return rows
