"""Multi-replica scaling bench: N worker processes vs one serve loop.

The ISSUE 9 acceptance quantity: on the SAME backlogged Poisson trace,
``serve_replicas`` with N=2 workers must reach aggregate effective
images/s >= the single-process ``serve_dynamic`` path (PR 5) — the
paper's inter-macro replication argument applied at process level.
Also measures what the shared disk cache buys a cold worker: the same
fleet is brought up twice against one cache directory, cold (empty
cache — every worker builds its search tables) then warm (pure disk
hits), and per-worker start-up seconds are reported for both.

    python -m benchmarks.replica_bench --smoke            # CI: 2 workers
    python -m benchmarks.replica_bench --full --replicas 4
    python -m benchmarks.replica_bench --smoke --ledger BENCH_serve.json \
        --pr "PR 9"

Prints the harness CSV (``name,usec,extras``) to stdout — CI tees it
into ``bench-out/replica_bench.csv``.  Exposes ``run(full)`` returning
`benchmarks.common.Row`s like every bench module, though it is not in
run.py's default MODULES: spawning worker fleets is minutes, not the
seconds budget ``python -m benchmarks.run`` holds to.
"""
from __future__ import annotations

import argparse
import statistics
import tempfile

from repro.core import memo
from repro.launch.replica import WorkerConfig, serve_replicas
from repro.launch.serve_cnn import poisson_arrivals, serve_dynamic

from .common import Row

NET = "cnn8"
LAYERS = 4            # cnn8 prefix: keeps per-worker CPU compiles sane
ARRAY = (64, 64)
GRID = (2, 2)
GROUPS = (1, 2)
MAX_BATCH = 4
MAX_DELAY_MS = 2.0


def bench_config(cache_dir: str) -> WorkerConfig:
    """The worker profile both sides of the comparison serve."""
    return WorkerConfig(net=NET, array=ARRAY, grid=GRID, layers=LAYERS,
                        groups=GROUPS, max_batch=MAX_BATCH,
                        max_delay_ms=MAX_DELAY_MS, warmup=1,
                        cache_dir=cache_dir)


def bench_trace(full: bool):
    """One backlogged Poisson trace (rate 0) shared by every leg."""
    n = 96 if full else 32
    return poisson_arrivals(n, 0.0, MAX_BATCH, seed=0)


def single_process_baseline(trace, cache_dir: str):
    """The PR 5 path: one process, one mesh, one plan ladder."""
    from repro.launch.replica import _build_mapping
    memo.set_disk_cache(cache_dir)
    mapping = _build_mapping(bench_config(cache_dir))
    return serve_dynamic(mapping, trace, max_batch=MAX_BATCH,
                         max_delay_ms=MAX_DELAY_MS, warmup=1)


def replica_run(trace, cache_dir: str, n_replicas: int):
    return serve_replicas(trace, bench_config(cache_dir), n_replicas)


def _startup(rs) -> float:
    return statistics.mean(v.startup_s for v in rs.workers.values())


def run(full: bool = False, n_replicas: int = 2):
    """Harness-shaped entry: cold fleet, warm fleet, single baseline,
    and the scaling row comparing warm aggregate rate to the single
    process on the same trace."""
    trace = bench_trace(full)
    n_req = len(trace)
    rows = []
    with tempfile.TemporaryDirectory(prefix="replica-bench-") as cache:
        cold = replica_run(trace, cache, n_replicas)
        warm = replica_run(trace, cache, n_replicas)
        single = single_process_baseline(trace, cache)
        scaling = warm.images_per_s / max(single.images_per_s, 1e-12)
        rows.append(Row(
            f"replica/{NET}/single",
            single.wall_s / max(single.request_images, 1) * 1e6,
            f"images_per_s={single.images_per_s:.1f};"
            f"padded_images_per_s={single.padded_images_per_s:.1f};"
            f"requests={n_req};p50_ms={single.delay_ms(50):.2f};"
            f"p95_ms={single.delay_ms(95):.2f}"))
        rows.append(Row(
            f"replica/{NET}/n{n_replicas}-cold",
            cold.wall_s / max(cold.request_images, 1) * 1e6,
            f"images_per_s={cold.images_per_s:.1f};"
            f"startup_s={_startup(cold):.2f};"
            f"table_builds="
            f"{sum(v.table_misses for v in cold.workers.values())};"
            f"disk_hits={sum(v.disk_hits for v in cold.workers.values())}"))
        rows.append(Row(
            f"replica/{NET}/n{n_replicas}",
            warm.wall_s / max(warm.request_images, 1) * 1e6,
            f"images_per_s={warm.images_per_s:.1f};"
            f"padded_images_per_s={warm.padded_images_per_s:.1f};"
            f"scaling={scaling:.2f};requests={n_req};"
            f"startup_s={_startup(warm):.2f};"
            f"table_builds="
            f"{sum(v.table_misses for v in warm.workers.values())};"
            f"disk_hits={sum(v.disk_hits for v in warm.workers.values())};"
            f"p50_ms={warm.delay_ms(50):.2f};"
            f"p95_ms={warm.delay_ms(95):.2f};"
            f"requeued={warm.requeued};"
            f"duplicate_serves={warm.duplicate_serves}"))
    return rows


def ledger_entry(rows, *, pr: str, note: str) -> dict:
    """BENCH_serve.json entry: the single- vs multi-replica rates (and
    the cold/warm start-up the disk cache buys) as plain numbers."""
    def kv(row):
        return dict(p.split("=", 1) for p in row.derived.split(";"))
    single = next(r for r in rows if r.name.endswith("/single"))
    cold = next(r for r in rows if r.name.endswith("-cold"))
    multi = next(r for r in rows if not r.name.endswith("/single")
                 and not r.name.endswith("-cold"))
    return {
        "pr": pr,
        "note": note,
        "net": NET,
        "replicas": int(multi.name.rsplit("/n", 1)[1]),
        "requests": int(kv(multi)["requests"]),
        "single_images_per_s": float(kv(single)["images_per_s"]),
        "multi_images_per_s": float(kv(multi)["images_per_s"]),
        "scaling": float(kv(multi)["scaling"]),
        "cold_startup_s": float(kv(cold)["startup_s"]),
        "warm_startup_s": float(kv(multi)["startup_s"]),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="32-request trace (the CI run)")
    mode.add_argument("--full", action="store_true",
                      help="96-request trace")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--csv", default=None,
                    help="also write the CSV to this path")
    ap.add_argument("--ledger", default=None,
                    help="append a BENCH_serve.json ledger entry here")
    ap.add_argument("--pr", default="",
                    help="ledger entry tag for --ledger")
    args = ap.parse_args(argv)

    rows = run(full=args.full, n_replicas=args.replicas)
    text = "\n".join(r.csv() for r in rows) + "\n"
    print(text, end="")
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(text)
    if args.ledger:
        from repro.tune.report import append_trajectory
        append_trajectory(args.ledger, ledger_entry(
            rows, pr=args.pr, note="smoke" if args.smoke else "full"))


if __name__ == "__main__":
    main()
