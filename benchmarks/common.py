"""Shared benchmark plumbing: timing + CSV row format.

Every benchmark module exposes ``run(full: bool) -> list[Row]``;
run.py prints ``name,us_per_call,derived`` per the harness contract.

The interleaved-rounds/median measurement shape every comparative
benchmark here uses lives in `repro.tune.measure` (it is also the
autotuner's measurement primitive) — re-exported below so benchmark
modules keep importing it from `.common`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

from repro.tune.measure import (interleaved_medians, interleaved_rounds,
                                median)

__all__ = ["Row", "timed", "median", "interleaved_rounds",
           "interleaved_medians"]


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
