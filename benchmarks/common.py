"""Shared benchmark plumbing: timing + CSV row format.

Every benchmark module exposes ``run(full: bool) -> list[Row]``;
run.py prints ``name,us_per_call,derived`` per the harness contract.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
