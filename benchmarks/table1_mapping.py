"""Table I: per-layer windows + total cycles for CNN8 / Inception on a
512x512 array, all algorithms.  Paper anchors: VW-SDK=128 / Tetris=116 /
TetrisG=84 on CNN8 (exact); Inception deltas discussed in EXPERIMENTS.md."""
from __future__ import annotations

from repro.core import ALGORITHMS, ArrayConfig, map_net, networks

from .common import Row, timed

PAPER = {("cnn8", "VW-SDK"): 128, ("cnn8", "Tetris-SDK"): 116,
         ("cnn8", "TetrisG-SDK"): 84, ("inception", "VW-SDK"): 627,
         ("inception", "VWC-SDK"): 506, ("inception", "Tetris-SDK"): 557,
         ("inception", "TetrisG-SDK"): 470}


def run(full: bool = False):
    arr = ArrayConfig(512, 512)
    rows = []
    for net in ("cnn8", "inception"):
        layers = networks.NETWORKS[net]()
        for alg in ALGORITHMS:
            kw = {}
            if alg == "TetrisG-SDK" and net == "inception":
                kw["groups"] = (1, 2)     # accuracy-constrained (SIV-C1)
            m, us = timed(map_net, net, layers, arr, alg, **kw)
            paper = PAPER.get((net, alg))
            tag = f"cycles={m.total_cycles}"
            if paper:
                tag += f";paper={paper}"
            rows.append(Row(f"table1/{net}/{alg}", us, tag))
    return rows
