"""Serve a MoE model: batched prefill + greedy decode with the SWA ring
cache (mixtral-family) — exercises the EP/grouped expert path the
paper's grouped convolutions map onto.

    PYTHONPATH=src python examples/serve_moe.py
"""
from repro.launch import serve


if __name__ == "__main__":
    serve.main(["--arch", "mixtral_8x7b", "--smoke", "--batch", "2",
                "--prompt-len", "24", "--gen", "8"])
