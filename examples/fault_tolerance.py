"""Fault-tolerance demo: train, kill a worker mid-run, re-mesh on the
survivors, resume from the checkpoint — final state identical to an
uninterrupted run.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import ShardedDataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.steps import TrainConfig, init_train_state, \
    make_train_step
from repro.runtime import (HeartbeatMonitor, TrainSupervisor,
                           derive_elastic_mesh)
from repro.runtime.recovery import WorkerLost


def main():
    cfg = get_config("stablelm_1_6b", smoke=True)
    tc = TrainConfig(microbatches=1, peak_lr=1e-3, warmup_steps=2,
                     total_steps=40)
    raw_step = jax.jit(make_train_step(cfg, tc))

    def step_fn(state, tokens):
        return raw_step(state, {"tokens": jnp.asarray(tokens)})

    ts = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    tmp = Path(tempfile.mkdtemp())
    store = CheckpointStore(tmp, keep=2)
    sup = TrainSupervisor(store=store, pipeline=ShardedDataPipeline(ts),
                          monitor=HeartbeatMonitor(1), save_every=10)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    try:
        sup.run(state, step_fn, steps=40, inject_failure_at=25)
    except WorkerLost as e:
        print(f"!! {e} — deriving elastic mesh for survivors")
        plan = derive_elastic_mesh(496, model_parallel=16)  # lost a host
        print(f"   re-mesh: {plan.shape} ({plan.dropped} idle devices)")

    sup2 = TrainSupervisor(store=store, pipeline=ShardedDataPipeline(ts),
                           monitor=HeartbeatMonitor(1), save_every=10)
    like = jax.eval_shape(partial(init_train_state, cfg),
                          jax.random.PRNGKey(0))
    state, last = sup2.resume(like, step_fn, steps=40)
    print(f"resumed and finished at step {last}; events: {sup2.events}")


if __name__ == "__main__":
    main()
