"""End-to-end driver: train a ~100M-param LM (mamba2-130m full config at
reduced depth, or any --arch) for a few hundred steps on the synthetic
token stream, with checkpointing + restart.

CPU note: the default invocation trains the smoke config quickly; pass
--full-arch to train the real 130M mamba2 (slow on 1 CPU core — this is
the 'production driver' shape, sized for a real device).

    PYTHONPATH=src python examples/train_lm.py            # quick
    PYTHONPATH=src python examples/train_lm.py --steps 300 --batch 16
"""
import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-arch", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--save-every", "100"]
    if not args.full_arch:
        argv.append("--smoke")
    train_driver.main(argv)


if __name__ == "__main__":
    main()
