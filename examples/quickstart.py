"""Quickstart: the paper's technique end to end.

1. map a conv layer with every algorithm and compare cycles;
2. execute the TetrisG mapping in JAX — the placement-batched reference
   executor (cim_conv2d_jit) AND the macro-parallel executor
   (mapped_conv2d, executed grid steps == the mapping's cycle count) —
   and check both against lax.conv;
3. run the macro-grid search (Alg 2), execute the whole mapped network,
   feed it to the CIM simulator, and print the summary table;
4. compile the network into ONE execution plan (repro.exec) and run the
   same forward as a single fused program — bit-identical, one host
   dispatch instead of one per layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (ALGORITHMS, ArrayConfig, ConvLayerSpec, grid_search,
                        map_layer, networks)
from repro.core.simulator import simulate
from repro.cnn import (cim_conv2d_jit, executed_steps, mapped_conv2d,
                       mapped_net_apply, reference_conv2d,
                       zero_pruned_kernels)

# --- 1. mapping: CNN8 layer 3 (the paper's Fig 12 example) -------------
layer = ConvLayerSpec("CNN8-3", 18, 18, 3, 3, 32, 32)
arr = ArrayConfig(512, 512)
print(f"{layer.name} on a {arr.ar}x{arr.ac} CIM array:")
for alg in ALGORITHMS:
    m = map_layer(layer, arr, alg)
    tiles = ", ".join(f"{t.window}x{t.ic_t}" for t in m.tiles)
    print(f"  {alg:12s} cycles={m.cycles:>3d} G={m.group} tiles=[{tiles}]")

# --- 2. the mapping actually computes the convolution ------------------
m = map_layer(layer, arr, "TetrisG-SDK")
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(1, layer.ic, 18, 18), jnp.float32)
k = jnp.asarray(rng.randn(3, 3, layer.ic // m.group, layer.oc),
                jnp.float32)
ref = reference_conv2d(layer, x, k, groups=m.group)
err_cim = float(jnp.max(jnp.abs(cim_conv2d_jit(m, x, k) - ref)))
err_map = float(jnp.max(jnp.abs(mapped_conv2d(m, x, k) - ref)))
print(f"\nreference executor  == lax.conv (max err {err_cim:.1e})")
print(f"macro-parallel path == lax.conv (max err {err_map:.1e}), "
      f"executed steps {executed_steps(m)} == cycles {m.cycles}")

# --- 3. Alg 2 grid search -> execute the mapped network -> simulate ----
res = grid_search("cnn8", networks.cnn8(), ArrayConfig(64, 64), p_max=8,
                  algorithm="TetrisG-SDK", groups=(1, 2, 4))
net = res.best
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(l.layer.k_h, l.layer.k_w,
                          l.layer.ic // l.group, l.layer.oc),
                jnp.float32) * 0.1 for l in net.layers])
x0 = jnp.asarray(rng.randn(1, 24, 18, 18), jnp.float32)
logits = mapped_net_apply(net, ks, x0)   # asserts steps == cycles per layer
sim = simulate(net)
print(f"\nAlg 2 over 8x 64x64 macros -> best grid "
      f"{net.grid.r}x{net.grid.c}, {net.total_cycles} cycles, "
      f"EDAP {sim.edap:.2e} J*s*m^2, {sim.active_macros} active macros; "
      f"mapped forward out {tuple(logits.shape)}")

# --- 4. compile the whole network into one execution plan --------------
from repro.exec import compile_plan, execute_plan

plan = compile_plan(net, executor_policy="mapped")   # steps==cycles here
fused = execute_plan(plan, ks, x0)                   # ONE jitted program
assert bool(jnp.all(fused == logits)), "plan forward must be bit-identical"
print("\n" + plan.describe())
print("\n" + net.summary())
