"""Quickstart: the paper's technique end to end in ~40 lines.

1. map a conv layer with every algorithm and compare cycles;
2. execute the TetrisG mapping in JAX and check it against lax.conv;
3. run the macro-grid search (Alg 2) and the CIM simulator (EDAP).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (ALGORITHMS, ArrayConfig, ConvLayerSpec, grid_search,
                        map_layer, map_net, networks)
from repro.core.simulator import simulate
from repro.cnn import cim_conv2d, reference_conv2d

# --- 1. mapping: CNN8 layer 3 (the paper's Fig 12 example) -------------
layer = ConvLayerSpec("CNN8-3", 18, 18, 3, 3, 32, 32)
arr = ArrayConfig(512, 512)
print(f"{layer.name} on a {arr.ar}x{arr.ac} CIM array:")
for alg in ALGORITHMS:
    m = map_layer(layer, arr, alg)
    tiles = ", ".join(f"{t.window}x{t.ic_t}" for t in m.tiles)
    print(f"  {alg:12s} cycles={m.cycles:>3d} G={m.group} tiles=[{tiles}]")

# --- 2. the mapping actually computes the convolution ------------------
m = map_layer(layer, arr, "TetrisG-SDK")
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(1, layer.ic, 18, 18), jnp.float32)
k = jnp.asarray(rng.randn(3, 3, layer.ic // m.group, layer.oc),
                jnp.float32)
err = float(jnp.max(jnp.abs(
    cim_conv2d(m, x, k) - reference_conv2d(layer, x, k, groups=m.group))))
print(f"\nmapped conv == lax.conv (max err {err:.1e})")

# --- 3. macro-grid search + system metrics ------------------------------
res = grid_search("cnn8", networks.cnn8(), ArrayConfig(64, 64), p_max=8,
                  algorithm="TetrisG-SDK")
sim = simulate(res.best)
print(f"\nAlg 2 over 8x 64x64 macros -> best grid "
      f"{res.best.grid.r}x{res.best.grid.c}, "
      f"{res.best.total_cycles} cycles, "
      f"EDAP {sim.edap:.2e} J*s*m^2, {sim.active_macros} active macros")
