"""Launch-layer units: sharding rules, HLO analyzer, shapes, roofline."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch import roofline as rl
from repro.launch import sharding as sh
from repro.launch.shapes import SHAPES, cell_supported
from repro.models import transformer as T


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_shardings_cover_tree():
    mesh = _mesh11()
    for arch in ("mixtral_8x7b", "mamba2_130m", "recurrentgemma_9b",
                 "deepseek_v2_lite_16b", "whisper_base"):
        cfg = get_config(arch, smoke=True)
        shapes = jax.eval_shape(lambda: T.init_params(
            cfg, jax.random.PRNGKey(0)))
        shs = sh.param_shardings(cfg, shapes, mesh)
        n = len(jax.tree.leaves(shs))
        assert n == len(jax.tree.leaves(shapes))


def test_param_spec_head_dim_fallback():
    """qwen: 40 heads don't divide 16 -> head_dim axis gets 'model'."""
    # synthesize without devices: use spec function directly
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    cfg = get_config("qwen1_5_32b")
    spec = sh.param_spec(("stages", "[0]", "[0]", "attn", "wq"),
                         (64, 5120, 40, 128), FakeMesh(), cfg)
    assert spec == P(None, ("data",), None, "model")


def test_cache_spec_seq_over_model():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    cfg = get_config("mixtral_8x7b")
    spec = sh.cache_spec(("stages", "k"), (32, 128, 4096, 8, 128),
                         FakeMesh(), cfg)
    assert spec == P(None, ("data",), "model", None, None)


def test_long500k_skips():
    for arch, expect in [("deepseek_67b", False), ("mamba2_130m", True),
                         ("mixtral_8x7b", True),
                         ("recurrentgemma_9b", True),
                         ("qwen1_5_32b", False)]:
        ok, reason = cell_supported(get_config(arch), SHAPES["long_500k"])
        assert ok == expect, arch


def test_hlo_analyzer_loop_amplification():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    t = H.analyze_hlo(comp.as_text())
    assert t.flops == pytest.approx(7 * 2 * 32 * 64 * 64, rel=0.01)


def test_hlo_analyzer_layer_count_amplification():
    """A scanned 3-layer smoke model must show ~3x the single-layer dot
    flops — the exact failure cost_analysis() has."""
    cfg = get_config("stablelm_1_6b", smoke=True)
    params = jax.eval_shape(lambda: T.init_params(cfg,
                                                  jax.random.PRNGKey(0)))
    def fwd(p, tokens):
        return T.forward(p, cfg, tokens=tokens, mode="train")
    comp = jax.jit(fwd).lower(
        params, jax.ShapeDtypeStruct((2, 32), jnp.int32)).compile()
    t = H.analyze_hlo(comp.as_text())
    # analytic forward flops: ~2 * n_block_params * tokens (+ attn, logits)
    n = T.count_params(cfg)
    tokens = 2 * 32
    assert t.flops > 1.5 * n * tokens   # >~2*N*D proves layers amplified


def test_roofline_terms():
    t = rl.RooflineTerms(flops_per_chip=197e12, bytes_per_chip=819e9,
                         coll_bytes_per_chip=0.0, chips=1,
                         model_flops_total=197e12)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.roofline_fraction == pytest.approx(1.0)


def test_collective_shape_bytes():
    assert H.shape_info("bf16[128,256]{1,0}")[1] == 128 * 256 * 2
    assert H.shape_info("(f32[8], s32[4])")[1] == 32 + 16
