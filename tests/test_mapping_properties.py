"""Hypothesis property tests: invariants of the mapping framework over
random layers/arrays."""

import pytest

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(optional test dependency, see pyproject.toml)")
from hypothesis import assume, given, settings, strategies as st

from repro.core import ArrayConfig, ConvLayerSpec, MacroGrid, map_layer
from repro.core import cycles as cyc
from repro.cnn.cim_conv import window_placements


layer_st = st.builds(
    lambda i, k, ic, oc: ConvLayerSpec("h", i, i, k, k, ic, oc),
    i=st.integers(5, 24),
    k=st.sampled_from([1, 3, 5]),
    ic=st.integers(1, 48),
    oc=st.integers(1, 64),
).filter(lambda sp: sp.i_w >= sp.k_w)

array_st = st.builds(ArrayConfig,
                     ar=st.sampled_from([64, 128, 256, 512]),
                     ac=st.sampled_from([64, 128, 256, 512]))


@settings(max_examples=60, deadline=None)
@given(layer=layer_st, array=array_st)
def test_tetris_never_worse_than_vw(layer, array):
    assume(layer.k_w * layer.k_h <= array.ar)
    vw = map_layer(layer, array, "VW-SDK").cycles
    tt = map_layer(layer, array, "Tetris-SDK").cycles
    assert tt <= vw


@settings(max_examples=60, deadline=None)
@given(layer=layer_st, array=array_st)
def test_tetrisg_never_worse_than_tetris(layer, array):
    assume(layer.k_w * layer.k_h <= array.ar)
    tt = map_layer(layer, array, "Tetris-SDK").cycles
    tg = map_layer(layer, array, "TetrisG-SDK").cycles
    assert tg <= tt


@settings(max_examples=60, deadline=None)
@given(layer=layer_st, array=array_st,
       r=st.integers(1, 4), c=st.integers(1, 4))
def test_multi_macro_never_worse(layer, array, r, c):
    assume(layer.k_w * layer.k_h <= array.ar)
    single = map_layer(layer, array, "Tetris-SDK").cycles
    multi = map_layer(layer, array, "Tetris-SDK",
                      grid=MacroGrid(r, c)).cycles
    assert multi <= single


@settings(max_examples=80, deadline=None)
@given(layer=layer_st, array=array_st)
def test_placement_coverage(layer, array):
    """Every output position is produced by at least one window load —
    the structural correctness property behind the conv equivalence."""
    assume(layer.k_w * layer.k_h <= array.ar)
    m = map_layer(layer, array, "Tetris-SDK", max_prune=0)
    covered = set()
    for tile in m.tiles:
        for (y, x, ph, pw) in window_placements(layer, tile):
            for oy in range(y, y + ph - layer.k_h + 1):
                for ox in range(x, x + pw - layer.k_w + 1):
                    covered.add((oy, ox))
    want = {(oy, ox) for oy in range(layer.o_h) for ox in range(layer.o_w)}
    assert want <= covered


@settings(max_examples=100, deadline=None)
@given(i=st.integers(3, 64), pw=st.integers(3, 64), k=st.sampled_from([1, 3, 5]))
def test_window_count_forms_agree_when_divisible(i, pw, k):
    assume(k <= pw <= i)
    lo = cyc.axis_leftover(i, pw, k)
    nf = cyc.axis_windows_floor(i, pw, k)
    nc = cyc.axis_windows_ceil(i, pw, k)
    if lo == 0:
        assert nf == nc
    else:
        assert nc >= nf


@settings(max_examples=60, deadline=None)
@given(layer=layer_st, array=array_st)
def test_cycles_positive_and_utilization_bounded(layer, array):
    assume(layer.k_w * layer.k_h <= array.ar)
    m = map_layer(layer, array, "Tetris-SDK")
    assert m.cycles >= 1
    assert 0 < m.utilization <= 1.0
