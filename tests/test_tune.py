"""Measured-feedback autotuner (repro/tune): deterministic search under
an injectable fake timer/runner, analytical-seed shortlist correctness,
disk-cache round-trip (a cold process with a warm cache performs ZERO
measurements), and the real-measurement cnn8 smoke — tuned never slower
than the "auto" default on its own interleaved-median evidence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.exec import compile_plan

RNG = np.random.RandomState(3)


def _net(name="cnn8", layers=None, grid=MacroGrid(2, 2), groups=(1, 2)):
    layers = networks.NETWORKS[name]() if layers is None else layers
    return map_net(name, layers, ArrayConfig(64, 64), "TetrisG-SDK",
                   grid, groups=groups)


def _fake(costs, *, default=1.0):
    """A deterministic measurement fixture: a virtual clock plus a
    runner whose per-candidate step advances it by a scripted cost —
    ``costs`` maps candidate -> seconds (callables get the candidate)."""
    t = [0.0]

    def clock():
        return t[0]

    def runner(cand):
        def step():
            c = costs(cand) if callable(costs) else costs.get(cand, default)
            t[0] += c
        return step

    return clock, runner


# ---------------------------------------------------------------- measure


def test_median_and_interleaving_order():
    assert tune.median([3.0, 1.0, 2.0]) == 2.0
    assert tune.median([4.0, 1.0, 3.0, 2.0]) == 3.0   # upper median
    with pytest.raises(ValueError):
        tune.median([])
    calls = []
    outs = tune.interleaved_rounds(
        [lambda: calls.append("a"), lambda: calls.append("b")],
        rounds=2, warmup=1)
    # warmup first (a, b), then strict round-robin rounds
    assert calls == ["a", "b", "a", "b", "a", "b"]
    assert [len(o) for o in outs] == [2, 2]


def test_interleaved_medians_fake_clock():
    t = [0.0]
    costs = iter([5.0, 3.0, 4.0])       # slow's three timed rounds

    def slow():
        t[0] += next(costs)

    def fast():
        pass
    meds = tune.interleaved_medians([slow, fast], rounds=3,
                                    clock=lambda: t[0], warmup=0)
    assert meds == [4.0, 0.0]


# ----------------------------------------------------------------- space


def test_analytic_cost_ranks_policies_and_splits():
    net = _net()
    n = len(net.layers)
    ref = tune.Candidate(policy=("reference",) * n)
    mapped = tune.Candidate(policy=("mapped",) * n)
    # without a mesh no macro parallelism is realized: the weights rank
    assert tune.analytic_cost(net, ref) < tune.analytic_cost(net, mapped)
    # a data split divides the whole cost; lookahead variants tie
    split = tune.Candidate(policy=("reference",) * n,
                           mesh_split=(2, 1, 1))
    assert tune.analytic_cost(net, split) == pytest.approx(
        tune.analytic_cost(net, ref) / 2)
    assert tune.analytic_cost(net, ref) == tune.analytic_cost(
        net, tune.Candidate(policy=("reference",) * n, lookahead=2))


def test_shortlist_seeds_base_major_and_keeps_baseline():
    net = _net()
    n = len(net.layers)
    space = tune.enumerate_space(net, batch=4)
    assert len(set(space)) == len(space)
    k = 5
    short = tune.shortlist(net, space, k)
    assert len(short) == k
    # base-major promotion: distinct bases appear in non-decreasing
    # analytic cost, and a base's variants are contiguous
    costs, seen = [], []
    for c in short:
        if c.base not in seen:
            seen.append(c.base)
            costs.append(tune.analytic_cost(net, c))
        else:
            assert c.base == seen[-1], "base variants not contiguous"
    assert costs == sorted(costs)
    # the model-predicted best base (all-reference on CPU) leads
    assert short[0].policy == ("reference",) * n
    # a worst-cost baseline is forced in, displacing the tail
    worst = tune.Candidate(policy=("mapped",) * n, lookahead=7)
    short2 = tune.shortlist(net, space, k, baseline=worst)
    assert len(short2) == k and short2[-1] == worst
    with pytest.raises(ValueError, match="k >= 1"):
        tune.shortlist(net, space, 0)


# ---------------------------------------------------------------- search


def test_autotune_deterministic_fake_timer():
    """The full driver under a scripted runner: the cheapest candidate
    wins, the baseline survives to the final rounds, and the measured-
    step count honors the per-candidate budget exactly."""
    memo.clear()
    net = _net()
    n = len(net.layers)
    budget = tune.TuneBudget(shortlist=4, rounds=2, eta=2, max_rounds=4)
    base = tune.baseline_candidate(net, batch=4)

    def costs(c):                    # reference wins big, lookahead=2 best
        s = 1.0 if c.policy == ("reference",) * n else 4.0
        return s - 0.1 * c.lookahead

    clock, runner = _fake(costs)
    res = tune.autotune(net, batch=4, budget=budget, clock=clock,
                        runner=runner, store=False)
    assert not res.cached
    win = res.config.candidate
    assert win.policy == ("reference",) * n and win.lookahead == 2
    assert res.config.median_s == pytest.approx(0.8)
    # baseline measured in the SAME final rounds -> speedup is evidence
    assert res.config.baseline_s == pytest.approx(costs(base))
    assert res.config.speedup > 1
    final = [t for t in res.trials if t.rounds == res.config.rounds]
    assert any(t.candidate == base for t in final)
    assert any(t.candidate == win for t in final)
    # measurement budget: every trial cost its rounds + one warmup step
    assert res.measurements == sum(t.rounds + budget.warmup
                                   for t in res.trials)
    # rounds escalate by eta and never exceed the cap
    stages = sorted({t.rounds for t in res.trials})
    assert stages == [2, 4]
    assert tune.tuned_config(net, batch=4) is None     # store=False


def test_autotune_winner_never_slower_than_baseline_by_construction():
    """Even when every challenger is WORSE than the default, the winner
    is the default itself — tuned can tie auto but never lose to it."""
    memo.clear()
    net = _net()
    base = tune.baseline_candidate(net, batch=4)
    clock, runner = _fake(lambda c: 1.0 if c == base else 9.0)
    res = tune.autotune(net, batch=4, clock=clock, runner=runner,
                        budget=tune.SMOKE_BUDGET, store=False)
    assert res.config.candidate == base
    assert res.config.median_s <= res.config.baseline_s


def test_autotune_persists_and_cold_process_loads(tmp_path):
    """Acceptance: winners survive a process restart — with a warm disk
    cache a cold process adopts the tuned config with zero measurements
    (memo counters asserted), and `compile_plan(executor_policy=
    "tuned")` serves it; without any tuning it falls back to "auto"."""
    memo.clear()
    memo.set_disk_cache(tmp_path)
    try:
        net = _net()
        n = len(net.layers)
        # untuned: "tuned" falls back to the auto policy
        auto_plan = compile_plan(net, executor_policy="tuned", batch=2)
        assert auto_plan.executors == compile_plan(
            net, executor_policy="auto", batch=2).executors

        clock, runner = _fake(
            lambda c: 0.5 if c.policy == ("reference",) * n else 2.0)
        res = tune.autotune(net, batch=4, budget=tune.SMOKE_BUDGET,
                            clock=clock, runner=runner)
        assert res.measurements > 0
        win = res.config.candidate

        memo.clear()            # in-memory gone, disk persists = cold process
        st0 = dict(memo.stats)

        def exploding(_cand):
            raise AssertionError("cold process must not measure")
        res2 = tune.autotune(net, batch=4, clock=clock, runner=exploding)
        assert res2.cached and res2.measurements == 0
        assert res2.config == res.config
        assert memo.stats["disk_hits"] >= st0.get("disk_hits", 0) + 1

        # the serve-path entry: "tuned" compiles the winner's config
        plan = compile_plan(net, executor_policy="tuned", batch=4)
        assert plan.executors == win.policy
        assert plan.lookahead == win.lookahead
        # generic slot: other batches inherit the tuning
        assert tune.tuned_config(net, batch=16) == res.config
    finally:
        memo.set_disk_cache(None)
        memo.clear()


def test_autotune_rejects_bad_inputs():
    net = _net()
    with pytest.raises(ValueError, match="batch"):
        tune.autotune(net, batch=0)
    with pytest.raises(ValueError, match="malformed budget"):
        tune.TuneBudget(rounds=0)
    with pytest.raises(ValueError, match="malformed budget"):
        tune.TuneBudget(rounds=4, max_rounds=2)


# ------------------------------------------------------- real measurement


def test_autotune_cnn8_real_smoke():
    """ISSUE 6 acceptance (cnn8, real wall-clock): the tuned config's
    final interleaved-round median is never slower than the auto
    baseline measured in the SAME rounds, and the tuned plan executes
    correctly end to end."""
    memo.clear()
    net = _net()
    res = tune.autotune(net, batch=2, budget=tune.SMOKE_BUDGET,
                        store=True)
    assert res.measurements > 0
    assert res.config.median_s <= res.config.baseline_s
    assert res.config.speedup >= 1.0

    # the winner actually serves: tuned plan forward == reference values
    from repro.cnn.mapped_net import (reference_net_apply,
                                      zero_pruned_kernels)
    from repro.exec import execute_plan
    plan = compile_plan(net, executor_policy="tuned", batch=2)
    assert plan.executors == res.config.candidate.policy
    ks = zero_pruned_kernels(net, [
        jnp.asarray(RNG.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net.layers])
    first = net.layers[0].layer
    x = jnp.asarray(RNG.randn(2, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    y = execute_plan(plan, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(r), rtol=1e-4,
        atol=1e-4 * float(jnp.max(jnp.abs(r))))
    memo.clear()


def test_report_csv_json_trajectory(tmp_path):
    memo.clear()
    net = _net()
    clock, runner = _fake(lambda c: 1.0)
    res = tune.autotune(net, batch=4, budget=tune.SMOKE_BUDGET,
                        clock=clock, runner=runner, store=False)
    results = {"cnn8": res}
    text = tune.write_csv(results, str(tmp_path / "tune_bench.csv"))
    assert text.splitlines()[0] == "name,usec,extras"
    assert any(line.startswith("tune/cnn8,") for line in text.splitlines())
    assert "speedup=" in text and "baseline_us=" in text
    assert (tmp_path / "tune_bench.csv").read_text() == text
    js = tune.write_json(results, str(tmp_path / "tune.json"))
    import json
    payload = json.loads(js)
    assert payload["cnn8"]["config"]["candidate"]["policy"]
    entry = tune.trajectory_entry(results, pr="PR 6", note="test")
    assert entry["nets"]["cnn8"]["speedup"] == pytest.approx(
        res.config.speedup)
    ledger = tmp_path / "BENCH_autotune.json"
    tune.append_trajectory(str(ledger), entry)
    tune.append_trajectory(str(ledger), entry)
    assert len(json.loads(ledger.read_text())) == 2


def test_fleet_signature_keys_platform_and_count():
    fleet = tune.fleet_signature()
    assert fleet == (jax.default_backend(), len(jax.devices()))
    key1 = tune.tuning_key("net", fleet, 4)
    key2 = tune.tuning_key("net", ("tpu", 8), 4)
    assert key1 != key2
