"""Per-arch smoke tests (reduced configs): forward/train step on CPU,
shape + finiteness; prefill+decode consistency; SSD oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import TrainConfig, init_train_state, make_train_step
from repro.models import transformer as T
from repro.models.ssm import SSMConfig, ssd_chunked, ssd_decode_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, train=False):
    extra = 1 if train else 0
    out = {}
    if cfg.frontend == "vision":
        out["tokens"] = jax.random.randint(
            KEY, (b, s - cfg.n_prefix + extra), 0, cfg.vocab)
        out["prefix_embeds"] = jnp.zeros((b, cfg.n_prefix, cfg.d_model),
                                         jnp.bfloat16)
    else:
        out["tokens"] = jax.random.randint(KEY, (b, s + extra), 0,
                                           cfg.vocab)
    if cfg.kind == "encdec":
        out["enc_embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                              jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    kw = {k: v for k, v in batch.items() if k != "tokens"}
    logits = T.forward(params, cfg, tokens=batch["tokens"], mode="train",
                       **kw)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "mixtral_8x7b",
                                  "mamba2_130m", "recurrentgemma_9b"])
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    state = init_train_state(cfg, KEY)
    step = make_train_step(cfg, TrainConfig(microbatches=2,
                                            warmup_steps=2,
                                            total_steps=10))
    batch = _batch(cfg, train=True)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, KEY)
    S, B = 32, 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    kw = {}
    if cfg.kind == "encdec":
        kw["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                             jnp.bfloat16)
    full = T.forward(params, cfg, tokens=toks, mode="train", **kw)
    _, cache = T.forward(params, cfg, tokens=toks[:, :S], mode="prefill",
                         cache_len=S + 8, **kw)
    dl, _ = T.forward(params, cfg, tokens=toks[:, S:S + 1], mode="decode",
                      cache=cache, pos=jnp.array(S, jnp.int32))
    a = full[:, S].astype(jnp.float32)
    b = dl[:, 0].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(a)))
                                            + 1e-9)
    assert rel < 0.05
    assert bool((a.argmax(-1) == b.argmax(-1)).all())


def test_ssd_chunked_matches_sequential():
    rng = np.random.RandomState(1)
    B, S, H, P, G, N = 2, 64, 4, 8, 1, 16
    s = SSMConfig(d_inner=H * P, n_heads=H, head_dim=P, d_state=N,
                  n_groups=G, chunk=16)
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.1 + 0.05, jnp.float32)
    a_log = jnp.asarray(rng.randn(H) * 0.3, jnp.float32)
    b = jnp.asarray(rng.randn(B, S, G, N) * 0.3, jnp.float32)
    c = jnp.asarray(rng.randn(B, S, G, N) * 0.3, jnp.float32)
    d = jnp.asarray(rng.randn(H), jnp.float32)
    y_chunk, st_chunk = ssd_chunked(x, dt, a_log, b, c, d, s)
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y1, st = ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], a_log,
                                 b[:, t:t + 1], c[:, t:t + 1], d, st)
        ys.append(y1)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st),
                               atol=1e-4)


def test_param_counts_sane():
    """Full-config analytic param counts are in the advertised ballpark."""
    expect = {"mamba2_130m": (0.10e9, 0.2e9),
              "stablelm_1_6b": (1.2e9, 2.2e9),
              "mixtral_8x7b": (40e9, 55e9),
              "deepseek_67b": (55e9, 75e9),
              "mistral_large_123b": (110e9, 135e9),
              "deepseek_v2_lite_16b": (12e9, 20e9)}
    for arch, (lo, hi) in expect.items():
        n = T.count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)
