"""Mapping-driven Pallas executor (kernels.im2win_conv.sdk_conv) vs the
lax.conv oracle and the reference batched executor: both paths execute
the *same* LayerMapping (DESIGN.md equivalence contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, ConvLayerSpec, conv1d, map_layer
from repro.cnn import cim_conv2d, reference_conv2d
from repro.kernels import im2win_conv
from repro.kernels.im2win_conv import sdk_conv, sdk_conv_cycles

RNG = np.random.RandomState(7)


def _check(layer, alg, arr=ArrayConfig(512, 512), **kw):
    m = map_layer(layer, arr, alg, **kw)
    g = m.group
    ic_g = layer.ic // g
    x = jnp.asarray(RNG.randn(2, layer.ic, layer.i_h, layer.i_w),
                    jnp.float32)
    k = jnp.asarray(RNG.randn(layer.k_h, layer.k_w, ic_g, layer.oc),
                    jnp.float32)
    pruned = sum(t.pruned_channels for t in m.tiles)
    if pruned:
        k = k.at[:, :, ic_g - pruned:, :].set(0.0)
    y = sdk_conv(m, x, k, interpret=True)
    ref = reference_conv2d(layer, x, k, groups=g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
    # the Pallas path and the reference batched path execute the same
    # mapping => identical results up to float summation order
    yr = cim_conv2d(m, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-3, rtol=1e-3)
    return m


@pytest.mark.parametrize("alg", ["img2col", "VW-SDK", "Tetris-SDK",
                                 "TetrisG-SDK"])
def test_sdk_conv_equivalence(alg):
    _check(ConvLayerSpec("t", 18, 18, 3, 3, 24, 32), alg)


def test_sdk_conv_marginal_windows():
    m = _check(ConvLayerSpec("t", 18, 18, 3, 3, 32, 32), "Tetris-SDK")
    assert any(t.marginals for t in m.tiles)      # border loads exercised
    assert any(t.pruned_channels for t in m.tiles)


def test_sdk_conv_strided():
    _check(ConvLayerSpec("t", 10, 10, 3, 3, 8, 8, stride=2), "Tetris-SDK",
           ArrayConfig(128, 128))


@pytest.mark.slow
def test_sdk_conv_grouped_and_multi_tile():
    m = _check(ConvLayerSpec("t", 7, 7, 3, 3, 64, 64), "Tetris-SDK")
    assert len(m.tiles) > 1
    _check(ConvLayerSpec("t", 10, 10, 3, 3, 16, 16, groups=16),
           "Tetris-SDK", ArrayConfig(128, 128))


def test_sdk_conv_conv1d():
    _check(conv1d("t", 32, 4, 8, 8), "Tetris-SDK", ArrayConfig(128, 128))


def test_sdk_conv_window_blocked():
    """The DMA window-blocked path (BlockSpecs smaller than whole-array:
    one window patch + one output tile in VMEM per grid step) matches the
    whole-array path and the oracle, marginals and stride included."""
    for layer, arr in (
            (ConvLayerSpec("t", 18, 18, 3, 3, 32, 32), ArrayConfig(512, 512)),
            (ConvLayerSpec("s", 10, 10, 3, 3, 8, 8, stride=2),
             ArrayConfig(128, 128))):
        m = map_layer(layer, arr, "Tetris-SDK")
        ic_g = layer.ic // m.group
        x = jnp.asarray(RNG.randn(2, layer.ic, layer.i_h, layer.i_w),
                        jnp.float32)
        k = jnp.asarray(RNG.randn(layer.k_h, layer.k_w, ic_g, layer.oc),
                        jnp.float32)
        pruned = sum(t.pruned_channels for t in m.tiles)
        if pruned:
            k = k.at[:, :, ic_g - pruned:, :].set(0.0)
        yw = sdk_conv(m, x, k, interpret=True, block="window")
        y0 = sdk_conv(m, x, k, interpret=True, block="whole")
        ref = reference_conv2d(layer, x, k, groups=m.group)
        np.testing.assert_allclose(np.asarray(yw), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(yw), np.asarray(y0),
                                   atol=1e-3, rtol=1e-3)


def test_sdk_conv_auto_block_big_layer():
    """auto mode drops to window blocks when the whole-array working set
    exceeds the VMEM budget (big Inception-style layer)."""
    layer = ConvLayerSpec("big", 30, 30, 5, 5, 16, 32)
    m = map_layer(layer, ArrayConfig(64, 64), "VW-SDK")
    x = jnp.asarray(RNG.randn(1, layer.ic, 30, 30), jnp.float32)
    k = jnp.asarray(RNG.randn(5, 5, 16, 32), jnp.float32)
    y = sdk_conv(m, x, k, interpret=True, block="auto",
                 vmem_budget=64 * 1024)     # force the window path
    ref = reference_conv2d(layer, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def _double_buffer_case():
    """Stride-2 mapping exercising every blocked-kernel hazard at once:
    multiple channel passes (slot reuse across ci), marginal windows
    (border-clamped prefetch origins) and pruned channels."""
    layer = ConvLayerSpec("db", 11, 11, 3, 3, 16, 16, stride=2)
    m = map_layer(layer, ArrayConfig(128, 128), "Tetris-SDK")
    assert any(t.marginals for t in m.tiles)
    assert any(t.pruned_channels for t in m.tiles)
    assert any(t.ar_c > 1 for t in m.tiles)
    ic_g = layer.ic // m.group
    x = jnp.asarray(RNG.randn(2, layer.ic, layer.i_h, layer.i_w),
                    jnp.float32)
    k = jnp.asarray(RNG.randn(layer.k_h, layer.k_w, ic_g, layer.oc),
                    jnp.float32)
    pruned = sum(t.pruned_channels for t in m.tiles)
    k = k.at[:, :, ic_g - pruned:, :].set(0.0)
    return layer, m, x, k


def test_double_buffered_window_blocked():
    """The double-buffered DMA pipeline (prefetch window t+1 during the
    MXU step t, stores drained on slot reuse) matches block="whole" and
    both reference executors on the stride>1 + marginal + pruned case,
    and the steps==cycles contract is untouched."""
    layer, m, x, k = _double_buffer_case()
    yw = sdk_conv(m, x, k, interpret=True, block="window")
    y0 = sdk_conv(m, x, k, interpret=True, block="whole")
    ref = reference_conv2d(layer, x, k, groups=m.group)
    yr = cim_conv2d(m, x, k)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(y0),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(yr),
                               atol=1e-3, rtol=1e-3)
    # steps==cycles contract, unchanged by double-buffering: exact on a
    # ceil-form (marginal-free) mapping of the same strided layer
    mv = map_layer(layer, ArrayConfig(512, 512), "VW-SDK")
    assert not any(t.marginals for t in mv.tiles)
    assert sdk_conv_cycles(mv) == mv.cycles
    yv = sdk_conv(mv, x, k, interpret=True, block="window")
    np.testing.assert_allclose(
        np.asarray(yv),
        np.asarray(reference_conv2d(layer, x, k, groups=mv.group)),
        atol=1e-3, rtol=1e-3)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas TPU path needs a TPU")
def test_double_buffered_window_blocked_compiled():
    """Same cross-check with the kernel actually compiled (Mosaic), where
    DMA/compute overlap is real rather than interpreted."""
    layer, m, x, k = _double_buffer_case()
    yw = sdk_conv(m, x, k, block="window")
    ref = reference_conv2d(layer, x, k, groups=m.group)
    np.testing.assert_allclose(np.asarray(yw), np.asarray(ref),
                               atol=1e-2, rtol=1e-2)


def test_sdk_conv_no_retrace():
    """sdk_conv dispatches through a static-shape-keyed jit entry: repeat
    calls with identical (mapping, shapes, flags) must not rebuild the
    pallas_call closures; new shapes/flags trace exactly once each."""
    layer = ConvLayerSpec("t", 12, 12, 3, 3, 8, 8)
    m = map_layer(layer, ArrayConfig(256, 256), "VW-SDK")
    x = jnp.asarray(RNG.randn(2, 8, 12, 12), jnp.float32)
    k = jnp.asarray(RNG.randn(3, 3, 8, 8), jnp.float32)
    im2win_conv._trace_counts.clear()
    for _ in range(3):
        sdk_conv(m, x, k, interpret=True)
    assert list(im2win_conv._trace_counts.values()) == [1]
    sdk_conv(m, x[:1], k, interpret=True)         # new batch: one retrace
    sdk_conv(m, x, k, interpret=True, block="window")  # new flag: one more
    assert sorted(im2win_conv._trace_counts.values()) == [1, 1, 1]


def test_grid_steps_match_ceil_cycles():
    """The pallas grid enumerates the mapping's loads: for a ceil-form
    (marginal-free, single-macro) mapping the step count equals the
    mapping's cycle count exactly."""
    layer = ConvLayerSpec("t", 18, 18, 3, 3, 24, 32)
    m = map_layer(layer, ArrayConfig(512, 512), "VW-SDK")
    assert not any(t.marginals for t in m.tiles)
    assert sdk_conv_cycles(m) == m.cycles
    # SDK tiles multiplex rows over ar_c passes; the grid must account
    # (and execute) those passes too
    ms = map_layer(layer, ArrayConfig(512, 512), "SDK")
    assert sdk_conv_cycles(ms) == ms.cycles
    _check(layer, "SDK")
