"""Transformer lowering end to end (DESIGN.md §11): block-by-block
matmul specs + GlueSpec glue through compile_plan -> execute_plan,
steps==cycles at compile time, the plan-batch ladder, and the mixed
CNN+transformer fleet with tokens/s next to images/s."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, GlueSpec, MacroGrid, memo
from repro.exec import compile_plan, execute_plan
from repro.launch import batching
from repro.launch.transformer import (TRANSFORMERS, tokens_per_row,
                                      transformer_mapping)

RNG = np.random.RandomState(11)
ARR = ArrayConfig(64, 64)
GRID = MacroGrid(2, 2)


def _net(name="stablelm_smoke", seq=16, blocks=1, groups=(1,)):
    memo.clear()
    return transformer_mapping(name, seq=seq, array=ARR, grid=GRID,
                               blocks=blocks, groups=groups)


def _kernels(net, scale=0.1):
    return [jnp.asarray(RNG.randn(1, 1, m.layer.ic // m.group,
                                  m.layer.oc) * scale, jnp.float32)
            for m in net.layers]


# --- lowering --------------------------------------------------------------

def test_lowering_shapes_and_glue():
    net = _net()
    assert [m.layer.name for m in net.layers] == [
        "blk0.qkv", "blk0.o", "blk0.w1", "blk0.w2"]
    assert all(m.layer.op == "matmul" for m in net.layers)
    assert len(net.glue) == 4
    qkv, o, w1, w2 = net.glue
    assert qkv.post == "attention" and qkv.save and qkv.pre == "layernorm"
    assert o.kind == "residual"
    assert w1.act in ("gelu", "silu") and w1.save
    assert w2.kind == "residual"
    assert tokens_per_row(net) == 16
    assert net.total_cycles > 0


def test_whisper_encoder_is_bidirectional():
    net = _net("whisper_smoke", blocks=1)
    assert net.glue[0].causal is False
    assert net.glue[0].post == "attention"


def test_transformer_registry_covers_smoke_configs():
    assert set(TRANSFORMERS) == {"stablelm_smoke", "whisper_smoke"}
    for name in TRANSFORMERS:
        net = _net(name, blocks=1)
        assert net.glue is not None and len(net.glue) == len(net.layers)


def test_conv_net_has_no_tokens():
    from repro.core import map_net, networks
    cnn = map_net("cnn8", networks.cnn8()[:2], ARR, "Tetris-SDK", GRID)
    assert tokens_per_row(cnn) is None


# --- compile ---------------------------------------------------------------

def test_compile_steps_equal_cycles():
    net = _net(blocks=2)
    plan = compile_plan(net, executor_policy="mapped", batch=2)
    assert plan.total_steps == net.total_cycles
    assert all(lp.glue is not None for lp in plan.layers)


def test_compile_rejects_matmul_executor_on_conv():
    from repro.core import map_net, networks
    cnn = map_net("cnn8", networks.cnn8()[:2], ARR, "Tetris-SDK", GRID)
    with pytest.raises(ValueError, match="matmul"):
        compile_plan(cnn, executor_policy="matmul")


def test_compile_rejects_inconsistent_glue():
    """Explicit glue is validated by carry-channel simulation at compile
    time: a residual with nothing saved must fail, as must a dangling
    save."""
    import dataclasses
    net = _net()
    bad = dataclasses.replace(net, glue=(
        GlueSpec(kind="residual"),) + net.glue[1:])
    with pytest.raises(ValueError):
        compile_plan(bad, executor_policy="mapped")
    dangling = dataclasses.replace(net, glue=net.glue[:3] + (
        GlueSpec(kind="last", save=True),))
    with pytest.raises(ValueError):
        compile_plan(dangling, executor_policy="mapped")


# --- execute vs a pure-jnp reference ---------------------------------------

def _ref_forward(net, kernels, x, blocks):
    """Independent oracle: plain jnp transformer blocks over the same
    (B, d_model, M, 1) layout and parameter-free layernorm."""
    from repro.models.attention import attention as jax_attn

    def ln(t):
        mu = t.mean(axis=1, keepdims=True)
        var = ((t - mu) ** 2).mean(axis=1, keepdims=True)
        return (t - mu) / jnp.sqrt(var + 1e-5)

    def mm(t, w):                       # (B,d,M,1) @ (1,1,d,f)
        return jnp.einsum("bdmo,df->bfmo", t, w[0, 0])

    import jax
    i = 0
    for b in range(blocks):
        qkv_g, o_g, w1_g, w2_g = net.glue[4 * b:4 * b + 4]
        hq, hkv, hd = qkv_g.heads
        resid = x
        qkv = mm(ln(x), kernels[i]); i += 1
        tok = qkv[..., 0].transpose(0, 2, 1)          # (B, M, F)
        bsz, m, _ = tok.shape
        q = tok[..., :hq * hd].reshape(bsz, m, hq, hd)
        k = tok[..., hq * hd:(hq + hkv) * hd].reshape(bsz, m, hkv, hd)
        v = tok[..., (hq + hkv) * hd:].reshape(bsz, m, hkv, hd)
        o = jax_attn(q, k, v, causal=qkv_g.causal)    # (B, M, hq, hd)
        y = o.reshape(bsz, m, hq * hd).transpose(0, 2, 1)[..., None]
        x = resid + mm(y, kernels[i]); i += 1
        resid = x
        h = mm(ln(x), kernels[i]); i += 1
        h = {"gelu": jax.nn.gelu, "silu": jax.nn.silu}[w1_g.act](h)
        x = resid + mm(h, kernels[i]); i += 1
    return x


@pytest.mark.parametrize("policy", ["reference", "mapped"])
def test_execute_plan_matches_jnp_reference(policy):
    net = _net(blocks=2, groups=(1,))   # dense: the einsum oracle applies
    kernels = _kernels(net)
    x = jnp.asarray(RNG.randn(2, 128, 16, 1) * 0.5, jnp.float32)
    plan = compile_plan(net, executor_policy=policy, batch=2)
    y = execute_plan(plan, kernels, x)
    r = _ref_forward(net, kernels, x, blocks=2)
    assert y.shape == r.shape == (2, 128, 16, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=5e-4, rtol=5e-4)


def test_explicit_glue_ignores_global_activation():
    """An explicit-glue plan applies per-layer GlueSpec.act only — the
    network-global activation must not leak in between layers."""
    import jax
    net = _net(blocks=1, groups=(1,))
    kernels = _kernels(net)
    x = jnp.asarray(RNG.randn(1, 128, 16, 1) * 0.5, jnp.float32)
    plan = compile_plan(net, executor_policy="reference", batch=1)
    base = execute_plan(plan, kernels, x)
    with_act = execute_plan(plan, kernels, x, activation=jax.nn.relu)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(with_act))


# --- ladder + fleet --------------------------------------------------------

def test_plan_ladder_serves_transformer_tiers():
    net = _net(blocks=1, groups=(1,))
    kernels = _kernels(net)
    ladder = batching.PlanLadder(net, (1, 2))
    for tier in (1, 2):
        t, plan = ladder.plan_for(tier)
        assert t == tier
        x = jnp.asarray(RNG.randn(tier, 128, 16, 1) * 0.5, jnp.float32)
        y = execute_plan(plan, kernels, x)
        assert y.shape == (tier, 128, 16, 1)


def test_chainable_prefix_keeps_glue_mappings_whole():
    from repro.launch.fleet import chainable_prefix
    net = _net(blocks=1)
    assert chainable_prefix(net) is net


def test_mixed_fleet_cli_reports_tokens_and_dropped(capsys):
    """serve_cnn --fleet with a CNN and a transformer on one mesh:
    tokens/s rides next to images/s for the transformer, dropped-layer
    accounting appears for every model."""
    from repro.launch import serve_cnn
    serve_cnn.main(["--fleet", "cnn8,stablelm_smoke", "--batch", "2",
                    "--requests", "8", "--arrival-rate", "200",
                    "--warmup", "1", "--slo-ms", "500", "--seq", "16",
                    "--ar", "64", "--ac", "64", "--grid", "2x2"])
    out = capsys.readouterr().out
    cnn = next(ln for ln in out.splitlines()
               if ln.startswith("serve_fleet/cnn8,"))
    tfm = next(ln for ln in out.splitlines()
               if ln.startswith("serve_fleet/stablelm_smoke,"))
    assert "tokens_per_s=" in tfm and "dropped_layers=0" in tfm
    assert "tokens_per_s=" not in cnn and "dropped_layers=" in cnn
    agg = next(ln for ln in out.splitlines()
               if ln.startswith("serve_fleet/all,"))
    assert "models=cnn8/stablelm_smoke" in agg
