"""Memoized + vectorized search == the scalar reference search, bit for
bit: same windows, tiles, cycles, and chosen grids (DESIGN.md §3)."""
import random

import pytest

from repro.core import (ArrayConfig, ConvLayerSpec, MacroGrid, grid_search,
                        map_layer, map_net, networks)
from repro.core import baselines, memo, tetris


def _random_cases(n, seed=3):
    rng = random.Random(seed)
    out = []
    while len(out) < n:
        i = rng.randint(5, 22)
        k = rng.choice([1, 3, 5])
        if i < k:
            continue
        layer = ConvLayerSpec("r", i, i, k, k, rng.randint(1, 48),
                              rng.randint(1, 64),
                              stride=rng.choice([1, 1, 2]))
        arr = ArrayConfig(rng.choice([64, 128, 256, 512]),
                          rng.choice([64, 128, 256, 512]))
        if k * k > arr.ar:
            continue
        grid = MacroGrid(rng.randint(1, 4), rng.randint(1, 4))
        out.append((layer, arr, grid))
    return out


@pytest.mark.parametrize("search,name", [
    (tetris.tetris_layer, "tetris"),
    (baselines.vw_sdk, "vw"),
    (baselines.sdk, "sdk"),
    (baselines.vwc_sdk, "vwc"),
])
def test_vectorized_matches_scalar(search, name):
    """The vectorized/memoized path and the scalar first-strictly-better
    loop must pick identical mappings on random geometries."""
    for layer, arr, grid in _random_cases(40):
        memo.clear()
        fast = search(layer, arr, grid)
        with memo.disabled():
            slow = search(layer, arr, grid)
        assert fast == slow, (name, layer, arr, grid)


def test_effective_grid_rebase():
    """Grids beyond (IC, OC) collapse to one cache entry whose result is
    re-stamped with the caller's grid — and matches a direct search."""
    layer = ConvLayerSpec("t", 18, 18, 3, 3, 8, 8)
    arr = ArrayConfig(256, 256)
    memo.clear()
    a = tetris.tetris_layer(layer, arr, MacroGrid(9, 9))
    b = tetris.tetris_layer(layer, arr, MacroGrid(16, 12))
    assert memo.stats["result_misses"] >= 1
    assert a.tiles == b.tiles
    assert a.grid == MacroGrid(9, 9) and b.grid == MacroGrid(16, 12)
    with memo.disabled():
        assert tetris.tetris_layer(layer, arr, MacroGrid(16, 12)) == b


def test_grid_search_cache_correctness():
    """Memoized grid search returns bit-identical mappings, chosen grids
    and per-grid cycle counts to the uncached path (Alg 2 contract)."""
    layers = networks.cnn8()
    arr = ArrayConfig(512, 512)
    memo.clear()
    cached = grid_search("cnn8", layers, arr, p_max=6)
    with memo.disabled():
        uncached = grid_search("cnn8", layers, arr, p_max=6)
    assert cached.best == uncached.best
    assert cached.per_grid == uncached.per_grid


def test_cache_hit_counts():
    layers = networks.cnn8()
    arr = ArrayConfig(512, 512)
    memo.clear()
    map_net("cnn8", layers, arr, "Tetris-SDK")
    misses = memo.stats["result_misses"]
    map_net("cnn8", layers, arr, "Tetris-SDK")
    assert memo.stats["result_misses"] == misses   # second pass all hits
    assert memo.stats["result_hits"] >= len(layers)


def test_paper_numbers_survive_memoization():
    """Table I anchors: CNN8 Tetris-SDK == 116 total cycles."""
    memo.clear()
    m = map_net("cnn8", networks.cnn8(), ArrayConfig(512, 512),
                "Tetris-SDK")
    assert m.total_cycles == 116
    m2 = map_layer(networks.cnn8()[1], ArrayConfig(512, 512), "Tetris-SDK")
    assert m2.cycles == 38                          # CNN8-3, Fig 12
