"""Memoized + vectorized search == the scalar reference search, bit for
bit: same windows, tiles, cycles, and chosen grids (DESIGN.md §3) — plus
the LRU bounds and the persistent on-disk result cache (DESIGN.md §7)."""
import os
import random
import subprocess
import sys

import pytest

from repro.core import (ArrayConfig, ConvLayerSpec, MacroGrid, grid_search,
                        map_layer, map_net, networks)
from repro.core import baselines, memo, tetris


@pytest.fixture
def disk_cache(tmp_path):
    """Point the disk layer at a temp dir; restore pristine state after."""
    memo.clear()
    memo.set_disk_cache(tmp_path)
    try:
        yield tmp_path
    finally:
        memo.set_disk_cache(None)
        memo.clear()


@pytest.fixture
def cache_limits():
    prev = memo.cache_limits()
    try:
        yield
    finally:
        memo.set_cache_limits(*prev)


def _random_cases(n, seed=3):
    rng = random.Random(seed)
    out = []
    while len(out) < n:
        i = rng.randint(5, 22)
        k = rng.choice([1, 3, 5])
        if i < k:
            continue
        layer = ConvLayerSpec("r", i, i, k, k, rng.randint(1, 48),
                              rng.randint(1, 64),
                              stride=rng.choice([1, 1, 2]))
        arr = ArrayConfig(rng.choice([64, 128, 256, 512]),
                          rng.choice([64, 128, 256, 512]))
        if k * k > arr.ar:
            continue
        grid = MacroGrid(rng.randint(1, 4), rng.randint(1, 4))
        out.append((layer, arr, grid))
    return out


@pytest.mark.parametrize("search,name", [
    (tetris.tetris_layer, "tetris"),
    (baselines.vw_sdk, "vw"),
    (baselines.sdk, "sdk"),
    (baselines.vwc_sdk, "vwc"),
])
def test_vectorized_matches_scalar(search, name):
    """The vectorized/memoized path and the scalar first-strictly-better
    loop must pick identical mappings on random geometries."""
    for layer, arr, grid in _random_cases(40):
        memo.clear()
        fast = search(layer, arr, grid)
        with memo.disabled():
            slow = search(layer, arr, grid)
        assert fast == slow, (name, layer, arr, grid)


def test_effective_grid_rebase():
    """Grids beyond (IC, OC) collapse to one cache entry whose result is
    re-stamped with the caller's grid — and matches a direct search."""
    layer = ConvLayerSpec("t", 18, 18, 3, 3, 8, 8)
    arr = ArrayConfig(256, 256)
    memo.clear()
    a = tetris.tetris_layer(layer, arr, MacroGrid(9, 9))
    b = tetris.tetris_layer(layer, arr, MacroGrid(16, 12))
    assert memo.stats["result_misses"] >= 1
    assert a.tiles == b.tiles
    assert a.grid == MacroGrid(9, 9) and b.grid == MacroGrid(16, 12)
    with memo.disabled():
        assert tetris.tetris_layer(layer, arr, MacroGrid(16, 12)) == b


def test_grid_search_cache_correctness():
    """Memoized grid search returns bit-identical mappings, chosen grids
    and per-grid cycle counts to the uncached path (Alg 2 contract)."""
    layers = networks.cnn8()
    arr = ArrayConfig(512, 512)
    memo.clear()
    cached = grid_search("cnn8", layers, arr, p_max=6)
    with memo.disabled():
        uncached = grid_search("cnn8", layers, arr, p_max=6)
    assert cached.best == uncached.best
    assert cached.per_grid == uncached.per_grid


def test_cache_hit_counts():
    layers = networks.cnn8()
    arr = ArrayConfig(512, 512)
    memo.clear()
    map_net("cnn8", layers, arr, "Tetris-SDK")
    misses = memo.stats["result_misses"]
    map_net("cnn8", layers, arr, "Tetris-SDK")
    assert memo.stats["result_misses"] == misses   # second pass all hits
    assert memo.stats["result_hits"] >= len(layers)


def test_lru_eviction_bound(cache_limits):
    """The in-memory caches cannot grow past their bounds in a long-lived
    process: oldest entries evict, counters surface it, results stay
    correct (evicted entries just recompute)."""
    memo.clear()
    memo.set_cache_limits(results=4, tables=2)
    layers = [ConvLayerSpec(f"l{i}", 12 + i, 12 + i, 3, 3, 8, 8)
              for i in range(8)]
    arr = ArrayConfig(256, 256)
    first = [tetris.tetris_layer(ly, arr, MacroGrid(2, 2)) for ly in layers]
    assert len(memo._results) <= 4 and len(memo._tables) <= 2
    assert memo.stats["result_evictions"] >= 4
    assert memo.stats["table_evictions"] >= 6
    again = [tetris.tetris_layer(ly, arr, MacroGrid(2, 2)) for ly in layers]
    assert first == again
    # shrinking below the live population evicts immediately
    memo.set_cache_limits(results=1)
    assert len(memo._results) <= 1


def test_disk_cache_round_trip(disk_cache):
    """A populated disk cache survives an in-memory wipe: the re-search
    is all disk hits, zero table builds, bit-identical mappings."""
    layers = networks.cnn8()
    arr = ArrayConfig(512, 512)
    first = map_net("cnn8", layers, arr, "Tetris-SDK")
    assert memo.stats["disk_writes"] > 0
    files = list(disk_cache.glob("*.mapping.pkl"))
    assert len(files) == memo.stats["disk_writes"]
    memo.clear()                      # cold in-memory, warm disk
    again = map_net("cnn8", layers, arr, "Tetris-SDK")
    assert again == first
    assert memo.stats["table_misses"] == 0
    assert memo.stats["disk_hits"] > 0 and memo.stats["disk_writes"] == 0


def test_disk_cache_corrupt_entry_recomputes(disk_cache):
    """Truncated/garbage entries are dropped and recomputed, not fatal."""
    layer = ConvLayerSpec("t", 18, 18, 3, 3, 8, 8)
    arr = ArrayConfig(256, 256)
    m = tetris.tetris_layer(layer, arr, MacroGrid(2, 2))
    for f in disk_cache.glob("*.mapping.pkl"):
        f.write_bytes(b"not a pickle")
    memo.clear()
    m2 = tetris.tetris_layer(layer, arr, MacroGrid(2, 2))
    assert m2 == m
    assert memo.stats["disk_errors"] > 0


def test_disk_cache_bypassed_when_disabled(disk_cache):
    with memo.disabled():
        tetris.tetris_layer(ConvLayerSpec("t", 18, 18, 3, 3, 8, 8),
                            ArrayConfig(256, 256), MacroGrid(2, 2))
    assert memo.stats["disk_writes"] == 0
    assert not list(disk_cache.glob("*.mapping.pkl"))


def test_disk_cache_cold_process_densenet40(disk_cache):
    """Acceptance anchor: a cold process with a warm on-disk cache maps
    DenseNet40 at p_max=16 with ZERO search-table builds, and picks the
    identical grid/cycles."""
    warm = grid_search("densenet40", networks.densenet40(),
                       ArrayConfig(512, 512), 16)
    code = """
from repro.core import ArrayConfig, grid_search, memo, networks
r = grid_search("densenet40", networks.densenet40(),
                ArrayConfig(512, 512), 16)
assert memo.stats["table_misses"] == 0, memo.stats
assert memo.stats["disk_hits"] > 0
print("COLD-OK", r.best.grid.r, r.best.grid.c, r.best.total_cycles)
"""
    env = dict(os.environ,
               REPRO_MAPPING_CACHE=str(disk_cache),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    b = warm.best
    assert out.stdout.split()[-4:] == [
        "COLD-OK", str(b.grid.r), str(b.grid.c), str(b.total_cycles)]


def test_paper_numbers_survive_memoization():
    """Table I anchors: CNN8 Tetris-SDK == 116 total cycles."""
    memo.clear()
    m = map_net("cnn8", networks.cnn8(), ArrayConfig(512, 512),
                "Tetris-SDK")
    assert m.total_cycles == 116
    m2 = map_layer(networks.cnn8()[1], ArrayConfig(512, 512), "Tetris-SDK")
    assert m2.cycles == 38                          # CNN8-3, Fig 12


def test_disk_cache_eviction_converges(tmp_path):
    """A size-capped disk cache prunes oldest-mtime entries on insert
    (counted in stats) instead of growing forever; the entry just
    written always survives."""
    memo.clear()
    payload = b"x" * 256
    try:
        memo.set_disk_cache(tmp_path, max_bytes=4096)
        for i in range(40):                 # ~10x the cap, distinct keys
            memo.cached_result(("evict", i), lambda: payload,
                               persist=True)
        total = sum(f.stat().st_size
                    for f in tmp_path.glob("*.mapping.pkl"))
        assert 0 < total <= 4096            # converged, not grown
        assert memo.stats["disk_evictions"] > 0
        # the newest insert is still present on disk
        memo.clear()
        assert memo.cached_result(("evict", 39), lambda: None,
                                  persist=True) == payload
        # untouched early keys were evicted (recompute happens)
        memo.clear()
        assert memo.cached_result(("evict", 0), lambda: "gone",
                                  persist=True) == "gone"
    finally:
        memo.set_disk_cache(None)
        memo.clear()


def test_disk_cache_eviction_is_mtime_lru(tmp_path):
    """Hits refresh an entry's mtime, so a recently-read old entry
    outlives a colder, newer one when the cap bites.  Entry ages are
    pinned with explicit os.utime so the ordering never depends on the
    filesystem's mtime granularity."""
    import time
    memo.clear()
    entry = b"z" * 128                      # ~150 B pickled
    try:
        memo.set_disk_cache(tmp_path, max_bytes=420)
        memo.cached_result(("lru", "a"), lambda: entry, persist=True)
        memo.cached_result(("lru", "b"), lambda: entry, persist=True)
        a_path = memo._disk_path(("lru", "a"))
        b_path = memo._disk_path(("lru", "b"))
        now = time.time()
        os.utime(a_path, (now - 200, now - 200))   # a is the older entry
        os.utime(b_path, (now - 100, now - 100))
        memo.clear()                        # force the next read to disk
        assert memo.cached_result(("lru", "a"), lambda: None,
                                  persist=True) == entry
        # the hit refreshed a's mtime past b's: b is now the LRU victim
        assert a_path.stat().st_mtime > b_path.stat().st_mtime
        memo.cached_result(("lru", "c"), lambda: entry, persist=True)
        memo.clear()
        assert memo.cached_result(("lru", "a"), lambda: "gone",
                                  persist=True) == entry
        memo.clear()
        assert memo.cached_result(("lru", "b"), lambda: "gone",
                                  persist=True) == "gone"
    finally:
        memo.set_disk_cache(None)
        memo.clear()


def test_disk_cache_uncapped_by_default(tmp_path):
    memo.clear()
    try:
        memo.set_disk_cache(tmp_path)
        assert memo.disk_cache_max_bytes() is None
        for i in range(8):
            memo.cached_result(("nocap", i), lambda: b"y" * 512,
                               persist=True)
        assert len(list(tmp_path.glob("*.mapping.pkl"))) == 8
        assert memo.stats["disk_evictions"] == 0
    finally:
        memo.set_disk_cache(None)
        memo.clear()
