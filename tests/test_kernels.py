"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode on
CPU; TPU is the target)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(optional test dependency, see pyproject.toml)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.im2win_conv import n_cycles, select_window
from repro.kernels.tetris_matmul import select_block_shape

RNG = np.random.RandomState(1)


@pytest.mark.parametrize("mnk", [(256, 256, 256), (384, 128, 512),
                                 (100, 60, 40), (129, 257, 130),
                                 (8, 8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tetris_matmul_sweep(mnk, dtype):
    m, n, k = mnk
    x = jnp.asarray(RNG.randn(m, k), dtype)
    w = jnp.asarray(RNG.randn(k, n), dtype)
    y = np.asarray(ops.matmul(x, w), np.float32)
    r = np.asarray(ref.matmul_ref(x, w), np.float32)
    tol = 1e-4 * k if dtype == jnp.float32 else 0.2 * np.sqrt(k)
    np.testing.assert_allclose(y, r, atol=tol, rtol=1e-2)


@pytest.mark.parametrize("gmdf", [(4, 64, 32, 48), (8, 128, 64, 64),
                                  (3, 50, 20, 30), (1, 16, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(gmdf, dtype):
    g, m, d, f = gmdf
    x = jnp.asarray(RNG.randn(g, m, d), dtype)
    w = jnp.asarray(RNG.randn(g, d, f), dtype)
    y = np.asarray(ops.gmm(x, w), np.float32)
    r = np.asarray(ref.grouped_matmul_ref(x, w), np.float32)
    tol = 1e-4 * d if dtype == jnp.float32 else 0.2 * np.sqrt(d)
    np.testing.assert_allclose(y, r, atol=tol, rtol=1e-2)


@pytest.mark.parametrize("cfg", [(2, 18, 18, 24, 3, 32),
                                 (1, 12, 12, 8, 5, 16),
                                 (2, 9, 9, 32, 3, 64),
                                 (1, 7, 7, 3, 3, 5)])
def test_im2win_conv_sweep(cfg):
    b, h, w_, c, k, o = cfg
    x = jnp.asarray(RNG.randn(b, h, w_, c), jnp.float32)
    kk = jnp.asarray(RNG.randn(k, k, c, o) * 0.1, jnp.float32)
    y = np.asarray(ops.conv2d(x, kk))
    r = np.asarray(ref.conv2d_ref(x, kk))
    np.testing.assert_allclose(y, r, atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(h=st.integers(6, 20), c=st.integers(1, 16), o=st.integers(1, 16),
       k=st.sampled_from([1, 3]))
def test_im2win_conv_property(h, c, o, k):
    x = jnp.asarray(RNG.randn(1, h, h, c), jnp.float32)
    kk = jnp.asarray(RNG.randn(k, k, c, o) * 0.2, jnp.float32)
    y = np.asarray(ops.conv2d(x, kk))
    r = np.asarray(ref.conv2d_ref(x, kk))
    np.testing.assert_allclose(y, r, atol=2e-3, rtol=2e-3)


def test_select_block_shape_respects_budget():
    bm, bn, bk = select_block_shape(4096, 4096, 4096, dtype_bytes=2)
    assert (bm * bk + bk * bn) * 2 + bm * bn * 4 <= 8 * 1024 * 1024
    assert bm % 128 == 0 and bn % 128 == 0


def test_select_window_square_inclined():
    th, tw = select_window(32, 32, 3, 64, 64)
    assert abs(th - tw) <= max(th, tw) // 2   # near-square (AM-GM, Alg 3)


def test_grid_is_cycle_count():
    assert n_cycles(16, 16, 8, 8) == 4
    assert n_cycles(17, 16, 8, 8) == 6        # ceil form on ragged edge


# --- flash attention -------------------------------------------------------

@pytest.mark.parametrize("cfg", [(4, 128, 128, 64), (2, 256, 256, 32),
                                 (3, 128, 384, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(cfg, causal):
    bh, sq, sk, d = cfg
    q = jnp.asarray(RNG.randn(bh, sq, d), jnp.float32)
    k = jnp.asarray(RNG.randn(bh, sk, d), jnp.float32)
    v = jnp.asarray(RNG.randn(bh, sk, d), jnp.float32)
    y = np.asarray(ops.attention(q, k, v, causal=causal))
    r = np.asarray(ref.flash_attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(y, r, atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.randn(2, 128, 64), jnp.bfloat16)
    k = jnp.asarray(RNG.randn(2, 128, 64), jnp.bfloat16)
    v = jnp.asarray(RNG.randn(2, 128, 64), jnp.bfloat16)
    y = np.asarray(ops.attention(q, k, v), np.float32)
    r = np.asarray(ref.flash_attention_ref(q, k, v), np.float32)
    np.testing.assert_allclose(y, r, atol=5e-2, rtol=5e-2)


def test_mha_flash_gqa():
    from repro.kernels.flash_attention import mha_flash
    q = jnp.asarray(RNG.randn(2, 128, 8, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(2, 128, 2, 32), jnp.float32)
    v = jnp.asarray(RNG.randn(2, 128, 2, 32), jnp.float32)
    y = mha_flash(q, k, v, interpret=True)
    from repro.models.attention import attention as jax_attn
    r = jax_attn(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=2e-4, rtol=1e-3)


# --- ssd chunk kernel ------------------------------------------------------

def test_ssd_chunk_matches_oracle():
    from repro.kernels.ssd_chunk import ssd_chunk
    B, S, H, P, N = 2, 128, 4, 16, 8
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1 + 0.05, jnp.float32)
    a_log = jnp.asarray(RNG.randn(H) * 0.3, jnp.float32)
    b = jnp.asarray(RNG.randn(B, S, H, N) * 0.3, jnp.float32)
    c = jnp.asarray(RNG.randn(B, S, H, N) * 0.3, jnp.float32)
    y, s = ssd_chunk(x, dt, a_log, b, c, chunk=S, interpret=True)
    r = ref.ssd_intra_chunk_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=1e-4)
    # chunked intra parts match per-chunk oracle
    y2, s2 = ssd_chunk(x, dt, a_log, b, c, chunk=32, interpret=True)
    for i in range(S // 32):
        sl = slice(32 * i, 32 * (i + 1))
        ri = ref.ssd_intra_chunk_ref(x[:, sl], dt[:, sl], a_log,
                                     b[:, sl], c[:, sl])
        np.testing.assert_allclose(np.asarray(y2[:, sl]), np.asarray(ri),
                                   atol=1e-4)
    assert s2.shape == (B, S // 32, H, P, N)
