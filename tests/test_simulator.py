"""CIM simulator behaviours the paper reports (directional claims)."""
import pytest

from repro.core import ArrayConfig, MacroGrid, grid_search, map_net, networks
from repro.core.simulator import TechConfig, chip_area, macro_area, simulate

ARR = ArrayConfig(512, 512)


def _sim(net, alg, **kw):
    return simulate(map_net(net, networks.NETWORKS[net](), ARR, alg, **kw))


def test_tetrisg_beats_vwc_on_all_networks():
    """Fig 17 direction: lower latency AND energy for every benchmark."""
    for net in ("cnn8", "inception", "densenet40"):
        kw = {"groups": (1, 2)} if net != "cnn8" else {}
        g = _sim(net, "TetrisG-SDK", **kw)
        v = _sim(net, "VWC-SDK")
        assert g.latency_s < v.latency_s, net
        assert g.energy_j < v.energy_j, net
        assert g.edap < v.edap, net


def test_img2col_worst_edap():
    for net in ("cnn8", "inception"):
        i = _sim(net, "img2col")
        g = _sim(net, "TetrisG-SDK")
        assert g.edap < i.edap


def test_area_scales_with_budget():
    t = TechConfig()
    a1 = chip_area(ARR, MacroGrid(1, 1), t)
    a8 = chip_area(ARR, MacroGrid(4, 2), t)
    # constant terms (global buffer, misc) dilute the per-macro scaling
    assert 4 * a1 < a8 < 8.5 * a1


def test_power_gating_fig20():
    """SIV-E: under the same macro budget, grouping reduces EDAP via
    fewer cycles and fewer *active* macros."""
    arr = ArrayConfig(64, 64)
    ls = networks.cnn8()
    for p in (4, 8):
        g = grid_search("cnn8", ls, arr, p_max=p,
                        algorithm="TetrisG-SDK", groups=(1, 2, 4))
        t = grid_search("cnn8", ls, arr, p_max=p,
                        algorithm="Tetris-SDK")
        sg, st_ = simulate(g.best), simulate(t.best)
        assert sg.edap < st_.edap
        reduction = 1 - sg.edap / st_.edap
        assert reduction > 0.3          # paper reports 36-70 %


def test_energy_breakdown_positive():
    m = _sim("cnn8", "Tetris-SDK")
    for l in m.layers:
        for k in ("array", "adc", "accum", "buffer", "interconnect"):
            assert l.breakdown[k] > 0
