"""CIM simulator behaviours the paper reports (directional claims)."""
import dataclasses

import pytest

from repro.core import (ArrayConfig, ConvLayerSpec, MacroGrid, grid_search,
                        map_layer, map_net, networks)
from repro.core.simulator import (TechConfig, chip_area, simulate,
                                  simulate_layer)

ARR = ArrayConfig(512, 512)


def _sim(net, alg, **kw):
    return simulate(map_net(net, networks.NETWORKS[net](), ARR, alg, **kw))


def test_tetrisg_beats_vwc_on_all_networks():
    """Fig 17 direction: lower latency AND energy for every benchmark."""
    for net in ("cnn8", "inception", "densenet40"):
        kw = {"groups": (1, 2)} if net != "cnn8" else {}
        g = _sim(net, "TetrisG-SDK", **kw)
        v = _sim(net, "VWC-SDK")
        assert g.latency_s < v.latency_s, net
        assert g.energy_j < v.energy_j, net
        assert g.edap < v.edap, net


def test_img2col_worst_edap():
    for net in ("cnn8", "inception"):
        i = _sim(net, "img2col")
        g = _sim(net, "TetrisG-SDK")
        assert g.edap < i.edap


def test_area_scales_with_budget():
    t = TechConfig()
    a1 = chip_area(ARR, MacroGrid(1, 1), t)
    a8 = chip_area(ARR, MacroGrid(4, 2), t)
    # constant terms (global buffer, misc) dilute the per-macro scaling
    assert 4 * a1 < a8 < 8.5 * a1


def test_power_gating_fig20():
    """SIV-E: under the same macro budget, grouping reduces EDAP via
    fewer cycles and fewer *active* macros."""
    arr = ArrayConfig(64, 64)
    ls = networks.cnn8()
    for p in (4, 8):
        g = grid_search("cnn8", ls, arr, p_max=p,
                        algorithm="TetrisG-SDK", groups=(1, 2, 4))
        t = grid_search("cnn8", ls, arr, p_max=p,
                        algorithm="Tetris-SDK")
        sg, st_ = simulate(g.best), simulate(t.best)
        assert sg.edap < st_.edap
        reduction = 1 - sg.edap / st_.edap
        assert reduction > 0.3          # paper reports 36-70 %


def test_energy_breakdown_positive():
    m = _sim("cnn8", "Tetris-SDK")
    for ly in m.layers:
        for k in ("array", "adc", "accum", "buffer", "interconnect"):
            assert ly.breakdown[k] > 0


def test_simulate_layer_grouped_scaling():
    """Grouped-mapping regression (the sub_r/sub_c hoist must not change
    semantics): every energy term is linear in ``m.group``; the array
    latency is linear in ``seq_groups`` (parallel groups on disjoint
    sub-grids are free); breakdown keys sum to the reported totals."""
    tech = TechConfig()
    grid = MacroGrid(2, 2)
    base = map_layer(ConvLayerSpec("g", 18, 18, 3, 3, 32, 32),
                     ArrayConfig(64, 64), "Tetris-SDK", grid)

    def sim(**kw):
        return simulate_layer(dataclasses.replace(base, **kw), tech)

    one = sim(group=1, group_split=(1, 1))
    two = sim(group=2, group_split=(1, 1))
    assert two.energy_j == pytest.approx(2 * one.energy_j, rel=1e-12)
    # latency: the array term scales with seq_groups (= group here), the
    # IFM/OFM buffer+interconnect staging term is per-inference
    assert two.breakdown["lat_array"] == pytest.approx(
        2 * one.breakdown["lat_array"], rel=1e-12)
    assert two.breakdown["lat_buffer"] == pytest.approx(
        one.breakdown["lat_buffer"], rel=1e-12)

    # 4 groups fully parallel on (2,2) disjoint sub-grids: seq_groups=1,
    # so array latency stays put while energy still scales 4x vs the
    # same-sub-grid single group
    par1 = sim(group=1, group_split=(2, 2))
    par4 = sim(group=4, group_split=(2, 2))
    seq8 = sim(group=8, group_split=(2, 2))
    assert par4.energy_j == pytest.approx(4 * par1.energy_j, rel=1e-12)
    assert par4.breakdown["lat_array"] == pytest.approx(
        par1.breakdown["lat_array"], rel=1e-12)
    assert seq8.breakdown["lat_array"] == pytest.approx(
        2 * par4.breakdown["lat_array"], rel=1e-12)

    for m in (one, two, par4, seq8):
        assert sum(m.breakdown[k] for k in
                   ("array", "adc", "accum", "buffer", "interconnect")
                   ) == pytest.approx(m.energy_j, rel=1e-12)
        assert m.breakdown["lat_array"] + m.breakdown["lat_buffer"] == \
            pytest.approx(m.latency_s, rel=1e-12)
