"""Dynamic batching (launch/batching.py): coalescer semantics under a
fake clock, the tier ladder, per-tier stats, the donation input ring,
and the arrival-driven serve loop with deterministic virtual time."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.launch.batching import (Coalescer, DynamicServeStats, InputRing,
                                   PlanLadder, TierStats, batch_tiers,
                                   percentile, tier_for)


class _FakeMesh:
    """Just enough mesh for pad_to_data_axis/data_axis_size."""

    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def _small_net(n_layers=2, grid=MacroGrid(2, 2)):
    return map_net("cnn8", networks.cnn8()[:n_layers], ArrayConfig(64, 64),
                   "Tetris-SDK", grid)


# ---------------------------------------------------------------------------
# Coalescer (fake clock: explicit `now` everywhere)
# ---------------------------------------------------------------------------

def test_coalescer_max_batch_trigger():
    """Reaching max_batch rows makes the queue ready immediately — no
    delay has to expire."""
    co = Coalescer(max_batch=4, max_delay_s=10.0)
    co.push(2, now=0.0)
    co.push(1, now=0.0)
    assert len(co) == 3 and not co.ready(0.0)
    co.push(1, now=0.0)
    assert co.ready(0.0)
    batch = co.pop(0.0)
    assert [r.rows for r in batch] == [2, 1, 1]
    assert len(co) == 0


def test_coalescer_max_delay_expiry():
    """A lone small request is served once the OLDEST arrival is
    max_delay old, not before."""
    co = Coalescer(max_batch=8, max_delay_s=0.005)
    co.push(1, now=1.000)
    assert co.next_deadline() == pytest.approx(1.005)
    assert not co.ready(1.0049) and co.pop(1.0049) == []
    assert co.ready(1.005)
    co.push(2, now=1.005)             # younger request rides along
    batch = co.pop(1.005)
    assert [r.rows for r in batch] == [1, 2]


def test_coalescer_never_splits_requests():
    """Requests are whole units: the drain stops before overflowing
    max_batch, and an oversized request is refused at push."""
    co = Coalescer(max_batch=4, max_delay_s=0.0)
    co.push(3, now=0.0)
    co.push(2, now=0.0)               # 3 + 2 > 4: must wait its turn
    batch = co.pop(0.0)
    assert [r.rows for r in batch] == [3]
    assert len(co) == 2
    assert [r.rows for r in co.pop(0.0)] == [2]
    with pytest.raises(ValueError, match="never split"):
        co.push(5, now=0.0)
    with pytest.raises(ValueError, match=">= 1 row"):
        co.push(0, now=0.0)


def test_coalescer_empty_queue_drain():
    """An empty queue drains to [] — force included — and has no
    deadline; pop(force=True) ignores an unexpired delay otherwise."""
    co = Coalescer(max_batch=4, max_delay_s=5.0)
    assert co.pop(0.0) == [] and co.pop(0.0, force=True) == []
    assert co.next_deadline() is None
    co.push(1, now=0.0)
    assert co.pop(0.001) == []            # delay not expired
    assert [r.rows for r in co.pop(0.001, force=True)] == [1]


def test_coalescer_validates_config():
    with pytest.raises(ValueError, match="max_batch"):
        Coalescer(0, 1.0)
    with pytest.raises(ValueError, match="max_delay_s"):
        Coalescer(1, -0.1)


def test_coalescer_payload_round_trip():
    co = Coalescer(max_batch=2, max_delay_s=0.0)
    co.push(1, now=0.0, payload="imgs")
    assert co.pop(0.0)[0].payload == "imgs"


# ---------------------------------------------------------------------------
# Tier ladder
# ---------------------------------------------------------------------------

def test_batch_tiers_powers_of_two():
    assert batch_tiers(1) == (1,)
    assert batch_tiers(8) == (1, 2, 4, 8)
    assert batch_tiers(6) == (1, 2, 4, 6)    # top tier covers max_batch
    with pytest.raises(ValueError, match="max_batch"):
        batch_tiers(0)


def test_batch_tiers_pad_to_mesh_data_axis():
    """Every tier is a multiple of the shared serving mesh's data axis
    (pad_to_data_axis), deduplicated ascending."""
    mesh = _FakeMesh(data=2, row=2, col=2)
    assert batch_tiers(8, mesh) == (2, 4, 8)
    assert batch_tiers(6, mesh) == (2, 4, 6)
    assert batch_tiers(3, mesh) == (2, 4)    # 3 pads to 4 on data=2


def test_tier_for_selects_smallest_fit():
    tiers = (1, 2, 4, 8)
    assert [tier_for(r, tiers) for r in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    with pytest.raises(ValueError, match="exceed"):
        tier_for(9, tiers)


def test_plan_ladder_shares_mesh_and_compiles_each_tier_once():
    """Each tier compiles exactly once per process (memo.cached_plan);
    rebuilding the ladder is pure cache hits — the compile counters in
    exec/plan.py are the evidence."""
    from repro.exec import compile_counts
    memo.clear()
    net = _small_net()
    ladder = PlanLadder(net, (1, 2, 4))
    assert ladder.tiers == (1, 2, 4) and ladder.max_batch == 4
    for t in ladder.tiers:
        assert ladder.plans[t].batch == t
    counts = compile_counts(net=net)
    assert len(counts) == 3 and set(counts.values()) == {1}
    again = PlanLadder(net, (1, 2, 4))
    assert compile_counts(net=net) == counts      # no recompiles
    assert all(again.plans[t] is ladder.plans[t] for t in ladder.tiers)
    t, plan = ladder.plan_for(3)
    assert t == 4 and plan.batch == 4
    with pytest.raises(ValueError, match="at least one tier"):
        PlanLadder(net, ())
    with pytest.raises(ValueError, match="data axis"):
        PlanLadder(net, (3,), mesh=_FakeMesh(data=2, row=1, col=1))


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 95) == 5.0
    assert percentile(xs, 100) == 5.0
    assert percentile([7.0], 99) == 7.0
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match="q must be"):
        percentile(xs, 101)


def test_percentile_matches_numpy_inverted_cdf():
    """The pure-Python nearest-rank percentile is exactly numpy's
    ``method="inverted_cdf"`` — random inputs across sizes, the full
    q sweep including the q=0 / q=100 / singleton edges."""
    rng = np.random.RandomState(11)
    qs = [0, 1, 25, 50, 75, 90, 95, 99, 100]
    for n in [1, 2, 3, 5, 8, 17, 100]:
        xs = rng.randn(n).tolist()
        for q in qs + [float(rng.uniform(0, 100)) for _ in range(5)]:
            expect = float(np.percentile(xs, q, method="inverted_cdf"))
            assert percentile(xs, q) == expect, (n, q)
    assert percentile([4.0], 0) == 4.0 == percentile([4.0], 100)
    xs = [3.0, 1.0, 2.0]
    assert percentile(xs, 0) == float(
        np.percentile(xs, 0, method="inverted_cdf")) == 1.0
    assert percentile(xs, 100) == float(
        np.percentile(xs, 100, method="inverted_cdf")) == 3.0


def test_tier_stats_effective_vs_padded_and_delays():
    ts = TierStats(plan_batch=4)
    co = Coalescer(4, 0.0)
    co.push(2, now=0.0)
    co.push(1, now=0.5)
    ts.record(co.pop(1.0, force=True), launch_s=1.0, exec_s=0.25)
    assert ts.batches == 1 and ts.request_images == 3
    assert ts.padded_images == 4 and ts.exec_s == 0.25
    assert ts.delays_s == [1.0, 0.5]
    assert ts.delay_ms(50) == pytest.approx(500.0)
    s = DynamicServeStats(tiers={4: ts}, request_images=3, padded_images=4,
                          wall_s=0.5, warmup_steps=2)
    assert s.images_per_s == pytest.approx(6.0)
    assert s.padded_images_per_s == pytest.approx(8.0)
    assert "tier 4" in s.describe() and "warmup_steps=2" in s.describe()


# ---------------------------------------------------------------------------
# Input ring (donation)
# ---------------------------------------------------------------------------

def test_input_ring_without_donation_reuses_one_buffer():
    x = np.ones((2, 3), np.float32)
    ring = InputRing(x, donate=False)
    a, b = ring.next(), ring.next()
    assert a is b                        # no per-step upload
    assert bool(jnp.all(a == 1))


def test_input_ring_with_donation_hands_fresh_buffers():
    """Under donation every step must receive a buffer the program may
    consume: successive next() calls return distinct live buffers with
    identical contents."""
    x = np.arange(6, dtype=np.float32).reshape(2, 3)
    ring = InputRing(x, donate=True)
    a, b, c = ring.next(), ring.next(), ring.next()
    assert a is not b and b is not c
    for buf in (a, b, c):
        np.testing.assert_array_equal(np.asarray(buf), x)


# ---------------------------------------------------------------------------
# serve_dynamic under a virtual clock
# ---------------------------------------------------------------------------

class _VirtualClock:
    """Deterministic time for the serve loop: only sleep() advances."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, dt):
        assert dt > 0
        self.sleeps.append(dt)
        self.t += dt


def test_serve_dynamic_virtual_time_coalescing():
    """Deterministic end-to-end: two early arrivals coalesce at the
    max-delay deadline, the straggler is force-drained once no future
    arrival can grow the batch, delays are measured from scheduled
    arrival to batch launch."""
    from repro.launch import serve_cnn
    net = _small_net()
    clk = _VirtualClock()
    reqs = [(0.0, 1), (0.001, 2), (0.010, 3)]
    s = serve_cnn.serve_dynamic(
        net, reqs, max_batch=4, max_delay_ms=5.0, warmup=1,
        clock=clk, sleep=clk.sleep)
    assert s.warmup_steps == len(batch_tiers(4))     # once per tier
    assert s.request_images == 6
    t4 = s.tiers[4]
    assert t4.batches == 2 and t4.request_images == 6
    assert t4.padded_images == 8                     # 2 batches of tier 4
    assert s.tiers[1].batches == s.tiers[2].batches == 0
    # batch 1: requests at 0.000 + 0.001 launched at the 5ms deadline
    # batch 2: request at 0.010 force-drained on arrival (queue empty)
    assert sorted(t4.delays_s) == pytest.approx([0.0, 0.004, 0.005])
    assert s.images_per_s > 0 and s.padded_images_per_s > 0


def test_serve_dynamic_honors_warmup_zero():
    from repro.launch import serve_cnn
    net = _small_net()
    clk = _VirtualClock()
    s = serve_cnn.serve_dynamic(net, [(0.0, 2)], max_batch=2,
                                max_delay_ms=0.0, warmup=0,
                                clock=clk, sleep=clk.sleep)
    assert s.warmup_steps == 0
    assert s.request_images == 2
    with pytest.raises(ValueError, match="warmup"):
        serve_cnn.serve_dynamic(net, [(0.0, 1)], max_batch=2,
                                max_delay_ms=1.0, warmup=-1)
    with pytest.raises(ValueError, match="never split"):
        serve_cnn.serve_dynamic(net, [(0.0, 5)], max_batch=2,
                                max_delay_ms=1.0)
    with pytest.raises(ValueError, match="do not cover"):
        # explicit tiers must reach max_batch: a full coalesced batch
        # would otherwise have no plan to run on
        serve_cnn.serve_dynamic(net, [(0.0, 1)], max_batch=4,
                                max_delay_ms=1.0, tiers=(1, 2))


def test_poisson_arrivals_schedule():
    from repro.launch.serve_cnn import poisson_arrivals
    reqs = poisson_arrivals(16, rate_per_s=100.0, max_rows=3, seed=1)
    times = [t for t, _ in reqs]
    rows = [r for _, r in reqs]
    assert len(reqs) == 16 and times[0] == 0.0
    assert times == sorted(times)
    assert all(1 <= r <= 3 for r in rows) and len(set(rows)) > 1
    backlog = poisson_arrivals(4, rate_per_s=0.0, max_rows=2, seed=0)
    assert all(t == 0.0 for t, _ in backlog)
    with pytest.raises(ValueError, match="request"):
        poisson_arrivals(0, 1.0, 1)


# ---------------------------------------------------------------------------
# Donation gating (exec/run.py satellite)
# ---------------------------------------------------------------------------

class _Dev:
    def __init__(self, platform):
        self.platform = platform


class _PlatformMesh:
    def __init__(self, *platforms):
        self.devices = np.array([_Dev(p) for p in platforms])


def test_donation_gates_on_mesh_platform_not_default_backend():
    """The plan's mesh may live on a different platform than
    jax.default_backend(): donation keys on the mesh's devices."""
    from repro.exec import donation_supported
    from repro.launch.mesh import mesh_platform
    assert mesh_platform(None) is None
    assert mesh_platform(_PlatformMesh("cpu", "cpu")) == "cpu"
    assert mesh_platform(_PlatformMesh("tpu", "tpu")) == "tpu"
    assert mesh_platform(_PlatformMesh("tpu", "cpu")) == "mixed"
    assert not donation_supported(_PlatformMesh("cpu", "cpu"))
    assert donation_supported(_PlatformMesh("tpu", "tpu"))
    assert donation_supported(_PlatformMesh("gpu", "gpu"))
    assert not donation_supported(_PlatformMesh("tpu", "cpu"))  # mixed
    # no mesh: fall back to the default backend (CPU in CI)
    assert donation_supported(None) == (jax.default_backend() != "cpu")


def test_execute_plan_donate_falls_back_cleanly_on_cpu():
    """donate=True on a CPU mesh/backend must not donate (XLA has no
    CPU donation): the input stays live and results match exactly."""
    from repro.cnn.mapped_net import zero_pruned_kernels
    from repro.exec import compile_plan, execute_plan
    net = _small_net()
    rng = np.random.RandomState(3)
    ks = zero_pruned_kernels(net, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net.layers])
    first = net.layers[0].layer
    x = jnp.asarray(rng.randn(2, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    plan = compile_plan(net, executor_policy="mapped")
    y_plain = execute_plan(plan, ks, x)
    y_donate = execute_plan(plan, ks, x, donate=True)
    assert bool(jnp.all(y_plain == y_donate))
    assert bool(jnp.all(x == x + 0))     # buffer not consumed on CPU


# ---------------------------------------------------------------------------
# Adaptive delay (load-proportional coalescing) + pooled percentiles
# ---------------------------------------------------------------------------

def test_adaptive_delay_scales_with_queue_depth():
    """The policy interpolates linearly: empty queue waits the full
    cap, a queue at/above ref_rows drains immediately."""
    from repro.launch.batching import AdaptiveDelay
    pol = AdaptiveDelay(max_delay_s=0.010, ref_rows=8)
    assert pol(0) == pytest.approx(0.010)
    assert pol(4) == pytest.approx(0.005)
    assert pol(8) == 0.0
    assert pol(100) == 0.0               # clamped, never negative
    with pytest.raises(ValueError, match="ref_rows"):
        AdaptiveDelay(0.01, 0)
    with pytest.raises(ValueError, match="max_delay_s"):
        AdaptiveDelay(-1.0, 4)


def test_coalescer_adaptive_delay_moves_deadline_earlier():
    """With a delay policy the deadline is re-derived from LIVE queue
    depth on every call: the same oldest arrival expires sooner as the
    backlog deepens — and a deep backlog becomes ready immediately."""
    from repro.launch.batching import AdaptiveDelay
    co = Coalescer(max_batch=8, max_delay_s=0.010,
                   delay_policy=AdaptiveDelay(0.010, ref_rows=8))
    co.push(1, now=0.0)
    # 1 queued row of 8: deadline ~ 0 + 10ms * (1 - 1/8)
    assert co.next_deadline() == pytest.approx(0.010 * 7 / 8)
    co.push(3, now=0.001)                # depth 4 -> delay halves
    assert co.next_deadline() == pytest.approx(0.010 * 4 / 8)
    assert not co.ready(0.004)
    assert co.ready(0.005)
    co2 = Coalescer(max_batch=8, max_delay_s=0.010,
                    delay_policy=AdaptiveDelay(0.010, ref_rows=4))
    co2.push(2, now=0.0)
    co2.push(2, now=0.0)                 # depth == ref_rows: drain now
    assert co2.effective_delay_s() == 0.0
    assert co2.ready(0.0)
    assert [r.rows for r in co2.pop(0.0)] == [2, 2]


def test_coalescer_delay_policy_clamped_by_max_delay():
    """A policy may never extend the wait beyond the configured cap
    (or below zero) — the cap is the latency contract."""
    co = Coalescer(max_batch=8, max_delay_s=0.010,
                   delay_policy=lambda rows: 99.0)
    co.push(1, now=0.0)
    assert co.effective_delay_s() == pytest.approx(0.010)
    assert co.next_deadline() == pytest.approx(0.010)
    co_neg = Coalescer(max_batch=8, max_delay_s=0.010,
                       delay_policy=lambda rows: -5.0)
    co_neg.push(1, now=0.0)
    assert co_neg.effective_delay_s() == 0.0
    assert co_neg.ready(0.0)


def test_serve_dynamic_adaptive_delay_virtual_time():
    """End-to-end through serve_dynamic on a virtual clock: with load
    queued, the adaptive policy launches earlier than the fixed one
    (50ms * (1 - 3/8) vs the full 50ms cap), so the pooled queue-delay
    p50 shrinks; the trailing arrival force-drains either way."""
    from repro.launch.serve_cnn import serve_dynamic

    def virtual(adaptive):
        t = [0.0]

        def clock():
            return t[0]

        def sleep(dt):
            t[0] += dt
        net = _small_net()
        # 3 early singles never fill max_batch=8: the fixed policy
        # holds them until the last arrival force-drains the queue at
        # t=45ms, the adaptive one serves them at 50ms * (1 - 3/8)
        reqs = [(0.0, 1)] * 3 + [(0.045, 1)]
        s = serve_dynamic(net, reqs, max_batch=8, max_delay_ms=50.0,
                          mesh=None, warmup=1, adaptive_delay=adaptive,
                          clock=clock, sleep=sleep)
        assert s.request_images == 4
        return s

    fast, slow = virtual(True), virtual(False)
    assert fast.delay_ms(50) == pytest.approx(50.0 * (1 - 3 / 8))
    assert slow.delay_ms(50) == pytest.approx(45.0)
    assert fast.delay_ms(50) < slow.delay_ms(50)


def test_dynamic_stats_pooled_percentiles_match_numpy():
    """Aggregate queue-delay percentiles pool ALL per-tier samples and
    match numpy on the pooled vector — never the average of per-tier
    percentiles, which is a different (wrong) number here."""
    t1 = TierStats(plan_batch=1)
    t1.delays_s = [0.001, 0.002, 0.003, 0.100]
    t4 = TierStats(plan_batch=4)
    t4.delays_s = [0.004, 0.005, 0.200, 0.300, 0.400]
    s = DynamicServeStats(tiers={1: t1, 4: t4}, request_images=9,
                          padded_images=17, wall_s=1.0, warmup_steps=0)
    pooled = t1.delays_s + t4.delays_s
    for q in (50, 95, 99):
        expect = float(np.percentile(pooled, q,
                                     method="inverted_cdf")) * 1e3
        assert s.delay_ms(q) == pytest.approx(expect)
        avg_of_percentiles = (t1.delay_ms(q) + t4.delay_ms(q)) / 2
        assert s.delay_ms(q) != pytest.approx(avg_of_percentiles)
    assert "pooled" in s.describe()


def test_fleet_stats_pooled_percentiles_match_numpy():
    """FleetStats.delay_ms pools per-model samples the same way."""
    from repro.launch.fleet import FleetStats, ModelStats
    ma = ModelStats(name="a", slo_ms=None)
    ma.tiers[1] = TierStats(plan_batch=1)
    ma.tiers[1].delays_s = [0.010, 0.020, 0.030]
    mb = ModelStats(name="b", slo_ms=None)
    mb.tiers[2] = TierStats(plan_batch=2)
    mb.tiers[2].delays_s = [0.001, 0.002, 0.500, 0.600]
    fs = FleetStats(models={"a": ma, "b": mb}, wall_s=1.0,
                    warmup_steps=0, shared_constants=True)
    pooled = ma.tiers[1].delays_s + mb.tiers[2].delays_s
    for q in (50, 95, 99):
        expect = float(np.percentile(pooled, q,
                                     method="inverted_cdf")) * 1e3
        assert fs.delay_ms(q) == pytest.approx(expect)
    assert "pooled" in fs.describe()
