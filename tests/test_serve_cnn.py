"""CNN serving driver (launch/serve_cnn.py): maps with the persistent
cache, serves batches through executor="mapped", reports images/s."""
import jax
import pytest

from repro.core import ArrayConfig, MacroGrid, memo
from repro.launch import serve_cnn


def test_serve_cnn_reports_images_per_s(capsys, tmp_path):
    """End-to-end acceptance: the driver maps CNN8 (populating the disk
    cache), runs batched mapped-executor steps, and reports images/s."""
    memo.clear()
    try:
        serve_cnn.main(["--net", "cnn8", "--batch", "2", "--steps", "2",
                        "--warmup", "1", "--grid", "2x2",
                        "--cache-dir", str(tmp_path)])
    finally:
        memo.set_disk_cache(None)
        memo.clear()
    out = capsys.readouterr().out
    assert "images/s" in out and "executor=mapped" in out
    assert "serve/cnn8/b2," in out            # harness CSV row
    assert list(tmp_path.glob("*.mapping.pkl"))   # cache populated


def test_map_for_serving_grid_and_budget_paths():
    m_grid, _ = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "Tetris-SDK", grid=MacroGrid(2, 1))
    assert m_grid.grid == MacroGrid(2, 1)
    m_sweep, secs = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "TetrisG-SDK", p_max=2)
    assert m_sweep.grid.p <= 2 and secs > 0


def test_serving_mesh_for_single_device():
    """On one device the driver falls back to the vmap path (mesh None)
    rather than a degenerate 1x1 shard_map."""
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(2, 2))
    if len(jax.devices()) == 1:
        assert serve_cnn.serving_mesh_for(m, batch=4) is None


def test_serve_returns_effective_and_padded_rates():
    """Without a data mesh the request batch needs no padding: plan
    batch == request batch and both rates agree."""
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(1, 1))
    s = serve_cnn.serve(m, batch=2, steps=1, warmup=1, mesh=None)
    assert s.plan_batch == s.request_batch == 2
    assert s.images_per_s == s.padded_images_per_s > 0
    assert s.plan.host_dispatches == 1       # one fused program per step
    assert s.warmup_steps == 1
    assert not s.donated                     # CPU: no donation


def test_serve_honors_warmup_zero(monkeypatch):
    """Regression: serve(warmup=0) used to run max(1, warmup) warmup
    steps — 0 must mean 0 (timing then includes compile) and the actual
    count surfaces in ServeStats.warmup_steps."""
    import repro.exec as exec_mod
    calls = []
    real = exec_mod.execute_plan

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(exec_mod, "execute_plan", counting)
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(1, 1))
    s = serve_cnn.serve(m, batch=1, steps=2, warmup=0, mesh=None)
    assert s.warmup_steps == 0
    assert len(calls) == 2               # exactly the timed steps
    calls.clear()
    s = serve_cnn.serve(m, batch=1, steps=2, warmup=3, mesh=None)
    assert s.warmup_steps == 3 and len(calls) == 5
    with pytest.raises(ValueError, match="warmup"):
        serve_cnn.serve(m, batch=1, steps=1, warmup=-1)


def _parse_kv(row: str) -> dict:
    return dict(kv.split("=") for kv in row.strip().split(",")[-1].split(";")
                if "=" in kv)


def test_main_search_stats_snapshot_regression(capsys, tmp_path):
    """Regression (memo.stats aliasing): the final CSV row must report
    the SEARCH-phase counters, not the live dict after serve() — plan
    compilation during serving hits the disk cache and used to leak
    into the reported search stats."""
    args = ["--net", "cnn8", "--batch", "2", "--steps", "1",
            "--warmup", "1", "--grid", "2x2", "--cache-dir",
            str(tmp_path)]
    memo.clear()
    try:
        serve_cnn.main(args)              # cold: populate mapping + plan
        memo.clear()                      # drop in-memory, keep disk
        capsys.readouterr()
        serve_cnn.main(args)              # warm: search AND plan disk-hit
        out = capsys.readouterr().out
        live_hits = memo.stats["disk_hits"]
    finally:
        memo.set_disk_cache(None)
        memo.clear()
    search_line = next(ln for ln in out.splitlines() if "search=" in ln)
    search_hits = int(search_line.split("disk_hits=")[1].split(" ")[0])
    csv = _parse_kv(next(ln for ln in out.splitlines()
                         if ln.startswith("serve/cnn8/")))
    assert int(csv["disk_hits"]) == search_hits
    assert int(csv["table_builds"]) == 0      # warm search: no builds
    # the plan load DID hit the disk after the snapshot — the live dict
    # would have reported more (this is what the old code leaked)
    assert live_hits > search_hits


def test_main_dynamic_batching_cli(capsys, tmp_path):
    """Dynamic mode end-to-end: --max-delay-ms drives the coalescer +
    tier ladder; per-tier and aggregate CSV rows come out, and every
    tier's plan compiled exactly once."""
    memo.clear()
    try:
        serve_cnn.main(["--net", "cnn8", "--grid", "2x2",
                        "--max-delay-ms", "1", "--max-batch", "4",
                        "--requests", "8", "--warmup", "1",
                        "--cache-dir", str(tmp_path)])
    finally:
        memo.set_disk_cache(None)
        memo.clear()
    out = capsys.readouterr().out
    assert "queue-delay p50=" in out
    agg = _parse_kv(next(ln for ln in out.splitlines()
                         if ln.startswith("serve_dyn/cnn8/all,")))
    assert agg["tiers"] == "1/2/4"
    assert int(agg["plan_compiles"]) == 3      # once per tier
    assert float(agg["images_per_s"]) > 0
    assert float(agg["padded_images_per_s"]) >= float(agg["images_per_s"])
    assert any(ln.startswith("serve_dyn/cnn8/tier") for ln
               in out.splitlines())


def test_dynamic_effective_rate_beats_fixed_ragged():
    """ISSUE 5 acceptance: on the same backlogged ragged stream the
    dynamic coalescer's effective images/s must be >= the fixed-batch
    driver's (interleaved medians, benchmarks/serve_bench.py)."""
    from benchmarks import serve_bench
    rows = serve_bench.run(full=False)
    by_name = {r.name: _parse_kv(r.csv()) for r in rows}
    fixed = float(by_name["serve_dyn/cnn8/fixed-ragged"]["images_per_s"])
    dyn = float(by_name["serve_dyn/cnn8/dynamic"]["images_per_s"])
    assert dyn >= fixed, (dyn, fixed)


def test_pad_to_data_axis():
    from repro.launch.mesh import data_axis_size, pad_to_data_axis

    class _FakeMesh:
        def __init__(self, **shape):
            self.axis_names = tuple(shape)
            self.shape = dict(shape)

    assert pad_to_data_axis(3, None) == 3
    plain = _FakeMesh(row=2, col=2)
    assert data_axis_size(plain) == 1 and pad_to_data_axis(3, plain) == 3
    data = _FakeMesh(data=2, row=2, col=2)
    assert data_axis_size(data) == 2
    assert pad_to_data_axis(3, data) == 4
    assert pad_to_data_axis(4, data) == 4
    assert pad_to_data_axis(1, data) == 2


def test_serve_ragged_batch_pads_and_masks():
    """Tentpole/satellite contract on 8 forced host devices: a request
    batch of 3 does NOT divide the serving mesh's data axis (2) — the
    driver pads to the plan batch (4), serves through the mesh, masks
    the padded row, and the 3 real outputs are bit-identical to the
    single-device vmap plan.  Pad-and-mask isolation is total: garbage
    in the padded row leaves the request rows bit-identical, and the
    masked loss's input gradient matches the vmap plan on the request
    rows with an exactly-zero gradient on the padded row."""
    import os
    import subprocess
    import sys
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import compile_plan, execute_plan
from repro.launch import serve_cnn
from repro.launch.mesh import pad_to_data_axis, serving_mesh_for
assert len(jax.devices()) == 8
net = map_net("cnn8", networks.cnn8()[:3], ArrayConfig(64, 64),
              "Tetris-SDK", MacroGrid(2, 2))
mesh = serving_mesh_for(net, 3)
assert dict(mesh.shape) == {"data": 2, "row": 2, "col": 2}, dict(mesh.shape)
assert pad_to_data_axis(3, mesh) == 4
s = serve_cnn.serve(net, batch=3, steps=1, warmup=1, mesh=mesh)
assert s.request_batch == 3 and s.plan_batch == 4
assert abs(s.padded_images_per_s / s.images_per_s - 4 / 3) < 1e-6
# masked outputs == vmap plan on the same 3 images
rng = np.random.RandomState(0)
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                          m.layer.ic // m.group, m.layer.oc) * 0.2,
                jnp.float32) for m in net.layers])
first = net.layers[0].layer
x3 = jnp.asarray(rng.randn(3, first.ic, first.i_h, first.i_w), jnp.float32)
x4 = jnp.pad(x3, ((0, 1), (0, 0), (0, 0), (0, 0)))
plan = compile_plan(net, executor_policy="mapped", mesh=mesh, batch=4)
y = execute_plan(plan, ks, x4, mesh=mesh)[:3]
vmap_plan = compile_plan(net, executor_policy="mapped")
y_ref = execute_plan(vmap_plan, ks, x3)
assert bool(jnp.all(y == y_ref)), "masked sharded outputs != vmap"
# isolation: garbage in the padded row must not touch request rows
x4_dirty = x4.at[3].set(7.5)
y_dirty = execute_plan(plan, ks, x4_dirty, mesh=mesh)[:3]
assert bool(jnp.all(y_dirty == y_ref)), "padded row leaked into outputs"
# gradient isolation: masked loss -> request-row grads match the vmap
# plan, padded-row grad exactly zero
g4 = jax.grad(lambda xx: jnp.sum(
    execute_plan(plan, ks, xx, mesh=mesh)[:3] ** 2))(x4)
g3 = jax.grad(lambda xx: jnp.sum(
    execute_plan(vmap_plan, ks, xx) ** 2))(x3)
scale = float(jnp.max(jnp.abs(g3)))
assert float(jnp.max(jnp.abs(g4[:3] - g3))) <= 1e-6 * scale, \
    "request-row grads drift under pad-and-mask"
assert bool(jnp.all(g4[3] == 0)), "padded row has nonzero gradient"
print("RAGGED-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "RAGGED-OK" in out.stdout, out.stderr[-2000:]
