"""CNN serving driver (launch/serve_cnn.py): maps with the persistent
cache, serves batches through executor="mapped", reports images/s."""
import jax

from repro.core import ArrayConfig, MacroGrid, memo
from repro.launch import serve_cnn


def test_serve_cnn_reports_images_per_s(capsys, tmp_path):
    """End-to-end acceptance: the driver maps CNN8 (populating the disk
    cache), runs batched mapped-executor steps, and reports images/s."""
    memo.clear()
    try:
        serve_cnn.main(["--net", "cnn8", "--batch", "2", "--steps", "2",
                        "--warmup", "1", "--grid", "2x2",
                        "--cache-dir", str(tmp_path)])
    finally:
        memo.set_disk_cache(None)
        memo.clear()
    out = capsys.readouterr().out
    assert "images/s" in out and "executor=mapped" in out
    assert "serve/cnn8/b2," in out            # harness CSV row
    assert list(tmp_path.glob("*.mapping.pkl"))   # cache populated


def test_map_for_serving_grid_and_budget_paths():
    m_grid, _ = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "Tetris-SDK", grid=MacroGrid(2, 1))
    assert m_grid.grid == MacroGrid(2, 1)
    m_sweep, secs = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "TetrisG-SDK", p_max=2)
    assert m_sweep.grid.p <= 2 and secs > 0


def test_serving_mesh_for_single_device():
    """On one device the driver falls back to the vmap path (mesh None)
    rather than a degenerate 1x1 shard_map."""
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(2, 2))
    if len(jax.devices()) == 1:
        assert serve_cnn.serving_mesh_for(m, batch=4) is None


def test_serve_returns_effective_and_padded_rates():
    """Without a data mesh the request batch needs no padding: plan
    batch == request batch and both rates agree."""
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(1, 1))
    s = serve_cnn.serve(m, batch=2, steps=1, warmup=1, mesh=None)
    assert s.plan_batch == s.request_batch == 2
    assert s.images_per_s == s.padded_images_per_s > 0
    assert s.plan.host_dispatches == 1       # one fused program per step


def test_pad_to_data_axis():
    from repro.launch.mesh import data_axis_size, pad_to_data_axis

    class _FakeMesh:
        def __init__(self, **shape):
            self.axis_names = tuple(shape)
            self.shape = dict(shape)

    assert pad_to_data_axis(3, None) == 3
    plain = _FakeMesh(row=2, col=2)
    assert data_axis_size(plain) == 1 and pad_to_data_axis(3, plain) == 3
    data = _FakeMesh(data=2, row=2, col=2)
    assert data_axis_size(data) == 2
    assert pad_to_data_axis(3, data) == 4
    assert pad_to_data_axis(4, data) == 4
    assert pad_to_data_axis(1, data) == 2


def test_serve_ragged_batch_pads_and_masks():
    """Tentpole/satellite contract on 8 forced host devices: a request
    batch of 3 does NOT divide the serving mesh's data axis (2) — the
    driver pads to the plan batch (4), serves through the mesh, masks
    the padded row, and the 3 real outputs are bit-identical to the
    single-device vmap plan."""
    import os
    import subprocess
    import sys
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import compile_plan, execute_plan
from repro.launch import serve_cnn
from repro.launch.mesh import pad_to_data_axis, serving_mesh_for
assert len(jax.devices()) == 8
net = map_net("cnn8", networks.cnn8()[:3], ArrayConfig(64, 64),
              "Tetris-SDK", MacroGrid(2, 2))
mesh = serving_mesh_for(net, 3)
assert dict(mesh.shape) == {"data": 2, "row": 2, "col": 2}, dict(mesh.shape)
assert pad_to_data_axis(3, mesh) == 4
s = serve_cnn.serve(net, batch=3, steps=1, warmup=1, mesh=mesh)
assert s.request_batch == 3 and s.plan_batch == 4
assert abs(s.padded_images_per_s / s.images_per_s - 4 / 3) < 1e-6
# masked outputs == vmap plan on the same 3 images
rng = np.random.RandomState(0)
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                          m.layer.ic // m.group, m.layer.oc) * 0.2,
                jnp.float32) for m in net.layers])
first = net.layers[0].layer
x3 = jnp.asarray(rng.randn(3, first.ic, first.i_h, first.i_w), jnp.float32)
x4 = jnp.pad(x3, ((0, 1), (0, 0), (0, 0), (0, 0)))
plan = compile_plan(net, executor_policy="mapped", mesh=mesh, batch=4)
y = execute_plan(plan, ks, x4, mesh=mesh)[:3]
y_ref = execute_plan(compile_plan(net, executor_policy="mapped"), ks, x3)
assert bool(jnp.all(y == y_ref)), "masked sharded outputs != vmap"
print("RAGGED-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "RAGGED-OK" in out.stdout, out.stderr[-2000:]
