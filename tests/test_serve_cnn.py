"""CNN serving driver (launch/serve_cnn.py): maps with the persistent
cache, serves batches through executor="mapped", reports images/s."""
import jax

from repro.core import ArrayConfig, MacroGrid, memo
from repro.launch import serve_cnn


def test_serve_cnn_reports_images_per_s(capsys, tmp_path):
    """End-to-end acceptance: the driver maps CNN8 (populating the disk
    cache), runs batched mapped-executor steps, and reports images/s."""
    memo.clear()
    try:
        serve_cnn.main(["--net", "cnn8", "--batch", "2", "--steps", "2",
                        "--warmup", "1", "--grid", "2x2",
                        "--cache-dir", str(tmp_path)])
    finally:
        memo.set_disk_cache(None)
        memo.clear()
    out = capsys.readouterr().out
    assert "images/s" in out and "executor=mapped" in out
    assert "serve/cnn8/b2," in out            # harness CSV row
    assert list(tmp_path.glob("*.mapping.pkl"))   # cache populated


def test_map_for_serving_grid_and_budget_paths():
    m_grid, _ = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "Tetris-SDK", grid=MacroGrid(2, 1))
    assert m_grid.grid == MacroGrid(2, 1)
    m_sweep, secs = serve_cnn.map_for_serving(
        "cnn8", ArrayConfig(512, 512), "TetrisG-SDK", p_max=2)
    assert m_sweep.grid.p <= 2 and secs > 0


def test_serving_mesh_for_single_device():
    """On one device the driver falls back to the vmap path (mesh None)
    rather than a degenerate 1x1 shard_map."""
    m, _ = serve_cnn.map_for_serving("cnn8", ArrayConfig(512, 512),
                                     "Tetris-SDK", grid=MacroGrid(2, 2))
    if len(jax.devices()) == 1:
        assert serve_cnn.serving_mesh_for(m, batch=4) is None
