"""Checkpoint/restore, restart-exactness, elastic resharding, and the
fault-tolerance supervisor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, restore_checkpoint, \
    save_checkpoint
from repro.data import ShardedDataPipeline
from repro.data.synthetic import TokenStream
from repro.runtime import (HeartbeatMonitor, StragglerPolicy,
                           TrainSupervisor, derive_elastic_mesh)
from repro.runtime.recovery import WorkerLost


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": jnp.ones((8, 8)), "step": jnp.array(3)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 7, s, extra={"data_step": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    r, step, extra = restore_checkpoint(tmp_path, like)
    assert step == 7 and extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_store_keeps_last_k(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        store.save(step, _state())
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000003", "step_00000004"]


def test_async_save(tmp_path):
    store = CheckpointStore(tmp_path, keep=2, async_save=True)
    store.save(5, _state())
    store.wait()
    r, step, _ = store.restore_latest(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     _state()))
    assert step == 5


def test_elastic_resharding(tmp_path):
    """Restore onto a different mesh: leaves land with the new sharding."""
    s = _state()
    save_checkpoint(tmp_path, 1, s)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh2 = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh2, P()), s)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    r, step, _ = restore_checkpoint(tmp_path, like, shardings=sh)
    assert jax.tree.leaves(r)[0].sharding.mesh.shape == {"data": 1}


def test_derive_elastic_mesh():
    p = derive_elastic_mesh(512, model_parallel=16)
    assert p.shape == (32, 16) and p.dropped == 0
    p = derive_elastic_mesh(480, model_parallel=16)   # lost 2 pods' worth
    assert p.shape[1] == 16 and p.shape[0] * 16 <= 480
    assert p.shape[0] & (p.shape[0] - 1) == 0         # power of two
    with pytest.raises(RuntimeError):
        derive_elastic_mesh(8, model_parallel=16)


def test_data_pipeline_restart_exact():
    ts = TokenStream(vocab=100, seq_len=16, global_batch=4, seed=1)
    p1 = ShardedDataPipeline(ts, shard=0, n_shards=2)
    seq = [p1.next() for _ in range(5)]
    p2 = ShardedDataPipeline(ts, shard=0, n_shards=2)
    p2.skip_to(3)
    np.testing.assert_array_equal(p2.next(), seq[3])
    np.testing.assert_array_equal(p2.next(), seq[4])


def test_supervisor_failure_and_resume(tmp_path):
    """End-to-end: train, crash mid-run, resume from checkpoint, finish —
    final state identical to an uninterrupted run (restart-exact)."""
    ts = TokenStream(vocab=50, seq_len=8, global_batch=2, seed=0)

    def step_fn(state, batch):
        s = state["sum"] + float(batch.sum())
        return {"sum": jnp.asarray(s), "n": state["n"] + 1}, {}

    def fresh():
        return {"sum": jnp.asarray(0.0), "n": jnp.asarray(0)}

    # uninterrupted reference
    ref = TrainSupervisor(store=CheckpointStore(tmp_path / "ref"),
                          pipeline=ShardedDataPipeline(ts),
                          monitor=HeartbeatMonitor(1), save_every=5)
    ref_state, _ = ref.run(fresh(), step_fn, steps=20)

    # crash at step 12, resume
    store = CheckpointStore(tmp_path / "ckpt")
    sup = TrainSupervisor(store=store, pipeline=ShardedDataPipeline(ts),
                          monitor=HeartbeatMonitor(1), save_every=5)
    with pytest.raises(WorkerLost):
        sup.run(fresh(), step_fn, steps=20, inject_failure_at=12)
    sup2 = TrainSupervisor(store=store, pipeline=ShardedDataPipeline(ts),
                           monitor=HeartbeatMonitor(1), save_every=5)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), fresh())
    state, last = sup2.resume(like, step_fn, steps=20)
    assert last == 20
    assert float(state["sum"]) == float(ref_state["sum"])
    assert any("resumed" in e for e in sup2.events)


def test_straggler_detection():
    clock = [0.0]
    mon = HeartbeatMonitor(3, dead_after_s=10,
                           policy=StragglerPolicy(window=4),
                           clock=lambda: clock[0])
    for _ in range(4):
        mon.report(0, 1.0)
        mon.report(1, 1.0)
        mon.report(2, 5.0)       # slow worker
    s = mon.stragglers()
    assert s.get(2) in ("warn", "demote")
    clock[0] = 100.0
    assert set(mon.dead_workers()) == {0, 1, 2}


def test_gradient_compression_error_feedback():
    """int8 EF compression: the *accumulated* update converges to the true
    gradient sum (error feedback property), per-step error bounded."""
    import jax.numpy as jnp
    from repro.optim import compress_int8, decompress_int8, \
        ef_compress_update
    rng = np.random.RandomState(0)
    true_sum = np.zeros(256, np.float32)
    applied_sum = np.zeros(256, np.float32)
    residual = jnp.zeros(256, jnp.float32)
    for _ in range(50):
        g = jnp.asarray(rng.randn(256) * (1 + 10 * rng.rand()), jnp.float32)
        q, scale, residual = ef_compress_update(g, residual)
        applied_sum += np.asarray(decompress_int8(q, scale))
        true_sum += np.asarray(g)
    # EF: cumulative applied == cumulative true up to the last residual
    np.testing.assert_allclose(applied_sum + np.asarray(residual),
                               true_sum, rtol=1e-5, atol=1e-3)
    # compression is actually 4x smaller payload
    q, scale = compress_int8(jnp.ones(1024))
    assert q.dtype == jnp.int8
