"""Macro-parallel mapped-network executor (cnn/mapped_net.py): forward
equivalence against the lax.conv composition, executed grid steps ==
analytical cycle counts, exact gradients, and the shard_map device path.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArrayConfig, ConvLayerSpec, MacroGrid, map_layer,
                        map_net, networks)
from repro.cnn.cim_conv import reference_conv2d
from repro.cnn.mapped_net import (assert_steps_match, executed_steps,
                                  layer_schedule, mapped_conv2d,
                                  mapped_net_apply, network_schedule,
                                  reference_net_apply, zero_pruned_kernels)

RNG = np.random.RandomState(11)


def _layer_data(m, batch=2):
    lay = m.layer
    x = jnp.asarray(RNG.randn(batch, lay.ic, lay.i_h, lay.i_w), jnp.float32)
    k = jnp.asarray(RNG.randn(lay.k_h, lay.k_w, lay.ic // m.group, lay.oc),
                    jnp.float32)
    pruned = sum(t.pruned_channels for t in m.tiles)
    if pruned:
        k = k.at[:, :, lay.ic // m.group - pruned:, :].set(0.0)
    return x, k


def _check_layer(layer, alg, arr, grid, **kw):
    m = map_layer(layer, arr, alg, grid, **kw)
    x, k = _layer_data(m)
    y = mapped_conv2d(m, x, k)
    ref = reference_conv2d(layer, x, k, groups=m.group)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
    assert executed_steps(m) == m.cycles
    return m


@pytest.mark.parametrize("grid", [MacroGrid(1, 1), MacroGrid(2, 2),
                                  MacroGrid(4, 2), MacroGrid(1, 16)])
def test_mapped_conv2d_grids(grid):
    """The executor realizes every grid shape layer_cycles accounts for:
    rows parallelize channel passes, columns oc passes."""
    _check_layer(ConvLayerSpec("t", 18, 18, 3, 3, 32, 32), "Tetris-SDK",
                 ArrayConfig(64, 64), grid)


@pytest.mark.parametrize("alg", ["img2col", "SDK", "VW-SDK", "Tetris-SDK",
                                 "TetrisG-SDK"])
def test_mapped_conv2d_algorithms(alg):
    _check_layer(ConvLayerSpec("t", 18, 18, 3, 3, 24, 32), alg,
                 ArrayConfig(64, 64), MacroGrid(2, 2))


def test_mapped_conv2d_strided_and_grouped():
    _check_layer(ConvLayerSpec("s", 10, 10, 3, 3, 8, 8, stride=2),
                 "Tetris-SDK", ArrayConfig(128, 128), MacroGrid(2, 2))
    m = _check_layer(ConvLayerSpec("g", 18, 18, 3, 3, 32, 32),
                     "TetrisG-SDK", ArrayConfig(64, 64), MacroGrid(2, 4))
    assert m.group > 1
    _check_layer(ConvLayerSpec("dw", 10, 10, 3, 3, 16, 16, groups=16),
                 "Tetris-SDK", ArrayConfig(128, 128), MacroGrid(2, 2))


def test_group_rounds_time_multiplex():
    """More groups than the grid's group-parallel slots: rounds > 1 and
    the step count reflects the time multiplexing."""
    m = map_layer(ConvLayerSpec("dw", 10, 10, 3, 3, 16, 16, groups=16),
                  ArrayConfig(128, 128), "Tetris-SDK", MacroGrid(2, 2))
    s = layer_schedule(m)
    assert m.group == 16 and s.group_rounds > 1
    assert s.steps == m.cycles


def test_mapped_net_cnn8():
    """Whole-network forward through the mapped path == lax.conv
    composition; total executed steps == NetworkMapping.total_cycles."""
    net = map_net("cnn8", networks.cnn8(), ArrayConfig(64, 64),
                  "TetrisG-SDK", MacroGrid(2, 2), groups=(1, 2, 4))
    ks = zero_pruned_kernels(net, [
        _layer_data(m)[1] * 0.1 for m in net.layers])
    x = jnp.asarray(RNG.randn(2, 24, 18, 18), jnp.float32)
    y = mapped_net_apply(net, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               rtol=1e-4, atol=1e-4 * float(jnp.max(jnp.abs(r))))
    assert any(m.group > 1 for m in net.layers)          # grouped layers ran
    assert sum(s.steps for s in network_schedule(net)) == net.total_cycles


def test_mapped_net_densenet_slice():
    """DenseNet40 slice across a transition: dense-concat chaining,
    marginal-window layers, 1x1 transition + spatial pooling."""
    layers = networks.densenet40()[10:15]    # b1l11, b1l12, t1, b2l1, b2l2
    net = map_net("dn40", layers, ArrayConfig(64, 64), "TetrisG-SDK",
                  MacroGrid(4, 1), groups=(1, 2))
    assert any(t.marginals for m in net.layers for t in m.tiles)
    assert any(m.group > 1 for m in net.layers)
    ks = zero_pruned_kernels(net, [
        _layer_data(m)[1] * 0.1 for m in net.layers])
    x = jnp.asarray(RNG.randn(1, layers[0].ic, layers[0].i_h,
                              layers[0].i_w), jnp.float32)
    y = mapped_net_apply(net, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               rtol=1e-4, atol=1e-4 * float(jnp.max(jnp.abs(r))))
    assert_steps_match(net)


def test_mapped_net_strided_chain():
    """A strided layer inside a chained stack."""
    layers = [
        ConvLayerSpec("a", 18, 18, 3, 3, 8, 16),
        ConvLayerSpec("b", 16, 16, 3, 3, 16, 16, stride=2),
        ConvLayerSpec("c", 9, 9, 3, 3, 16, 32),
    ]
    net = map_net("strided", layers, ArrayConfig(64, 64), "Tetris-SDK",
                  MacroGrid(2, 2))
    ks = zero_pruned_kernels(net, [
        _layer_data(m)[1] * 0.1 for m in net.layers])
    x = jnp.asarray(RNG.randn(2, 8, 18, 18), jnp.float32)
    y = mapped_net_apply(net, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               rtol=1e-4, atol=1e-4 * float(jnp.max(jnp.abs(r))))


def test_steps_equal_cycles_all_bench_networks():
    """Executed schedule == analytical cycles for every bench network —
    host-side only, no compute (the Fig 20 contract)."""
    for name, fn in networks.NETWORKS.items():
        net = map_net(name, fn(), ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(4, 4), groups=(1, 2))
        assert_steps_match(net)


def test_mapped_gradients_match_reference():
    """Training-path contract: gradients through the macro-parallel
    executor equal the lax.conv gradients (overlapping border windows
    recompute identical values; the scatter transpose must not
    double-count)."""
    layer = ConvLayerSpec("CNN8-2", 18, 18, 3, 3, 24, 32)
    m = map_layer(layer, ArrayConfig(64, 64), "TetrisG-SDK")
    x, k = _layer_data(m, batch=1)
    ic_g = layer.ic // m.group
    pruned = sum(t.pruned_channels for t in m.tiles)

    def zap(t):
        return t.at[:, :, ic_g - pruned:, :].set(0.0) if pruned else t

    gm = jax.grad(lambda kk: jnp.sum(mapped_conv2d(m, x, kk) ** 2))(k)
    gr = jax.grad(lambda kk: jnp.sum(
        reference_conv2d(layer, x, kk, groups=m.group) ** 2))(k)
    np.testing.assert_allclose(np.asarray(zap(gm)), np.asarray(zap(gr)),
                               rtol=1e-4, atol=1e-4 * float(jnp.max(jnp.abs(gr))))


@pytest.mark.slow
def test_train_through_mapped_executor():
    """train_cnn(executor="mapped") optimizes and tracks the reference
    path (identical init, data, and schedule)."""
    from repro.cnn.models import cnn8_config
    from repro.cnn.train import train_cnn
    kw = dict(steps=20, batch=32, n_train=256, n_test=64)
    rm = train_cnn(cnn8_config(group=2), executor="mapped",
                   grid=MacroGrid(2, 2), **kw)
    rr = train_cnn(cnn8_config(group=2), **kw)
    assert np.isfinite(rm.final_loss)
    assert abs(rm.final_loss - rr.final_loss) < 1e-2
    assert rm.executor == "mapped"


class _FakeMesh:
    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def test_macro_pass_specs_data_axis():
    """Spec selection: a "data" axis shards the batch axis of patches and
    output; weights replicate across it; psum stays confined to "row"."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import macro_mesh_fits, macro_pass_specs
    plain = _FakeMesh(row=2, col=2)
    assert macro_pass_specs(plain) == (P("row"), P("row", "col"), P("col"))
    assert macro_pass_specs(None) == (P("row"), P("row", "col"), P("col"))
    data = _FakeMesh(data=2, row=2, col=2)
    p, w, o = macro_pass_specs(data)
    assert p == P("row", "data") and o == P("col", "data")
    assert w == P("row", "col")                  # replicated over "data"
    # fits: data meshes additionally require batch % data == 0
    assert macro_mesh_fits(plain, 2, 2)
    assert macro_mesh_fits(plain, 2, 2, batch=3)  # no data axis: any batch
    assert macro_mesh_fits(data, 2, 2, batch=4)
    assert not macro_mesh_fits(data, 2, 2, batch=3)
    assert not macro_mesh_fits(data, 2, 2)        # unknown batch
    assert not macro_mesh_fits(data, 3, 2, batch=4)


def test_make_macro_mesh_single_device_degenerate():
    """On one device every composition degenerates to the vmap path."""
    from repro.launch.mesh import make_macro_mesh, make_serving_mesh
    dev = jax.devices()[:1]
    assert make_macro_mesh(2, 2, dev) is None
    assert make_macro_mesh(2, 2, dev, data=1) is None
    assert make_macro_mesh(2, 2, dev, data=2) is None   # not enough devices
    assert make_serving_mesh(2, 2, 4, dev) is None
    with pytest.raises(ValueError):
        make_macro_mesh(2, 2, dev, data=0)


def test_data_axis_shard_map():
    """Tentpole contract: a (data=2, row=2, col=2) mesh on 8 forced host
    devices composes batch sharding with the macro grid — forward output
    is bit-identical to the single-device vmap path on a CNN8 slice, the
    psum stays confined to "row", and gradients agree to float-reassoc
    tolerance (exactly vs the lax reference at the usual 1e-3)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import (mapped_conv2d, mapped_net_apply,
                                  reference_net_apply, zero_pruned_kernels)
from repro.launch.mesh import make_macro_mesh, make_serving_mesh
assert len(jax.devices()) == 8
net = map_net("cnn8", networks.cnn8()[:3], ArrayConfig(64, 64),
              "Tetris-SDK", MacroGrid(2, 2))
assert all(m.sub_grid == MacroGrid(2, 2) for m in net.layers)
mesh = make_macro_mesh(2, 2, data=2)
assert dict(mesh.shape) == {"data": 2, "row": 2, "col": 2}
assert dict(make_serving_mesh(2, 2, 4).shape) == \\
    {"data": 2, "row": 2, "col": 2}
rng = np.random.RandomState(0)
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                          m.layer.ic // m.group, m.layer.oc) * 0.2,
                jnp.float32) for m in net.layers])
first = net.layers[0].layer
x = jnp.asarray(rng.randn(4, first.ic, first.i_h, first.i_w), jnp.float32)
y_sharded = mapped_net_apply(net, ks, x, mesh=mesh)
y_vmap = mapped_net_apply(net, ks, x)
assert bool(jnp.all(y_sharded == y_vmap)), "forward not bit-identical"
ref = reference_net_apply(net, ks, x)
assert float(jnp.max(jnp.abs(y_sharded - ref))) < 1e-3

m0, k0 = net.layers[0], ks[0]
gs = jax.grad(lambda k: jnp.sum(mapped_conv2d(m0, x, k, mesh=mesh)**2))(k0)
gv = jax.grad(lambda k: jnp.sum(mapped_conv2d(m0, x, k)**2))(k0)
scale = float(jnp.max(jnp.abs(gv)))
assert float(jnp.max(jnp.abs(gs - gv))) < 1e-6 * scale, "grad diverged"
print("DATA-SHARDED-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DATA-SHARDED-OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_shard_map_macro_path():
    """The shard_map realization on a real multi-device ("row", "col")
    mesh (forced host devices in a subprocess) matches lax.conv."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, ConvLayerSpec, MacroGrid, map_layer
from repro.cnn.cim_conv import reference_conv2d
from repro.cnn.mapped_net import mapped_conv2d
from repro.launch.mesh import make_macro_mesh
assert len(jax.devices()) == 4
layer = ConvLayerSpec("t", 18, 18, 3, 3, 32, 32)
m = map_layer(layer, ArrayConfig(64, 64), "Tetris-SDK", MacroGrid(2, 2))
mesh = make_macro_mesh(m.sub_grid.r, m.sub_grid.c)
assert mesh is not None and dict(mesh.shape) == {"row": 2, "col": 2}
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(2, 32, 18, 18), jnp.float32)
k = jnp.asarray(rng.randn(3, 3, 32, 32), jnp.float32)
pruned = sum(t.pruned_channels for t in m.tiles)
if pruned: k = k.at[:, :, 32 - pruned:, :].set(0.0)
y = mapped_conv2d(m, x, k, mesh=mesh)
ref = reference_conv2d(layer, x, k)
assert float(jnp.max(jnp.abs(y - ref))) < 1e-3
print("SHARDED-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SHARDED-OK" in out.stdout, out.stderr[-2000:]
