"""Fleet scheduler (launch/fleet.py): property-tested deterministic
core (conservation, per-model FIFO, no-split, deadline bound, tier
monotonicity), the determinism regression (bit-identical launch
schedules across runs and a pickle round-trip of the config), plan-
constant sharing (once per network, not per tier; bit-identical
outputs), and the shared-fleet vs dedicated-slice acceptance row.

The hypothesis suite is guarded with a soft import (NOT a module-level
importorskip: the non-property tests here must run without hypothesis);
a seeded-random fallback drives the same invariant checkers over 100
traces either way.
"""
import pickle
import random

import pytest

from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.launch import batching
from repro.launch.fleet import (FleetConfig, FleetScheduler, LaunchRecord,
                                ModelSpec, chainable_prefix,
                                mixed_poisson_trace, run_fleet)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class _VClock:
    """Deterministic time for the fleet loop: only sleep() advances."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        assert dt > 0
        self.t += dt


class _FakeMesh:
    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def _small_net(n_layers=2, grid=MacroGrid(2, 2)):
    return map_net("cnn8", networks.cnn8()[:n_layers], ArrayConfig(64, 64),
                   "Tetris-SDK", grid)


def _replay(cfg, trace):
    clk = _VClock()
    return run_fleet(FleetScheduler(cfg), trace, clock=clk,
                     sleep=clk.sleep)


# ---------------------------------------------------------------------------
# Shared invariant checkers (hypothesis AND the seeded fallback drive
# these — one definition of correctness)
# ---------------------------------------------------------------------------

def _check_invariants(cfg, trace, records):
    pushed = {}
    for t, m, r in trace:
        pushed.setdefault(m, []).append((t, r))
    served = {}
    for rec in records:
        spec = cfg.spec(rec.model)
        # no-split: whole requests only, each within the model's cap
        assert len(rec.rows) == len(rec.arrivals_s) >= 1
        assert all(1 <= r <= spec.max_batch for r in rec.rows)
        total = sum(rec.rows)
        assert total <= spec.max_batch
        # tier stamp = smallest ladder rung that fits the drained rows
        tiers = batching.batch_tiers(spec.max_batch)
        assert rec.tier == batching.tier_for(total, tiers)
        # deadline bound: under pure replay (virtual time, instant
        # execution) nothing launches later than max_delay past arrival
        for a in rec.arrivals_s:
            assert rec.launch_s <= a + spec.max_delay_s + 1e-9
        served.setdefault(rec.model, []).extend(
            zip(rec.arrivals_s, rec.rows))
    # conservation + per-model FIFO: every pushed request is served
    # exactly once, in arrival order (stable on tied timestamps)
    for m, events in pushed.items():
        assert served.pop(m, []) == sorted(events, key=lambda e: e[0])
    assert not served                     # nothing served but not pushed


def _random_case(rng: random.Random):
    n_models = rng.randint(1, 3)
    specs = tuple(
        ModelSpec(name=f"m{i}",
                  max_batch=rng.randint(1, 8),
                  max_delay_s=rng.choice([0.0, 0.001, 0.005, 0.02]),
                  weight=rng.choice([0.5, 1.0, 2.0]))
        for i in range(n_models))
    cfg = FleetConfig(models=specs)
    t = 0.0
    trace = []
    for _ in range(rng.randint(1, 30)):
        t += rng.choice([0.0, 0.0005, 0.002, 0.01])
        spec = specs[rng.randrange(n_models)]
        trace.append((t, spec.name, rng.randint(1, spec.max_batch)))
    return cfg, tuple(trace)


def test_fleet_invariants_seeded_fallback():
    """100 seeded-random traces through the shared checkers — the same
    coverage shape as the hypothesis suite, always runnable."""
    rng = random.Random(7)
    for _ in range(100):
        cfg, trace = _random_case(rng)
        _check_invariants(cfg, trace, _replay(cfg, trace))


if HAVE_HYPOTHESIS:
    @st.composite
    def fleet_cases(draw):
        n_models = draw(st.integers(1, 3))
        specs = tuple(
            ModelSpec(name=f"m{i}",
                      max_batch=draw(st.integers(1, 8)),
                      max_delay_s=draw(st.floats(
                          0, 0.02, allow_nan=False, allow_infinity=False)),
                      weight=draw(st.floats(
                          0.1, 4.0, allow_nan=False, allow_infinity=False)))
            for i in range(n_models))
        cfg = FleetConfig(models=specs)
        n = draw(st.integers(1, 30))
        gaps = draw(st.lists(
            st.floats(0, 0.01, allow_nan=False, allow_infinity=False),
            min_size=n, max_size=n))
        picks = draw(st.lists(st.integers(0, n_models - 1),
                              min_size=n, max_size=n))
        trace, t = [], 0.0
        for gap, mi in zip(gaps, picks):
            t += gap
            spec = specs[mi]
            trace.append((t, spec.name,
                          draw(st.integers(1, spec.max_batch))))
        return cfg, tuple(trace)

    @settings(max_examples=100, deadline=None)
    @given(case=fleet_cases())
    def test_fleet_conservation_fifo_nosplit_deadline(case):
        """For arbitrary tagged arrival sequences: every pushed row is
        served exactly once (conservation at forced flush), one model's
        requests never reorder (FIFO), requests stay whole (no-split),
        and nothing launches later than its model's max-delay past
        arrival under pure replay."""
        cfg, trace = case
        _check_invariants(cfg, trace, _replay(cfg, trace))

    @settings(max_examples=100, deadline=None)
    @given(case=fleet_cases())
    def test_fleet_schedule_deterministic(case):
        cfg, trace = case
        assert _replay(cfg, trace) == _replay(cfg, trace)

    @settings(max_examples=100, deadline=None)
    @given(max_batch=st.integers(1, 64), data=st.integers(1, 8),
           rows=st.integers(1, 64))
    def test_batch_tiers_and_tier_for_monotone_under_mesh(
            max_batch, data, rows):
        """Ladder invariants under a mesh: tiers ascend, every tier is
        a multiple of the data axis, the top covers max_batch, and
        tier_for is monotone in rows (more rows never select a smaller
        tier)."""
        mesh = _FakeMesh(data=data, row=2, col=2)
        tiers = batching.batch_tiers(max_batch, mesh)
        assert list(tiers) == sorted(set(tiers))
        assert all(t % data == 0 for t in tiers)
        assert tiers[-1] >= max_batch
        if rows <= tiers[-1]:
            t = batching.tier_for(rows, tiers)
            assert t >= rows
            if rows > 1:
                assert batching.tier_for(rows - 1, tiers) <= t
        else:
            with pytest.raises(ValueError, match="exceed"):
                batching.tier_for(rows, tiers)


# ---------------------------------------------------------------------------
# Determinism regression (ISSUE 7 satellite 2)
# ---------------------------------------------------------------------------

def test_fleet_determinism_across_runs_and_pickle():
    """The invariant documented in launch/fleet.py's docstring: same
    config + trace + fake clock => bit-identical LaunchRecord schedule,
    across independent runs AND across a pickle round-trip of the
    scheduler config."""
    cfg = FleetConfig(models=(
        ModelSpec("a", max_batch=8, max_delay_s=0.002, weight=1.0),
        ModelSpec("b", max_batch=4, max_delay_s=0.001, weight=2.0),
        ModelSpec("c", max_batch=2, max_delay_s=0.0, weight=0.5)))
    trace = mixed_poisson_trace(["a", "b", "c"], 60, 700.0,
                                {"a": 4, "b": 3, "c": 2}, seed=11)
    r1, r2 = _replay(cfg, trace), _replay(cfg, trace)
    assert r1 == r2 and len(r1) > 0
    assert all(isinstance(r, LaunchRecord) for r in r1)
    cfg2 = pickle.loads(pickle.dumps(cfg))
    assert cfg2 == cfg
    assert _replay(cfg2, trace) == r1


# ---------------------------------------------------------------------------
# Drain-policy unit cases (deadline override / weighted fair / ties)
# ---------------------------------------------------------------------------

def test_pop_deadline_override_beats_weighted_fair():
    """An expired model drains first even when another model has far
    more weighted backlog; among expired models the nearest (most
    overdue) deadline wins."""
    cfg = FleetConfig(models=(
        ModelSpec("big", max_batch=8, max_delay_s=1.0, weight=10.0),
        ModelSpec("late", max_batch=4, max_delay_s=0.001),
        ModelSpec("later", max_batch=4, max_delay_s=0.002)))
    s = FleetScheduler(cfg)
    s.push("big", 6, now=0.0)            # huge weighted backlog
    s.push("late", 1, now=0.0)           # expires at 1ms
    s.push("later", 1, now=0.0)          # expires at 2ms
    assert s.next_deadline() == pytest.approx(0.001)
    launch = s.pop(now=0.005)            # both small models overdue
    assert launch.model == "late"
    assert s.pop(now=0.005).model == "later"
    assert s.pop(now=0.005) is None      # big: not ready, not forced
    assert len(s) == 6


def test_pop_weighted_fair_and_config_order_tiebreak():
    """Among ready-by-fill models the largest queued_rows x weight
    drains; exact ties resolve to the earliest model in the config."""
    cfg = FleetConfig(models=(
        ModelSpec("a", max_batch=2, max_delay_s=9.0, weight=1.0),
        ModelSpec("b", max_batch=2, max_delay_s=9.0, weight=3.0),
        ModelSpec("c", max_batch=2, max_delay_s=9.0, weight=1.0)))
    s = FleetScheduler(cfg)
    for m in ("a", "b", "c"):
        s.push(m, 2, now=0.0)            # all ready via max-batch
    assert s.pop(now=0.0).model == "b"   # 2x3 beats 2x1
    assert s.pop(now=0.0).model == "a"   # tie with c -> config order
    assert s.pop(now=0.0).model == "c"
    assert s.pop(now=0.0) is None and len(s) == 0


def test_pop_forced_flush_drains_in_deadline_order():
    cfg = FleetConfig(models=(
        ModelSpec("a", max_batch=4, max_delay_s=5.0),
        ModelSpec("b", max_batch=4, max_delay_s=5.0)))
    s = FleetScheduler(cfg)
    s.push("b", 1, now=0.0)              # oldest obligation
    s.push("a", 1, now=0.1)
    assert s.pop(now=0.2) is None        # neither expired nor full
    assert s.pop(now=0.2, force=True).model == "b"
    assert s.pop(now=0.2, force=True).model == "a"


def test_scheduler_validates_and_launch_metadata():
    cfg = FleetConfig(models=(ModelSpec("a", max_batch=4,
                                        max_delay_s=0.0),))
    s = FleetScheduler(cfg, mesh=_FakeMesh(data=2, row=1, col=1))
    assert s.tiers["a"] == (2, 4)        # padded to the data axis
    with pytest.raises(KeyError, match="not in fleet"):
        s.push("nope", 1, now=0.0)
    s.push("a", 3, now=0.0)
    launch = s.pop(now=0.0)
    assert (launch.model, launch.tier, launch.rows) == ("a", 4, 3)
    assert s.queued_rows("a") == 0
    with pytest.raises(ValueError, match="duplicate"):
        FleetConfig(models=(ModelSpec("x", 1, 0.0),
                            ModelSpec("x", 1, 0.0)))
    with pytest.raises(ValueError, match="at least one"):
        FleetConfig(models=())
    with pytest.raises(ValueError, match="weight"):
        ModelSpec("x", 1, 0.0, weight=0.0)
    with pytest.raises(ValueError, match="do not cover"):
        FleetScheduler(cfg, tiers={"a": (1, 2)})


def test_run_fleet_validates_trace_upfront():
    cfg = FleetConfig(models=(ModelSpec("a", max_batch=2,
                                        max_delay_s=0.0),))
    clk = _VClock()
    with pytest.raises(ValueError, match="never split"):
        run_fleet(FleetScheduler(cfg), [(0.0, "a", 3)], clock=clk,
                  sleep=clk.sleep)
    with pytest.raises(KeyError, match="not in fleet"):
        run_fleet(FleetScheduler(cfg), [(0.0, "zz", 1)], clock=clk,
                  sleep=clk.sleep)


def test_mixed_poisson_trace_shape_and_chainable_prefix():
    trace = mixed_poisson_trace(["a", "b"], 32, 200.0, {"a": 3, "b": 1},
                                seed=2, weights=[3.0, 1.0])
    assert len(trace) == 32 and trace[0][0] == 0.0
    times = [t for t, _, _ in trace]
    assert times == sorted(times)
    by = {"a": 0, "b": 0}
    for _, m, r in trace:
        by[m] += 1
        assert 1 <= r <= {"a": 3, "b": 1}[m]
    assert by["a"] > by["b"]             # 3:1 traffic weights
    backlog = mixed_poisson_trace(["a"], 4, 0.0, 2, seed=0)
    assert all(t == 0.0 for t, _, _ in backlog)
    # inception is a layer SET (two disjoint blocks): the fleet serves
    # its longest chainable prefix; cnn8 chains end to end and passes
    # through unchanged
    incep = map_net("inception", networks.inception(),
                    ArrayConfig(64, 64), "Tetris-SDK", MacroGrid(1, 1))
    pre = chainable_prefix(incep)
    assert 1 <= len(pre.layers) < len(incep.layers)
    cnn = _small_net()
    assert chainable_prefix(cnn) is cnn


# ---------------------------------------------------------------------------
# Plan-constant sharing (ISSUE 7 satellite 3)
# ---------------------------------------------------------------------------

def test_constants_materialize_once_per_network_not_per_tier():
    """The shared-constants handle comes out of memo.cached_constants:
    every tier of the ladder gets the SAME PlanConstants object, the
    per-key counters show ONE materialization for the network, and
    outputs with the handle are bit-identical to the in-trace build."""
    import jax.numpy as jnp
    import numpy as np
    from repro.exec import (constant_counts, execute_plan,
                            prepare_constants)
    from repro.launch.serve_cnn import _serving_kernels
    memo.clear()
    net = _small_net()
    ladder = batching.PlanLadder(net, (1, 2))
    rng, ks = _serving_kernels(net, 0)
    c1 = prepare_constants(ladder.plans[1], ks, token=("fleet", 0))
    c2 = prepare_constants(ladder.plans[2], ks, token=("fleet", 0))
    assert c1 is c2                      # one handle across tiers
    counts = constant_counts(net=net)
    assert len(counts) == 1 and list(counts.values()) == [1]
    assert memo.stats["const_misses"] == 1
    assert memo.stats["const_hits"] == 1
    first = net.layers[0].layer
    for t in ladder.tiers:
        x = jnp.asarray(rng.randn(t, first.ic, first.i_h, first.i_w),
                        jnp.float32)
        y_off = execute_plan(ladder.plans[t], ks, x)
        y_on = execute_plan(ladder.plans[t], ks, x, constants=c1)
        assert bool(jnp.all(y_on == y_off))
    # a different kernel token materializes separately; token=None is
    # an unshared handle and never touches the cache/counters
    c3 = prepare_constants(ladder.plans[1], ks, token=("fleet", 1))
    assert c3 is not c1
    assert sum(constant_counts(net=net).values()) == 2
    c4 = prepare_constants(ladder.plans[1], ks)
    assert c4 is not c1
    assert sum(constant_counts(net=net).values()) == 2
    # handles validate against the plan they are fed to
    other = _small_net(3)
    from repro.exec import compile_plan
    plan_o = compile_plan(other, executor_policy="mapped", batch=1)
    x1 = jnp.asarray(np.zeros((1, first.ic, first.i_h, first.i_w),
                              np.float32))
    with pytest.raises(ValueError, match="different network"):
        execute_plan(plan_o, _serving_kernels(other, 0)[1], x1,
                     constants=c1)


def test_fleet_schedule_identical_with_and_without_sharing():
    """Constant sharing is a pure execution-side optimization: under the
    virtual clock the drain/launch schedule and per-model stats are
    identical with sharing on and off."""
    from repro.launch.fleet import serve_fleet
    net = _small_net()
    maps = {"a": net, "b": _small_net(3)}
    cfg = FleetConfig(models=(
        ModelSpec("a", max_batch=2, max_delay_s=0.001, slo_ms=100.0),
        ModelSpec("b", max_batch=2, max_delay_s=0.001, slo_ms=100.0)))
    trace = mixed_poisson_trace(["a", "b"], 8, 300.0, 2, seed=5)

    def run(share):
        clk = _VClock()
        return serve_fleet(maps, cfg, trace, warmup=1,
                           share_constants=share, clock=clk,
                           sleep=clk.sleep)

    s_on, r_on = run(True)
    s_off, r_off = run(False)
    assert r_on == r_off
    assert s_on.shared_constants and not s_off.shared_constants
    for m in ("a", "b"):
        assert (s_on.models[m].request_images
                == s_off.models[m].request_images)
        assert s_on.models[m].slo_attainment == 1.0
    assert s_on.request_images == sum(r for _, _, r in trace)


def test_constants_shared_across_tiers_forced_multi_device():
    """Forced-8-device case (pattern from tests/test_serve_cnn.py):
    with a data=2 serving mesh, outputs are bit-identical with sharing
    on vs off on every tier, and the counters show constants
    materialized once per network, not once per tier."""
    import os
    import subprocess
    import sys
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import (compile_plan, constant_counts, execute_plan,
                        prepare_constants)
from repro.launch.mesh import serving_mesh_for
assert len(jax.devices()) == 8
net = map_net("cnn8", networks.cnn8()[:3], ArrayConfig(64, 64),
              "Tetris-SDK", MacroGrid(2, 2))
mesh = serving_mesh_for(net, 4)
assert dict(mesh.shape) == {"data": 2, "row": 2, "col": 2}
plans = {t: compile_plan(net, executor_policy="mapped", mesh=mesh,
                         batch=t) for t in (2, 4)}
rng = np.random.RandomState(0)
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                          m.layer.ic // m.group, m.layer.oc) * 0.2,
                jnp.float32) for m in net.layers])
handles = [prepare_constants(plans[t], ks, token=("fleet", 0))
           for t in (2, 4)]
assert handles[0] is handles[1], "tiers got distinct handles"
counts = constant_counts(net=net)
assert len(counts) == 1 and list(counts.values()) == [1], counts
first = net.layers[0].layer
for t in (2, 4):
    x = jnp.asarray(rng.randn(t, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    y_off = execute_plan(plans[t], ks, x, mesh=mesh)
    y_on = execute_plan(plans[t], ks, x, mesh=mesh,
                        constants=handles[0])
    assert bool(jnp.all(y_on == y_off)), f"tier {t} outputs drifted"
assert list(constant_counts(net=net).values()) == [1]
print("FLEET-CONSTS-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "FLEET-CONSTS-OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# Acceptance: shared fleet >= dedicated slices; CLI smoke
# ---------------------------------------------------------------------------

def _parse_kv(row: str) -> dict:
    return dict(kv.split("=") for kv in row.strip().split(",")[-1].split(";")
                if "=" in kv)


@pytest.mark.slow
def test_fleet_bench_shared_beats_dedicated_slices():
    """ISSUE 7 acceptance: on the same mixed Poisson stream the shared
    fleet's aggregate effective images/s must be >= serving each model
    on a dedicated slice (interleaved medians, benchmarks/fleet_bench);
    per-model + aggregate SLO attainment are reported."""
    from benchmarks import fleet_bench
    rows = {r.name: _parse_kv(r.csv()) for r in fleet_bench.run(full=False)}
    shared, dedicated = rows["fleet/shared"], rows["fleet/dedicated"]
    assert float(shared["images_per_s"]) >= float(dedicated["images_per_s"])
    assert float(shared["speedup"]) >= 1.0
    assert 0.0 <= float(shared["slo_attainment"]) <= 1.0
    assert all(n in shared["per_model_slo"]
               for n in ("cnn8", "inception", "densenet40"))


@pytest.mark.slow
def test_fleet_cli_smoke(capsys):
    """serve_cnn --fleet end to end: per-model + aggregate CSV rows with
    SLO attainment, constants shared by default."""
    from repro.launch import serve_cnn
    serve_cnn.main(["--fleet", "cnn8,inception", "--batch", "2",
                    "--requests", "8", "--arrival-rate", "200",
                    "--warmup", "1", "--slo-ms", "500",
                    "--ar", "64", "--ac", "64"])
    out = capsys.readouterr().out
    assert "serve_fleet/cnn8," in out
    assert "serve_fleet/inception," in out
    agg = next(ln for ln in out.splitlines()
               if ln.startswith("serve_fleet/all,"))
    kv = _parse_kv(agg)
    assert kv["models"] == "cnn8/inception"
    assert float(kv["images_per_s"]) > 0
    assert kv["shared_constants"] == "True"
    assert 0.0 <= float(kv["slo_attainment"]) <= 1.0
    assert "chainable prefix" in out     # inception is a layer set
