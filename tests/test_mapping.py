"""Paper-anchor and invariant tests for the TetrisG-SDK mapping core."""
import math

import pytest

from repro.core import (ALGORITHMS, ArrayConfig, ConvLayerSpec, Window,
                        conv1d, grid_search, map_layer, map_net, networks)
from repro.core import cycles as cyc
from repro.core.tetris import square_inclined

ARR = ArrayConfig(512, 512)


# ---------------------------------------------------------------------------
# exact anchors from the paper
# ---------------------------------------------------------------------------

def test_vw_sdk_cnn8_matches_table1():
    net = map_net("cnn8", networks.cnn8(), ARR, "VW-SDK")
    assert net.total_cycles == 128            # Table I
    per_layer = [m.cycles for m in net.layers]
    assert per_layer == [32, 48, 14, 15, 15, 4]


def test_tetris_sdk_cnn8_matches_table1():
    net = map_net("cnn8", networks.cnn8(), ARR, "Tetris-SDK")
    assert net.total_cycles == 116            # Table I
    assert [m.cycles for m in net.layers] == [32, 38, 14, 14, 14, 4]


def test_tetrisg_sdk_cnn8_matches_table1():
    net = map_net("cnn8", networks.cnn8(), ARR, "TetrisG-SDK")
    assert net.total_cycles == 84             # Table I


def test_fig12_cnn8_layer3_vw_48_tetris_38():
    layer = networks.cnn8()[1]                # CNN8-3
    assert map_layer(layer, ARR, "VW-SDK").cycles == 48
    m = map_layer(layer, ARR, "Tetris-SDK").cycles
    assert m == 38                            # Fig 12 worked example
    # and the depth-optimal remainder is the paper's 6x6 @14ch (prune 1)
    t = map_layer(layer, ARR, "Tetris-SDK").tiles[-1]
    assert (t.window.pw_w, t.window.pw_h) in ((6, 6),)
    assert t.depth == 14 and t.pruned_channels == 1


def test_alg5_worked_example_cnn8_layer5():
    layer = networks.cnn8()[3]                # CNN8-5: 7x7, 3x64x64
    m = map_layer(layer, ARR, "Tetris-SDK")
    # paper: two 24-ch tiles (7x3) + one 16-ch depth-optimal tile (6x4)
    depths = sorted(t.depth for t in m.tiles)
    assert depths == [16, 48]
    rem = [t for t in m.tiles if t.depth == 16][0]
    assert {rem.window.pw_w, rem.window.pw_h} == {4, 6}


def test_mobilenet_depthwise_finding():
    """SIV-C3: depthwise+pointwise mixtures leave nothing for grouping —
    TetrisG == Tetris == VW-SDK on MobileNet."""
    ls = networks.mobilenet()
    cc = {a: map_net("mbn", ls, ARR, a).total_cycles
          for a in ("VW-SDK", "Tetris-SDK", "TetrisG-SDK")}
    assert cc["VW-SDK"] == cc["Tetris-SDK"] == cc["TetrisG-SDK"]


# ---------------------------------------------------------------------------
# ordering invariants (hold for every network in the suite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("netname", ["cnn8", "inception", "densenet40",
                                     "mobilenet"])
def test_algorithm_ordering(netname):
    ls = networks.NETWORKS[netname]()
    cc = {a: map_net(netname, ls, ARR, a).total_cycles
          for a in ALGORITHMS}
    # the paper's headline ordering
    assert cc["Tetris-SDK"] <= cc["VW-SDK"] <= cc["img2col"] * 10
    assert cc["TetrisG-SDK"] <= cc["Tetris-SDK"]
    assert cc["VWC-SDK"] <= cc["VW-SDK"]


def test_macro_grid_monotone():
    """More macros never cost more cycles (Alg 2, Fig 20)."""
    ls = networks.cnn8()
    arr = ArrayConfig(64, 64)
    prev = math.inf
    for p in (1, 2, 4, 8):
        best = grid_search("cnn8", ls, arr, p_max=p,
                           algorithm="Tetris-SDK").best.total_cycles
        assert best <= prev
        prev = best


def test_grid_search_reduces_to_eq5():
    ls = networks.cnn8()
    single = map_net("cnn8", ls, ARR, "Tetris-SDK").total_cycles
    g = grid_search("cnn8", ls, ARR, p_max=1,
                    algorithm="Tetris-SDK").best.total_cycles
    assert g == single


# ---------------------------------------------------------------------------
# window arithmetic
# ---------------------------------------------------------------------------

def test_square_inclined_prefers_square():
    layer = ConvLayerSpec("t", 20, 20, 3, 3, 16, 16)
    w = square_inclined(layer, ARR, Window(10, 4))   # 8x2=16 conv
    n_before = Window(10, 4).positions(3, 3)
    assert w.positions(3, 3) == n_before
    assert w.rows(1) <= Window(10, 4).rows(1)
    assert abs(w.pw_w - w.pw_h) <= abs(10 - 4)


def test_marginal_windows_cover_exactly():
    layer = ConvLayerSpec("t", 18, 18, 3, 3, 32, 32)
    n_reg, margs = cyc.n_windows(layer, Window(5, 6), marginal=True)
    assert n_reg == 20 and sum(m.count for m in margs) == 2  # Fig 12


def test_conv1d_maps():
    m = map_layer(conv1d("c1d", 64, 4, 16, 16), ArrayConfig(256, 256),
                  "Tetris-SDK")
    assert m.cycles > 0


def test_utilization_bounds():
    for layer in networks.cnn8():
        for alg in ALGORITHMS:
            m = map_layer(layer, ARR, alg)
            assert 0.0 < m.utilization <= 1.0
