"""Semantic equivalence: the CIM-mapped executor vs lax.conv oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, ConvLayerSpec, conv1d, map_layer
from repro.cnn import cim_conv2d, reference_conv2d

RNG = np.random.RandomState(0)


def _check(layer, alg, arr=ArrayConfig(512, 512), **kw):
    m = map_layer(layer, arr, alg, **kw)
    g = m.group
    ic_g = layer.ic // g
    x = jnp.asarray(RNG.randn(2, layer.ic, layer.i_h, layer.i_w),
                    jnp.float32)
    k = jnp.asarray(RNG.randn(layer.k_h, layer.k_w, ic_g, layer.oc),
                    jnp.float32)
    pruned = sum(t.pruned_channels for t in m.tiles)
    if pruned:
        k = k.at[:, :, ic_g - pruned:, :].set(0.0)
    y = cim_conv2d(m, x, k)
    ref = reference_conv2d(layer, x, k, groups=g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
    return m


@pytest.mark.parametrize("alg", ["img2col", "SDK", "VW-SDK", "Tetris-SDK",
                                 "TetrisG-SDK"])
def test_equivalence_all_algorithms(alg):
    _check(ConvLayerSpec("t", 18, 18, 3, 3, 24, 32), alg)


def test_equivalence_pruned_tile():
    m = _check(ConvLayerSpec("t", 18, 18, 3, 3, 32, 32), "Tetris-SDK")
    assert any(t.pruned_channels for t in m.tiles)


@pytest.mark.slow
def test_equivalence_multi_tile():
    _check(ConvLayerSpec("t", 7, 7, 3, 3, 64, 64), "Tetris-SDK")


@pytest.mark.parametrize("alg", ["img2col", "VW-SDK", "Tetris-SDK"])
def test_equivalence_stride2(alg):
    _check(ConvLayerSpec("t", 10, 10, 3, 3, 8, 8, stride=2), alg,
           ArrayConfig(128, 128))
    _check(ConvLayerSpec("t", 13, 13, 3, 3, 4, 4, stride=2), alg,
           ArrayConfig(96, 96))


@pytest.mark.parametrize("geo", [(11, 3, 2), (9, 3, 3), (15, 3, 2),
                                 (15, 3, 3), (14, 5, 2)])
def test_equivalence_strided_border_coverage(geo):
    """Strided geometries whose border clamp falls off the stride grid:
    the search must only pick windows (and grow marginal windows) whose
    stride-aligned clamped raster still reaches the last outputs
    (cycles.axis_covers / grow_to_cover)."""
    i, k, s = geo
    _check(ConvLayerSpec("t", i, i, k, k, 8, 8, stride=s), "Tetris-SDK",
           ArrayConfig(128, 128))
    _check(ConvLayerSpec("t", i, i, k, k, 8, 8, stride=s), "VW-SDK",
           ArrayConfig(128, 128))


@pytest.mark.slow
def test_equivalence_depthwise():
    _check(ConvLayerSpec("t", 10, 10, 3, 3, 16, 16, groups=16),
           "Tetris-SDK", ArrayConfig(128, 128))


def test_equivalence_conv1d():
    _check(conv1d("t", 32, 4, 8, 8), "Tetris-SDK", ArrayConfig(128, 128))


def test_equivalence_5x5_kernel():
    _check(ConvLayerSpec("t", 12, 12, 5, 5, 16, 32), "Tetris-SDK",
           ArrayConfig(256, 256))


def test_jit_entry_point_matches():
    """cim_conv2d_jit treats the mapping as static and must agree with
    the reference oracle (and hence the un-jitted path)."""
    from repro.core import map_layer
    from repro.cnn.cim_conv import cim_conv2d_jit

    layer = ConvLayerSpec("t", 18, 18, 3, 3, 24, 32)
    m = map_layer(layer, ArrayConfig(512, 512), "Tetris-SDK")
    x = jnp.asarray(RNG.randn(2, 24, 18, 18), jnp.float32)
    k = jnp.asarray(RNG.randn(3, 3, 24, 32), jnp.float32)
    y = cim_conv2d_jit(m, x, k)
    ref = reference_conv2d(layer, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_multi_tile_pruned_channels_zero_per_tile():
    """Regression: pruned channels are the trailing slice of EACH tile's
    nominal channel range.  With a pruned tile that is not last, the
    executors must skip that tile's trailing channels in place (not
    shift the next tile's range onto them), and zero_pruned_kernels must
    zero exactly those per-tile slices — a single layer-trailing slice
    of the summed prune counts zeroes the wrong channels."""
    from repro.core.types import (LayerMapping, NetworkMapping,
                                  TileMapping, Window)
    from repro.cnn.mapped_net import mapped_conv2d, zero_pruned_kernels
    from repro.kernels.im2win_conv import sdk_conv

    layer = ConvLayerSpec("mt", 18, 18, 3, 3, 12, 8)
    # window 6x6 -> 4x4 raster of 16 regular loads, no marginals
    tiles = (
        TileMapping(window=Window(6, 6), depth=5, ic_t=5, oc_t=8,
                    ar_c=1, ac_c=1, n_regular=16, pruned_channels=1),
        TileMapping(window=Window(6, 6), depth=6, ic_t=6, oc_t=8,
                    ar_c=1, ac_c=1, n_regular=16, pruned_channels=0),
    )
    m = LayerMapping(layer=layer, array=ArrayConfig(512, 512),
                     algorithm="synthetic", tiles=tiles)
    x = jnp.asarray(RNG.randn(2, 12, 18, 18), jnp.float32)
    k = jnp.asarray(RNG.randn(3, 3, 12, 8), jnp.float32)
    net = NetworkMapping(name="mt", algorithm="synthetic",
                         array=m.array, layers=(m,))
    (kz,) = zero_pruned_kernels(net, [k])
    # tile 0 covers channels [0, 6): keeps [0, 5), prunes {5}
    assert float(jnp.abs(kz[:, :, 5]).sum()) == 0.0
    assert float(jnp.abs(kz[:, :, 11]).sum()) > 0.0   # last ch is KEPT
    ref = reference_conv2d(layer, x, kz)
    for y in (cim_conv2d(m, x, k), mapped_conv2d(m, x, k),
              sdk_conv(m, x, k, interpret=True)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)
    # the old convention (zero the layer-trailing sum) is NOT equivalent
    k_old = k.at[:, :, 11:, :].set(0.0)
    bad = reference_conv2d(layer, x, k_old)
    assert float(jnp.max(jnp.abs(bad - ref))) > 1e-3
