"""The shared training step (cnn/train.py, DESIGN.md §13): the
hand-rolled-Adam → optim/adamw bitwise regression, gradient
accumulation and pad-and-mask exactness, the one-compile-per-shape
rider, and the forced-memory-budget → remat="auto" acceptance path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.cnn.models import cnn8_config
from repro.cnn.train import (ADAM, _accum_grads, _microbatched,
                             _pad_and_mask, train_cnn, train_plan)
from repro.exec import compile_plan
from repro.exec.plan import compile_counts
from repro.optim.adamw import adamw_init, adamw_update

RNG = np.random.RandomState(3)


# ------------------------------------------------------------ optimizer

def test_adamw_step_bitwise_matches_handrolled_adam():
    """The optimizer dedup contract: with :data:`ADAM` (decay/clip off),
    `adamw_update` reproduces the hand-rolled closure it replaced
    BIT-FOR-BIT, step after step, under jit — the trainers changed
    modules without changing a single parameter bit."""
    lr = 3e-3
    params = {"w": jnp.asarray(RNG.randn(6, 6), jnp.float32),
              "b": jnp.asarray(RNG.randn(6), jnp.float32)}

    @jax.jit
    def old_step(params, opt, grads):
        # the pre-ISSUE-10 train_cnn closure, verbatim
        m = jax.tree.map(lambda m_, g: 0.9 * m_ + 0.1 * g,
                         opt["m"], grads)
        v = jax.tree.map(lambda v_, g: 0.999 * v_ + 0.001 * g * g,
                         opt["v"], grads)
        t = opt["t"] + 1

        def upd(p, m_, v_):
            mh = m_ / (1 - 0.9 ** t)
            vh = v_ / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}

    @jax.jit
    def new_step(params, opt, grads):
        p, o, _ = adamw_update(params, grads, opt, lr, ADAM)
        return p, o

    p_old = p_new = params
    o_old = {"m": jax.tree.map(jnp.zeros_like, params),
             "v": jax.tree.map(jnp.zeros_like, params),
             "t": jnp.zeros((), jnp.int32)}
    o_new = adamw_init(params)
    for i in range(50):
        grads = jax.tree.map(
            lambda p: jnp.asarray(RNG.randn(*p.shape), jnp.float32),
            params)
        p_old, o_old = old_step(p_old, o_old, grads)
        p_new, o_new = new_step(p_new, o_new, grads)
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(p_old[k]), np.asarray(p_new[k]),
                err_msg=f"step {i} param {k} diverged")


# --------------------------------------------------- accumulation + pad

def _toy_loss_sum(params, x, y, mask):
    per = (x @ params["w"] - y) ** 2
    return (per * mask).sum()


def test_pad_and_mask_grads_exact():
    """Padding a ragged tail to the compiled shape must not change the
    gradient AT ALL: the padded rows contribute exact float zeros, so
    the padded sum-then-divide is bitwise the unpadded one."""
    params = {"w": jnp.asarray(RNG.randn(5), jnp.float32)}
    x = jnp.asarray(RNG.randn(6, 5), jnp.float32)
    y = jnp.asarray(RNG.randn(6), jnp.float32)

    def sum_loss(params):
        return ((x @ params["w"] - y) ** 2).sum()
    g_sum = jax.grad(sum_loss)(params)
    g_ref = jax.tree.map(lambda g: g / 6.0, g_sum)

    xp, yp, mask = _pad_and_mask(x, y, 8)
    assert xp.shape[0] == 8 and float(mask.sum()) == 6.0
    loss, g = _accum_grads(_toy_loss_sum, params,
                           *_microbatched(xp, yp, mask, 1))
    np.testing.assert_array_equal(np.asarray(g["w"]),
                                  np.asarray(g_ref["w"]))
    np.testing.assert_array_equal(np.asarray(loss),
                                  np.asarray(sum_loss(params) / 6.0))


def test_accumulation_matches_whole_batch():
    """K scanned microbatches, summed then divided once == the
    whole-batch mean gradient (up to f32 summation order)."""
    params = {"w": jnp.asarray(RNG.randn(5), jnp.float32)}
    x = jnp.asarray(RNG.randn(8, 5), jnp.float32)
    y = jnp.asarray(RNG.randn(8), jnp.float32)
    mask = jnp.ones((8,), jnp.float32)
    _, g1 = _accum_grads(_toy_loss_sum, params,
                         *_microbatched(x, y, mask, 1))
    for accum in (2, 4):
        _, gk = _accum_grads(_toy_loss_sum, params,
                             *_microbatched(x, y, mask, accum))
        np.testing.assert_allclose(np.asarray(gk["w"]),
                                   np.asarray(g1["w"]), rtol=1e-6)


def test_train_cnn_validates_accum():
    with pytest.raises(ValueError, match="accum"):
        train_cnn(cnn8_config(), steps=1, batch=8, accum=3)


def test_train_cnn_remat_requires_plan_executor():
    with pytest.raises(ValueError, match="remat"):
        train_cnn(cnn8_config(), steps=1, batch=8, remat="auto",
                  executor="reference")


# ---------------------------------------------------------------- rider

def test_one_compile_per_shape_despite_ragged_tail():
    """The bugfix rider: with n_train < batch every step is ragged —
    pad-and-mask keeps the compiled step at ONE shape, so every plan
    cache key lowers exactly once (`exec.plan.compile_counts`)."""
    memo.clear()                      # resets the compile counters too
    train_cnn(cnn8_config(group=1), steps=3, batch=8, accum=2,
              n_train=6, n_test=6, executor="mapped",
              array=ArrayConfig(64, 64))
    counts = compile_counts()
    assert counts, "the mapped trainer must compile through plans"
    assert all(n == 1 for n in counts.values()), counts


# ----------------------------------------------------------- plan scale

def _densenet_prefix():
    return map_net("densenet40_p", networks.densenet40()[:14],
                   ArrayConfig(64, 64), "TetrisG-SDK", MacroGrid(2, 2),
                   groups=(1, 2))


def test_train_plan_budget_refusal_and_auto_remat(monkeypatch):
    """The acceptance path at test scale: under a forced
    REPRO_TRAIN_MEM_BUDGET between the segmented and unremat'd peak
    estimates, the flat plan refuses to train (deterministic OOM
    stand-in) and ``remat="auto"`` segments under the budget and
    trains, loss finite and moving."""
    net = _densenet_prefix()
    monkeypatch.delenv("REPRO_TRAIN_MEM_BUDGET", raising=False)
    flat = compile_plan(net, executor_policy="reference", batch=2)
    cut = compile_plan(net, executor_policy="reference", batch=2,
                       remat=(12,))
    assert cut.peak_bytes < flat.peak_bytes
    budget = (cut.peak_bytes + flat.peak_bytes) // 2
    monkeypatch.setenv("REPRO_TRAIN_MEM_BUDGET", str(budget))

    with pytest.raises(MemoryError, match="exceeds"):
        train_plan(net, steps=1, batch=2, n_train=16)

    losses: list = []
    r = train_plan(net, steps=2, batch=2, remat="auto", n_train=16,
                   losses=losses)
    assert r.segments == 2
    assert r.peak_mb < budget / 1e6 < r.unremat_peak_mb
    assert len(losses) == 2 and all(np.isfinite(losses))
    assert r.first_loss == losses[0] and r.final_loss == losses[-1]


def test_train_plan_validates_accum():
    net = _densenet_prefix()
    with pytest.raises(ValueError, match="accum"):
        train_plan(net, steps=1, batch=3, accum=2)


# -------------------------------------------------------------- tuning

def test_candidate_remat_in_space_and_describe():
    from repro.tune.space import Candidate, enumerate_space
    c = Candidate(policy=("mapped",), remat="auto")
    assert "remat=auto" in c.describe()
    assert "remat" not in Candidate(policy=("mapped",)).describe()
    net = _densenet_prefix()
    base = enumerate_space(net, batch=2)
    both = enumerate_space(net, batch=2, remats=(None, "auto"))
    assert len(both) == 2 * len(base)
    assert {c.remat for c in both} == {None, "auto"}
