"""Operator-generic mapping IR (DESIGN.md §11): matmul specs lower
through the unchanged TetrisG window/grid machinery, the "matmul"
executor matches the einsum oracle and the other executors, the ragged
tail blocks of the underlying kernels are exact, and the op kind rides
in the persistent disk-cache keys so stale conv-era entries are ignored.
"""
import pickle

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArrayConfig, ConvLayerSpec, MacroGrid, map_layer,
                        matmul_spec, memo)
from repro.cnn.mapped_net import check_steps

RNG = np.random.RandomState(7)


@pytest.fixture
def disk_cache(tmp_path):
    memo.clear()
    memo.set_disk_cache(tmp_path)
    try:
        yield tmp_path
    finally:
        memo.set_disk_cache(None)
        memo.clear()


# --- spec lowering ---------------------------------------------------------

def test_matmul_spec_is_degenerate_conv():
    s = matmul_spec("mm", m=16, d=128, f=384)
    assert s.op == "matmul"
    assert (s.i_w, s.i_h, s.k_w, s.k_h, s.ic, s.oc) == (1, 16, 1, 1, 128,
                                                        384)
    assert s.o_h == 16 and s.o_w == 1
    assert s.macs == 16 * 128 * 384
    g = matmul_spec("gmm", m=16, d=128, f=384, groups=4)
    assert g.macs == 16 * (128 // 4) * 384


def test_matmul_op_rejects_conv_geometry():
    with pytest.raises(ValueError, match="matmul_spec"):
        ConvLayerSpec("bad", 18, 18, 3, 3, 8, 8, op="matmul")
    with pytest.raises(ValueError, match="unknown op"):
        ConvLayerSpec("bad", 18, 18, 3, 3, 8, 8, op="attention")


@pytest.mark.parametrize("mdf,groups", [((16, 128, 384), (1,)),
                                        ((16, 352, 128), (1, 2, 4)),
                                        ((7, 96, 40), (1, 2, 4))])
def test_matmul_spec_maps_and_counts(mdf, groups):
    """The unchanged search maps a matmul spec; the ceil-form cycle
    count and the steps==cycles invariant hold exactly."""
    m, d, f = mdf
    memo.clear()
    lm = map_layer(matmul_spec("mm", m, d, f), ArrayConfig(64, 64),
                   "TetrisG-SDK", MacroGrid(2, 2), groups=groups)
    check_steps(lm)                       # steps == cycles, per tile
    assert lm.layer.op == "matmul"
    assert lm.cycles > 0
    assert lm.utilization > 0


def test_grouped_matmul_beats_dense_when_wide():
    """A wide square matmul on a small array: the §III-B grouped
    transform (k=1) must win cycles over the dense mapping."""
    memo.clear()
    spec = matmul_spec("mm", 16, 256, 256)
    dense = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(2, 2), groups=(1,))
    grouped = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                        MacroGrid(2, 2), groups=(1, 2, 4))
    assert grouped.group >= 2
    assert grouped.cycles < dense.cycles


# --- "matmul" executor vs oracles ------------------------------------------

def _mapped(m, d, f, groups=(1,)):
    return map_layer(matmul_spec("mm", m, d, f), ArrayConfig(64, 64),
                     "TetrisG-SDK", MacroGrid(2, 2), groups=groups)


@pytest.mark.parametrize("mdf,groups", [((16, 64, 96), (1,)),
                                        ((16, 128, 64), (1, 2, 4)),
                                        ((12, 60, 40), (1, 2))])
def test_matmul_executor_matches_einsum(mdf, groups):
    from repro.kernels.matmul_exec import (matmul_layer_ref,
                                           matmul_layer_traced)
    m, d, f = mdf
    memo.clear()
    lm = _mapped(m, d, f, groups)
    g = lm.group
    kernel = jnp.asarray(RNG.randn(1, 1, d // g, f) * 0.1, jnp.float32)
    x = jnp.asarray(RNG.randn(2, d, m, 1), jnp.float32)
    y = matmul_layer_traced(lm, x, kernel, interpret=True)
    r = matmul_layer_ref(lm, x, kernel)
    assert y.shape == (2, f, m, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=1e-4, rtol=1e-4)


def test_matmul_layer_through_reference_executor():
    """A matmul layer is an ordinary degenerate conv to the conv
    executors — both paths agree on the same mapping and kernel."""
    from repro.cnn.cim_conv import reference_conv2d
    from repro.kernels.matmul_exec import matmul_layer_traced
    memo.clear()
    lm = _mapped(16, 64, 48)
    kernel = jnp.asarray(RNG.randn(1, 1, 64, 48) * 0.1, jnp.float32)
    x = jnp.asarray(RNG.randn(2, 64, 16, 1), jnp.float32)
    y = matmul_layer_traced(lm, x, kernel, interpret=True)
    r = reference_conv2d(lm.layer, x, kernel)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=1e-4, rtol=1e-4)


# --- ragged tail blocks of the underlying kernels --------------------------
# explicit block shapes that do NOT divide the problem: the clamped
# overlapping edge blocks (the marginal-window analogue) must still
# produce exactly the dense result on every tail row/column.

@pytest.mark.parametrize("mnk,block", [
    ((100, 60, 48), (32, 32, 16)),    # M and N tails, K divides
    ((33, 129, 64), (32, 128, 32)),   # single-row M tail, 1-col N tail
    ((64, 64, 50), (32, 32, 32)),     # K does not divide -> bk shrinks
    ((7, 5, 3), (8, 8, 8)),           # blocks larger than the problem
])
def test_tetris_matmul_tail_blocks(mnk, block):
    from repro.kernels.ref import matmul_ref
    from repro.kernels.tetris_matmul import tetris_matmul
    m, n, k = mnk
    x = jnp.asarray(RNG.randn(m, k), jnp.float32)
    w = jnp.asarray(RNG.randn(k, n), jnp.float32)
    y = tetris_matmul(x, w, block=block, interpret=True)
    r = matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=1e-4 * k, rtol=1e-4)


@pytest.mark.parametrize("gmdf,bmbf", [
    ((3, 50, 24, 30), (16, 16)),      # M and F tails in every group
    ((2, 17, 40, 65), (16, 64)),      # 1-row M tail, 1-col F tail
    ((5, 8, 12, 8), (16, 16)),        # blocks larger than the problem
])
def test_grouped_matmul_tail_blocks(gmdf, bmbf):
    from repro.kernels.grouped_matmul import grouped_matmul
    from repro.kernels.ref import grouped_matmul_ref
    g, m, d, f = gmdf
    bm, bf = bmbf
    x = jnp.asarray(RNG.randn(g, m, d), jnp.float32)
    w = jnp.asarray(RNG.randn(g, d, f), jnp.float32)
    y = grouped_matmul(x, w, bm=bm, bf=bf, interpret=True)
    r = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               atol=1e-4 * d, rtol=1e-4)


# --- cache schema (ISSUE 8 satellite) --------------------------------------

def test_conv_and_matmul_specs_never_share_disk_entries(disk_cache):
    """Same name, same degenerate geometry, different op kind: two
    distinct disk entries — the op axis rides in the memo key."""
    mm = matmul_spec("t", 16, 32, 24)
    conv = ConvLayerSpec("t", i_w=1, i_h=16, k_w=1, k_h=1, ic=32, oc=24)
    a = map_layer(mm, ArrayConfig(64, 64), "TetrisG-SDK", MacroGrid(2, 2),
                  groups=(1,))
    b = map_layer(conv, ArrayConfig(64, 64), "TetrisG-SDK", MacroGrid(2, 2),
                  groups=(1,))
    assert a.layer.op == "matmul" and b.layer.op == "conv"
    assert memo.stats["disk_writes"] >= 2
    assert len(list(disk_cache.glob("*.mapping.pkl"))) >= 2


def test_stale_schema_disk_entries_ignored(disk_cache, monkeypatch):
    """A schema bump (the op-kind axis) must orphan old entries, not
    deserialize them: a process with a newer SCHEMA_VERSION sees only
    misses against an old directory and recomputes bit-identically."""
    spec = matmul_spec("mm", 16, 64, 48)
    first = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(2, 2), groups=(1, 2))
    assert memo.stats["disk_writes"] > 0
    old_files = set(disk_cache.glob("*.mapping.pkl"))

    monkeypatch.setattr(memo, "SCHEMA_VERSION", memo.SCHEMA_VERSION + 1)
    memo.clear()                       # cold in-memory, "old" disk
    again = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(2, 2), groups=(1, 2))
    assert again == first              # recomputed, not deserialized
    assert memo.stats["disk_hits"] == 0
    assert memo.stats["disk_writes"] > 0          # re-persisted under v+1
    assert old_files - set(disk_cache.glob("*.mapping.pkl")) == set()


def test_stale_payload_version_ignored(disk_cache):
    """Belt-and-braces: an entry whose pickled payload carries the wrong
    version (however it got to that path) reads as a miss, never as a
    value."""
    spec = matmul_spec("mm", 16, 32, 24)
    first = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(2, 2), groups=(1,))
    files = list(disk_cache.glob("*.mapping.pkl"))
    assert files
    for f in files:
        version, value = pickle.loads(f.read_bytes())
        f.write_bytes(pickle.dumps((version + 1, value)))
    memo.clear()
    again = map_layer(spec, ArrayConfig(64, 64), "TetrisG-SDK",
                      MacroGrid(2, 2), groups=(1,))
    assert again == first
    assert memo.stats["disk_hits"] == 0
