"""Memory-model + segmentation passes (exec/memory.py, exec/remat.py)
and the remat'd fused forward: IR-derived byte estimates, the
concat-groups-never-split boundary rule, greedy budgeting, the
PLAN_VERSION stale-cache contract, and remat on/off forward
bit-identity + exact gradients through `jax.checkpoint` segments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import compile_plan, execute_plan
from repro.exec.memory import (ITEMSIZE, LayerMemory, activation_bytes,
                               peak_bytes, total_bytes, weight_prep_bytes)
from repro.exec.remat import (allowed_cuts, canonical_remat,
                              greedy_segments, plan_segments)

RNG = np.random.RandomState(11)


def _net(name="cnn8", layers=None):
    layers = networks.NETWORKS[name]() if layers is None else layers
    return map_net(name, layers, ArrayConfig(64, 64), "TetrisG-SDK",
                   MacroGrid(2, 2), groups=(1, 2))


def _densenet_prefix(n=14):
    """densenet40 block 1 + its 1x1 transition (index 12) + the start
    of block 2 — the smallest slice with a legal cut inside it."""
    return _net("densenet40_p", networks.densenet40()[:n])


def _data(net, batch=2):
    ks = zero_pruned_kernels(net, [
        jnp.asarray(RNG.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net.layers])
    first = net.layers[0].layer
    x = jnp.asarray(RNG.randn(batch, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    return ks, x


# ---------------------------------------------------------------- model

def test_layer_memory_matches_formulas():
    """The memory pass writes per-layer estimates into the IR: act bytes
    are the carry activation entering the layer at the plan batch, and
    the plan's unremat peak is their plain sum."""
    net = _net()
    plan = compile_plan(net, executor_policy="mapped", batch=2)
    for lp in plan.layers:
        lay = lp.mapping.layer
        assert lp.act_bytes == 2 * lp.carry_c * lay.i_h * lay.i_w * ITEMSIZE
        assert lp.act_bytes == activation_bytes(lp.mapping, lp.carry_c, 2)
        assert lp.weight_bytes == weight_prep_bytes(lp.mapping) > 0
        assert lp.mem_bytes == lp.act_bytes + lp.weight_bytes
    assert plan.unremat_peak_bytes == sum(lp.mem_bytes
                                          for lp in plan.layers)
    # no batch given -> estimates price a single example
    b1 = compile_plan(net, executor_policy="mapped")
    assert b1.layers[0].act_bytes == plan.layers[0].act_bytes // 2


def test_peak_model():
    """peak = heaviest segment + stored boundary carries; one segment
    degenerates to the total."""
    mem = [LayerMemory(f"l{i}", act_bytes=10, weight_bytes=5)
           for i in range(4)]
    assert total_bytes(mem) == 60
    assert peak_bytes(mem, [(0, 4)]) == 60
    # two segments of 2: heaviest 30, one boundary carry of 10
    assert peak_bytes(mem, [(0, 2), (2, 4)]) == 40
    assert peak_bytes(mem, [(0, 1), (1, 4)]) == 45 + 10


def test_describe_surfaces_memory():
    net = _densenet_prefix()
    plan = compile_plan(net, executor_policy="mapped", batch=2,
                        remat=(12,))
    assert "peak_mem=" in plan.describe()
    assert "segments=2" in plan.describe()
    txt = plan.describe_memory()
    assert txt.count("act=") == len(plan.layers)
    assert "<- segment" in txt
    flat = compile_plan(net, executor_policy="mapped", batch=2)
    assert "segments=" not in flat.describe()      # PR-4-era shape


# ----------------------------------------------------------- boundaries

def test_allowed_cuts_chain_every_boundary():
    net = _net()
    plan = compile_plan(net, executor_policy="mapped")
    glue = [lp.glue for lp in plan.layers]
    assert allowed_cuts(glue) == tuple(range(len(net.layers) - 1))


def test_allowed_cuts_densenet_transitions_only():
    """Inside a dense block every output is saved for downstream concats
    — the only legal cuts are the 1x1 transitions (full net: 12, 25)."""
    net = _densenet_prefix()
    plan = compile_plan(net, executor_policy="mapped")
    glue = [lp.glue for lp in plan.layers]
    assert allowed_cuts(glue) == (12,)


def test_explicit_cuts_never_split_concat_groups():
    """Property form of the never-split rule: EVERY non-transition
    boundary of the densenet prefix is rejected with the allowed list
    in the message; the transition itself compiles to two segments."""
    net = _densenet_prefix()
    for bad in range(12):
        with pytest.raises(ValueError, match="illegal remat boundaries"):
            compile_plan(net, executor_policy="mapped", batch=2,
                         remat=(bad,))
    plan = compile_plan(net, executor_policy="mapped", batch=2,
                        remat=(12,))
    assert plan.segments == ((0, 13), (13, len(net.layers)))
    assert plan.peak_bytes < plan.unremat_peak_bytes


def test_greedy_segments_budget_behavior():
    mem = [LayerMemory(f"l{i}", act_bytes=8, weight_bytes=2)
           for i in range(6)]
    allowed = tuple(range(5))
    assert greedy_segments(mem, allowed, total_bytes(mem)) == ((0, 6),)
    # tiny budget: every allowed boundary cuts
    segs = greedy_segments(mem, allowed, 1)
    assert segs == ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6))
    # segments always tile the layer range contiguously
    for budget in (15, 25, 40):
        segs = greedy_segments(mem, allowed, budget)
        assert segs[0][0] == 0 and segs[-1][1] == 6
        assert all(a[1] == b[0] for a, b in zip(segs, segs[1:]))
    # restricted legality: greedy only uses the cuts it is given
    segs = greedy_segments(mem, (3,), 1)
    assert segs == ((0, 4), (4, 6))


def test_plan_segments_spec_forms():
    mem = [LayerMemory(f"l{i}", act_bytes=8, weight_bytes=2)
           for i in range(6)]
    allowed = tuple(range(5))
    assert plan_segments(mem, allowed, None) is None
    assert plan_segments(mem, allowed, ("cuts", (2,))) == ((0, 3), (3, 6))
    assert plan_segments(mem, allowed, ("budget", 30)) == \
        greedy_segments(mem, allowed, 30)
    # auto with no env budget targets ~sqrt(n) segments
    auto = plan_segments(mem, allowed, ("auto", None))
    assert len(auto) >= 2


def test_canonical_remat_forms(monkeypatch):
    monkeypatch.delenv("REPRO_TRAIN_MEM_BUDGET", raising=False)
    assert canonical_remat(None) is None
    assert canonical_remat("off") is None
    assert canonical_remat(False) is None
    assert canonical_remat("auto") == ("auto", None)
    monkeypatch.setenv("REPRO_TRAIN_MEM_BUDGET", "12345")
    assert canonical_remat("auto") == ("auto", 12345)
    assert canonical_remat(1 << 20) == ("budget", 1 << 20)
    assert canonical_remat([3, 1]) == ("cuts", (1, 3))
    with pytest.raises(ValueError, match="positive"):
        canonical_remat(0)
    with pytest.raises(ValueError, match="ambiguous"):
        canonical_remat(True)
    with pytest.raises(ValueError):
        canonical_remat(object())


# ------------------------------------------------------------ execution

def test_remat_forward_and_grads_exact_chain():
    """cnn8 (plain chain): the segmented program is the SAME math —
    forward bit-identical, gradients exactly equal.  Reference executor:
    the property is segment-structural, and mapped-vs-reference gradient
    equality is already pinned by tests/test_mapped_net.py."""
    net = _net()
    ks, x = _data(net)
    flat = compile_plan(net, executor_policy="reference", batch=2)
    seg = compile_plan(net, executor_policy="reference", batch=2,
                       remat="auto")
    assert len(seg.spans) > 1

    def loss(plan):
        return lambda ks: execute_plan(plan, ks, x).sum()

    y0, y1 = execute_plan(flat, ks, x), execute_plan(seg, ks, x)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    g0 = jax.grad(loss(flat))(ks)
    g1 = jax.grad(loss(seg))(ks)
    for a, b in zip(g0, g1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remat_forward_and_grads_exact_densenet_concat():
    """DenseNet prefix (concat glue + a transition cut): checkpointing
    at the transition must not perturb forward or gradients."""
    net = _densenet_prefix()
    ks, x = _data(net)
    flat = compile_plan(net, executor_policy="reference", batch=2)
    seg = compile_plan(net, executor_policy="reference", batch=2,
                       remat=(12,))

    def loss(plan):
        return lambda ks: execute_plan(plan, ks, x,
                                       activation=jax.nn.relu).sum()

    y0 = execute_plan(flat, ks, x, activation=jax.nn.relu)
    y1 = execute_plan(seg, ks, x, activation=jax.nn.relu)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    g0 = jax.grad(loss(flat))(ks)
    g1 = jax.grad(loss(seg))(ks)
    for a, b in zip(g0, g1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------- caching

@pytest.fixture
def disk_cache(tmp_path):
    memo.clear()
    memo.set_disk_cache(tmp_path)
    try:
        yield tmp_path
    finally:
        memo.set_disk_cache(None)
        memo.clear()


def test_plan_version_stale_cache(disk_cache, monkeypatch):
    """The PLAN_VERSION bump contract: plans persist under their
    version, so a payload written by an older schema reads as a miss
    (recompile), never as a stale value."""
    net = _net()
    compile_plan(net, executor_policy="mapped", batch=2, remat="auto")
    memo.clear()
    h0 = memo.stats["disk_hits"]
    compile_plan(net, executor_policy="mapped", batch=2, remat="auto")
    assert memo.stats["disk_hits"] > h0          # warm across processes
    # a version bump must ignore every previously persisted plan
    monkeypatch.setattr(memo, "PLAN_VERSION", memo.PLAN_VERSION + 1)
    memo.clear()
    h1, m1 = memo.stats["disk_hits"], memo.stats["disk_misses"]
    plan = compile_plan(net, executor_policy="mapped", batch=2,
                        remat="auto")
    assert memo.stats["disk_hits"] == h1         # no stale read
    assert memo.stats["disk_misses"] > m1
    assert plan.segments is not None             # recompiled for real


def test_env_budget_part_of_cache_key(monkeypatch):
    """Flipping REPRO_TRAIN_MEM_BUDGET must never serve a stale "auto"
    plan: the env budget folds into the canonical spec and the key."""
    net = _densenet_prefix()
    monkeypatch.delenv("REPRO_TRAIN_MEM_BUDGET", raising=False)
    a = compile_plan(net, executor_policy="mapped", batch=2, remat="auto")
    monkeypatch.setenv("REPRO_TRAIN_MEM_BUDGET",
                       str(net.layers[0].layer.i_w))   # absurdly tiny
    b = compile_plan(net, executor_policy="mapped", batch=2, remat="auto")
    assert a is not b
    assert len(b.spans) >= len(a.spans)
