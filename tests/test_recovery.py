"""Fault-tolerance runtime (runtime/recovery.py) under a fake clock:
heartbeat deadlines, liveness-only beats vs step reports, straggler
warn/demote thresholds, retirement via forget, and elastic re-meshing
on the surviving device count."""
import pytest

from repro.runtime.recovery import (HeartbeatMonitor, StragglerPolicy,
                                    derive_elastic_mesh)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# HeartbeatMonitor: liveness
# ---------------------------------------------------------------------------

def test_dead_after_detection():
    """A worker silent past dead_after_s is declared dead; anything
    that beat within the deadline is not."""
    clk = FakeClock()
    mon = HeartbeatMonitor(3, dead_after_s=1.0, clock=clk)
    assert mon.dead_workers() == []
    clk.advance(0.9)
    mon.beat(1)                       # refresh worker 1 only
    assert mon.dead_workers() == []   # nobody past the deadline yet
    clk.advance(0.2)                  # t=1.1: workers 0,2 silent 1.1s
    assert mon.dead_workers() == [0, 2]
    clk.advance(1.0)                  # t=2.1: worker 1 silent 1.2s
    assert mon.dead_workers() == [0, 1, 2]


def test_beat_is_liveness_only_report_feeds_durations():
    """beat() refreshes the deadline without polluting the straggler
    step statistics; report() does both."""
    clk = FakeClock()
    mon = HeartbeatMonitor(2, dead_after_s=1.0, clock=clk)
    for _ in range(5):
        mon.beat(0)
    mon.report(1, 0.25)
    assert mon.durations[0] == []          # idle heartbeats left no steps
    assert mon.durations[1] == [0.25]
    clk.advance(1.5)
    assert mon.dead_workers() == [0, 1]
    mon.beat(0)
    mon.report(1, 0.3)
    assert mon.dead_workers() == []        # both signals refresh liveness


def test_forget_retires_dead_worker():
    """After forget() a dead worker stops being re-reported — the
    router re-queues its work exactly once — and its step history
    leaves the straggler scan."""
    clk = FakeClock()
    mon = HeartbeatMonitor(2, dead_after_s=1.0, clock=clk)
    mon.report(0, 0.1)
    clk.advance(2.0)
    assert mon.dead_workers() == [0, 1]
    mon.forget(0)
    assert mon.dead_workers() == [1]
    assert 0 not in mon.durations and 0 not in mon.last_seen
    mon.forget(0)                          # idempotent
    assert mon.dead_workers() == [1]


def test_report_window_bounds_history():
    clk = FakeClock()
    mon = HeartbeatMonitor(1, policy=StragglerPolicy(window=3), clock=clk)
    for i in range(10):
        mon.report(0, float(i))
    assert mon.durations[0] == [7.0, 8.0, 9.0]


# ---------------------------------------------------------------------------
# Straggler policy
# ---------------------------------------------------------------------------

def _fed_monitor(per_worker):
    clk = FakeClock()
    mon = HeartbeatMonitor(len(per_worker), clock=clk)
    for w, durs in enumerate(per_worker):
        for d in durs:
            mon.report(w, d)
    return mon


def test_straggler_warn_and_demote_thresholds():
    """Per-worker median vs fleet median: > warn_factor x -> warn,
    > demote_factor x -> demote (defaults 1.5x / 3x)."""
    mon = _fed_monitor([
        [1.0] * 5,          # healthy: median 1.0
        [1.0] * 5,
        [2.0] * 3,          # 2x fleet median -> warn
        [4.0] * 3,          # 4x -> demote
    ])
    out = mon.stragglers()
    assert out == {2: "warn", 3: "demote"}


def test_straggler_needs_history():
    """No step reports anywhere -> no stragglers (median undefined);
    a worker with no history is skipped, not flagged."""
    clk = FakeClock()
    mon = HeartbeatMonitor(2, clock=clk)
    assert mon.stragglers() == {}
    mon.report(0, 1.0)
    assert 1 not in mon.stragglers()


def test_straggler_policy_factors_respected():
    mon = _fed_monitor([[1.0] * 6, [1.6] * 4])
    mon.policy = StragglerPolicy(warn_factor=2.0, demote_factor=4.0)
    assert mon.stragglers() == {}          # 1.6x < 2x: healthy now
    mon.policy = StragglerPolicy(warn_factor=1.1, demote_factor=1.5)
    assert mon.stragglers()[1] == "demote"


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

def test_derive_elastic_mesh_power_of_two_data_axis():
    p = derive_elastic_mesh(8, model_parallel=2)
    assert p.shape == (4, 2) and p.axes == ("data", "model")
    assert p.dropped == 0
    p = derive_elastic_mesh(7, model_parallel=2)   # 7//2=3 -> floor to 2
    assert p.shape == (2, 2) and p.dropped == 3
    p = derive_elastic_mesh(6, model_parallel=1)
    assert p.shape == (4, 1) and p.dropped == 2


def test_derive_elastic_mesh_survivor_counts():
    """Walking survivors down re-meshes monotonically: the data axis
    never grows as workers die."""
    sizes = [derive_elastic_mesh(n, model_parallel=2).shape[0]
             for n in range(8, 1, -1)]
    assert sizes == sorted(sizes, reverse=True)
    assert derive_elastic_mesh(2, model_parallel=2).shape == (1, 2)


def test_derive_elastic_mesh_raises_below_model_parallel():
    with pytest.raises(RuntimeError, match="model_parallel"):
        derive_elastic_mesh(1, model_parallel=2)
