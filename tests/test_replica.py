"""Multi-replica serving (launch/replica.py): router dispatch and
exactly-once accounting as pure unit tests, the full serve loop driven
deterministically through the in-memory fake transport + fake clock
(worker death, re-queue, heartbeat-timeout hang detection), and slow
real-multiprocess runs (scaling vs the single-process path, lossless
kill-a-worker recovery)."""
import os

import numpy as np
import pytest

from repro.launch import batching
from repro.launch.batching import (CTRL_DIE, CTRL_GO, CTRL_STOP, MSG_DONE,
                                   MSG_DYING, MSG_HEARTBEAT, MSG_READY,
                                   MSG_STATS, Coalescer, InMemoryTransport,
                                   WorkItem)
from repro.launch.replica import (NoSurvivorsError, ReplicaRouter,
                                  ReplicaStats, WorkerConfig, WorkerView,
                                  serve_replicas)
from repro.runtime.recovery import HeartbeatMonitor


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Router unit tests (no transport, no clock)
# ---------------------------------------------------------------------------

def test_router_least_loaded_dispatch():
    """Items go to the replica with the fewest outstanding rows; ties
    break to fewer outstanding requests, then lowest wid."""
    r = ReplicaRouter(3)
    assert r.dispatch(WorkItem(0, 4, 0.0)) == 0      # all empty -> wid 0
    assert r.dispatch(WorkItem(1, 1, 0.0)) == 1
    assert r.dispatch(WorkItem(2, 1, 0.0)) == 2
    assert r.dispatch(WorkItem(3, 2, 0.0)) == 1      # 1 row < 2 rows < 4
    assert r.dispatch(WorkItem(4, 1, 0.0)) == 2      # now 2: ties to wid 2
    assert r.load(0) == 4 and r.load(1) == 3 and r.load(2) == 2
    assert r.dispatched == 5 and r.incomplete() == 5


def test_router_completion_accounting_and_dedup():
    """First completion wins; a second completion for the same seq is
    counted as duplicate_serves and changes nothing else."""
    r = ReplicaRouter(2)
    r.dispatch(WorkItem(0, 2, 0.0))
    r.dispatch(WorkItem(1, 1, 0.0))
    new = r.on_batch_done(0, 2, [(0, 2, 0.010)], exec_s=0.005)
    assert new == 1 and r.incomplete() == 1
    assert r.views[0].served_requests == 1 and r.views[0].served_rows == 2
    assert r.views[0].delays_s == [0.010]
    assert r.load(0) == 0                    # outstanding retired
    new = r.on_batch_done(1, 1, [(0, 2, 0.020), (1, 1, 0.001)])
    assert new == 1                          # seq 0 was a duplicate
    assert r.duplicate_serves == 1 and r.incomplete() == 0
    assert r.served == {0: 0, 1: 1}


def test_router_mark_dead_requeues_once():
    """mark_dead hands back the dead worker's outstanding items in seq
    order exactly once (idempotent), and re-dispatching them does not
    inflate the distinct-request count."""
    r = ReplicaRouter(2)
    for seq in range(4):
        r.dispatch(WorkItem(seq, 1, 0.0))
    r.on_batch_done(0, 1, [(0, 1, 0.0)])
    items = r.mark_dead(0)
    assert [i.seq for i in items] == [2]     # seq 0 served, 1/3 on wid 1
    assert r.mark_dead(0) == []              # idempotent
    assert r.deaths == 1 and r.requeued == 1
    assert r.dispatch(items[0]) == 1         # only survivor
    assert r.dispatched == 4                 # re-queue is not a new request
    assert not r.views[0].alive and r.alive_ids() == [1]


def test_router_no_survivors_raises():
    r = ReplicaRouter(1)
    r.dispatch(WorkItem(0, 1, 0.0))
    r.mark_dead(0)
    with pytest.raises(NoSurvivorsError, match="no live replica"):
        r.dispatch(WorkItem(1, 1, 0.0))
    with pytest.raises(ValueError, match=">= 1 replica"):
        ReplicaRouter(0)


def test_router_heartbeat_and_deadline_dead():
    """Heartbeats feed the monitor for live workers only; a silent
    worker crosses the deadline and shows up in deadline_dead until it
    is marked dead (then the monitor forgets it)."""
    clk = FakeClock()
    mon = HeartbeatMonitor(2, dead_after_s=1.0, clock=clk)
    r = ReplicaRouter(2, monitor=mon)
    clk.advance(0.9)
    r.on_heartbeat(0)
    clk.advance(0.2)                         # wid 1 now silent for 1.1s
    assert r.deadline_dead() == [1]
    r.mark_dead(1)
    assert r.deadline_dead() == []           # forgotten, not re-reported
    r.on_heartbeat(1)                        # late beat from a dead wid
    assert 1 not in mon.last_seen            # ignored: not alive


def test_replica_stats_pooled_percentiles_match_numpy():
    """Aggregate queue-delay percentiles pool ALL per-worker samples —
    cross-checked against numpy on the pooled vector, and distinct from
    the average of per-worker percentiles."""
    w0 = WorkerView(0, delays_s=[0.001, 0.002, 0.003, 0.100])
    w1 = WorkerView(1, delays_s=[0.004, 0.200, 0.300, 0.400, 0.500])
    rs = ReplicaStats(workers={0: w0, 1: w1}, wall_s=1.0, requeued=0,
                      duplicate_serves=0, deaths=0)
    pooled = w0.delays_s + w1.delays_s
    for q in (50, 95, 99):
        expect = float(np.percentile(pooled, q,
                                     method="inverted_cdf")) * 1e3
        assert rs.delay_ms(q) == pytest.approx(expect)
        avg = (batching.percentile(w0.delays_s, q)
               + batching.percentile(w1.delays_s, q)) / 2 * 1e3
        assert rs.delay_ms(q) != pytest.approx(avg)
    assert "pooled" in rs.describe()


# ---------------------------------------------------------------------------
# Deterministic end-to-end: fake transport + fake clock
# ---------------------------------------------------------------------------

SERVED_LOG: list = []      # every (wid, seq) any fake worker ever served


class FakeWorker:
    """Synchronous stand-in for `_worker_main`: same protocol, one
    coalescer pop per step, virtual clock, rate-limited heartbeats."""

    def __init__(self, wid, cfg, inbox, emit, clock, *, startup_s=0.1,
                 exec_s=0.001, table_misses=1, disk_hits=0):
        self.wid, self.cfg = wid, cfg
        self.inbox, self.emit, self.clock = inbox, emit, clock
        self.epoch = None
        self.co = Coalescer(cfg.max_batch, cfg.max_delay_ms / 1e3)
        self.stopping = False
        self.exec_s = exec_s
        self.last_hb = None
        self.served_rows = self.padded_rows = self.batches = 0
        emit((MSG_READY, wid, startup_s, table_misses, disk_hits))

    def on_batch(self, entries):
        SERVED_LOG.extend((self.wid, seq) for seq, _, _ in entries)

    def step(self):
        while self.inbox:
            msg = self.inbox.popleft()
            if isinstance(msg, WorkItem):
                self.co.push(msg.rows, msg.arrival_s, payload=msg)
            elif msg[0] == CTRL_GO:
                self.epoch = float(msg[1])
            elif msg[0] == CTRL_STOP:
                self.stopping = True
            elif msg[0] == CTRL_DIE:
                self.emit((MSG_DYING, self.wid, "killed"))
                return False
        if self.epoch is None:
            return True
        now = self.clock() - self.epoch
        if self.last_hb is None or now - self.last_hb >= self.cfg.heartbeat_s:
            self.last_hb = now
            self.emit((MSG_HEARTBEAT, self.wid, now))
        batch = self.co.pop(now, force=self.stopping)
        if batch:
            rows = sum(r.rows for r in batch)
            tier = batching.tier_for(
                rows, batching.batch_tiers(self.cfg.max_batch))
            entries = tuple((r.payload.seq, r.rows, now - r.arrival_s)
                            for r in batch)
            self.on_batch(entries)
            self.served_rows += rows
            self.padded_rows += tier
            self.batches += 1
            self.emit((MSG_DONE, self.wid, tier, entries, self.exec_s))
        elif self.stopping and not len(self.co):
            self.emit((MSG_STATS, self.wid, self.served_rows,
                       self.padded_rows, self.batches))
            return False
        return True


def _fake_serve(trace, n, *, worker_cls=FakeWorker, cfg=None, **kw):
    SERVED_LOG.clear()
    clk = FakeClock()
    cfg = cfg or WorkerConfig(max_batch=4, max_delay_ms=2.0,
                              heartbeat_s=0.05)
    transport = InMemoryTransport(
        lambda wid, c, inbox, emit: worker_cls(wid, c, inbox, emit, clk))
    rs = serve_replicas(trace, cfg, n, transport=transport,
                        clock=clk, sleep=clk.advance, **kw)
    return rs


def test_fake_transport_serves_everything_balanced():
    """A backlogged trace of singles drains across both workers, every
    request exactly once, with the router's least-loaded dispatch
    splitting the load evenly."""
    trace = [(0.0, 1)] * 12
    rs = _fake_serve(trace, 2)
    assert rs.request_images == 12 and rs.deaths == 0
    assert rs.duplicate_serves == 0 and rs.requeued == 0
    served = sorted(seq for _, seq in SERVED_LOG)
    assert served == list(range(12))         # exactly once, all of them
    per_worker = [rs.workers[w].served_requests for w in (0, 1)]
    assert per_worker == [6, 6]
    assert rs.workers[0].startup_s == pytest.approx(0.1)
    assert len(rs.delays_s) == 12


def test_fake_transport_timed_arrivals_advance_clock():
    """A timed trace forces the serve loop through its idle path: the
    fake clock must advance (injected sleep) until each arrival is due,
    and queue delays reflect the coalescer's max-delay wait."""
    trace = [(0.0, 1), (0.5, 2), (1.0, 1)]
    rs = _fake_serve(trace, 2)
    assert rs.request_images == 4
    assert rs.wall_s >= 1.0                  # virtual time really passed
    assert rs.duplicate_serves == 0
    # lone singles wait out the 2ms coalescing delay before launching
    assert all(0.0 <= d <= 0.1 for d in rs.delays_s)


def test_fake_transport_kill_worker_lossless():
    """THE recovery contract: a worker killed mid-backlog loses nothing
    — its outstanding requests are re-queued to the survivor and every
    request is served exactly once."""
    trace = [(0.0, 1)] * 12
    rs = _fake_serve(trace, 2, kill_worker=1, kill_after_batches=1)
    assert rs.deaths == 1 and not rs.workers[1].alive
    assert rs.requeued > 0
    assert rs.duplicate_serves == 0
    served = sorted(seq for _, seq in SERVED_LOG)
    assert served == list(range(12))         # exactly once, all of them
    assert rs.workers[1].batches >= 1        # it did work before dying
    assert rs.request_images == 12


class HangingWorker(FakeWorker):
    """wid 1 goes silent after its first batch: alive per the
    transport, but no heartbeats, no completions — the deadline must
    catch it (process-death detection alone never would)."""

    def step(self):
        if self.wid == 1 and self.batches >= 1:
            return True                      # hung: holds work forever
        return super().step()


def test_fake_transport_heartbeat_timeout_recovers_hung_worker():
    """A hung worker (process alive, no heartbeats) is declared dead at
    the monitor's deadline and its queued work re-served — the recovery
    path that process-death detection alone cannot catch."""
    trace = [(0.0, 1)] * 12
    rs = _fake_serve(trace, 2, kill_worker=None, dead_after_s=0.5)
    assert rs.deaths == 0                    # healthy baseline first
    SERVED_LOG.clear()
    rs = _fake_serve(trace, 2, worker_cls=HangingWorker, dead_after_s=0.5)
    assert rs.deaths == 1 and rs.requeued > 0
    assert rs.duplicate_serves == 0
    served = sorted(seq for _, seq in SERVED_LOG)
    assert served == list(range(12))
    hung = [w for w, v in rs.workers.items() if not v.alive]
    assert len(hung) == 1


class StillbornWorker(FakeWorker):
    """Dies during startup instead of reporting ready."""

    def __init__(self, wid, cfg, inbox, emit, clock, **kw):
        self.wid = wid
        emit((MSG_DYING, wid, "startup: boom"))

    def step(self):
        return False


def test_startup_death_raises():
    with pytest.raises(RuntimeError, match="died during startup"):
        _fake_serve([(0.0, 1)], 1, worker_cls=StillbornWorker)


class SilentWorker(FakeWorker):
    """Never reports ready at all (hung startup)."""

    def __init__(self, wid, cfg, inbox, emit, clock, **kw):
        self.wid = wid

    def step(self):
        return True


def test_ready_timeout_raises():
    with pytest.raises(RuntimeError, match="became\\s+ready|ready within"):
        _fake_serve([(0.0, 1)], 1, worker_cls=SilentWorker,
                    ready_timeout_s=1.0)


def test_kill_only_worker_raises_no_survivors():
    # 10 singles: two full batches drain instantly, the 2-row leftover
    # keeps the worker loaded so the kill injection actually fires.
    trace = [(0.0, 1)] * 10
    with pytest.raises(NoSurvivorsError):
        _fake_serve(trace, 1, kill_worker=0, kill_after_batches=1)


def test_serve_replicas_validates_inputs():
    cfg = WorkerConfig(max_batch=4)
    with pytest.raises(ValueError, match="never split"):
        serve_replicas([(0.0, 8)], cfg, 2,
                       transport=InMemoryTransport(lambda *a: None))
    with pytest.raises(ValueError, match="kill_worker"):
        serve_replicas([(0.0, 1)], cfg, 2, kill_worker=5,
                       transport=InMemoryTransport(lambda *a: None))
    with pytest.raises(ValueError, match=">= 1 replica"):
        serve_replicas([(0.0, 1)], cfg, 0,
                       transport=InMemoryTransport(lambda *a: None))


# ---------------------------------------------------------------------------
# Real multiprocess paths (slow)
# ---------------------------------------------------------------------------

def _mp_config(cache_dir):
    return WorkerConfig(net="cnn8", array=(64, 64), grid=(2, 2), layers=4,
                        groups=(1, 2), max_batch=4, max_delay_ms=2.0,
                        warmup=1, cache_dir=str(cache_dir))


@pytest.mark.slow
def test_mp_kill_worker_lossless(tmp_path):
    """Spawned-process recovery: kill one of two real workers while it
    holds a backlog — the run still serves every request exactly once
    (zero lost, zero duplicated), survivors pick up the re-queued
    work."""
    from repro.launch.serve_cnn import poisson_arrivals
    trace = poisson_arrivals(24, 0.0, 4, seed=1)
    rs = serve_replicas(trace, _mp_config(tmp_path / "cache"), 2,
                        kill_worker=1, kill_after_batches=0)
    assert rs.deaths == 1 and not rs.workers[1].alive
    assert rs.requeued > 0
    assert rs.duplicate_serves == 0
    assert sum(v.served_requests for v in rs.workers.values()) == 24
    assert rs.request_images == sum(r for _, r in trace)
    assert rs.workers[0].served_requests + rs.workers[1].served_requests \
        == 24


@pytest.mark.slow
@pytest.mark.skipif((os.cpu_count() or 1) < 2,
                    reason="process scale-out cannot beat one process on "
                           "a single core (workers just timeshare it)")
def test_mp_two_replicas_scale_vs_single_process():
    """ISSUE 9 acceptance: on the same backlogged trace, 2 replicas'
    aggregate effective images/s >= the single-process serve_dynamic
    baseline — measured through benchmarks/replica_bench so the test
    and the CI artifact share one code path."""
    from benchmarks import replica_bench
    rows = replica_bench.run(full=False, n_replicas=2)
    multi = next(r for r in rows if r.name.endswith("/n2"))
    kv = dict(p.split("=", 1) for p in multi.derived.split(";"))
    assert float(kv["scaling"]) >= 1.0, multi.derived
    assert int(kv["requeued"]) == 0 and int(kv["duplicate_serves"]) == 0
