"""End-to-end behaviour tests: train a tiny LM to decreasing loss; CNN
grouped-conv accuracy parity (Table II claim, proxy task)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ShardedDataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.steps import TrainConfig, init_train_state, make_train_step


@pytest.mark.slow
def test_tiny_lm_loss_decreases():
    cfg = get_config("stablelm_1_6b", smoke=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, TrainConfig(microbatches=1, peak_lr=3e-3, warmup_steps=5,
                         total_steps=80)))
    ts = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    pipe = ShardedDataPipeline(ts)
    losses = []
    for _ in range(40):
        batch = {"tokens": jnp.asarray(pipe.next())}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::6]


@pytest.mark.slow
def test_grouped_cnn_near_lossless():
    from repro.cnn.models import cnn8_config
    from repro.cnn.train import train_cnn
    r1 = train_cnn(cnn8_config(group=1), steps=120, n_train=1024,
                   n_test=256)
    r2 = train_cnn(cnn8_config(group=2), steps=120, n_train=1024,
                   n_test=256)
    assert r2.test_acc >= r1.test_acc - 0.05   # near-lossless (Table II)
