"""Compiled execution plans (repro/exec): IR contents, compile-time
checks, fused-forward equivalence (bit-identical to the per-layer loop
and to the mapped wrappers), mixed-executor dispatch, plan caching, and
the forced-multi-device shard_map path."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ArrayConfig, MacroGrid, map_net, memo, networks
from repro.cnn.mapped_net import (mapped_net_apply, reference_net_apply,
                                  zero_pruned_kernels)
from repro.exec import (EXECUTORS, compile_plan, execute_layerwise,
                        execute_looped, execute_oracle, execute_plan)

RNG = np.random.RandomState(7)


def _net(name="cnn8", layers=None, grid=MacroGrid(2, 2), groups=(1, 2)):
    layers = networks.NETWORKS[name]() if layers is None else layers
    return map_net(name, layers, ArrayConfig(64, 64), "TetrisG-SDK",
                   grid, groups=groups)


def _data(net, batch=2):
    ks = zero_pruned_kernels(net, [
        jnp.asarray(RNG.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net.layers])
    first = net.layers[0].layer
    x = jnp.asarray(RNG.randn(batch, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    return ks, x


def test_compile_plan_ir_fields():
    """The IR records executor, schedule (steps==cycles), glue, carry
    channels, and sharding decisions — all fixed at compile time."""
    net = _net()
    plan = compile_plan(net, executor_policy="mapped")
    assert plan.chained and plan.mesh_axes is None and plan.batch is None
    assert plan.executors == ("mapped",) * len(net.layers)
    assert plan.total_steps == net.total_cycles
    assert plan.host_dispatches == 1
    for lp, m in zip(plan.layers, net.layers):
        assert lp.mapping is m
        assert lp.schedule.steps == m.cycles     # compile-time contract
        assert not lp.use_mesh                   # no mesh given
        assert lp.carry_c == m.layer.ic
    assert all(lp.glue.kind == "chain" for lp in plan.layers[:-1])
    assert plan.layers[-1].glue.kind == "last"
    assert "dispatches/forward=1" in plan.describe()


def test_compile_plan_policies():
    """Policy forms: single name, per-layer sequence, callable, auto."""
    net = _net()
    n = len(net.layers)
    assert compile_plan(net, executor_policy="reference").executors == \
        ("reference",) * n
    seq = ["mapped", "reference"] + ["mapped"] * (n - 2)
    assert compile_plan(net, executor_policy=seq).executors == tuple(seq)
    by_ic = compile_plan(
        net, executor_policy=lambda m: "mapped" if m.layer.ic > 32
        else "reference")
    assert set(by_ic.executors) == {"mapped", "reference"}
    auto = compile_plan(net, executor_policy="auto")
    assert all(e in EXECUTORS for e in auto.executors)
    assert "sdk" not in auto.executors       # no TPU in CI
    with pytest.raises(ValueError, match="unknown executor"):
        compile_plan(net, executor_policy="warp")
    with pytest.raises(ValueError, match="lists 2 executors"):
        compile_plan(net, executor_policy=["mapped", "mapped"])


def test_compile_plan_rejects_bad_chain():
    """Chaining errors surface at compile time with the existing
    message, not mid-forward."""
    layers = networks.inception()        # representative set, no chain
    net = _net("inception", layers)
    with pytest.raises(ValueError, match="cannot chain"):
        compile_plan(net, executor_policy="mapped")
    plan = compile_plan(net, executor_policy="mapped", chained=False)
    assert all(lp.glue.kind == "layerwise" for lp in plan.layers)
    ks, _ = _data(net)
    with pytest.raises(ValueError, match="chained plan"):
        execute_plan(plan, ks, jnp.zeros((1, 1, 1, 1)))


def test_compile_plan_sdk_grid_guard():
    """The sdk executor runs passes/groups sequentially: pinning it on a
    mapping that owes a non-degenerate sub-grid must fail at compile."""
    net = _net(grid=MacroGrid(2, 2), groups=(1,))
    assert any(m.sub_grid.p > 1 for m in net.layers)
    with pytest.raises(ValueError, match="cannot realize sub-grid"):
        compile_plan(net, executor_policy="sdk")


def test_execute_plan_matches_wrapper_and_loop_cnn8():
    """Acceptance: the fused one-dispatch forward is bit-identical to
    mapped_net_apply (itself the plan wrapper) and to the per-layer
    dispatch loop; oracle agreement at the usual tolerance."""
    net = _net()
    ks, x = _data(net)
    plan = compile_plan(net, executor_policy="mapped")
    y_fused = execute_plan(plan, ks, x)
    assert bool(jnp.all(y_fused == mapped_net_apply(net, ks, x)))
    assert bool(jnp.all(y_fused == execute_looped(plan, ks, x)))
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(
        np.asarray(y_fused), np.asarray(r), rtol=1e-4,
        atol=1e-4 * float(jnp.max(jnp.abs(r))))


def test_execute_plan_matches_wrapper_densenet_slice():
    """Same bit-identity through DenseNet concat glue + marginal-window
    layers, plus gradients: fused vs looped exact, vs oracle at
    reassociation tolerance."""
    net = _net("densenet40", networks.densenet40()[10:15],
               grid=MacroGrid(4, 1))
    ks, x = _data(net, batch=1)
    plan = compile_plan(net, executor_policy="mapped")
    assert any(lp.glue.kind == "concat" for lp in plan.layers)
    y_fused = execute_plan(plan, ks, x)
    assert bool(jnp.all(y_fused == mapped_net_apply(net, ks, x)))
    assert bool(jnp.all(y_fused == execute_looped(plan, ks, x)))

    def loss(fn, k0):
        return jnp.sum(fn(plan, [k0] + list(ks[1:]), x) ** 2)

    gf = jax.grad(lambda k: loss(execute_plan, k))(ks[0])
    gl = jax.grad(lambda k: loss(execute_looped, k))(ks[0])
    assert bool(jnp.all(gf == gl))           # same program modulo fences
    go = jax.grad(lambda k: loss(
        lambda p, kk, xx: execute_oracle(p, kk, xx), k))(ks[0])
    scale = float(jnp.max(jnp.abs(go)))
    assert float(jnp.max(jnp.abs(gf - go))) < 1e-4 * scale


def test_mixed_executor_dispatch():
    """One plan, several executors: reference and mapped layers compose
    in a single fused program and still match the oracle."""
    net = _net()
    n = len(net.layers)
    seq = ["reference" if i % 2 else "mapped" for i in range(n)]
    plan = compile_plan(net, executor_policy=seq)
    ks, x = _data(net)
    y = execute_plan(plan, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(r), rtol=1e-4,
        atol=1e-4 * float(jnp.max(jnp.abs(r))))


def test_mixed_executor_with_sdk_interpret():
    """An sdk (Pallas, interpret mode off-TPU) layer dispatches inside
    the fused program next to the other executors."""
    layers = [networks.cnn8()[0]]
    net = _net("cnn8", layers, grid=MacroGrid(1, 1), groups=(1,))
    plan = compile_plan(net, executor_policy="sdk")
    assert plan.layers[0].interpret          # off-TPU default
    ks, x = _data(net, batch=1)
    y = execute_plan(plan, ks, x)
    r = reference_net_apply(net, ks, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(r), rtol=1e-3,
        atol=1e-3 * float(jnp.max(jnp.abs(r))))


def test_execute_layerwise_matches_loop():
    """Layer-set plans: the fused layerwise program equals per-layer
    dispatch on every layer's own input."""
    from repro.exec import apply_layer
    net = _net("inception", networks.inception())
    plan = compile_plan(net, executor_policy="mapped", chained=False)
    ks, _ = _data(net)
    xs = [jnp.asarray(RNG.randn(1, m.layer.ic, m.layer.i_h, m.layer.i_w),
                      jnp.float32) for m in net.layers]
    fused = execute_layerwise(plan, ks, xs)
    for i, y in enumerate(fused):
        assert bool(jnp.all(y == apply_layer(plan, i, xs[i], ks[i])))


def test_plan_memoized():
    """compile_plan joins the memo result cache: the second identical
    compile is a hit, a different policy/batch is a fresh key."""
    memo.clear()
    net = _net()
    p1 = compile_plan(net, executor_policy="mapped")
    misses = memo.stats["result_misses"]
    p2 = compile_plan(net, executor_policy="mapped")
    assert p2 is p1
    assert memo.stats["result_misses"] == misses
    assert memo.stats["result_hits"] >= 1
    compile_plan(net, executor_policy="reference")
    assert memo.stats["result_misses"] == misses + 1


def test_execute_plan_call_checks():
    net = _net()
    ks, x = _data(net)
    plan = compile_plan(net, executor_policy="mapped")
    with pytest.raises(ValueError, match="kernels for"):
        execute_plan(plan, ks[:-1], x)
    with pytest.raises(ValueError, match="channels"):
        execute_plan(plan, ks, x[:, :5])
    batched = compile_plan(net, executor_policy="mapped", batch=4)
    with pytest.raises(ValueError, match="plan batch"):
        execute_plan(batched, ks, x)         # x has batch 2


def test_compile_plan_refuses_ragged_data_batch():
    """A batch that does not divide the mesh's data axis must fail
    loudly at compile (pad first), never silently degrade the whole
    forward to the vmap path."""
    class _FakeMesh:
        axis_names = ("data", "row", "col")
        shape = {"data": 2, "row": 2, "col": 2}
    with pytest.raises(ValueError, match="data axis"):
        compile_plan(_net(), executor_policy="mapped", mesh=_FakeMesh(),
                     batch=3)


def test_plan_shard_map_bit_identical():
    """Tentpole contract on a forced (data=2, row=2, col=2) mesh: the
    fused plan forward is bit-identical to the per-layer loop AND to the
    single-device vmap plan, with use_mesh resolved at compile time."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ArrayConfig, MacroGrid, map_net, networks
from repro.cnn.mapped_net import zero_pruned_kernels
from repro.exec import compile_plan, execute_looped, execute_plan
from repro.launch.mesh import make_serving_mesh
assert len(jax.devices()) == 8
net = map_net("cnn8", networks.cnn8()[:3], ArrayConfig(64, 64),
              "Tetris-SDK", MacroGrid(2, 2))
mesh = make_serving_mesh(2, 2, 4)
assert dict(mesh.shape) == {"data": 2, "row": 2, "col": 2}
plan = compile_plan(net, executor_policy="mapped", mesh=mesh, batch=4)
assert all(lp.use_mesh for lp in plan.layers)
assert plan.mesh_axes == (("data", 2), ("row", 2), ("col", 2))
rng = np.random.RandomState(0)
ks = zero_pruned_kernels(net, [
    jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                          m.layer.ic // m.group, m.layer.oc) * 0.2,
                jnp.float32) for m in net.layers])
first = net.layers[0].layer
x = jnp.asarray(rng.randn(4, first.ic, first.i_h, first.i_w), jnp.float32)
y_fused = execute_plan(plan, ks, x, mesh=mesh)
y_loop = execute_looped(plan, ks, x, mesh=mesh)
vmap_plan = compile_plan(net, executor_policy="mapped")
y_vmap = execute_plan(vmap_plan, ks, x)
assert bool(jnp.all(y_fused == y_loop)), "fused != loop on mesh"
assert bool(jnp.all(y_fused == y_vmap)), "sharded != vmap"
try:
    execute_plan(plan, ks, x)                 # mesh omitted: must refuse
except ValueError as e:
    assert "compile mesh" in str(e)
else:
    raise AssertionError("mesh mismatch not caught")
print("PLAN-SHARDED-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PLAN-SHARDED-OK" in out.stdout, out.stderr[-2000:]


def test_plan_lookahead_field_recompiles_once():
    """ISSUE 6 satellite: lookahead is a compiled-plan field (formerly
    the module constant exec.run._LOOKAHEAD).  Each distinct value is a
    distinct plan (own cache key) and retraces the fused program exactly
    once — replays hit the jit cache — and every depth computes the
    same forward."""
    from repro.exec import run as exec_run
    # a (layers, grid) combination no other test executes fused, so the
    # jit cache holds no prior trace of these plans
    net = _net("cnn8", networks.cnn8()[:2], grid=MacroGrid(1, 1),
               groups=(1,))
    ks, x = _data(net, batch=3)
    plans = {la: compile_plan(net, executor_policy="reference",
                              lookahead=la) for la in (0, 1, 2)}
    for la, p in plans.items():
        assert p.lookahead == la
        assert f"lookahead={la}" in p.describe()
    assert len({id(p) for p in plans.values()}) == 3   # distinct keys
    base = exec_run.fused_trace_count
    ys = []
    for p in plans.values():
        y0 = execute_plan(p, ks, x)
        y1 = execute_plan(p, ks, x)          # replay: no retrace
        assert bool(jnp.all(y0 == y1))
        ys.append(y0)
    assert exec_run.fused_trace_count == base + 3  # one per depth
    for y in ys[1:]:                 # fences reorder nothing observable
        assert bool(jnp.all(y == ys[0]))
    # default plans keep lookahead=1 and memoize as before
    assert compile_plan(net, executor_policy="reference").lookahead == 1
    assert compile_plan(net, executor_policy="reference",
                        lookahead=1) is \
        compile_plan(net, executor_policy="reference")
    with pytest.raises(ValueError, match="lookahead"):
        compile_plan(net, executor_policy="reference", lookahead=-1)


def test_plan_vmem_budget_param_and_env(monkeypatch):
    """ISSUE 6 satellite: the sdk block="auto" VMEM budget is an
    explicit byte parameter with the REPRO_SDK_VMEM_BUDGET env var as
    the deploy-time default — resolved at compile, recorded in the IR,
    and part of the plan cache key."""
    from repro.kernels.im2win_conv import (DEFAULT_VMEM_BUDGET,
                                           default_vmem_budget)
    net = _net()
    monkeypatch.delenv("REPRO_SDK_VMEM_BUDGET", raising=False)
    assert default_vmem_budget() == DEFAULT_VMEM_BUDGET
    p_def = compile_plan(net, executor_policy="mapped")
    assert all(lp.vmem_budget == DEFAULT_VMEM_BUDGET for lp in p_def.layers)
    p_exp = compile_plan(net, executor_policy="mapped",
                         vmem_budget=1 << 20)
    assert all(lp.vmem_budget == 1 << 20 for lp in p_exp.layers)
    assert p_exp is not p_def                # distinct cache key
    # env default: None resolves through the env var, landing on the
    # SAME cache key as the explicit byte count
    monkeypatch.setenv("REPRO_SDK_VMEM_BUDGET", str(1 << 20))
    assert default_vmem_budget() == 1 << 20
    assert compile_plan(net, executor_policy="mapped") is p_exp
    monkeypatch.setenv("REPRO_SDK_VMEM_BUDGET", "8M")
    with pytest.raises(ValueError, match="not an integer"):
        default_vmem_budget()
    monkeypatch.setenv("REPRO_SDK_VMEM_BUDGET", "-4")
    with pytest.raises(ValueError, match="must be > 0"):
        default_vmem_budget()
