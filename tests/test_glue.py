"""Direct unit tests of the inter-layer glue (repro/exec/glue.py):
fit_spatial / center_crop geometry (odd sizes, identity no-op,
pool-then-pad) and the chain-classification errors — previously only
exercised indirectly through whole-net runs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.exec.glue import (center_crop, fit_spatial, resolve_chain)


def _x(h, w, b=2, c=3, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(b, c, h, w), jnp.float32)


def test_fit_spatial_identity_noop():
    x = _x(18, 18)
    assert fit_spatial(x, 18, 18) is x


def test_fit_spatial_center_pad_even_and_odd():
    x = _x(5, 4)
    y = fit_spatial(x, 8, 7)
    assert y.shape[-2:] == (8, 7)
    # centred: floor(pad/2) before, remainder after
    np.testing.assert_array_equal(np.asarray(y[..., 1:6, 1:5]),
                                  np.asarray(x))
    assert float(jnp.abs(y).sum()) == pytest.approx(
        float(jnp.abs(x).sum()), rel=1e-6)      # zero padding only


def test_fit_spatial_center_crop_odd_sizes():
    x = _x(9, 7)
    y = fit_spatial(x, 6, 4)
    assert y.shape[-2:] == (6, 4)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x[..., 1:7, 1:5]))


def test_fit_spatial_pools_exact():
    """>= 2x on both axes pools (2x2 max) down to the exact target —
    the DenseNet transition shape."""
    x = _x(16, 16)
    y = fit_spatial(x, 8, 8)
    pooled = jnp.max(x.reshape(2, 3, 8, 2, 8, 2), axis=(3, 5))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(pooled))


def test_fit_spatial_pools_then_crops_odd_target():
    """Pooling stops below 2x the target; the odd remainder is cropped
    (a leading slice when the surplus is a single row/column)."""
    x = _x(16, 16)
    y = fit_spatial(x, 7, 7)
    assert y.shape[-2:] == (7, 7)
    pooled = jnp.max(x.reshape(2, 3, 8, 2, 8, 2), axis=(3, 5))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(pooled[..., :7, :7]))


def test_fit_spatial_pools_only_when_both_axes_large():
    x = _x(16, 6)                 # width below 2x target: no pooling
    y = fit_spatial(x, 8, 6)
    assert y.shape[-2:] == (8, 6)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x[..., 4:12, :]))


def test_center_crop_odd_and_identity():
    x = _x(7, 9)
    np.testing.assert_array_equal(np.asarray(center_crop(x, 7, 9)),
                                  np.asarray(x))
    y = center_crop(x, 4, 5)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x[..., 1:5, 2:7]))


def test_resolve_chain_kinds_and_error():
    assert resolve_chain("a", 32, 16, "b", 32) == "chain"
    assert resolve_chain("a", 32, 16, "b", 48) == "concat"
    with pytest.raises(ValueError, match=r"cannot chain a \(oc=32, "
                                         r"carry=16\) into b \(ic=40\)"):
        resolve_chain("a", 32, 16, "b", 40)


def test_concat_carry_mismatch_raises_at_compile():
    """A DenseNet-style stack whose concat arithmetic breaks raises the
    clear chaining error from compile_plan (not mid-forward)."""
    from repro.core import ArrayConfig, ConvLayerSpec, MacroGrid, map_net
    from repro.exec import compile_plan
    layers = [
        ConvLayerSpec("a", 10, 10, 3, 3, 8, 12),
        ConvLayerSpec("b", 8, 8, 3, 3, 20, 12),    # 8 + 12: concat, ok
        ConvLayerSpec("c", 6, 6, 3, 3, 13, 8),     # neither 12 nor 32
    ]
    net = map_net("bad", layers, ArrayConfig(64, 64), "Tetris-SDK",
                  MacroGrid(1, 1))
    with pytest.raises(ValueError, match="cannot chain b"):
        compile_plan(net, executor_policy="reference")
