"""Tetris-SDK adaptive-window search (paper §III D-F, Algs 3-5).

Structure of the per-layer search (validated against the paper's worked
examples — CNN8 totals 116, CNN8-3 = 38, CNN8-5 tiles 24+24+16):

1. enumerate base parallel windows (square-inclined shapes rank first,
   Alg 3 — for a fixed number of in-window convolutions, a near-square
   output footprint minimises input rows, AM-GM);
2. the base window defines `ic_t`; channels split into ``ic // ic_t`` full
   tiles + one remainder tile;
3. the remainder tile gets its own *depth-optimal* window (Alg 5), allowed
   to prune up to ``max_prune`` channels when that unlocks a strictly
   better factorisation (paper prunes 1 channel in CNN8-3);
4. every tile uses floor-form window counts plus *marginal windows*
   (Alg 4, implemented in cycles.marginal_windows);
5. keep the base window minimising total layer cycles.

Execution strategy: the candidate scoring is vectorized — one numpy pass
over the whole window set (cycles.window_table) ranks every candidate by
exact integer cycle count, and only the argmin set is materialised as
TileMapping objects for the float utilization tie-break.  The table is
grid-independent and cached (core/memo.py), so a macro-grid sweep
(Alg 2) scores ~P·log P grids against one table; full results are also
cached under the *effective* grid.  ``memo.disabled()`` falls back to
the original first-strictly-better scalar loop (kept as
``tetris_layer_scalar``), and both paths are asserted identical in
tests/test_search_cache.py.
"""
from __future__ import annotations

import functools
import math
from typing import List, Optional, Tuple

import numpy as np

from . import cycles as cyc
from . import memo
from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    TileMapping, Window)


def factor_pairs_square_first(n: int) -> List[Tuple[int, int]]:
    """Factor pairs (a, b) of n ordered square-inclined first (Alg 3 l.4:
    'factorize N_conv using square-root')."""
    pairs = []
    for a in range(int(math.isqrt(n)), 0, -1):
        if n % a == 0:
            b = n // a
            pairs.append((a, b))
            if a != b:
                pairs.append((b, a))
    return pairs


def square_inclined(layer: ConvLayerSpec, array: ArrayConfig,
                    window: Window) -> Window:
    """Alg 3: replace `window` by the most square window computing the same
    number of convolutions, if it needs no more rows (=> ic_t can only
    grow).  The exhaustive search in :func:`tetris_layer` subsumes this,
    but the faithful refinement is exposed (and unit-tested) on its own."""
    n_conv = window.positions(layer.k_w, layer.k_h, layer.stride)
    s = layer.stride
    best = window
    for a, b in factor_pairs_square_first(n_conv):
        cand = Window((a - 1) * s + layer.k_w, (b - 1) * s + layer.k_h)
        if cand.pw_w > layer.i_w or cand.pw_h > layer.i_h:
            continue
        if cand.rows(1) <= best.rows(1):  # fewer rows per channel
            if cand.rows(1) < best.rows(1) or cand is window:
                best = cand
    return best


def _mk_tile(layer: ConvLayerSpec, array: ArrayConfig, window: Window,
             depth: int, pruned: int = 0) -> Optional[TileMapping]:
    ic_t = cyc.ic_t_for(window, depth, array)
    if ic_t < 1:
        return None
    oc_t = cyc.oc_t_for(window, layer, array)
    if oc_t < 1:
        return None
    n_reg, margs = cyc.n_windows(layer, window, marginal=True)
    return TileMapping(window=window, depth=depth, ic_t=ic_t, oc_t=oc_t,
                       ar_c=math.ceil(depth / ic_t),
                       ac_c=math.ceil(layer.oc / oc_t),
                       n_regular=n_reg, marginals=margs,
                       pruned_channels=pruned)


def _better_tile(t: Optional[TileMapping], ref: Optional[TileMapping]
                 ) -> bool:
    """Alg 5 ordering: fewest single-grid cycles, then least pruning,
    then densest load."""
    if t is None:
        return False
    if ref is None:
        return True
    a = (t.n_windows * t.ar_c * t.ac_c, t.pruned_channels,
         -t.ic_t * t.window.rows(1))
    b = (ref.n_windows * ref.ar_c * ref.ac_c, ref.pruned_channels,
         -ref.ic_t * ref.window.rows(1))
    return a < b


@functools.lru_cache(maxsize=65536)
def depth_optimal_tile_scalar(layer: ConvLayerSpec, array: ArrayConfig,
                              depth: int, max_prune: int = 1
                              ) -> Optional[TileMapping]:
    """Reference scalar loop for Alg 5 (see :func:`depth_optimal_tile`);
    lru-cached exactly as the seed implementation was, but on a cache of
    its own so memo.disabled() parity runs truly execute the scalar
    scan."""
    best: Optional[TileMapping] = None
    for prune in range(0, max_prune + 1):
        d = depth - prune
        if d < 1:
            break
        for w in cyc.candidate_windows(layer, array):
            if w.rows(d) > array.ar:
                continue  # the whole remainder must fit one load
            t = _mk_tile(layer, array, w, d, pruned=prune)
            if t is not None and _better_tile(t, best):
                best = t
    return best


@functools.lru_cache(maxsize=65536)
def _depth_optimal_tile_fast(layer: ConvLayerSpec, array: ArrayConfig,
                             depth: int, max_prune: int = 1
                             ) -> Optional[TileMapping]:
    """Vectorized Alg 5 scan: one pass per prune level over the cached
    window table (see :func:`depth_optimal_tile`)."""
    tab = cyc.cached_window_table(layer, array)
    if not len(tab):
        return None
    ac_c = cyc.ceil_div(layer.oc, tab.oc_t)
    best: Optional[TileMapping] = None
    best_key = None
    for prune in range(0, max_prune + 1):
        d = depth - prune
        if d < 1:
            break
        fits = tab.rows1 * d <= array.ar   # whole remainder in one load
        if not fits.any():
            continue
        # one load => ar_c == 1; Alg 5 key (cycles, prune, -density)
        k1 = np.where(fits, tab.n_marg * ac_c, np.iinfo(np.int64).max)
        k3 = -d * tab.rows1
        i = int(np.lexsort((k3, k1))[0])   # stable: first in table order
        key = (int(k1[i]), prune, int(k3[i]))
        if best is None or key < best_key:
            t = _mk_tile(layer, array, tab.window(i), d, pruned=prune)
            if t is not None:
                best, best_key = t, key
    return best


def depth_optimal_tile(layer: ConvLayerSpec, array: ArrayConfig,
                       depth: int, max_prune: int = 1
                       ) -> Optional[TileMapping]:
    """Alg 5: best window for a remainder tile of `depth` channels, pruning
    up to `max_prune` channels when it strictly reduces cycles.

    Rather than only scanning factor pairs of ``Max_conv = AC // OC`` (the
    paper's inner loop, which assumes OC <= AC), we exhaustively score every
    feasible window whose full `depth` fits in one load — this subsumes the
    paper's loop and reproduces its examples (CNN8-3: 6x6 @ 14ch after
    pruning 1; CNN8-5: 6x4 @ 16ch, no pruning).  Scalar and vectorized
    implementations keep separate caches so the memo-disabled path never
    reads vectorized results (and vice versa).
    """
    if not memo.enabled():
        return depth_optimal_tile_scalar(layer, array, depth, max_prune)
    return _depth_optimal_tile_fast(layer, array, depth, max_prune)


memo.register_cache_clear(depth_optimal_tile_scalar.cache_clear)
memo.register_cache_clear(_depth_optimal_tile_fast.cache_clear)


def _candidate_mapping(layer: ConvLayerSpec, array: ArrayConfig,
                       w: Window, grid: MacroGrid, max_prune: int,
                       algorithm: str) -> Optional[LayerMapping]:
    """Materialise the full-tiles + depth-optimal-remainder mapping for one
    base window (the scalar loop body of the Tetris search)."""
    ic_t = cyc.ic_t_for(w, layer.ic, array)
    if ic_t < 1:
        return None
    oc_t = cyc.oc_t_for(w, layer, array)
    if oc_t < 1:
        return None
    n_full, rem = divmod(layer.ic, ic_t)
    tiles: List[TileMapping] = []
    if n_full:
        t = _mk_tile(layer, array, w, ic_t)
        if t is None:
            return None
        # n_full congruent tiles: represent once with ar_c = n_full
        tiles.append(TileMapping(
            window=t.window, depth=n_full * ic_t, ic_t=ic_t, oc_t=t.oc_t,
            ar_c=n_full, ac_c=t.ac_c, n_regular=t.n_regular,
            marginals=t.marginals))
    if rem:
        rt = depth_optimal_tile(layer, array, rem, max_prune=max_prune)
        if rt is None:
            # fall back: remainder under the base window (multi-load)
            rt = _mk_tile(layer, array, w, rem)
        if rt is None:
            return None
        tiles.append(rt)
    if not tiles:
        return None
    return LayerMapping(layer=layer, array=array, algorithm=algorithm,
                        tiles=tuple(tiles), grid=grid)


def _vw_seed(layer: ConvLayerSpec, array: ArrayConfig, grid: MacroGrid,
             algorithm: str) -> LayerMapping:
    """The VW-SDK solution (ceil windows, no marginal set) is included as
    a candidate, so Tetris is never worse than VW-SDK — on rare geometries
    the floor+marginal decomposition alone can lose to a single
    border-overhanging window (found by the hypothesis suite)."""
    from . import baselines
    vw = baselines.vw_sdk(layer, array, grid)
    return LayerMapping(layer=layer, array=array, algorithm=algorithm,
                        tiles=vw.tiles, grid=grid)


def tetris_layer_scalar(layer: ConvLayerSpec, array: ArrayConfig,
                        grid: MacroGrid = MacroGrid(), *,
                        max_prune: int = 1,
                        algorithm: str = "Tetris-SDK") -> LayerMapping:
    """Reference scalar loop (see :func:`tetris_layer`): first-strictly-
    better scan over every candidate window."""
    best: Optional[LayerMapping] = _vw_seed(layer, array, grid, algorithm)
    for w in cyc.candidate_windows(layer, array):
        m = _candidate_mapping(layer, array, w, grid, max_prune, algorithm)
        if m is None:
            continue
        key = (m.cycles, m.pruned_channels, -m.utilization)
        if best is None or key < (best.cycles, best.pruned_channels,
                                  -best.utilization):
            best = m
    if best is None:
        raise ValueError(f"{layer.name}: no feasible Tetris window")
    return best


def _tetris_layer_search(layer: ConvLayerSpec, array: ArrayConfig,
                         grid: MacroGrid, max_prune: int,
                         algorithm: str) -> LayerMapping:
    """Vectorized Tetris search: exact integer (cycles, pruned) scores for
    all candidates at once, then the scalar tie-break on the argmin set."""
    tab = cyc.cached_window_table(layer, array)
    if not len(tab):
        raise ValueError(f"{layer.name}: no feasible Tetris window")
    r, c = grid.r, grid.c

    ic_t = np.minimum(layer.ic, tab.ic_cap)     # >= 1 for all table rows
    n_full = layer.ic // ic_t                   # >= 1 (ic_t <= ic)
    rem = layer.ic % ic_t
    ac_c = cyc.ceil_div(layer.oc, tab.oc_t)
    cycles = tab.n_marg * cyc.ceil_div(n_full, r) * cyc.ceil_div(ac_c, c)
    pruned = np.zeros(len(tab), np.int64)

    # remainder-tile contribution per distinct remainder depth
    for d in np.unique(rem):
        d = int(d)
        if d == 0:
            continue
        lanes = rem == d
        # never None here: rem < ic_t <= ic_cap, so every lane's own base
        # window fits the whole remainder in one load
        rt = depth_optimal_tile(layer, array, d, max_prune=max_prune)
        cycles[lanes] += (rt.n_windows * math.ceil(rt.ar_c / r)
                          * math.ceil(rt.ac_c / c))
        pruned[lanes] += rt.pruned_channels

    best = _vw_seed(layer, array, grid, algorithm)
    m1 = cycles == cycles.min()
    subset = np.flatnonzero(m1 & (pruned == pruned[m1].min()))
    for i in subset:
        m = _candidate_mapping(layer, array, tab.window(int(i)), grid,
                               max_prune, algorithm)
        if m is None:
            continue
        key = (m.cycles, m.pruned_channels, -m.utilization)
        if key < (best.cycles, best.pruned_channels, -best.utilization):
            best = m
    return best


def tetris_layer(layer: ConvLayerSpec, array: ArrayConfig,
                 grid: MacroGrid = MacroGrid(), *,
                 max_prune: int = 1,
                 algorithm: str = "Tetris-SDK") -> LayerMapping:
    """Full Tetris-SDK search for one layer (one group's dims).

    Memoized under the effective grid (memo.effective_grid) and scored
    via the vectorized table; with ``memo.disabled()`` this is the plain
    scalar loop.  Both return bit-identical mappings.
    """
    return memo.memoized_search(
        "tetris", layer, array, grid,
        scalar=lambda g: tetris_layer_scalar(
            layer, array, g, max_prune=max_prune, algorithm=algorithm),
        vectorized=lambda g: _tetris_layer_search(
            layer, array, g, max_prune, algorithm),
        extra=(max_prune, algorithm))
