"""Tetris-SDK adaptive-window search (paper §III D-F, Algs 3-5).

Structure of the per-layer search (validated against the paper's worked
examples — CNN8 totals 116, CNN8-3 = 38, CNN8-5 tiles 24+24+16):

1. enumerate base parallel windows (square-inclined shapes rank first,
   Alg 3 — for a fixed number of in-window convolutions, a near-square
   output footprint minimises input rows, AM-GM);
2. the base window defines `ic_t`; channels split into ``ic // ic_t`` full
   tiles + one remainder tile;
3. the remainder tile gets its own *depth-optimal* window (Alg 5), allowed
   to prune up to ``max_prune`` channels when that unlocks a strictly
   better factorisation (paper prunes 1 channel in CNN8-3);
4. every tile uses floor-form window counts plus *marginal windows*
   (Alg 4, implemented in cycles.marginal_windows);
5. keep the base window minimising total layer cycles.
"""
from __future__ import annotations

import functools
import math
from typing import List, Optional, Tuple

from . import cycles as cyc
from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    TileMapping, Window)


def factor_pairs_square_first(n: int) -> List[Tuple[int, int]]:
    """Factor pairs (a, b) of n ordered square-inclined first (Alg 3 l.4:
    'factorize N_conv using square-root')."""
    pairs = []
    for a in range(int(math.isqrt(n)), 0, -1):
        if n % a == 0:
            b = n // a
            pairs.append((a, b))
            if a != b:
                pairs.append((b, a))
    return pairs


def square_inclined(layer: ConvLayerSpec, array: ArrayConfig,
                    window: Window) -> Window:
    """Alg 3: replace `window` by the most square window computing the same
    number of convolutions, if it needs no more rows (=> ic_t can only
    grow).  The exhaustive search in :func:`tetris_layer` subsumes this,
    but the faithful refinement is exposed (and unit-tested) on its own."""
    n_conv = window.positions(layer.k_w, layer.k_h, layer.stride)
    s = layer.stride
    best = window
    for a, b in factor_pairs_square_first(n_conv):
        cand = Window((a - 1) * s + layer.k_w, (b - 1) * s + layer.k_h)
        if cand.pw_w > layer.i_w or cand.pw_h > layer.i_h:
            continue
        if cand.rows(1) <= best.rows(1):  # fewer rows per channel
            if cand.rows(1) < best.rows(1) or cand is window:
                best = cand
    return best


def _mk_tile(layer: ConvLayerSpec, array: ArrayConfig, window: Window,
             depth: int, pruned: int = 0) -> Optional[TileMapping]:
    ic_t = cyc.ic_t_for(window, depth, array)
    if ic_t < 1:
        return None
    oc_t = cyc.oc_t_for(window, layer, array)
    if oc_t < 1:
        return None
    n_reg, margs = cyc.n_windows(layer, window, marginal=True)
    return TileMapping(window=window, depth=depth, ic_t=ic_t, oc_t=oc_t,
                       ar_c=math.ceil(depth / ic_t),
                       ac_c=math.ceil(layer.oc / oc_t),
                       n_regular=n_reg, marginals=margs,
                       pruned_channels=pruned)


@functools.lru_cache(maxsize=65536)
def depth_optimal_tile(layer: ConvLayerSpec, array: ArrayConfig,
                       depth: int, max_prune: int = 1
                       ) -> Optional[TileMapping]:
    """Alg 5: best window for a remainder tile of `depth` channels, pruning
    up to `max_prune` channels when it strictly reduces cycles.

    Rather than only scanning factor pairs of ``Max_conv = AC // OC`` (the
    paper's inner loop, which assumes OC <= AC), we exhaustively score every
    feasible window whose full `depth` fits in one load — this subsumes the
    paper's loop and reproduces its examples (CNN8-3: 6x6 @ 14ch after
    pruning 1; CNN8-5: 6x4 @ 16ch, no pruning).
    """
    best: Optional[TileMapping] = None

    def better(t: Optional[TileMapping], ref: Optional[TileMapping]) -> bool:
        if t is None:
            return False
        if ref is None:
            return True
        a = (t.n_windows * t.ar_c * t.ac_c, t.pruned_channels,
             -t.ic_t * t.window.rows(1))
        b = (ref.n_windows * ref.ar_c * ref.ac_c, ref.pruned_channels,
             -ref.ic_t * ref.window.rows(1))
        return a < b

    for prune in range(0, max_prune + 1):
        d = depth - prune
        if d < 1:
            break
        for w in cyc.candidate_windows(layer, array):
            if w.rows(d) > array.ar:
                continue  # the whole remainder must fit one load
            t = _mk_tile(layer, array, w, d, pruned=prune)
            if t is not None and better(t, best):
                best = t
        if best is not None and best.pruned_channels == prune and prune == 0:
            # only consider pruning if it can strictly beat the best;
            # continue the loop — `better` already demands strict gain.
            pass
    return best


def tetris_layer(layer: ConvLayerSpec, array: ArrayConfig,
                 grid: MacroGrid = MacroGrid(), *,
                 max_prune: int = 1,
                 algorithm: str = "Tetris-SDK") -> LayerMapping:
    """Full Tetris-SDK search for one layer (one group's dims).

    The VW-SDK solution (ceil windows, no marginal set) is included as a
    candidate, so Tetris is never worse than VW-SDK — on rare geometries
    the floor+marginal decomposition alone can lose to a single
    border-overhanging window (found by the hypothesis suite)."""
    from . import baselines
    vw = baselines.vw_sdk(layer, array, grid)
    best: Optional[LayerMapping] = LayerMapping(
        layer=layer, array=array, algorithm=algorithm, tiles=vw.tiles,
        grid=grid)
    for w in cyc.candidate_windows(layer, array):
        ic_t = cyc.ic_t_for(w, layer.ic, array)
        if ic_t < 1:
            continue
        oc_t = cyc.oc_t_for(w, layer, array)
        if oc_t < 1:
            continue
        n_full, rem = divmod(layer.ic, ic_t)
        tiles: List[TileMapping] = []
        if n_full:
            t = _mk_tile(layer, array, w, ic_t)
            if t is None:
                continue
            # n_full congruent tiles: represent once with ar_c = n_full
            tiles.append(TileMapping(
                window=t.window, depth=n_full * ic_t, ic_t=ic_t, oc_t=t.oc_t,
                ar_c=n_full, ac_c=t.ac_c, n_regular=t.n_regular,
                marginals=t.marginals))
        if rem:
            rt = depth_optimal_tile(layer, array, rem, max_prune=max_prune)
            if rt is None:
                # fall back: remainder under the base window (multi-load)
                rt = _mk_tile(layer, array, w, rem)
            if rt is None:
                continue
            tiles.append(rt)
        if not tiles:
            continue
        m = LayerMapping(layer=layer, array=array, algorithm=algorithm,
                         tiles=tuple(tiles), grid=grid)
        key = (m.cycles, m.pruned_channels, -m.utilization)
        if best is None or key < (best.cycles, best.pruned_channels,
                                  -best.utilization):
            best = m
    if best is None:
        raise ValueError(f"{layer.name}: no feasible Tetris window")
    return best
