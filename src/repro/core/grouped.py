"""TetrisG grouped-convolution mapping (paper §III-B, Alg 1).

A grouping factor G transforms the layer to per-group dims
``(IC/G, OC/G)`` (Eq 9), relaxing the AR/AC constraints (Eq 10/11): a
group's outputs only need IC/G input channels, so when AC bounds the
window (positions * OC > AC) a grouped window can grow by up to G x
positions — fewer parallel windows for the same coverage (Fig 11).

Accounting: one group's mapping is searched with Tetris-SDK on per-group
dims; the G congruent groups either time-multiplex a macro (single-macro
mode) or spread over disjoint sub-grids of the macro grid
(``group_split``), which is where the paper's EDAP wins come from (§IV-E).

Accuracy: the paper trains the network with grouped Conv2D and accepts G
only if accuracy loss stays under a threshold (<=0.5 %).  The training-side
counterpart lives in ``repro.cnn.train`` (grouped CNN training on the
synthetic dataset, now runnable *through* the mapped executor so the
accuracy and the cycles come from the same path); this module takes the
*mapping* decision given an allowed set of G.

Invariants:

* the winning ``LayerMapping`` has ``group == G`` and tiles searched on
  the per-group dims — executors therefore expect kernels in the lax
  grouped layout ``(k, k, ic/G, oc)``;
* ``group_split=(gr, gc)`` always satisfies ``gr <= grid.r``,
  ``gc <= grid.c`` and ``gr*gc <= G`` (best_group_split's lattice), so
  ``sub_grid`` never degenerates below 1x1;
* ties prefer fewer groups (accuracy headroom before cycle parity).

Operator-generic note (ISSUE 8): grouped *matmul* is exactly this
transform at k=1 — an ``op="matmul"`` spec (`types.matmul_spec`) with
``groups=G`` is the paper's §III-B grouped convolution on the degenerate
geometry, and the whole search (valid_groups, group_split, Eq 9-11)
applies unchanged; the ``"matmul"`` executor realises the G congruent
groups as `kernels.grouped_matmul`'s block-diagonal grid.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

from .tetris import tetris_layer
from .types import ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid


def valid_groups(layer: ConvLayerSpec,
                 candidates: Iterable[int] = (1, 2, 4, 8)) -> Tuple[int, ...]:
    """G must divide both IC and OC; native grouping (depthwise) composes
    multiplicatively and is handled by mapping the per-native-group layer."""
    return tuple(g for g in candidates
                 if layer.ic % g == 0 and layer.oc % g == 0)


def best_group_split(base: LayerMapping, group: int,
                     grid: MacroGrid) -> Tuple[int, int]:
    """Choose (gr, gc): how many groups run concurrently along each grid
    dim.  Exhaustive over the (small) grid divisor lattice."""
    best_split, best_cyc = (1, 1), None
    for gr in range(1, grid.r + 1):
        for gc in range(1, grid.c + 1):
            if gr * gc > group:
                continue
            m = LayerMapping(**{**base.__dict__, "group": group,
                                "group_split": (gr, gc)})
            if best_cyc is None or m.cycles < best_cyc:
                best_cyc, best_split = m.cycles, (gr, gc)
    return best_split


def tetrisg_layer(layer: ConvLayerSpec, array: ArrayConfig,
                  grid: MacroGrid = MacroGrid(), *,
                  groups: Iterable[int] = (1, 2, 4, 8),
                  max_prune: int = 1) -> LayerMapping:
    """Alg 1: pick the grouping factor (and its grid split) minimising
    layer cycles; per-group windows come from the Tetris-SDK search."""
    best: Optional[LayerMapping] = None
    for g in valid_groups(layer, groups):
        glayer = layer.per_group(g)
        base = tetris_layer(glayer, array, grid, max_prune=max_prune,
                            algorithm="TetrisG-SDK")
        split = best_group_split(base, g, grid)
        m = LayerMapping(layer=layer, array=array, algorithm="TetrisG-SDK",
                         tiles=base.tiles, grid=grid, group=g,
                         group_split=split)
        key = (m.cycles, m.group)   # prefer fewer groups on ties (accuracy)
        if best is None or key < (best.cycles, best.group):
            best = m
    assert best is not None
    return best
