"""Benchmark network conv-layer specs (paper §IV-A, Table I).

IFM sizes are the *padded* sizes used by the paper's tables (CNN8 and
Inception rows reproduce Table I exactly: e.g. CNN8-2 is an 18x18 IFM
for a 16x16 feature map under 3x3/pad-1).  DenseNet40 / MobileNet follow
their standard literature configurations; where the paper under-specifies
(it reports only totals), the construction is documented inline.

The same specs feed every stage of the pipeline: the mapping searches
(core/mapper.py), the simulator (§IV-D), the trained CNNs
(cnn/models.py builds its stacks from ``ConvLayerSpec``) and the
mapped-network executor (cnn/mapped_net.py chains these stacks
layer-by-layer — plain for CNN8, dense-concat for DenseNet40).

Invariants:

* layer order is forward-pass order; consecutive specs are chainable
  (next ic == this oc, or == carried channels + oc for dense blocks) —
  relied on by ``mapped_net_apply`` and its tests;
* ``stride``/``groups`` stay in the spec (MobileNet depthwise carries
  ``groups=ic``); nothing is pre-lowered, so every algorithm sees the
  layer the paper's tables describe;
* ``NETWORKS`` maps the paper's four benchmark names to zero-argument
  constructors (the benchmark scripts' registry).
"""
from __future__ import annotations

from typing import List

from .types import ConvLayerSpec


def _c(name, i, k, ic, oc, stride=1, groups=1) -> ConvLayerSpec:
    return ConvLayerSpec(name=name, i_w=i, i_h=i, k_w=k, k_h=k,
                         ic=ic, oc=oc, stride=stride, groups=groups)


def cnn8() -> List[ConvLayerSpec]:
    """CNN8 from VW-SDK [20]; layer 1 excluded (not quantised/accelerated,
    §IV-B).  Rows match Table I verbatim."""
    return [
        _c("CNN8-2", 18, 3, 24, 32),
        _c("CNN8-3", 18, 3, 32, 32),
        _c("CNN8-4", 9, 3, 32, 64),
        _c("CNN8-5", 7, 3, 64, 64),
        _c("CNN8-6", 7, 3, 64, 64),
        _c("CNN8-7", 5, 5, 64, 256),
    ]


def inception() -> List[ConvLayerSpec]:
    """GoogLeNet Inception 5x5 branches (Table I rows)."""
    return [
        _c("Incep-3a", 28, 5, 16, 32),
        _c("Incep-3b", 28, 5, 32, 96),
        _c("Incep-4a", 14, 5, 16, 48),
        _c("Incep-4b", 14, 5, 24, 64),
        _c("Incep-4c", 14, 5, 24, 64),
        _c("Incep-4d", 14, 5, 32, 64),
        _c("Incep-4e", 14, 5, 32, 128),
        _c("Incep-5a", 7, 5, 32, 128),
    ]


def densenet40(growth: int = 12, init_ch: int = 16) -> List[ConvLayerSpec]:
    """DenseNet-40 (3 dense blocks x 12 layers, growth k=12, no
    bottleneck/compression — the original DenseNet(L=40,k=12) [33]).

    3x3 convs inside blocks (pad 1 => IFM+2); 1x1 transition convs between
    blocks.  CIFAR geometry: blocks at 32/16/8 spatial.
    """
    layers: List[ConvLayerSpec] = []
    ch = init_ch
    size = 32
    for b in range(3):
        for li in range(12):
            layers.append(_c(f"DN40-b{b+1}l{li+1}", size + 2, 3, ch, growth))
            ch += growth
        if b < 2:
            layers.append(_c(f"DN40-t{b+1}", size, 1, ch, ch))
            size //= 2
    return layers


def mobilenet(width: int = 32) -> List[ConvLayerSpec]:
    """MobileNetV1 depthwise-separable stack at CIFAR geometry (§IV-C3:
    'mixture of depthwise and pointwise layers limits cross-channel reuse').

    Depthwise layers carry groups=IC (each group is a 1-channel conv);
    pointwise layers are 1x1.  Stride-2 layers keep stride in the spec.
    """
    cfg = [  # (dw stride, out channels) per separable block
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256),
        (2, 512), (1, 512), (1, 512),
    ]
    layers: List[ConvLayerSpec] = []
    size, ch = width, 32
    for i, (s, oc) in enumerate(cfg):
        layers.append(_c(f"MBN-dw{i+1}", size + 2, 3, ch, ch,
                         stride=s, groups=ch))
        size = size // s
        layers.append(_c(f"MBN-pw{i+1}", size, 1, ch, oc))
        ch = oc
    return layers


NETWORKS = {
    "cnn8": cnn8,
    "inception": inception,
    "densenet40": densenet40,
    "mobilenet": mobilenet,
}
