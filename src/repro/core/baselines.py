"""Baseline mapping algorithms the paper compares against (Fig 4 a-d).

* img2col — unroll one kernel window; no input reuse.
* SDK — one rigid parallel window spanning *all* input channels.
* VW-SDK — channel tiling + exhaustive window search (ceil window count,
  null-padded borders, one window shape for every tile).
* VWC-SDK — VW-SDK + residual-channel pruning under a global budget.

All return :class:`LayerMapping`; network-level helpers live in mapper.py.

Like the Tetris search, the exhaustive window scans are scored in one
numpy pass over the cached window table (cycles.window_table) and the
result is memoized under the effective grid (core/memo.py);
``memo.disabled()`` falls back to the original scalar loops, and both
paths return bit-identical mappings (tests/test_search_cache.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from . import cycles as cyc
from . import memo
from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    TileMapping, Window)


def _tile(layer: ConvLayerSpec, array: ArrayConfig, window: Window,
          depth: int, *, marginal: bool, ic_t: Optional[int] = None,
          oc: Optional[int] = None, pruned: int = 0) -> Optional[TileMapping]:
    """Build a TileMapping for `depth` channels under `window`."""
    ic_t = cyc.ic_t_for(window, depth, array) if ic_t is None else ic_t
    if ic_t < 1:
        return None
    oc_t = cyc.oc_t_for(window, layer, array, oc)
    if oc_t < 1:
        return None
    n_reg, margs = cyc.n_windows(layer, window, marginal=marginal)
    return TileMapping(
        window=window, depth=depth, ic_t=ic_t, oc_t=oc_t,
        ar_c=math.ceil(depth / ic_t),
        ac_c=math.ceil((layer.oc if oc is None else oc) / oc_t),
        n_regular=n_reg, marginals=margs, pruned_channels=pruned)


_memoized = memo.memoized_search


def img2col(layer: ConvLayerSpec, array: ArrayConfig,
            grid: MacroGrid = MacroGrid()) -> LayerMapping:
    """PW == K: every output position is its own window load."""
    w = Window(layer.k_w, layer.k_h)
    # img2col stacks the whole K*K*IC column vector; channel capacity per
    # array load is floor(AR / (K*K)).
    t = _tile(layer, array, w, layer.ic, marginal=False)
    if t is None:
        raise ValueError(f"{layer.name}: kernel column exceeds array")
    return LayerMapping(layer=layer, array=array, algorithm="img2col",
                        tiles=(t,), grid=grid)


def sdk_scalar(layer: ConvLayerSpec, array: ArrayConfig,
               grid: MacroGrid = MacroGrid()) -> LayerMapping:
    """Reference scalar loop for :func:`sdk`."""
    best = None
    for w in cyc.candidate_windows(layer, array):
        m = _sdk_candidate(layer, array, w, grid)
        if m is not None and (best is None or m.cycles < best.cycles):
            best = m
    if best is None:
        raise ValueError(f"{layer.name}: no feasible SDK window")
    return best


def _sdk_candidate(layer: ConvLayerSpec, array: ArrayConfig, w: Window,
                   grid: MacroGrid) -> Optional[LayerMapping]:
    rows = w.rows(layer.ic)
    ar_c = math.ceil(rows / array.ar)
    oc_t = cyc.oc_t_for(w, layer, array)
    if oc_t < 1:
        return None
    n_reg, _ = cyc.n_windows(layer, w, marginal=False)
    t = TileMapping(window=w, depth=layer.ic, ic_t=layer.ic, oc_t=oc_t,
                    ar_c=ar_c, ac_c=math.ceil(layer.oc / oc_t),
                    n_regular=n_reg)
    return LayerMapping(layer=layer, array=array, algorithm="SDK",
                        tiles=(t,), grid=grid)


def sdk(layer: ConvLayerSpec, array: ArrayConfig,
        grid: MacroGrid = MacroGrid()) -> LayerMapping:
    """SDK: search windows but *all* IC channels must live in one tile —
    if the unrolled window exceeds AR the load is multiplexed over
    ceil(rows/AR) array passes (the 'great number of CIM arrays' cost)."""

    def vectorized(g: MacroGrid) -> LayerMapping:
        tab = cyc.cached_window_table(layer, array)
        if not len(tab):
            raise ValueError(f"{layer.name}: no feasible SDK window")
        ar_c = cyc.ceil_div(tab.rows1 * layer.ic, array.ar)
        ac_c = cyc.ceil_div(layer.oc, tab.oc_t)
        cycles = tab.n_ceil * cyc.ceil_div(ar_c, g.r) * cyc.ceil_div(ac_c, g.c)
        i = int(np.argmin(cycles))          # first min == scalar strict <
        m = _sdk_candidate(layer, array, tab.window(i), g)
        assert m is not None
        return m

    return _memoized("sdk", layer, array, grid,
                     lambda g: sdk_scalar(layer, array, g), vectorized)


def vw_sdk_scalar(layer: ConvLayerSpec, array: ArrayConfig,
                  grid: MacroGrid = MacroGrid()) -> LayerMapping:
    """Reference scalar loop for :func:`vw_sdk` (Alg 1 core loop)."""
    best = None
    for w in cyc.candidate_windows(layer, array):
        t = _tile(layer, array, w, layer.ic, marginal=False)
        if t is None:
            continue
        m = LayerMapping(layer=layer, array=array, algorithm="VW-SDK",
                         tiles=(t,), grid=grid)
        key = (m.cycles, -m.utilization)
        if best is None or key < (best.cycles, -best.utilization):
            best = m
    if best is None:
        raise ValueError(f"{layer.name}: no feasible VW-SDK window")
    return best


def vw_sdk(layer: ConvLayerSpec, array: ArrayConfig,
           grid: MacroGrid = MacroGrid()) -> LayerMapping:
    """VW-SDK (Alg 1 core loop): minimise N_w * AR_c * AC_c over windows."""

    def vectorized(g: MacroGrid) -> LayerMapping:
        tab = cyc.cached_window_table(layer, array)
        if not len(tab):
            raise ValueError(f"{layer.name}: no feasible VW-SDK window")
        ic_t = np.minimum(layer.ic, tab.ic_cap)
        ar_c = cyc.ceil_div(layer.ic, ic_t)
        ac_c = cyc.ceil_div(layer.oc, tab.oc_t)
        cycles = tab.n_ceil * cyc.ceil_div(ar_c, g.r) * cyc.ceil_div(ac_c, g.c)
        best = None
        for i in np.flatnonzero(cycles == cycles.min()):
            t = _tile(layer, array, tab.window(int(i)), layer.ic,
                      marginal=False)
            if t is None:
                continue
            m = LayerMapping(layer=layer, array=array, algorithm="VW-SDK",
                             tiles=(t,), grid=g)
            key = (m.cycles, -m.utilization)
            if best is None or key < (best.cycles, -best.utilization):
                best = m
        assert best is not None
        return best

    return _memoized("vw", layer, array, grid,
                     lambda g: vw_sdk_scalar(layer, array, g), vectorized)


def vwc_sdk_scalar(layer: ConvLayerSpec, array: ArrayConfig,
                   grid: MacroGrid = MacroGrid(),
                   prune_budget: float = 0.05) -> LayerMapping:
    """Reference scalar loop for :func:`vwc_sdk`."""
    best = vw_sdk(layer, array, grid)
    best = dataclasses.replace(best, algorithm="VWC-SDK")
    for w in cyc.candidate_windows(layer, array):
        ic_t = cyc.ic_t_for(w, layer.ic, array)
        if ic_t < 1:
            continue
        residual = layer.ic % ic_t
        if residual == 0 or residual / layer.ic > prune_budget:
            continue
        kept = layer.ic - residual
        t = _tile(layer, array, w, kept, marginal=False, pruned=residual)
        if t is None:
            continue
        m = LayerMapping(layer=layer, array=array, algorithm="VWC-SDK",
                         tiles=(t,), grid=grid)
        if m.cycles < best.cycles:
            best = m
    return best


def vwc_sdk(layer: ConvLayerSpec, array: ArrayConfig,
            grid: MacroGrid = MacroGrid(),
            prune_budget: float = 0.05) -> LayerMapping:
    """VWC-SDK: VW-SDK + residual-channel pruning.

    For each window, if ``IC % IC_t`` leaves a residual tile, the residual
    channels may be pruned away (dropping AR_c by one) provided the pruned
    fraction of this layer stays within ``prune_budget``.  The paper notes
    this "only works for selected layers" — the budget is that selector.
    Exact VWC numbers in Table I/II come from the retrained network of
    [21] and are not derivable from layer dims alone (see EXPERIMENTS.md).
    """

    def vectorized(g: MacroGrid) -> LayerMapping:
        best = vw_sdk(layer, array, g)
        best = dataclasses.replace(best, algorithm="VWC-SDK")
        tab = cyc.cached_window_table(layer, array)
        if not len(tab):
            return best
        ic_t = np.minimum(layer.ic, tab.ic_cap)
        residual = layer.ic % ic_t
        ok = (residual > 0) & (residual <= prune_budget * layer.ic)
        if not ok.any():
            return best
        kept = layer.ic - residual
        ic_t2 = np.minimum(kept, tab.ic_cap)    # kept >= 1 on ok lanes
        ar_c = cyc.ceil_div(kept, np.maximum(ic_t2, 1))
        ac_c = cyc.ceil_div(layer.oc, tab.oc_t)
        cycles = np.where(
            ok, tab.n_ceil * cyc.ceil_div(ar_c, g.r) * cyc.ceil_div(ac_c, g.c),
            np.iinfo(np.int64).max)
        # the scalar loop keeps the first strict win == first argmin lane
        # (all table lanes are feasible, so _tile cannot fail here)
        i = int(np.argmin(cycles))
        t = _tile(layer, array, tab.window(i), int(kept[i]),
                  marginal=False, pruned=int(residual[i]))
        m = LayerMapping(layer=layer, array=array, algorithm="VWC-SDK",
                         tiles=(t,), grid=g)
        if m.cycles < best.cycles:
            best = m
        return best

    return _memoized("vwc", layer, array, grid,
                     lambda g: vwc_sdk_scalar(layer, array, g, prune_budget),
                     vectorized, extra=(prune_budget,))
