"""Macro-configuration search (paper §III-C, Alg 2).

Given a hardware budget of P_max identical macros, enumerate every
rectangular grid (r, c) with r*c <= P_max, map the whole network per grid
(re-running the window search — "the window set is resized for a P-macro
grid"), and keep the grid minimising total CC_multi.  The search is
offline (O(P_max log P_max) grids) and sub-second for practical budgets:
the per-layer searches this sweep fans out are memoized under their
*effective* grid and score candidates against a shared grid-independent
window table (core/memo.py), so the sweep only pays full search cost for
distinct effective shapes — see benchmarks/search_bench.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    NetworkMapping)


def candidate_grids(p_max: int) -> List[MacroGrid]:
    out = []
    for r in range(1, p_max + 1):
        for c in range(1, p_max // r + 1):
            out.append(MacroGrid(r, c))
    return out


@dataclass(frozen=True)
class GridSearchResult:
    best: NetworkMapping
    per_grid: Tuple[Tuple[MacroGrid, int], ...]   # (grid, total cycles)

    def table(self) -> str:
        lines = ["grid,cycles"]
        for g, cc in sorted(self.per_grid, key=lambda t: (t[0].p, t[0].r)):
            lines.append(f"{g.r}x{g.c},{cc}")
        return "\n".join(lines)


def map_network(name: str,
                layers: Sequence[ConvLayerSpec],
                array: ArrayConfig,
                layer_mapper: Callable[..., LayerMapping],
                grid: MacroGrid = MacroGrid(),
                algorithm: Optional[str] = None,
                **kw) -> NetworkMapping:
    mapped = tuple(layer_mapper(ly, array, grid, **kw) for ly in layers)
    return NetworkMapping(name=name,
                          algorithm=algorithm or mapped[0].algorithm,
                          array=array, layers=mapped, grid=grid)


def macro_grid_search(name: str,
                      layers: Sequence[ConvLayerSpec],
                      array: ArrayConfig,
                      layer_mapper: Callable[..., LayerMapping],
                      p_max: int,
                      **kw) -> GridSearchResult:
    """Alg 2 over a whole network."""
    best: Optional[NetworkMapping] = None
    per_grid: List[Tuple[MacroGrid, int]] = []
    for grid in candidate_grids(p_max):
        net = map_network(name, layers, array, layer_mapper, grid, **kw)
        per_grid.append((grid, net.total_cycles))
        key = (net.total_cycles, grid.p)     # fewest cycles, then macros
        if best is None or key < (best.total_cycles, best.grid.p):
            best = net
    assert best is not None
    return GridSearchResult(best=best, per_grid=tuple(per_grid))
