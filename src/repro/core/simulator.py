"""CIM hardware simulator — system/application-level latency, dynamic
energy, area and EDAP for a mapped network (paper §IV-D/E).

This is an analytical model in the style of DNN+NeuroSim [13] (the actual
NeuroSim C++ core is not available offline): a chip of ``P`` SRAM CIM macros
(PE = macro + adder tree + local buffers, tiles + global buffer + H-tree
interconnect, Fig 2), 22 nm CMOS, 1 GHz, parallel read-out with flash ADCs
(Fig 3), bit-serial multi-bit inputs.

All constants live in :class:`TechConfig` with their provenance; the
paper's headline results are *relative* (normalized latency / energy /
EDAP between mapping algorithms under identical hardware), which this
model reproduces from the exact cycle/window/macro accounting of the
mapping layer — absolute joules/seconds are order-of-magnitude.

Component breakdown per inference:

latency  = window loads x input_bits x t_clk            (array compute)
         + input-buffer traffic / buffer bandwidth       (IFM staging)
         + H-tree traffic / interconnect bandwidth       (cross-tile)
         + accumulation pipeline drain per load
energy   = array read + ADC conversions + shift/add accumulation
         + buffer R/W + interconnect transfer
area     = P x (array + ADC + decoders + adder tree + local buffer)
         + global buffer + H-tree wiring
EDAP     = energy x latency x area  (§IV-E; idle macros are power-gated:
           they cost area but neither energy nor latency)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .types import (ArrayConfig, LayerMapping, MacroGrid, NetworkMapping)


@dataclass(frozen=True)
class TechConfig:
    """22 nm CMOS @ 1 GHz, 300 K (paper §IV-D).  Energies in J, areas in
    m^2, bandwidths in bytes/s.  Values are NeuroSim-order constants:
    8T-SRAM CIM bitcell ~0.25 um^2 at 22 nm; 5b flash ADC ~2 pJ/conv,
    ~0.003 mm^2, shared by 8 columns (column-mux); SRAM buffer ~25 fJ/bit;
    on-chip H-tree ~0.2 pJ/bit/mm."""

    clock_hz: float = 1e9
    # --- array ---
    e_cell_read: float = 1.0e-15          # J per active bitcell per phase
    e_wl_driver: float = 2.0e-14          # J per row activation per phase
    a_cell: float = 0.25e-12              # m^2 per bitcell
    # --- ADC (5b flash, parallel read-out) ---
    e_adc: float = 2.0e-12                # J per conversion
    a_adc: float = 3.0e-9                 # m^2 per ADC
    adc_share: int = 8                    # columns per ADC (mux)
    # --- accumulation (shift&add + adder trees) ---
    e_acc: float = 5.0e-14                # J per partial-sum accumulate
    a_acc_per_col: float = 0.5e-9         # m^2 per column of adders
    # --- buffers ---
    e_buf_bit: float = 2.5e-14            # J per bit R/W (local SRAM buffer)
    buf_bw: float = 64e9                  # bytes/s per tile input buffer
    a_buf_per_kb: float = 2.0e-9          # m^2 per KiB of buffer
    local_buf_kb: float = 32.0
    global_buf_kb: float = 256.0
    # --- interconnect (H-tree) ---
    e_wire_bit_mm: float = 0.2e-12        # J per bit per mm
    htree_bw: float = 128e9               # bytes/s
    # --- misc digital (pooling/activation peripheries) ---
    a_misc: float = 0.05e-6               # m^2 flat
    act_bits: int = 8                     # activation precision
    weight_bits: int = 5                  # weight precision (Fig 4 example)


@dataclass
class LayerMetrics:
    name: str
    algorithm: str
    cycles: int
    latency_s: float
    energy_j: float
    breakdown: Dict[str, float] = field(default_factory=dict)


@dataclass
class SystemMetrics:
    name: str
    algorithm: str
    grid: MacroGrid
    active_macros: int
    latency_s: float
    energy_j: float
    area_m2: float
    layers: List[LayerMetrics] = field(default_factory=list)

    @property
    def edap(self) -> float:
        return self.energy_j * self.latency_s * self.area_m2

    @property
    def edp(self) -> float:
        return self.energy_j * self.latency_s

    def row(self) -> str:
        return (f"{self.name},{self.algorithm},{self.grid.r}x{self.grid.c},"
                f"{self.latency_s:.3e},{self.energy_j:.3e},"
                f"{self.area_m2 * 1e6:.3f},{self.edap:.3e}")


def macro_area(array: ArrayConfig, tech: TechConfig) -> float:
    """One PE: synaptic array + ADCs + adder tree + local buffer."""
    a_array = array.ar * array.ac * tech.a_cell
    a_adcs = math.ceil(array.ac / tech.adc_share) * tech.a_adc
    a_acc = array.ac * tech.a_acc_per_col
    a_buf = tech.local_buf_kb * tech.a_buf_per_kb
    return a_array + a_adcs + a_acc + a_buf


def chip_area(array: ArrayConfig, grid: MacroGrid, tech: TechConfig) -> float:
    """Full hardware budget (idle macros still occupy area, §IV-E)."""
    a = grid.p * macro_area(array, tech)
    a += tech.global_buf_kb * tech.a_buf_per_kb
    a *= 1.10          # H-tree + wiring overhead ~10 %
    return a + tech.a_misc


def simulate_layer(m: LayerMapping, tech: TechConfig) -> LayerMetrics:
    """Latency/energy for one mapped layer (one inference)."""
    arr = m.array
    layer = m.layer
    gr, gc = m.group_split
    g_par = min(m.group, gr * gc)
    seq_groups = math.ceil(m.group / g_par)
    t_clk = 1.0 / tech.clock_hz
    sub_r = max(1, m.grid.r // gr)      # one group's sub-grid: loop-
    sub_c = max(1, m.grid.c // gc)      # invariant across tiles

    lat_array = 0.0
    e_array = e_adc = e_acc = e_buf = e_wire = 0.0
    total_loads_time = 0            # sequential array loads (time axis)
    total_loads_energy = 0          # loads counted across parallel macros

    for t in m.tiles:
        seq_loads = (t.n_windows * math.ceil(t.ar_c / sub_r)
                     * math.ceil(t.ac_c / sub_c))
        all_loads = t.n_windows * t.ar_c * t.ac_c          # work, not time
        total_loads_time += seq_loads
        total_loads_energy += all_loads

        rows_used = t.window.rows(t.ic_t)
        cols_used = (t.window.positions(layer.k_w, layer.k_h, layer.stride)
                     * t.oc_t * arr.cols_per_weight)
        # cells that actually hold weights (null cells don't discharge)
        active_cells = t.mapped_cells(layer, arr)

        # --- energy per load (one parallel window, all input-bit phases) ---
        phases = tech.act_bits
        e_load = (rows_used * tech.e_wl_driver
                  + active_cells * tech.e_cell_read) * phases
        e_array += e_load * all_loads * m.group
        e_adc += (cols_used * phases * tech.e_adc) * all_loads * m.group
        e_acc += (cols_used * phases * tech.e_acc) * all_loads * m.group

        # --- buffer traffic: window inputs in, partial sums out ---
        in_bits = rows_used * tech.act_bits
        out_bits = cols_used * (tech.act_bits + tech.weight_bits
                                + math.ceil(math.log2(max(2, rows_used))))
        e_buf += (in_bits + out_bits) * tech.e_buf_bit * all_loads * m.group
        e_wire += ((in_bits + out_bits) * all_loads * m.group
                   * tech.e_wire_bit_mm * 1.0)   # ~1 mm mean H-tree hop

        # --- latency: bit-serial phases per sequential load + buffer/htree --
        lat_array += seq_loads * phases * t_clk
        lat_array += seq_loads * 4 * t_clk       # adder-tree pipeline drain
        # per-load input staging: every load re-streams its window pixels
        # through the WL switch matrix (img2col's "duplicated IFMs" cost);
        # the trailing *seq_groups on lat_array covers the group loop.
        lat_array += seq_loads * rows_used * (tech.act_bits / 8) / tech.buf_bw

    # buffer/interconnect latency: total IFM + OFM traffic at tile buffers
    ifm_bytes = layer.i_w * layer.i_h * layer.ic * tech.act_bits / 8
    ofm_bytes = layer.o_w * layer.o_h * layer.oc * tech.act_bits / 8
    lat_buf = (ifm_bytes + ofm_bytes) / tech.buf_bw
    lat_wire = (ifm_bytes + ofm_bytes) / tech.htree_bw

    lat = lat_array * seq_groups + lat_buf + lat_wire
    energy = e_array + e_adc + e_acc + e_buf + e_wire
    return LayerMetrics(
        name=layer.name, algorithm=m.algorithm, cycles=m.cycles,
        latency_s=lat, energy_j=energy,
        breakdown={"array": e_array, "adc": e_adc, "accum": e_acc,
                   "buffer": e_buf, "interconnect": e_wire,
                   "lat_array": lat_array * seq_groups,
                   "lat_buffer": lat_buf + lat_wire})


def simulate(net: NetworkMapping,
             tech: Optional[TechConfig] = None) -> SystemMetrics:
    tech = tech or TechConfig()
    layers = [simulate_layer(m, tech) for m in net.layers]
    active = max(m.active_macros for m in net.layers)
    return SystemMetrics(
        name=net.name, algorithm=net.algorithm, grid=net.grid,
        active_macros=active,
        latency_s=sum(m.latency_s for m in layers),
        energy_j=sum(m.energy_j for m in layers),
        area_m2=chip_area(net.array, net.grid, tech),
        layers=layers)
