# The paper's primary contribution: TetrisG-SDK convolution->CIM mapping.
# types.py      data model (layers, arrays, windows, mappings)
# cycles.py     window-count arithmetic (Eq 7) + marginal windows (Alg 4)
# baselines.py  img2col / SDK / VW-SDK / VWC-SDK
# tetris.py     square-inclined + depth-optimal search (Algs 3, 5)
# grouped.py    grouped-convolution mapping (Alg 1)
# macro_grid.py macro-configuration search (Alg 2)
# mapper.py     top-level dispatch
# simulator.py  NeuroSim-style latency/energy/area/EDAP model
# networks.py   benchmark conv stacks (CNN8, Inception, DenseNet40, MobileNet)
from .types import (ArrayConfig, ConvLayerSpec, GlueSpec, LayerMapping,
                    MacroGrid, MarginalWindow, NetworkMapping, TileMapping,
                    Window, conv1d, matmul_spec)
from .mapper import ALGORITHMS, grid_search, map_layer, map_net
from . import memo, networks

__all__ = [
    "ArrayConfig", "ConvLayerSpec", "GlueSpec", "LayerMapping", "MacroGrid",
    "MarginalWindow", "NetworkMapping", "TileMapping", "Window", "conv1d",
    "matmul_spec", "ALGORITHMS", "grid_search", "map_layer", "map_net",
    "memo", "networks",
]
