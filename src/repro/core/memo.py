"""Search memoization (the "mapping search must scale with mapped
execution" requirement, VW-SDK / Fast-OverlaPIM).

Two cache levels, both keyed on hashable frozen dataclasses:

* **result cache** — full ``LayerMapping`` results of a per-layer search
  (``tetris_layer`` / ``vw_sdk`` / ...), keyed by
  ``(algorithm, layer, array, effective grid, extra kwargs)``.
* **table cache** — grid-*independent* intermediate work of a search
  (the vectorized candidate-window score table, cycles.window_table),
  keyed by ``(layer, array)``.  One macro-grid sweep (Alg 2) re-scores
  the same candidate set under ~P_max.log(P_max) grids; the table is
  built once.

Effective grids: a tile's cycle count under grid ``(r, c)`` is
``n_windows * ceil(ar_c / r) * ceil(ac_c / c)`` with ``ar_c <= IC`` and
``ac_c <= OC`` for every candidate the searches enumerate, so every grid
with ``r >= IC`` (resp. ``c >= OC``) yields the *identical* argmin.
:func:`effective_grid` canonicalises the key; the cached mapping is
re-stamped with the caller's real grid (`dataclasses.replace`), which is
bit-identical to searching that grid directly (asserted in
tests/test_search_cache.py).

``disabled()`` turns the whole layer off (benchmarks time the uncached
path through it); ``clear()`` + ``stats`` support cache-correctness
tests and the search_bench module.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Tuple

from .types import MacroGrid

_results: Dict[Any, Any] = {}
_tables: Dict[Any, Any] = {}
_enabled: bool = True
_aux_clears: list = []

stats = {"result_hits": 0, "result_misses": 0,
         "table_hits": 0, "table_misses": 0}


def enabled() -> bool:
    return _enabled


def register_cache_clear(fn: Callable[[], None]) -> None:
    """Hook an auxiliary cache (e.g. an lru_cache) into :func:`clear`."""
    _aux_clears.append(fn)


def clear() -> None:
    _results.clear()
    _tables.clear()
    for fn in _aux_clears:
        fn()
    for k in stats:
        stats[k] = 0


@contextlib.contextmanager
def disabled():
    """Bypass (and do not populate) both cache levels inside the block."""
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


def effective_grid(grid: MacroGrid, ic: int, oc: int) -> MacroGrid:
    """Clamp a grid to the largest (r, c) that can still change the
    search outcome for a layer with `ic` input / `oc` output channels."""
    return MacroGrid(min(grid.r, ic), min(grid.c, oc))


def cached_result(key: Tuple, compute: Callable[[], Any]) -> Any:
    if not _enabled:
        return compute()
    try:
        out = _results[key]
        stats["result_hits"] += 1
        return out
    except KeyError:
        stats["result_misses"] += 1
        out = compute()
        _results[key] = out
        return out


def cached_table(key: Tuple, compute: Callable[[], Any]) -> Any:
    if not _enabled:
        return compute()
    try:
        out = _tables[key]
        stats["table_hits"] += 1
        return out
    except KeyError:
        stats["table_misses"] += 1
        out = compute()
        _tables[key] = out
        return out


def memoized_search(name: str, layer, array, grid: MacroGrid,
                    scalar: Callable[[MacroGrid], Any],
                    vectorized: Callable[[MacroGrid], Any],
                    extra: Tuple = ()) -> Any:
    """The per-layer search wrapper every algorithm shares: scalar loop
    when disabled, else the vectorized search cached under the effective
    grid, re-stamped with the caller's grid."""
    if not _enabled:
        return scalar(grid)
    eff = effective_grid(grid, layer.ic, layer.oc)
    m = cached_result((name, layer, array, eff) + tuple(extra),
                      lambda: vectorized(eff))
    return m if m.grid == grid else dataclasses.replace(m, grid=grid)
