"""Search memoization (the "mapping search must scale with mapped
execution" requirement, VW-SDK / Fast-OverlaPIM).

Three cache levels, all keyed on hashable frozen dataclasses:

* **result cache** — full ``LayerMapping`` results of a per-layer search
  (``tetris_layer`` / ``vw_sdk`` / ...), keyed by
  ``(algorithm, layer, array, effective grid, extra kwargs)``.
* **table cache** — grid-*independent* intermediate work of a search
  (the vectorized candidate-window score table, cycles.window_table),
  keyed by ``(layer, array)``.  One macro-grid sweep (Alg 2) re-scores
  the same candidate set under ~P_max.log(P_max) grids; the table is
  built once.
* **disk cache** (opt-in) — an on-disk layer under the result cache so
  a *fresh process* (a cold serving replica, a new ``benchmarks/run.py``
  invocation) skips the window search entirely.  Enabled by pointing
  ``REPRO_MAPPING_CACHE`` at a directory or calling
  :func:`set_disk_cache`; entries are pickled ``LayerMapping`` values in
  one file per key (sha256 of the canonical key repr, prefixed with
  :data:`SCHEMA_VERSION`), written atomically (tmp file + rename) so
  concurrent processes can share a directory.  Invalidation is by
  schema-version bump: bump :data:`SCHEMA_VERSION` whenever the search
  semantics or the ``LayerMapping`` data model change, and stale entries
  simply stop matching (see DESIGN.md §7 for the full rules).
  ``set_disk_cache(dir, max_bytes=...)`` (or
  ``REPRO_MAPPING_CACHE_MAX_BYTES``) bounds the directory: every insert
  prunes oldest-mtime entries first until the total fits (hits refresh
  mtime, so this is an LRU over entries), counted in
  ``stats["disk_evictions"]`` — a capped directory converges instead of
  growing until a schema bump.

Compiled network plans (:mod:`repro.exec.plan`) join the same cache via
:func:`cached_plan`, keyed on (mapping, resolved executor policy, mesh
shape, batch) under their own :data:`PLAN_VERSION` — a serving replica
with a warm disk cache skips both the window search *and* plan
compilation.

Prepared plan constants (:mod:`repro.exec.constants` — the shifted-weight
device buffers co-resident plan tiers share) get their own small
in-memory-only handle cache via :func:`cached_constants`, keyed on the
net mapping: device buffers never touch the disk layer, and a fleet
serving several models materializes each network's constants once.

Autotuner winners (:mod:`repro.tune`) persist through
:func:`load_tuning` / :func:`store_tuning`, keyed on (net mapping,
device-fleet signature, batch profile) under :data:`TUNE_VERSION`.
``load_tuning`` is a *peek* — no compute fallback — so a cold replica
with a warm disk cache adopts the tuned configuration with zero
re-measurement, and a miss simply means "not tuned yet" (callers fall
back to the ``"auto"`` policy).

Both in-memory caches are LRU-bounded (:func:`set_cache_limits`) so a
long-lived serving process cannot grow them without limit; hit / miss /
eviction and disk hit / miss / write counters are surfaced in ``stats``.

Effective grids: a tile's cycle count under grid ``(r, c)`` is
``n_windows * ceil(ar_c / r) * ceil(ac_c / c)`` with ``ar_c <= IC`` and
``ac_c <= OC`` for every candidate the searches enumerate, so every grid
with ``r >= IC`` (resp. ``c >= OC``) yields the *identical* argmin.
:func:`effective_grid` canonicalises the key; the cached mapping is
re-stamped with the caller's real grid (`dataclasses.replace`), which is
bit-identical to searching that grid directly (asserted in
tests/test_search_cache.py).

``disabled()`` turns the whole layer off — including the disk layer —
(benchmarks time the uncached path through it); ``clear()`` + ``stats``
support cache-correctness tests and the search_bench module.  ``clear()``
deliberately leaves the disk directory alone (persistence across
processes is its whole point); use :func:`clear_disk_cache` to wipe it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import pickle
import tempfile
from collections import OrderedDict
from pathlib import Path
from typing import Any, Callable, Optional, Tuple

from .types import MacroGrid

_results: "OrderedDict[Any, Any]" = OrderedDict()
_tables: "OrderedDict[Any, Any]" = OrderedDict()
_constants: "OrderedDict[Any, Any]" = OrderedDict()
_enabled: bool = True
_aux_clears: list = []

# In-memory bounds: a whole densenet40 Alg-2 sweep at p_max=16 touches
# ~9k distinct (algorithm, layer, effective-grid) result keys, so the
# bound sits above one flagship sweep while still capping a long-lived
# serving process; tables are per-(layer, array) and much heavier.
_result_limit: int = 16384
_table_limit: int = 256
# shared-constants handles hold live DEVICE buffers (prepared
# shifted-weight blocks, repro.exec.constants) — a handful of co-resident
# networks, never a sweep's worth of entries
_constants_limit: int = 16

#: Bump whenever search semantics or the LayerMapping schema change —
#: on-disk entries written under another version never match again.
SCHEMA_VERSION = 2      # 2: op-kind axis on layer specs (ISSUE 8)

#: Separate version for compiled NetworkPlan entries (:func:`cached_plan`)
#: — bump when the plan IR (exec/plan.py dataclasses) or the compile
#: semantics change without the mapping schema moving.
PLAN_VERSION = 4        # 4: memory estimates + remat segments (ISSUE 10)

#: Version for persisted autotuner winners (:func:`load_tuning` /
#: :func:`store_tuning`) — bump when the TunedConfig schema or the
#: tuning-key layout (repro/tune) changes.
TUNE_VERSION = 2        # 2: Candidate.remat field (ISSUE 10)

_ENV_VAR = "REPRO_MAPPING_CACHE"
_MAX_BYTES_ENV_VAR = "REPRO_MAPPING_CACHE_MAX_BYTES"
_UNSET = object()
_disk_dir: Any = _UNSET        # _UNSET -> resolve from env on first use
_disk_max_bytes: Any = _UNSET  # _UNSET -> resolve from env on first use

stats = {"result_hits": 0, "result_misses": 0, "result_evictions": 0,
         "table_hits": 0, "table_misses": 0, "table_evictions": 0,
         "const_hits": 0, "const_misses": 0, "const_evictions": 0,
         "disk_hits": 0, "disk_misses": 0, "disk_writes": 0,
         "disk_evictions": 0, "disk_errors": 0}


def enabled() -> bool:
    return _enabled


def snapshot() -> dict:
    """Point-in-time copy of :data:`stats`.  Measurement code must read
    counters from a snapshot taken at its phase boundary, never from the
    live dict — later cache traffic (e.g. plan compiles during serving)
    otherwise leaks into an earlier phase's report (the serve_cnn
    search-stats bug, tests/test_serve_cnn.py)."""
    return dict(stats)


def set_cache_limits(results: Optional[int] = None,
                     tables: Optional[int] = None) -> None:
    """Re-bound the in-memory LRU caches (entries, not bytes).  Shrinking
    below the current population evicts oldest-first immediately."""
    global _result_limit, _table_limit
    if results is not None:
        _result_limit = results
        _evict(_results, _result_limit, "result_evictions")
    if tables is not None:
        _table_limit = tables
        _evict(_tables, _table_limit, "table_evictions")


def cache_limits() -> Tuple[int, int]:
    return _result_limit, _table_limit


def register_cache_clear(fn: Callable[[], None]) -> None:
    """Hook an auxiliary cache (e.g. an lru_cache) into :func:`clear`."""
    _aux_clears.append(fn)


def clear() -> None:
    """Reset the in-memory caches and counters (not the disk layer)."""
    _results.clear()
    _tables.clear()
    _constants.clear()
    for fn in _aux_clears:
        fn()
    for k in stats:
        stats[k] = 0


@contextlib.contextmanager
def disabled():
    """Bypass (and do not populate) every cache level inside the block."""
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


def effective_grid(grid: MacroGrid, ic: int, oc: int) -> MacroGrid:
    """Clamp a grid to the largest (r, c) that can still change the
    search outcome for a layer with `ic` input / `oc` output channels."""
    return MacroGrid(min(grid.r, ic), min(grid.c, oc))


# ---------------------------------------------------------------------------
# Disk layer
# ---------------------------------------------------------------------------

def set_disk_cache(path: Optional[os.PathLike],
                   max_bytes: Optional[int] = None) -> None:
    """Point the persistent result cache at ``path`` (created on first
    write); ``None`` disables it, overriding the environment variable.
    ``max_bytes`` caps the directory's total entry size: every insert
    prunes least-recently-used entries (by mtime — hits refresh it)
    until the cache fits; ``None`` defers to
    ``REPRO_MAPPING_CACHE_MAX_BYTES`` (unbounded when that is unset
    too)."""
    global _disk_dir, _disk_max_bytes
    if max_bytes is not None and max_bytes < 0:
        raise ValueError(f"max_bytes must be >= 0, got {max_bytes} "
                         f"(omit it for an unbounded cache)")
    _disk_dir = Path(path) if path is not None else None
    _disk_max_bytes = _UNSET if max_bytes is None else max_bytes


def disk_cache_dir() -> Optional[Path]:
    """The active disk-cache directory (env ``REPRO_MAPPING_CACHE``
    unless :func:`set_disk_cache` was called), or ``None``."""
    global _disk_dir
    if _disk_dir is _UNSET:
        env = os.environ.get(_ENV_VAR)
        _disk_dir = Path(env) if env else None
    return _disk_dir


def disk_cache_max_bytes() -> Optional[int]:
    """Active size cap of the disk cache, or ``None`` (unbounded).
    A malformed ``REPRO_MAPPING_CACHE_MAX_BYTES`` raises a clear error —
    silently running uncapped is the exact failure the cap prevents."""
    global _disk_max_bytes
    if _disk_max_bytes is _UNSET:
        env = os.environ.get(_MAX_BYTES_ENV_VAR)
        try:
            _disk_max_bytes = int(env) if env else None
        except ValueError:
            raise ValueError(
                f"{_MAX_BYTES_ENV_VAR}={env!r} is not an integer byte "
                f"count (suffixes like '512M' are not supported)") \
                from None
        if _disk_max_bytes is not None and _disk_max_bytes < 0:
            _disk_max_bytes = _UNSET
            raise ValueError(
                f"{_MAX_BYTES_ENV_VAR}={env!r} must be >= 0 "
                f"(unset it for an unbounded cache)")
    return _disk_max_bytes


def clear_disk_cache() -> int:
    """Remove every entry of the active disk cache; returns the count."""
    d = disk_cache_dir()
    if d is None or not d.is_dir():
        return 0
    n = 0
    for f in d.glob("*.mapping.pkl"):
        try:
            f.unlink()
            n += 1
        except OSError:
            pass
    return n


def _disk_path(key: Tuple) -> Path:
    canon = repr((SCHEMA_VERSION,) + key).encode()
    return disk_cache_dir() / (hashlib.sha256(canon).hexdigest()
                               + ".mapping.pkl")


def _disk_load(key: Tuple) -> Any:
    """Cached value for ``key`` or ``None`` (miss / corrupt / stale)."""
    path = _disk_path(key)
    try:
        with open(path, "rb") as f:
            version, value = pickle.load(f)
    except FileNotFoundError:
        stats["disk_misses"] += 1
        return None
    except Exception:
        stats["disk_errors"] += 1
        with contextlib.suppress(OSError):
            path.unlink()           # corrupt entry: drop, recompute
        return None
    if version != SCHEMA_VERSION:   # belt-and-braces (version is keyed)
        stats["disk_misses"] += 1
        return None
    with contextlib.suppress(OSError):
        os.utime(path)              # refresh mtime: the LRU recency signal
    stats["disk_hits"] += 1
    return value


def _disk_store(key: Tuple, value: Any) -> None:
    d = disk_cache_dir()
    path = _disk_path(key)
    tmp = None
    stored = False
    try:
        d.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            pickle.dump((SCHEMA_VERSION, value), f,
                        protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)       # atomic: concurrent readers see
        stats["disk_writes"] += 1   # either the old file or the new one
        stored = True
    except Exception:               # full disk, unpicklable field, ...:
        stats["disk_errors"] += 1   # the cache layer must never be fatal
        if tmp is not None:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
    if stored:
        # outside the swallow-all handler: a misconfigured size cap
        # (malformed env var) must surface, not count as a disk error
        _disk_prune(keep=path)


def _disk_prune(keep: Optional[Path] = None) -> None:
    """mtime-LRU eviction on insert: drop oldest entries until the
    directory's total entry size fits :func:`disk_cache_max_bytes`.  The
    just-written entry (``keep``) is never evicted — a single oversized
    entry must not thrash the cache it was stored into."""
    limit = disk_cache_max_bytes()
    d = disk_cache_dir()
    if limit is None or d is None or not d.is_dir():
        return
    entries = []
    total = 0
    for f in d.glob("*.mapping.pkl"):
        try:
            st = f.stat()
        except OSError:
            continue                # concurrently evicted by a peer
        total += st.st_size
        if keep is None or f != keep:
            entries.append((st.st_mtime, st.st_size, f))
    entries.sort()                  # oldest mtime first
    for _, size, f in entries:
        if total <= limit:
            break
        with contextlib.suppress(OSError):
            f.unlink()
            total -= size
            stats["disk_evictions"] += 1


# ---------------------------------------------------------------------------
# In-memory LRU levels
# ---------------------------------------------------------------------------

def _evict(cache: "OrderedDict[Any, Any]", limit: int,
           counter: str) -> None:
    while len(cache) > max(0, limit):
        cache.popitem(last=False)
        stats[counter] += 1


def _lru_get(cache: "OrderedDict[Any, Any]", key: Tuple,
             hit_counter: str) -> Any:
    out = cache[key]                # KeyError propagates to the caller
    cache.move_to_end(key)
    stats[hit_counter] += 1
    return out


def _lru_put(cache: "OrderedDict[Any, Any]", key: Tuple, value: Any,
             limit: int, evict_counter: str) -> None:
    cache[key] = value
    cache.move_to_end(key)
    _evict(cache, limit, evict_counter)


def cached_result(key: Tuple, compute: Callable[[], Any],
                  persist: bool = False) -> Any:
    """Result-cache lookup; ``persist=True`` additionally consults /
    populates the disk layer (when one is configured)."""
    if not _enabled:
        return compute()
    try:
        return _lru_get(_results, key, "result_hits")
    except KeyError:
        pass
    stats["result_misses"] += 1
    disk = persist and disk_cache_dir() is not None
    out = _disk_load(key) if disk else None
    if out is None:
        out = compute()
        if disk:
            _disk_store(key, out)
    _lru_put(_results, key, out, _result_limit, "result_evictions")
    return out


def cached_table(key: Tuple, compute: Callable[[], Any]) -> Any:
    if not _enabled:
        return compute()
    try:
        return _lru_get(_tables, key, "table_hits")
    except KeyError:
        pass
    stats["table_misses"] += 1
    out = compute()
    _lru_put(_tables, key, out, _table_limit, "table_evictions")
    return out


def cached_plan(key: Tuple, compute: Callable[[], Any]) -> Any:
    """Compiled-NetworkPlan cache (exec/plan.compile_plan): the result
    cache — and the disk layer, when configured — keyed on (net mapping,
    resolved executor policy, mesh shape, batch, flags) under
    :data:`PLAN_VERSION`."""
    return cached_result(("plan", PLAN_VERSION) + key, compute,
                         persist=True)


def cached_constants(key: Tuple, compute: Callable[[], Any]) -> Any:
    """Shared-constants handle cache (repro.exec.constants, ISSUE 7):
    prepared plan constants — the shifted-weight device buffers every
    tier of a plan ladder shares — keyed on the net mapping (plus the
    resolved executors and the caller's kernel token).  In-memory ONLY:
    the values are live device buffers, which have no business in the
    pickled disk layer; a cold process re-materializes them once per
    network (cheap next to plan compilation).  Bounded by its own small
    LRU (`_constants_limit`): a handful of co-resident networks is the
    design point, and each handle can hold a whole network's weights."""
    if not _enabled:
        return compute()
    try:
        return _lru_get(_constants, key, "const_hits")
    except KeyError:
        pass
    stats["const_misses"] += 1
    out = compute()
    _lru_put(_constants, key, out, _constants_limit, "const_evictions")
    return out


def _tune_key(key: Tuple) -> Tuple:
    return ("tune", TUNE_VERSION) + key


def load_tuning(key: Tuple) -> Any:
    """Persisted-autotuner PEEK: the tuned config stored under ``key``
    (in memory, else on disk when a disk cache is configured), or
    ``None`` on a miss.  Unlike :func:`cached_result` there is no
    ``compute`` fallback — measurement is expensive and belongs to the
    caller (`repro.tune.autotune`); a cold process with a warm disk
    cache therefore loads the tuned config with ZERO measurements
    (asserted via these counters in tests/test_tune.py)."""
    if not _enabled:
        return None
    k = _tune_key(key)
    try:
        return _lru_get(_results, k, "result_hits")
    except KeyError:
        pass
    stats["result_misses"] += 1
    if disk_cache_dir() is None:
        return None
    out = _disk_load(k)
    if out is not None:
        _lru_put(_results, k, out, _result_limit, "result_evictions")
    return out


def store_tuning(key: Tuple, value: Any) -> None:
    """Persist an autotuner winner under ``key`` — the in-memory result
    cache plus the disk layer (when configured), under
    :data:`TUNE_VERSION`."""
    if not _enabled:
        return
    k = _tune_key(key)
    _lru_put(_results, k, value, _result_limit, "result_evictions")
    if disk_cache_dir() is not None:
        _disk_store(k, value)


def memoized_search(name: str, layer, array, grid: MacroGrid,
                    scalar: Callable[[MacroGrid], Any],
                    vectorized: Callable[[MacroGrid], Any],
                    extra: Tuple = ()) -> Any:
    """The per-layer search wrapper every algorithm shares: scalar loop
    when disabled, else the vectorized search cached under the effective
    grid (persistently, when a disk cache is configured), re-stamped with
    the caller's grid."""
    if not _enabled:
        return scalar(grid)
    eff = effective_grid(grid, layer.ic, layer.oc)
    # the op kind rides in the key explicitly (not only via the layer's
    # repr) so a conv and a matmul spec that ever normalise to the same
    # geometry still cannot alias each other's disk entries
    op = getattr(layer, "op", "conv")
    m = cached_result((name, op, layer, array, eff) + tuple(extra),
                      lambda: vectorized(eff), persist=True)
    return m if m.grid == grid else dataclasses.replace(m, grid=grid)
