"""Window-count arithmetic (Eq 7) and marginal windows (Alg 4).

Two counting conventions exist in the SDK literature and both appear in the
paper:

* **ceil form** (VW/VWC-SDK): ``ceil((I - K + 1) / (PW - K + 1))`` per axis —
  the last window overhangs the border and the overhang rows are *null
  inputs* (wasted array area but correct coverage).
* **floor form + marginal windows** (Tetris/TetrisG-SDK):
  ``floor((I - PW) / (PW - K + 1)) + 1`` regular windows, plus dedicated
  border windows from Alg 4 when the leftover is nonzero.

Verified against the paper: VW-SDK/CNN8/512x512 => 128 total cycles and
Tetris-SDK => 116 (Table I); CNN8-3 => 48 vs 38 (Fig 12).

Operator-generic note (ISSUE 8): an ``op="matmul"`` spec
(`types.matmul_spec`) is the degenerate k=1, stride=1, i_w=1 geometry, so
both conventions coincide — every candidate window is ``1 x pw_h`` with
``pw_h`` token positions per load, no marginals along the trivial axis —
and the window search below applies verbatim (the ceil-form cycle count
becomes ``ceil(M / pw_h) * ceil(ar_c / r) * ceil(ac_c / c)``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from . import memo
from .types import (ArrayConfig, ConvLayerSpec, MarginalWindow, Window)


def axis_windows_ceil(i: int, pw: int, k: int, stride: int = 1) -> int:
    """VW-SDK convention: over-cover the border with null inputs."""
    out = (i - k) // stride + 1                 # output positions along axis
    per_window = (pw - k) // stride + 1         # outputs one window yields
    return math.ceil(out / per_window)


def axis_windows_floor(i: int, pw: int, k: int, stride: int = 1) -> int:
    """Tetris convention: only fully-inside windows (Eq 7 floor form)."""
    per_window = (pw - k) // stride + 1
    return (i - pw) // (stride * per_window) + 1


def axis_leftover(i: int, pw: int, k: int, stride: int = 1) -> int:
    """Input pixels at the border not covered by floor-form windows
    (Alg 4 lines 1-2: ``(I - PW) % (PW - K + 1)`` for stride 1)."""
    per_window = (pw - k) // stride + 1
    return (i - pw) % (stride * per_window)


def axis_covers(i: int, pw: int, k: int, stride: int = 1) -> bool:
    """Can `pw`-sized windows at stride-aligned origins reach the last
    output of the axis?  Border clamps must stay on the stride grid
    (cnn.cim_conv.window_placements), so the largest usable origin is
    ``((i - pw) // s) * s``; the last output's receptive field ends at
    ``((i - k) // s) * s + k``.  Equivalent to
    ``(i - pw) % s <= (i - k) % s``.  Always true for stride 1."""
    return (i - pw) % stride <= (i - k) % stride


def grow_to_cover(i: int, pw: int, k: int, stride: int = 1) -> int:
    """Smallest feasible window size >= pw satisfying :func:`axis_covers`
    (growth < stride; capped at the IFM, where coverage is trivial)."""
    return min(i, pw + max(0, (i - pw) % stride - (i - k) % stride))


def ic_t_for(window: Window, depth_cap: int, array: ArrayConfig) -> int:
    """Channels mappable per array load: floor(AR / (PW_w*PW_h)), Alg 1 l.7."""
    per_ch_rows = window.pw_w * window.pw_h
    return min(depth_cap, array.ar // per_ch_rows)


def oc_t_for(window: Window, layer: ConvLayerSpec, array: ArrayConfig,
             oc_cap: Optional[int] = None) -> int:
    """Output channels per load: floor(AC / (positions * cols_per_weight)),
    Alg 1 l.8."""
    pos = window.positions(layer.k_w, layer.k_h, layer.stride)
    oc = layer.oc if oc_cap is None else oc_cap
    return min(oc, array.ac // (pos * array.cols_per_weight))


def marginal_windows(layer: ConvLayerSpec,
                     base: Window) -> Tuple[MarginalWindow, ...]:
    """Alg 4: dedicated border windows when the IFM is not evenly covered.

    The marginal window keeps roughly the base window's area (so the tile's
    ``ic_t`` still fits) but is reshaped to the leftover strip:
    ``MW_w = leftover + K - 1`` and ``MW_h = area // MW_w`` (capped at the
    IFM).  Its count covers the strip's output rows:
    ``ceil((I - K + 1) / (MW_h - K + 1))`` (equals Alg 4's ``ceil(I / MW_h)``
    on all the paper's worked examples, but is coverage-exact in general).
    """
    s = layer.stride
    area = base.pw_w * base.pw_h
    out: List[MarginalWindow] = []

    # a marginal set is needed only when the leftover strip contains
    # *uncovered outputs* — leftover pixels alone don't imply that at
    # stride > 1 (lo <= (I-K)%S means the last output is already inside
    # the floor-form raster); at stride 1 this is the plain lo > 0 gate
    lo_w = axis_leftover(layer.i_w, base.pw_w, layer.k_w, s)
    if lo_w > (layer.i_w - layer.k_w) % s:
        # max(1, .) guards stride > k geometries where the leftover strip
        # holds no full kernel position (degenerate zero-output window);
        # grow_to_cover keeps stride-aligned border clamps able to reach
        # the last output (no-op at stride 1)
        mw_w = grow_to_cover(layer.i_w, max(1, lo_w + layer.k_w - s),
                             layer.k_w, s)
        mw_h = grow_to_cover(layer.i_h,
                             min(layer.i_h, max(layer.k_h, area // mw_w)),
                             layer.k_h, s)
        per = (mw_h - layer.k_h) // s + 1
        count = math.ceil(((layer.i_h - layer.k_h) // s + 1) / per)
        out.append(MarginalWindow(mw_w=mw_w, mw_h=mw_h, count=count, edge="w"))

    lo_h = axis_leftover(layer.i_h, base.pw_h, layer.k_h, s)
    if lo_h > (layer.i_h - layer.k_h) % s:
        mw_h = grow_to_cover(layer.i_h, max(1, lo_h + layer.k_h - s),
                             layer.k_h, s)
        mw_w = grow_to_cover(layer.i_w,
                             min(layer.i_w, max(layer.k_w, area // mw_h)),
                             layer.k_w, s)
        per = (mw_w - layer.k_w) // s + 1
        count = math.ceil(((layer.i_w - layer.k_w) // s + 1) / per)
        out.append(MarginalWindow(mw_w=mw_w, mw_h=mw_h, count=count, edge="h"))

    return tuple(out)


def n_windows(layer: ConvLayerSpec, window: Window, *,
              marginal: bool) -> Tuple[int, Tuple[MarginalWindow, ...]]:
    """(regular windows, marginal windows) for one window shape.

    ``marginal=False`` => VW-SDK ceil convention, no marginal set.
    ``marginal=True``  => Tetris floor convention + Alg 4 marginal set.
    """
    s = layer.stride
    if not marginal:
        nw = (axis_windows_ceil(layer.i_w, window.pw_w, layer.k_w, s)
              * axis_windows_ceil(layer.i_h, window.pw_h, layer.k_h, s))
        return nw, ()
    nw = (axis_windows_floor(layer.i_w, window.pw_w, layer.k_w, s)
          * axis_windows_floor(layer.i_h, window.pw_h, layer.k_h, s))
    return nw, marginal_windows(layer, window)


def candidate_windows(layer: ConvLayerSpec, array: ArrayConfig):
    """All feasible (window) shapes: at least one channel and one output
    channel must fit (AR constraint Eq 10, AC constraint Eq 11)."""
    for pw_w in range(layer.k_w, layer.i_w + 1):
        for pw_h in range(layer.k_h, layer.i_h + 1):
            w = Window(pw_w, pw_h)
            if w.rows(1) > array.ar:
                continue
            pos = w.positions(layer.k_w, layer.k_h, layer.stride)
            if pos * array.cols_per_weight > array.ac:
                continue
            if not (axis_covers(layer.i_w, pw_w, layer.k_w, layer.stride)
                    and axis_covers(layer.i_h, pw_h, layer.k_h,
                                    layer.stride)):
                continue     # border clamp would fall off the stride grid
            yield w


# ---------------------------------------------------------------------------
# Vectorized candidate scoring
# ---------------------------------------------------------------------------

def ceil_div(a, b):
    """Ceiling division, exact for ints and numpy int arrays alike."""
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class WindowTable:
    """All feasible candidate windows of a (layer, array) pair scored at
    once with numpy — the grid-independent half of the window search.

    Rows follow :func:`candidate_windows` iteration order exactly
    (``pw_w`` outer, ``pw_h`` inner), so a stable argmin over table
    columns picks the same winner as the first-strictly-better scalar
    loop.  All columns are exact int64 replicas of the scalar formulas
    (asserted against the scalar path in tests/test_search_cache.py).
    """

    pw_w: np.ndarray       # candidate window widths
    pw_h: np.ndarray       # candidate window heights
    rows1: np.ndarray      # input rows per channel (pw_w * pw_h)
    pos: np.ndarray        # kernel positions inside the window
    ic_cap: np.ndarray     # channels per array load (AR constraint)
    oc_t: np.ndarray       # output channels per load (AC constraint)
    n_ceil: np.ndarray     # ceil-form window count (VW-SDK convention)
    n_marg: np.ndarray     # floor-form count + Alg 4 marginal loads

    def __len__(self) -> int:
        return len(self.pw_w)

    def window(self, i: int) -> Window:
        return Window(int(self.pw_w[i]), int(self.pw_h[i]))


def window_table(layer: ConvLayerSpec, array: ArrayConfig) -> WindowTable:
    """Score every feasible window of (layer, array) in one numpy pass."""
    s = layer.stride
    k_w, k_h = layer.k_w, layer.k_h
    ww = np.arange(k_w, layer.i_w + 1, dtype=np.int64)
    hh = np.arange(k_h, layer.i_h + 1, dtype=np.int64)
    pw_w = np.repeat(ww, len(hh))          # pw_w outer, pw_h inner
    pw_h = np.tile(hh, len(ww))

    rows1 = pw_w * pw_h
    px = (pw_w - k_w) // s + 1
    py = (pw_h - k_h) // s + 1
    pos = px * py
    feasible = ((rows1 <= array.ar)
                & (pos * array.cols_per_weight <= array.ac)
                & ((layer.i_w - pw_w) % s <= (layer.i_w - k_w) % s)
                & ((layer.i_h - pw_h) % s <= (layer.i_h - k_h) % s))
    pw_w, pw_h = pw_w[feasible], pw_h[feasible]
    rows1, px, py, pos = (rows1[feasible], px[feasible], py[feasible],
                          pos[feasible])

    ic_cap = array.ar // rows1
    oc_t = np.minimum(layer.oc, array.ac // (pos * array.cols_per_weight))

    out_w = (layer.i_w - k_w) // s + 1
    out_h = (layer.i_h - k_h) // s + 1
    n_ceil = ceil_div(out_w, px) * ceil_div(out_h, py)
    n_floor = (((layer.i_w - pw_w) // (s * px) + 1)
               * ((layer.i_h - pw_h) // (s * py) + 1))

    # Alg 4 marginal loads, vectorized (mirrors marginal_windows exactly,
    # including grow_to_cover: m + max(0, (i-m)%s - (i-k)%s) capped at i)
    def grow(i, m, k):
        return np.minimum(i, m + np.maximum(0, (i - m) % s - (i - k) % s))

    area = pw_w * pw_h
    lo_w = (layer.i_w - pw_w) % (s * px)
    mw_w = grow(layer.i_w, np.maximum(1, lo_w + k_w - s), k_w)
    mw_h = grow(layer.i_h,
                np.minimum(layer.i_h, np.maximum(k_h, area // mw_w)), k_h)
    per_w = (mw_h - k_h) // s + 1
    cnt_w = np.where(lo_w > (layer.i_w - k_w) % s,
                     ceil_div(out_h, per_w), 0)

    lo_h = (layer.i_h - pw_h) % (s * py)
    mw_h2 = grow(layer.i_h, np.maximum(1, lo_h + k_h - s), k_h)
    mw_w2 = grow(layer.i_w,
                 np.minimum(layer.i_w, np.maximum(k_w, area // mw_h2)), k_w)
    per_h = (mw_w2 - k_w) // s + 1
    cnt_h = np.where(lo_h > (layer.i_h - k_h) % s,
                     ceil_div(out_w, per_h), 0)

    return WindowTable(pw_w=pw_w, pw_h=pw_h, rows1=rows1, pos=pos,
                       ic_cap=ic_cap, oc_t=oc_t, n_ceil=n_ceil,
                       n_marg=n_floor + cnt_w + cnt_h)


def cached_window_table(layer: ConvLayerSpec,
                        array: ArrayConfig) -> WindowTable:
    """The (grid-independent) window table through the memo table cache —
    the single shared accessor for every search algorithm."""
    return memo.cached_table(("wtab", layer, array),
                             lambda: window_table(layer, array))
