"""Window-count arithmetic (Eq 7) and marginal windows (Alg 4).

Two counting conventions exist in the SDK literature and both appear in the
paper:

* **ceil form** (VW/VWC-SDK): ``ceil((I - K + 1) / (PW - K + 1))`` per axis —
  the last window overhangs the border and the overhang rows are *null
  inputs* (wasted array area but correct coverage).
* **floor form + marginal windows** (Tetris/TetrisG-SDK):
  ``floor((I - PW) / (PW - K + 1)) + 1`` regular windows, plus dedicated
  border windows from Alg 4 when the leftover is nonzero.

Verified against the paper: VW-SDK/CNN8/512x512 => 128 total cycles and
Tetris-SDK => 116 (Table I); CNN8-3 => 48 vs 38 (Fig 12).
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from .types import (ArrayConfig, ConvLayerSpec, MarginalWindow, Window)


def axis_windows_ceil(i: int, pw: int, k: int, stride: int = 1) -> int:
    """VW-SDK convention: over-cover the border with null inputs."""
    out = (i - k) // stride + 1                 # output positions along axis
    per_window = (pw - k) // stride + 1         # outputs one window yields
    return math.ceil(out / per_window)


def axis_windows_floor(i: int, pw: int, k: int, stride: int = 1) -> int:
    """Tetris convention: only fully-inside windows (Eq 7 floor form)."""
    per_window = (pw - k) // stride + 1
    return (i - pw) // (stride * per_window) + 1


def axis_leftover(i: int, pw: int, k: int, stride: int = 1) -> int:
    """Input pixels at the border not covered by floor-form windows
    (Alg 4 lines 1-2: ``(I - PW) % (PW - K + 1)`` for stride 1)."""
    per_window = (pw - k) // stride + 1
    return (i - pw) % (stride * per_window)


def ic_t_for(window: Window, depth_cap: int, array: ArrayConfig) -> int:
    """Channels mappable per array load: floor(AR / (PW_w*PW_h)), Alg 1 l.7."""
    per_ch_rows = window.pw_w * window.pw_h
    return min(depth_cap, array.ar // per_ch_rows)


def oc_t_for(window: Window, layer: ConvLayerSpec, array: ArrayConfig,
             oc_cap: Optional[int] = None) -> int:
    """Output channels per load: floor(AC / (positions * cols_per_weight)),
    Alg 1 l.8."""
    pos = window.positions(layer.k_w, layer.k_h, layer.stride)
    oc = layer.oc if oc_cap is None else oc_cap
    return min(oc, array.ac // (pos * array.cols_per_weight))


def marginal_windows(layer: ConvLayerSpec, base: Window,
                     array: ArrayConfig) -> Tuple[MarginalWindow, ...]:
    """Alg 4: dedicated border windows when the IFM is not evenly covered.

    The marginal window keeps roughly the base window's area (so the tile's
    ``ic_t`` still fits) but is reshaped to the leftover strip:
    ``MW_w = leftover + K - 1`` and ``MW_h = area // MW_w`` (capped at the
    IFM).  Its count covers the strip's output rows:
    ``ceil((I - K + 1) / (MW_h - K + 1))`` (equals Alg 4's ``ceil(I / MW_h)``
    on all the paper's worked examples, but is coverage-exact in general).
    """
    s = layer.stride
    area = base.pw_w * base.pw_h
    out: List[MarginalWindow] = []

    lo_w = axis_leftover(layer.i_w, base.pw_w, layer.k_w, s)
    if lo_w:
        mw_w = lo_w + layer.k_w - s
        mw_h = min(layer.i_h, max(layer.k_h, area // mw_w))
        per = (mw_h - layer.k_h) // s + 1
        count = math.ceil(((layer.i_h - layer.k_h) // s + 1) / per)
        out.append(MarginalWindow(mw_w=mw_w, mw_h=mw_h, count=count, edge="w"))

    lo_h = axis_leftover(layer.i_h, base.pw_h, layer.k_h, s)
    if lo_h:
        mw_h = lo_h + layer.k_h - s
        mw_w = min(layer.i_w, max(layer.k_w, area // mw_h))
        per = (mw_w - layer.k_w) // s + 1
        count = math.ceil(((layer.i_w - layer.k_w) // s + 1) / per)
        out.append(MarginalWindow(mw_w=mw_w, mw_h=mw_h, count=count, edge="h"))

    return tuple(out)


def n_windows(layer: ConvLayerSpec, window: Window, *,
              marginal: bool) -> Tuple[int, Tuple[MarginalWindow, ...]]:
    """(regular windows, marginal windows) for one window shape.

    ``marginal=False`` => VW-SDK ceil convention, no marginal set.
    ``marginal=True``  => Tetris floor convention + Alg 4 marginal set.
    """
    s = layer.stride
    if not marginal:
        nw = (axis_windows_ceil(layer.i_w, window.pw_w, layer.k_w, s)
              * axis_windows_ceil(layer.i_h, window.pw_h, layer.k_h, s))
        return nw, ()
    nw = (axis_windows_floor(layer.i_w, window.pw_w, layer.k_w, s)
          * axis_windows_floor(layer.i_h, window.pw_h, layer.k_h, s))
    return nw, marginal_windows(layer, window, ArrayConfig())


def candidate_windows(layer: ConvLayerSpec, array: ArrayConfig):
    """All feasible (window) shapes: at least one channel and one output
    channel must fit (AR constraint Eq 10, AC constraint Eq 11)."""
    for pw_w in range(layer.k_w, layer.i_w + 1):
        for pw_h in range(layer.k_h, layer.i_h + 1):
            w = Window(pw_w, pw_h)
            if w.rows(1) > array.ar:
                continue
            pos = w.positions(layer.k_w, layer.k_h, layer.stride)
            if pos * array.cols_per_weight > array.ac:
                continue
            yield w
