"""Top-level mapping API: algorithm dispatch + depthwise/native-group
handling + network mapping.

``map_layer(layer, array, algorithm=..., grid=...)`` is the single entry
point used by benchmarks, the CIM simulator and the JAX executors.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Iterable, Optional, Sequence

from . import baselines, grouped, tetris
from .macro_grid import GridSearchResult, macro_grid_search, map_network
from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    NetworkMapping)

ALGORITHMS = ("img2col", "SDK", "VW-SDK", "VWC-SDK", "Tetris-SDK",
              "TetrisG-SDK")


def _dispatch(algorithm: str) -> Callable[..., LayerMapping]:
    return {
        "img2col": baselines.img2col,
        "SDK": baselines.sdk,
        "VW-SDK": baselines.vw_sdk,
        "VWC-SDK": baselines.vwc_sdk,
        "Tetris-SDK": tetris.tetris_layer,
        "TetrisG-SDK": grouped.tetrisg_layer,
    }[algorithm]


def map_layer(layer: ConvLayerSpec, array: ArrayConfig,
              algorithm: str = "TetrisG-SDK",
              grid: MacroGrid = MacroGrid(), **kw) -> LayerMapping:
    """Map one conv layer.  Layers with native groups (depthwise etc.) are
    mapped per native group and the native-group loop folds into the
    `group` multiplier — the paper's MobileNet observation (depthwise
    leaves no cross-channel reuse) falls out of this accounting."""
    if layer.groups > 1:
        sub = layer.per_group(layer.groups)
        m = _dispatch(algorithm)(sub, array, grid, **kw)
        return LayerMapping(layer=layer, array=array, algorithm=m.algorithm,
                            tiles=m.tiles, grid=grid,
                            group=layer.groups * m.group,
                            group_split=grouped.best_group_split(
                                m, layer.groups * m.group, grid))
    return _dispatch(algorithm)(layer, array, grid, **kw)


def map_net(name: str, layers: Sequence[ConvLayerSpec], array: ArrayConfig,
            algorithm: str = "TetrisG-SDK",
            grid: MacroGrid = MacroGrid(), **kw) -> NetworkMapping:
    mapped = tuple(map_layer(l, array, algorithm, grid, **kw) for l in layers)
    return NetworkMapping(name=name, algorithm=algorithm, array=array,
                          layers=mapped, grid=grid)


def grid_search(name: str, layers: Sequence[ConvLayerSpec],
                array: ArrayConfig, p_max: int,
                algorithm: str = "TetrisG-SDK", **kw) -> GridSearchResult:
    """Alg 2 entry point."""
    def mapper(l, a, g, **kwargs):
        return map_layer(l, a, algorithm, g, **kwargs)
    return macro_grid_search(name, layers, array, mapper, p_max, **kw)
