"""Top-level mapping API: algorithm dispatch + depthwise/native-group
handling + network mapping (paper §III, Algs 1-5 behind one door).

``map_layer(layer, array, algorithm=..., grid=...)`` is the single entry
point used by benchmarks, the CIM simulator and all three JAX executors
(cnn/cim_conv.py, cnn/mapped_net.py, kernels/im2win_conv.py);
``map_net`` / ``grid_search`` lift it to whole networks and the Alg 2
macro-budget sweep.  ``ALGORITHMS`` orders the six searches exactly as
the paper's comparison tables do (img2col -> TetrisG-SDK).

Native groups (depthwise = ``groups=ic``, §IV-C3): a layer with
``groups > 1`` is mapped once on its per-group dims and the native-group
count folds *multiplicatively* into ``LayerMapping.group`` — TetrisG's
searched grouping composes on top, and the paper's MobileNet observation
(depthwise leaves no cross-channel reuse, so SDK windows degenerate)
falls out of this accounting rather than being special-cased.

Invariants:

* every returned ``LayerMapping`` carries the caller's ``grid`` and is
  executable as-is by the executors (tiles cover all kept channels; the
  DESIGN.md §5 equivalence contract is algorithm-independent);
* ``tiles`` always describe ONE group's mapping — for native groups the
  per-group sub-layer's, re-wrapped onto the full layer spec;
* dispatch is total over ``ALGORITHMS``: an unknown name raises KeyError
  rather than silently falling back.
"""
from __future__ import annotations

from typing import Callable, Sequence

from . import baselines, grouped, tetris
from .macro_grid import GridSearchResult, macro_grid_search
from .types import (ArrayConfig, ConvLayerSpec, LayerMapping, MacroGrid,
                    NetworkMapping)

ALGORITHMS = ("img2col", "SDK", "VW-SDK", "VWC-SDK", "Tetris-SDK",
              "TetrisG-SDK")


def _dispatch(algorithm: str) -> Callable[..., LayerMapping]:
    return {
        "img2col": baselines.img2col,
        "SDK": baselines.sdk,
        "VW-SDK": baselines.vw_sdk,
        "VWC-SDK": baselines.vwc_sdk,
        "Tetris-SDK": tetris.tetris_layer,
        "TetrisG-SDK": grouped.tetrisg_layer,
    }[algorithm]


def map_layer(layer: ConvLayerSpec, array: ArrayConfig,
              algorithm: str = "TetrisG-SDK",
              grid: MacroGrid = MacroGrid(), **kw) -> LayerMapping:
    """Map one conv layer.  Layers with native groups (depthwise etc.) are
    mapped per native group and the native-group loop folds into the
    `group` multiplier — the paper's MobileNet observation (depthwise
    leaves no cross-channel reuse) falls out of this accounting."""
    if layer.groups > 1:
        sub = layer.per_group(layer.groups)
        m = _dispatch(algorithm)(sub, array, grid, **kw)
        return LayerMapping(layer=layer, array=array, algorithm=m.algorithm,
                            tiles=m.tiles, grid=grid,
                            group=layer.groups * m.group,
                            group_split=grouped.best_group_split(
                                m, layer.groups * m.group, grid))
    return _dispatch(algorithm)(layer, array, grid, **kw)


def map_net(name: str, layers: Sequence[ConvLayerSpec], array: ArrayConfig,
            algorithm: str = "TetrisG-SDK",
            grid: MacroGrid = MacroGrid(), glue=None, **kw) -> NetworkMapping:
    """Map every layer; ``glue`` (optional tuple[GlueSpec, ...], one per
    layer) passes through to the NetworkMapping for compile_plan —
    mapping search itself never looks at it."""
    mapped = tuple(map_layer(ly, array, algorithm, grid, **kw)
                   for ly in layers)
    return NetworkMapping(name=name, algorithm=algorithm, array=array,
                          layers=mapped, grid=grid, glue=glue)


def grid_search(name: str, layers: Sequence[ConvLayerSpec],
                array: ArrayConfig, p_max: int,
                algorithm: str = "TetrisG-SDK", **kw) -> GridSearchResult:
    """Alg 2 entry point."""
    def mapper(ly, a, g, **kwargs):
        return map_layer(ly, a, algorithm, g, **kwargs)
    return macro_grid_search(name, layers, array, mapper, p_max, **kw)
