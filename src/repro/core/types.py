"""Core data model for CIM convolution mapping.

All mapping algorithms (img2col / SDK / VW-SDK / VWC-SDK / Tetris-SDK /
TetrisG-SDK) consume a :class:`ConvLayerSpec` + :class:`ArrayConfig` and
produce a :class:`LayerMapping` — an explicit, executable description of the
parallel-window tiling (window shapes, per-tile channel depths, marginal
windows, cycle counts). The `MappingPlan` for a whole network is the unit
consumed by the CIM simulator (core/simulator.py) and by the JAX executors
(cnn/cim_conv.py, kernels/).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Sequence, Tuple


@dataclass(frozen=True)
class ConvLayerSpec:
    """One convolutional layer, post-padding.

    ``i_w``/``i_h`` are the *padded* input feature-map spatial dims (the
    paper's Table I lists padded IFMs, e.g. 18x18 for a 16x16 feature map
    with 3x3/pad-1 convolution).  ``groups`` is the layer's *native* group
    count (depthwise = ic); TetrisG's grouped-convolution transform is
    applied on top via ``grouped.apply_grouping``.
    """

    name: str
    i_w: int
    i_h: int
    k_w: int
    k_h: int
    ic: int
    oc: int
    stride: int = 1
    groups: int = 1
    op: str = "conv"           # "conv" | "matmul" (degenerate 1x1 geometry)

    def __post_init__(self):
        if self.i_w < self.k_w or self.i_h < self.k_h:
            raise ValueError(f"{self.name}: IFM smaller than kernel")
        if self.ic % self.groups or self.oc % self.groups:
            raise ValueError(f"{self.name}: ic/oc not divisible by groups")
        if self.op not in ("conv", "matmul"):
            raise ValueError(f"{self.name}: unknown op {self.op!r}")
        if self.op == "matmul" and (self.k_w != 1 or self.k_h != 1
                                    or self.stride != 1 or self.i_w != 1):
            raise ValueError(
                f"{self.name}: op='matmul' must be the degenerate 1x1 "
                f"geometry (k=1, stride=1, i_w=1); use matmul_spec()")

    @property
    def k(self) -> int:
        if self.k_w != self.k_h:
            raise ValueError("square kernel expected")
        return self.k_w

    @property
    def o_w(self) -> int:
        return (self.i_w - self.k_w) // self.stride + 1

    @property
    def o_h(self) -> int:
        return (self.i_h - self.k_h) // self.stride + 1

    @property
    def macs(self) -> int:
        """MAC count of the layer (per image)."""
        return (self.k_w * self.k_h * (self.ic // self.groups) * self.oc
                * self.o_w * self.o_h)

    def per_group(self, g: int) -> "ConvLayerSpec":
        """Per-group dims after grouping (Eq 9).  The per-group layer is an
        ordinary (group-free) convolution."""
        if self.ic % g or self.oc % g:
            raise ValueError(f"{self.name}: cannot split into {g} groups")
        return dataclasses.replace(
            self, name=f"{self.name}/g{g}", ic=self.ic // g, oc=self.oc // g,
            groups=1)


def conv1d(name: str, length: int, k: int, ic: int, oc: int,
           groups: int = 1) -> ConvLayerSpec:
    """1-D (temporal) convolution as a degenerate Kx1 2-D layer."""
    return ConvLayerSpec(name=name, i_w=1, i_h=length, k_w=1, k_h=k,
                         ic=ic, oc=oc, groups=groups)


def matmul_spec(name: str, m: int, d: int, f: int,
                groups: int = 1) -> ConvLayerSpec:
    """An ``(M, D) @ (D, F)`` matmul as the degenerate 1x1 conv the
    mapping search already speaks: M token/row positions along ``i_h``,
    D input channels, F output channels (grouped matmul == the paper's
    §III-B grouped convolution with k=1).  ``macs`` reduces to
    ``M * (D // G) * F`` and the ceil-form cycle model, utilization and
    ``group_split`` all apply verbatim; the ``op`` tag is what executors
    and cache keys dispatch on."""
    return ConvLayerSpec(name=name, i_w=1, i_h=m, k_w=1, k_h=1,
                         ic=d, oc=f, groups=groups, op="matmul")


_GLUE_PRE = ("none", "layernorm")
_GLUE_ACT = ("none", "relu", "gelu", "silu")
_GLUE_POST = ("none", "attention")


@dataclass(frozen=True)
class GlueSpec:
    """Inter-layer glue for one plan step — everything between two mapped
    layers that the CIM macros do not execute.

    Applied around layer i's mapped op in this order: ``save`` captures
    the (pre-norm) input for a later residual; ``pre`` normalizes the
    mapped op's input; ``act`` activates its output (overriding any
    global activation for this layer); ``post='attention'`` runs the
    opaque flash-attention stage on a fused qkv output (``heads =
    (n_q, n_kv, head_dim)``); ``kind`` then forms the next layer's
    input — "chain" passes through, "concat" is the DenseNet skip,
    "residual" pops the innermost saved input and adds it.
    """

    kind: str = "chain"        # "chain" | "concat" | "residual" | "last"
    pre: str = "none"
    act: str = "none"
    post: str = "none"
    save: bool = False
    heads: "Tuple[int, int, int]" = None  # (n_q_heads, n_kv_heads, head_dim)
    causal: bool = True

    def __post_init__(self):
        if self.kind not in ("chain", "concat", "residual", "last",
                             "layerwise"):
            raise ValueError(f"unknown glue kind {self.kind!r}")
        if self.pre not in _GLUE_PRE:
            raise ValueError(f"unknown glue pre {self.pre!r}")
        if self.act not in _GLUE_ACT:
            raise ValueError(f"unknown glue act {self.act!r}")
        if self.post not in _GLUE_POST:
            raise ValueError(f"unknown glue post {self.post!r}")
        if (self.heads is not None) != (self.post == "attention"):
            raise ValueError("heads required iff post='attention'")


@dataclass(frozen=True)
class ArrayConfig:
    """A CIM macro: AR x AC bit-cells.

    ``cols_per_weight`` — columns one weight occupies (multi-bit weights on
    consecutive bitlines, Fig 3).  Table I accounting uses 1 (AC counted in
    weight units); the Fig 4 worked example uses 5 (5b weights on a 40x15
    array).
    """

    ar: int = 512
    ac: int = 512
    cols_per_weight: int = 1
    input_bits: int = 8        # bit-serial input cycles (used by simulator)

    @property
    def cells(self) -> int:
        return self.ar * self.ac


@dataclass(frozen=True)
class MacroGrid:
    """An r x c arrangement of identical macros (Alg 2 candidate)."""

    r: int = 1
    c: int = 1

    @property
    def p(self) -> int:
        return self.r * self.c


@dataclass(frozen=True)
class Window:
    """A parallel window: pw_w x pw_h input pixels, covering
    (pw_w-k_w+1) x (pw_h-k_h+1) kernel positions (stride 1 inside)."""

    pw_w: int
    pw_h: int

    def positions(self, k_w: int, k_h: int, stride: int = 1) -> int:
        return (((self.pw_w - k_w) // stride + 1)
                * ((self.pw_h - k_h) // stride + 1))

    def rows(self, depth: int) -> int:
        return self.pw_w * self.pw_h * depth

    def __str__(self):
        return f"{self.pw_w}x{self.pw_h}"


@dataclass(frozen=True)
class MarginalWindow:
    """Alg 4 border window: shape + how many window loads it contributes."""

    mw_w: int
    mw_h: int
    count: int
    edge: str  # "w" (right strip) or "h" (bottom strip)


@dataclass(frozen=True)
class TileMapping:
    """One channel-partition tile mapped with one window shape."""

    window: Window
    depth: int                 # input channels in this tile
    ic_t: int                  # channels per array load (<= depth)
    oc_t: int                  # output channels per array load
    ar_c: int                  # ceil(depth / ic_t) sequential channel loads
    ac_c: int                  # ceil(oc / oc_t) sequential output loads
    n_regular: int
    marginals: tuple = ()      # tuple[MarginalWindow, ...]
    pruned_channels: int = 0

    @property
    def n_windows(self) -> int:
        return self.n_regular + sum(m.count for m in self.marginals)

    def cycles(self, grid: MacroGrid = MacroGrid()) -> int:
        """Eq 5 (grid=1x1) / generalised Eq 6."""
        return (self.n_windows
                * math.ceil(self.ar_c / grid.r)
                * math.ceil(self.ac_c / grid.c))

    def mapped_cells(self, layer: ConvLayerSpec, array: ArrayConfig) -> int:
        """Weight-occupied cells (WC) per array load, for Eq 8.  SDK-style
        whole-channel tiles multiplex over several loads: a single load
        holds at most floor(AR / window area) channels."""
        k_area = layer.k_w * layer.k_h
        pos = self.window.positions(layer.k_w, layer.k_h, layer.stride)
        per_load_ic = min(self.ic_t,
                          array.ar // (self.window.pw_w * self.window.pw_h))
        return k_area * per_load_ic * pos * self.oc_t * array.cols_per_weight


def sub_grid(grid: MacroGrid, group_split: Tuple[int, int]) -> MacroGrid:
    """The disjoint sub-grid ONE group's mapping runs on when
    ``group_split=(gr,gc)`` groups execute concurrently along the grid
    axes (Eq 6): rows parallelize channel passes, columns oc passes."""
    gr, gc = group_split
    return MacroGrid(max(1, grid.r // gr), max(1, grid.c // gc))


def layer_cycles(tiles: Sequence["TileMapping"], grid: MacroGrid,
                 group: int, group_split: Tuple[int, int]) -> int:
    """Total cycles for `group` groups, `group_split=(gr,gc)` of them running
    concurrently on disjoint (r//gr) x (c//gc) sub-grids (Eq 5/6 general).

    The mapping of a single group runs on a sub-grid; `gr*gc` groups run in
    parallel; remaining groups are time-multiplexed.  With grid=1x1 and
    group=1 this is exactly Eq 5.
    """
    gr, gc = group_split
    sub = sub_grid(grid, group_split)
    per_group = sum(t.cycles(sub) for t in tiles)
    return per_group * math.ceil(group / (gr * gc))


@dataclass(frozen=True)
class LayerMapping:
    """Full mapping of one layer under one algorithm.

    ``group`` is the TetrisG grouping factor; ``tiles`` describe ONE group's
    mapping (all groups are congruent); ``group_split=(gr,gc)`` says how many
    groups run concurrently along each grid dimension.
    """

    layer: ConvLayerSpec
    array: ArrayConfig
    algorithm: str
    tiles: tuple                   # tuple[TileMapping, ...]
    grid: MacroGrid = MacroGrid()
    group: int = 1
    group_split: Tuple[int, int] = (1, 1)

    @property
    def cycles(self) -> int:
        return layer_cycles(self.tiles, self.grid, self.group,
                            self.group_split)

    @property
    def sub_grid(self) -> MacroGrid:
        """Sub-grid one group's passes occupy (rows -> channel passes,
        columns -> oc passes); see :func:`sub_grid`."""
        return sub_grid(self.grid, self.group_split)

    @property
    def group_rounds(self) -> int:
        """Sequential rounds of group execution: ``gr*gc`` groups run
        concurrently on disjoint sub-grids, the rest time-multiplex."""
        gr, gc = self.group_split
        return math.ceil(self.group / (gr * gc))

    def tile_passes(self, tile: "TileMapping") -> Tuple[int, int, int, int]:
        """Executed pass structure ``(ic_t, ar_c, oc_t, ac_c)`` of a tile,
        per group.  ``ar_c``/``ac_c`` are the MAPPING's sequential pass
        counts; the executed channel block is re-derived as
        ``ceil(depth / ar_c)`` because SDK-style tiles whose unrolled
        window exceeds AR multiplex *rows* (not channels) over their
        ``ar_c`` passes — re-deriving keeps executed passes == accounted
        passes for every algorithm (DESIGN.md §3 equivalence contract)."""
        oc_g = self.layer.oc // self.group
        ic_t = math.ceil(tile.depth / tile.ar_c)
        oc_t = min(tile.oc_t, oc_g)
        ac_c = math.ceil(oc_g / oc_t)
        return ic_t, tile.ar_c, oc_t, ac_c

    @property
    def n_windows(self) -> int:
        return sum(t.n_windows for t in self.tiles) * self.group

    @property
    def pruned_channels(self) -> int:
        return sum(t.pruned_channels for t in self.tiles) * self.group

    @property
    def utilization(self) -> float:
        """Array utilization (Eq 8), averaged over tiles weighted by loads."""
        num = 0
        den = 0
        for t in self.tiles:
            loads = t.ar_c * t.ac_c * t.n_windows
            num += t.mapped_cells(self.layer, self.array) * loads
            den += self.array.cells * loads
        return num / den if den else 0.0

    @property
    def active_macros(self) -> int:
        """Macros actually used (idle ones are power-gated, §IV-E)."""
        gr, gc = self.group_split
        sub = self.sub_grid
        used_r = max(min(t.ar_c, sub.r) for t in self.tiles)
        used_c = max(min(t.ac_c, sub.c) for t in self.tiles)
        g_par = min(self.group, gr * gc)
        return min(self.grid.p, used_r * used_c * g_par)


@dataclass(frozen=True)
class NetworkMapping:
    """Mapping of a whole network: one LayerMapping per mapped layer.

    ``glue`` is optional explicit inter-layer glue (one `GlueSpec` per
    layer, e.g. from `launch.transformer.transformer_mapping`); when
    None, ``compile_plan`` infers chain/concat glue from channel
    arithmetic as it always has for CNNs.
    """

    name: str
    algorithm: str
    array: ArrayConfig
    layers: tuple                  # tuple[LayerMapping, ...]
    grid: MacroGrid = MacroGrid()
    glue: tuple = None             # Optional[tuple[GlueSpec, ...]]

    def __post_init__(self):
        if self.glue is not None and len(self.glue) != len(self.layers):
            raise ValueError(
                f"{self.name}: glue length {len(self.glue)} != "
                f"{len(self.layers)} layers")

    @property
    def total_cycles(self) -> int:
        return sum(m.cycles for m in self.layers)

    @property
    def mean_utilization(self) -> float:
        us = [m.utilization for m in self.layers]
        return sum(us) / len(us) if us else 0.0

    def summary(self) -> str:
        lines = [f"{self.name} [{self.algorithm}] grid={self.grid.r}x{self.grid.c} "
                 f"total_cycles={self.total_cycles}"]
        for m in self.layers:
            tiles = ", ".join(
                f"{t.window}x{t.ic_t}x{t.oc_t}"
                + (f"(-{t.pruned_channels}ch)" if t.pruned_channels else "")
                for t in m.tiles)
            lines.append(
                f"  {m.layer.name:>14s} G={m.group} cycles={m.cycles:>5d} "
                f"util={m.utilization:5.1%}  [{tiles}]")
        return "\n".join(lines)
