"""Joint configuration space of the autotuner + analytical seeding.

The window/grid search (core/mapper.py) optimizes the paper's
*analytical* cycle count; since PR 4/5 the stack has knobs that count
just as much on a real machine but are invisible to that model:

* **executor policy** — which of reference / mapped / sdk runs each
  layer (the ``"auto"`` heuristic guesses; the machine decides);
* **mesh split** — how a fixed device budget divides into
  (data, row, col): macro parallelism vs batch replicas
  (`launch.mesh.mesh_split_candidates`);
* **lookahead** — the fused program's cross-layer pipeline depth
  (`NetworkPlan.lookahead`);
* **sdk block / vmem_budget** — the Pallas kernel's tiling mode and the
  ``block="auto"`` VMEM byte budget;
* **batch tiers** — the dynamic-serving plan-batch ladder.

A :class:`Candidate` pins all of them.  :func:`analytic_cost` scores the
part of a candidate the cycle model CAN see — per-layer cycles weighted
per executor, divided by the mesh parallelism the split realizes — and
:func:`shortlist` uses it to seed the measured search near-optimal:
candidates are ranked by their (policy, mesh_split) *base*, then
promoted base-major, so every measured-only knob variant (lookahead,
block, vmem, tiers — identical under the model by construction) of a
better base enters the shortlist before a worse base does.  Only the
shortlist is ever measured (repro/tune/search.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

#: Relative per-cycle wall-clock weight of each executor — a host-side
#: cost proxy (dispatch + gather/scatter overhead per super-step), NOT a
#: measurement: the placement-batched reference path issues the fewest
#: ops per cycle, the macro-parallel executor pays vmap/shard_map
#: plumbing unless a mesh absorbs it, the sdk kernel wins on the MXU.
#: Only used to RANK seeds; measurement settles every decision.  The
#: "matmul" MXU path for op="matmul" layers prices like the sdk kernel:
#: both hand the super-step to the systolic stand-in with no
#: gather/scatter plumbing per cycle.
EXEC_WEIGHTS = {"reference": 1.0, "mapped": 1.6, "sdk": 0.8,
                "matmul": 0.8}


@dataclass(frozen=True)
class Candidate:
    """One point of the joint space — everything `compile_plan` and the
    serve path need to realize it.  Frozen/hashable so candidates key
    dicts in the search driver and pickle into the disk cache."""

    policy: Tuple[str, ...]     # resolved per-layer executors
    lookahead: int = 1          # fused-program pipeline depth
    block: str = "auto"         # sdk tiling mode
    vmem_budget: Optional[int] = None   # sdk auto-block budget (None: env)
    tiers: Optional[Tuple[int, ...]] = None   # plan-batch ladder (None:
                                              # the power-of-two default)
    mesh_split: Optional[Tuple[int, int, int]] = None  # (data, row, col)
    #: rematerialization spec forwarded to `compile_plan(remat=...)` —
    #: None (off), "auto", a byte budget, or explicit cut indices; the
    #: autotuner can trade recompute cycles for live memory with it
    #: (training workloads — serving plans never differentiate)
    remat: object = None

    @property
    def base(self) -> Tuple:
        """The (policy, mesh_split) part the analytical model can see —
        candidates sharing a base tie under :func:`analytic_cost`."""
        return (self.policy, self.mesh_split)

    def describe(self) -> str:
        pol = ("/".join(sorted(set(self.policy)))
               if len(set(self.policy)) > 1 else self.policy[0])
        split = ("x".join(str(s) for s in self.mesh_split)
                 if self.mesh_split else "vmap")
        bits = [f"policy={pol}", f"mesh={split}",
                f"lookahead={self.lookahead}"]
        if self.block != "auto":
            bits.append(f"block={self.block}")
        if self.vmem_budget is not None:
            bits.append(f"vmem={self.vmem_budget}")
        if self.tiers is not None:
            bits.append(f"tiers={'/'.join(str(t) for t in self.tiers)}")
        if self.remat is not None:
            bits.append(f"remat={self.remat}")
        return " ".join(bits)


@dataclass(frozen=True)
class TunedConfig:
    """A persisted winner: the candidate plus the evidence it won on.
    What `memo.store_tuning` pickles and ``executor_policy="tuned"``
    loads (exec/plan.py)."""

    candidate: Candidate
    median_s: float             # winner's final-stage median wall-clock
    baseline_s: float           # the "auto" default, SAME final rounds
    rounds: int                 # final-stage rounds the medians used
    measurements: int           # total measured steps spent searching
    fleet: Tuple[str, int]      # (platform, device count) tuned on
    batch: int                  # batch profile tuned for

    @property
    def speedup(self) -> float:
        return self.baseline_s / max(self.median_s, 1e-12)

    def describe(self) -> str:
        return (f"tuned[{self.candidate.describe()}] "
                f"{self.median_s * 1e6:.0f}us vs auto "
                f"{self.baseline_s * 1e6:.0f}us "
                f"({self.speedup:.2f}x, rounds={self.rounds}, "
                f"measurements={self.measurements}, "
                f"fleet={self.fleet[0]}x{self.fleet[1]}, "
                f"batch={self.batch})")


def auto_policy(net, *, backend: Optional[str] = None) -> Tuple[str, ...]:
    """The per-layer executors the ``"auto"`` heuristic resolves to —
    the search's baseline policy and first seed."""
    import jax
    from repro.exec.plan import _resolve_policy
    return _resolve_policy("auto", net,
                           backend=backend or jax.default_backend())


def policy_candidates(net, *, backend: Optional[str] = None
                      ) -> Tuple[Tuple[str, ...], ...]:
    """Executor-policy seeds: the resolved auto heuristic, the uniform
    policies every layer supports (sdk only on TPU and only when every
    layer's mapping owes no macro/group parallelism), and single-layer
    flips of the heaviest layer (largest cycle share — where a wrong
    heuristic guess costs the most)."""
    import jax
    from repro.exec.plan import _sdk_realizable
    backend = backend or jax.default_backend()
    auto = auto_policy(net, backend=backend)
    n = len(net.layers)
    sdk_ok = (backend == "tpu"
              and all(_sdk_realizable(m) for m in net.layers))
    # the "matmul" executor only accepts op="matmul" layers (exec/plan
    # rejects it at compile time otherwise), and like sdk it only pays
    # off on the MXU
    matmul_ok = (backend == "tpu"
                 and all(getattr(m.layer, "op", "conv") == "matmul"
                         for m in net.layers))
    out = [auto]
    for name in (("reference", "mapped")
                 + (("sdk",) if sdk_ok else ())
                 + (("matmul",) if matmul_ok else ())):
        uniform = (name,) * n
        if uniform not in out:
            out.append(uniform)
    heavy = max(range(n), key=lambda i: net.layers[i].cycles)
    flips = ["reference", "mapped"] + (["sdk"] if sdk_ok else [])
    if (backend == "tpu"
            and getattr(net.layers[heavy].layer, "op", "conv") == "matmul"):
        flips.append("matmul")
    for name in flips:
        if name == auto[heavy]:
            continue
        if name == "sdk" and not _sdk_realizable(net.layers[heavy]):
            continue
        flipped = auto[:heavy] + (name,) + auto[heavy + 1:]
        if flipped not in out:
            out.append(flipped)
    return tuple(out)


def analytic_cost(net, cand: Candidate) -> float:
    """Cycle-model score of a candidate's *base*: per-layer analytical
    cycles, weighted per executor (:data:`EXEC_WEIGHTS`), divided by the
    macro parallelism the mesh split realizes for mapped layers and by
    the data-axis replica count for the whole batch.  Candidates that
    differ only in lookahead / block / vmem / tiers tie exactly — those
    knobs are what measurement exists for."""
    data, row, col = cand.mesh_split or (1, 1, 1)
    total = 0.0
    for m, ex in zip(net.layers, cand.policy):
        c = m.cycles * EXEC_WEIGHTS[ex]
        if ex == "mapped":
            # shard_map only engages when the mesh divides the sub-grid
            # (macro_mesh_fits); the gcd construction of the split
            # candidates guarantees it, so min() is the realized share
            par = (min(row, m.sub_grid.r) * min(col, m.sub_grid.c))
            c /= max(par, 1)
        total += c
    return total / max(data, 1)


def enumerate_space(net, *, batch: int, devices=None,
                    backend: Optional[str] = None,
                    lookaheads: Sequence[int] = (0, 1, 2),
                    blocks: Sequence[str] = ("auto",),
                    vmem_budgets: Sequence[Optional[int]] = (None,),
                    tiers_options: Sequence[Optional[Tuple[int, ...]]] =
                    (None,),
                    mesh_splits=None,
                    remats: Sequence = (None,)) -> Tuple[Candidate, ...]:
    """The full joint space (deduplicated, deterministic order): policy
    seeds x mesh splits x lookahead x sdk knobs x tier sets x remat
    specs.  sdk block / vmem variants only expand policies that actually
    run sdk layers — they are no-ops elsewhere and would only dilute the
    shortlist.  ``remats`` defaults to remat-off only (serving never
    differentiates); training tuners pass e.g. ``(None, "auto")`` to
    let the search trade recompute cycles for live memory."""
    from repro.launch import mesh as meshlib
    if mesh_splits is None:
        mesh_splits = meshlib.mesh_split_candidates(net, batch, devices)
    out = []
    for policy in policy_candidates(net, backend=backend):
        has_sdk = "sdk" in policy
        for split in mesh_splits:
            for la in lookaheads:
                for blk in (blocks if has_sdk else ("auto",)):
                    for vb in (vmem_budgets if has_sdk else (None,)):
                        for tiers in tiers_options:
                            for rm in remats:
                                c = Candidate(policy=policy, lookahead=la,
                                              block=blk, vmem_budget=vb,
                                              tiers=tiers, mesh_split=split,
                                              remat=rm)
                                if c not in out:
                                    out.append(c)
    return tuple(out)


def baseline_candidate(net, *, batch: int, devices=None,
                       backend: Optional[str] = None) -> Candidate:
    """What every serve entry point runs TODAY with no tuning: the auto
    executor heuristic, lookahead 1, sdk defaults, the default tier
    ladder, and `serving_mesh_for`'s mesh — the champion each search
    carries into its final round, so the reported speedup is always
    relative to the real default."""
    from repro.launch import mesh as meshlib
    split = meshlib.mesh_split(meshlib.serving_mesh_for(net, batch,
                                                        devices))
    return Candidate(policy=auto_policy(net, backend=backend),
                     lookahead=1, mesh_split=split)


def shortlist(net, cands: Sequence[Candidate], k: int, *,
              baseline: Optional[Candidate] = None) -> Tuple[Candidate, ...]:
    """Analytical seeding: the ``k`` candidates the search will actually
    measure.  Bases — distinct (policy, mesh_split) pairs — are ranked
    by :func:`analytic_cost` (ties keep first-seen order), and
    candidates promote base-major: every variant of a better base before
    any of a worse one, so the measured-only knobs of the
    model-predicted winner are always explored first.  ``baseline`` is
    forced in (displacing the tail when full): a winner is only
    meaningful measured against the default."""
    if k < 1:
        raise ValueError(f"shortlist needs k >= 1, got {k}")
    cands = list(cands)
    if baseline is not None and baseline not in cands:
        cands.append(baseline)
    order: dict = {}
    for c in cands:
        order.setdefault(c.base, len(order))
    ranked = sorted(order, key=lambda b: (analytic_cost(
        net, next(c for c in cands if c.base == b)), order[b]))
    short = [c for b in ranked for c in cands if c.base == b][:k]
    if baseline is not None and baseline not in short:
        short[-1:] = [baseline]
    return tuple(short)
