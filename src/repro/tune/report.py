"""Reporting for autotune runs: CSV rows, JSON dumps, BENCH trajectory.

Three audiences:

* **CI artifacts** — :func:`write_csv` emits the harness's CSV contract
  (``name,usec,extras``) with one row per (net, stage, candidate) trial
  plus a summary row per net; ``python -m benchmarks.tune_bench``
  uploads it as ``tune_bench.csv``.
* **Programmatic** — :func:`write_json` dumps the full
  :class:`~repro.tune.search.TuneResult` (winner, baseline, every
  trial) as plain JSON for downstream tooling.
* **Trajectory** — :func:`trajectory_entry` shapes one BENCH_*.json
  entry (the repo's perf-over-PRs ledger) from a set of finished
  results.

Serialization is hand-rolled (dataclasses → dicts) rather than pickle:
these files are for humans and dashboards, and must stay readable when
the dataclasses grow fields.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from .search import TuneResult
from .space import Candidate, TunedConfig


def candidate_dict(c: Candidate) -> dict:
    return {"policy": list(c.policy), "lookahead": c.lookahead,
            "block": c.block, "vmem_budget": c.vmem_budget,
            "tiers": list(c.tiers) if c.tiers is not None else None,
            "mesh_split": (list(c.mesh_split)
                           if c.mesh_split is not None else None)}


def config_dict(cfg: TunedConfig) -> dict:
    return {"candidate": candidate_dict(cfg.candidate),
            "median_s": cfg.median_s, "baseline_s": cfg.baseline_s,
            "speedup": cfg.speedup, "rounds": cfg.rounds,
            "measurements": cfg.measurements,
            "fleet": {"platform": cfg.fleet[0], "devices": cfg.fleet[1]},
            "batch": cfg.batch}


def result_dict(res: TuneResult) -> dict:
    return {"config": config_dict(res.config), "cached": res.cached,
            "measurements": res.measurements,
            "trials": [{"candidate": candidate_dict(t.candidate),
                        "rounds": t.rounds, "median_s": t.median_s}
                       for t in res.trials]}


def csv_rows(results: Dict[str, TuneResult]) -> list:
    """Harness CSV rows (``name,usec,key=val;...``): per net one
    ``tune/{net}`` summary row — tuned median vs the auto baseline from
    the SAME final interleaved rounds — and one ``tune/{net}/trial{i}``
    row per measured trial for the full search trajectory."""
    rows = []
    for net, res in sorted(results.items()):
        cfg = res.config
        rows.append((f"tune/{net}", cfg.median_s * 1e6,
                     f"baseline_us={cfg.baseline_s * 1e6:.1f};"
                     f"speedup={cfg.speedup:.3f};"
                     f"policy={'+'.join(sorted(set(cfg.candidate.policy)))};"
                     f"lookahead={cfg.candidate.lookahead};"
                     f"mesh={cfg.candidate.mesh_split or 'vmap'};"
                     f"batch={cfg.batch};rounds={cfg.rounds};"
                     f"measurements={res.measurements};"
                     f"cached={int(res.cached)};"
                     f"fleet={cfg.fleet[0]}x{cfg.fleet[1]}"))
        for i, t in enumerate(res.trials):
            rows.append((f"tune/{net}/trial{i}", t.median_s * 1e6,
                         f"rounds={t.rounds};"
                         f"cand={t.candidate.describe().replace(' ', '_')}"))
    return rows


def write_csv(results: Dict[str, TuneResult],
              path: Optional[str] = None) -> str:
    """Render (and optionally write) the CSV artifact; also the string
    ``tune_bench`` prints to stdout for the CI ``tee``."""
    lines = ["name,usec,extras"]
    lines += [f"{n},{us:.1f},{extras}" for n, us, extras in
              csv_rows(results)]
    text = "\n".join(lines) + "\n"
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


def write_json(results: Dict[str, TuneResult],
               path: Optional[str] = None) -> str:
    payload = {net: result_dict(res) for net, res in sorted(results.items())}
    text = json.dumps(payload, indent=2, sort_keys=True)
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


def trajectory_entry(results: Dict[str, TuneResult], *, pr: str,
                     note: str = "") -> dict:
    """One BENCH_autotune.json ledger entry: per net the tuned/baseline
    medians and the search cost, so the trajectory of 'how much does
    measurement buy over the auto heuristic' is tracked across PRs."""
    return {"pr": pr, "note": note,
            "nets": {net: {"tuned_us": res.config.median_s * 1e6,
                           "baseline_us": res.config.baseline_s * 1e6,
                           "speedup": res.config.speedup,
                           "measurements": res.measurements,
                           "fleet": list(res.config.fleet),
                           "batch": res.config.batch}
                     for net, res in sorted(results.items())}}


def append_trajectory(path: str, entry: dict) -> None:
    """Append ``entry`` to the JSON-list ledger at ``path`` (created if
    missing) — the shape every BENCH_*.json in this repo uses."""
    try:
        with open(path) as f:
            ledger = json.load(f)
    except FileNotFoundError:
        ledger = []
    if not isinstance(ledger, list):
        raise ValueError(f"{path}: trajectory ledger must be a JSON list")
    ledger.append(entry)
    with open(path, "w") as f:
        json.dump(ledger, f, indent=2, sort_keys=True)
        f.write("\n")


__all__ = ["candidate_dict", "config_dict", "result_dict", "csv_rows",
           "write_csv", "write_json", "trajectory_entry",
           "append_trajectory"]
