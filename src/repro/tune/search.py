"""Measured-feedback search driver: seeded successive halving.

`autotune` is the entry point.  Per (net mapping, device fleet, batch
profile) it:

1. **loads** a persisted winner when one exists (`memo.load_tuning` —
   a warm disk cache means a cold process adopts the tuned config with
   ZERO measurements, the acceptance contract of ISSUE 6);
2. else **enumerates** the joint space (tune/space.py) and **seeds** a
   shortlist from the analytical cycle model — only the shortlist is
   ever measured;
3. **measures** the shortlist against wall-clock with interleaved-round
   medians (tune/measure.py) under **successive halving**: every stage
   halves the pool (keeping the best ``1/eta``) and multiplies the
   per-candidate rounds by ``eta``, so cheap early rounds discard the
   clearly-bad seeds and the budget concentrates on the contenders.
   The ``"auto"``-default baseline candidate survives every cut
   (champion–challenger), so the final stage always measures the winner
   and the default in the SAME interleaved rounds — the tuned config
   can tie the default, but never lose to it on its own evidence;
4. **persists** the winner (`memo.store_tuning`) under the exact batch
   profile and under the generic (batch=None) slot, so ladder tiers
   compiled at other batches inherit it.

Both the timer (``clock``) and the per-candidate step builder
(``runner``) are injectable, which makes the whole search deterministic
under test: a fake runner that advances a fake clock by scripted
per-candidate costs must reproduce the halving schedule and the winner
exactly (tests/test_tune.py).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

from repro.core import memo

from .measure import interleaved_medians
from .space import (Candidate, TunedConfig, baseline_candidate,
                    enumerate_space, shortlist)


@dataclass(frozen=True)
class TuneBudget:
    """Measurement budget of one search.  ``shortlist`` candidates are
    promoted from the analytical seeding; stage 0 gives each ``rounds``
    interleaved rounds; every later stage keeps the best
    ``ceil(pool/eta)`` (plus the baseline) and multiplies rounds by
    ``eta``, capped at ``max_rounds`` per candidate per stage — so one
    candidate costs at most ``warmup + rounds + ... + max_rounds``
    measured steps, and the whole search is bounded up front."""

    shortlist: int = 8
    rounds: int = 3
    eta: int = 2
    max_rounds: int = 12
    warmup: int = 1

    def __post_init__(self):
        if self.shortlist < 1 or self.rounds < 1 or self.eta < 2 \
                or self.max_rounds < self.rounds or self.warmup < 0:
            raise ValueError(f"malformed budget {self}")


#: The tiny budget CI smoke runs use (benchmarks/tune_bench.py --smoke).
SMOKE_BUDGET = TuneBudget(shortlist=4, rounds=2, eta=2, max_rounds=4,
                          warmup=1)


@dataclass(frozen=True)
class Trial:
    """One candidate's median at one halving stage."""

    candidate: Candidate
    rounds: int
    median_s: float


@dataclass(frozen=True)
class TuneResult:
    """What `autotune` returns: the (possibly cached) winner plus the
    full measured trajectory for reporting (tune/report.py)."""

    config: TunedConfig
    trials: Tuple[Trial, ...]
    cached: bool                # loaded from the persistent cache —
    measurements: int           # ... then this is 0
    key: Tuple

    def describe(self) -> str:
        src = "cache" if self.cached else \
            f"search ({self.measurements} measured steps)"
        return f"{self.config.describe()} [{src}]"


def fleet_signature(devices=None) -> Tuple[str, int]:
    """(platform, device count) the tuning is valid for — part of the
    persistence key: a config tuned on 1 CPU core must not leak onto an
    8-device TPU fleet."""
    import jax
    devices = list(jax.devices() if devices is None else devices)
    plats = sorted({getattr(d, "platform", "unknown") for d in devices})
    return ("+".join(plats), len(devices))


def tuning_key(net, fleet: Tuple[str, int], batch: Optional[int],
               ragged: Optional[Tuple[int, ...]] = None) -> Tuple:
    """The persistence key: (net mapping, device fleet, batch profile).
    ``ragged`` distinguishes a dynamic-serving profile (the request-size
    stream tuned against) from the fixed-batch one."""
    return (net, fleet, batch, ragged)


def tuned_config(net, *, batch: Optional[int] = None, devices=None,
                 ragged: Optional[Tuple[int, ...]] = None
                 ) -> Optional[TunedConfig]:
    """Peek the persisted winner for this (net, fleet, batch) — exact
    batch first, then the generic slot a search also stores under — or
    ``None`` when nothing was ever tuned (callers fall back to
    ``"auto"``; `compile_plan(executor_policy="tuned")` does exactly
    that)."""
    fleet = fleet_signature(devices)
    slots = (batch, None) if batch is not None else (None,)
    for b in slots:
        cfg = memo.load_tuning(tuning_key(net, fleet, b, ragged))
        if cfg is not None:
            return cfg
    return None


def _chains(net) -> bool:
    """Whether the net compiles as a chain (execute_plan) or only as a
    layer set (execute_layerwise) — inception's spec list is a
    representative set, not a chain."""
    from repro.exec.glue import resolve_chain
    carry = net.layers[0].layer.ic
    try:
        for a, b in zip(net.layers, net.layers[1:]):
            resolve_chain(a.layer.name, a.layer.oc, carry,
                          b.layer.name, b.layer.ic)
            carry = b.layer.ic
        return True
    except ValueError:
        return False


def resolve_tiers(cand: Candidate, max_batch: int, mesh):
    """The candidate's tier ladder made valid for ITS mesh: every tier
    padded to the data axis (tiers were proposed mesh-agnostically) and
    the top tier covering ``max_batch``."""
    from repro.launch import batching, mesh as meshlib
    if cand.tiers is None:
        return batching.batch_tiers(max_batch, mesh)
    tiers = sorted({meshlib.pad_to_data_axis(int(t), mesh)
                    for t in cand.tiers})
    top = meshlib.pad_to_data_axis(max_batch, mesh)
    if not tiers or tiers[-1] < top:
        tiers.append(top)
    return tuple(tiers)


def default_runner(net, *, batch: int, devices=None,
                   ragged: Optional[Tuple[int, ...]] = None,
                   max_delay_ms: float = 0.5,
                   seed: int = 0) -> Callable[[Candidate], Callable]:
    """Build the measured step for a candidate.

    Fixed profile (``ragged=None``): one steady-state `execute_plan`
    forward at the candidate's padded plan batch (`execute_layerwise`
    for nets that do not chain).  Ragged profile: one backlogged
    `serve_dynamic` drain of the ``ragged`` request sizes through the
    candidate's tier ladder — the coalescer/ladder policy is then part
    of what is measured.  Compilation happens on the warmup call the
    measurement harness issues, so timed rounds see the steady state.
    """
    import jax
    import jax.numpy as jnp
    from repro.exec import compile_plan, execute_layerwise, execute_plan
    from repro.launch import mesh as meshlib, serve_cnn

    chained = _chains(net)
    rng, ks = serve_cnn._serving_kernels(net, seed)
    first = net.layers[0].layer

    def build(cand: Candidate) -> Callable[[], None]:
        mesh = meshlib.mesh_from_split(cand.mesh_split, devices)
        if ragged is not None and chained:
            reqs = tuple((0.0, int(r)) for r in ragged)
            tiers = resolve_tiers(cand, batch, mesh)

            def step():
                serve_cnn.serve_dynamic(
                    net, reqs, max_batch=batch,
                    max_delay_ms=max_delay_ms, mesh=mesh, tiers=tiers,
                    policy=cand.policy, warmup=0, seed=seed,
                    lookahead=cand.lookahead, block=cand.block,
                    vmem_budget=cand.vmem_budget)
            return step

        plan_batch = meshlib.pad_to_data_axis(batch, mesh)
        plan = compile_plan(net, executor_policy=cand.policy, mesh=mesh,
                            batch=plan_batch, chained=chained,
                            lookahead=cand.lookahead, block=cand.block,
                            vmem_budget=cand.vmem_budget,
                            remat=cand.remat)
        if chained:
            x = jnp.asarray(rng.randn(plan_batch, first.ic, first.i_h,
                                      first.i_w), jnp.float32)

            def step():
                jax.block_until_ready(
                    execute_plan(plan, ks, x, mesh=mesh))
            return step

        xs = tuple(jnp.asarray(
            rng.randn(plan_batch, m.layer.ic, m.layer.i_h, m.layer.i_w),
            jnp.float32) for m in net.layers)

        def step():
            jax.block_until_ready(
                execute_layerwise(plan, ks, xs, mesh=mesh))
        return step

    return build


def autotune(net, *, batch: int, devices=None,
             space: Optional[Sequence[Candidate]] = None,
             baseline: Optional[Candidate] = None,
             budget: Optional[TuneBudget] = None,
             clock: Callable[[], float] = time.perf_counter,
             runner: Optional[Callable[[Candidate], Callable]] = None,
             ragged: Optional[Tuple[int, ...]] = None,
             max_delay_ms: float = 0.5, seed: int = 0,
             force: bool = False, store: bool = True) -> TuneResult:
    """Find (or load) the fastest measured configuration of ``net`` for
    this device fleet and batch profile — see the module docstring for
    the search shape.  ``force=True`` re-measures even with a persisted
    winner; ``store=False`` skips persisting (exploratory sweeps)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    budget = budget or TuneBudget()
    fleet = fleet_signature(devices)
    ragged = tuple(int(r) for r in ragged) if ragged is not None else None
    key = tuning_key(net, fleet, batch, ragged)
    if not force:
        cfg = memo.load_tuning(key)
        if cfg is not None:
            return TuneResult(config=cfg, trials=(), cached=True,
                              measurements=0, key=key)

    if baseline is None:
        baseline = baseline_candidate(net, batch=batch, devices=devices)
    if space is None:
        tiers_options = ((None, (batch,)) if ragged is not None
                         else (None,))
        space = enumerate_space(net, batch=batch, devices=devices,
                                tiers_options=tiers_options)
    short = shortlist(net, space, budget.shortlist, baseline=baseline)

    if runner is None:
        runner = default_runner(net, batch=batch, devices=devices,
                                ragged=ragged,
                                max_delay_ms=max_delay_ms, seed=seed)
    measured = 0

    def counted(step):
        def run():
            nonlocal measured
            measured += 1
            step()
        return run

    steps = {c: counted(runner(c)) for c in short}

    pool = list(short)
    rounds = budget.rounds
    trials = []
    while True:
        meds = interleaved_medians([steps[c] for c in pool],
                                   rounds=rounds, clock=clock,
                                   warmup=budget.warmup)
        trials.extend(Trial(c, rounds, m) for c, m in zip(pool, meds))
        if len(pool) <= 2 or rounds >= budget.max_rounds:
            break
        keep = max(1, math.ceil(len(pool) / budget.eta))
        order = sorted(range(len(pool)), key=meds.__getitem__)
        pool = [pool[i] for i in order[:keep]]
        if baseline not in pool:        # the champion survives every cut
            pool.append(baseline)
        rounds = min(rounds * budget.eta, budget.max_rounds)

    win_i = min(range(len(pool)), key=meds.__getitem__)
    cfg = TunedConfig(candidate=pool[win_i], median_s=meds[win_i],
                      baseline_s=meds[pool.index(baseline)],
                      rounds=rounds, measurements=measured, fleet=fleet,
                      batch=batch)
    if store:
        memo.store_tuning(key, cfg)
        # the generic slot: ladder tiers compiled at other batches (and
        # `tuned_config(batch=None)` callers) inherit the newest tuning
        memo.store_tuning(tuning_key(net, fleet, None, ragged), cfg)
    return TuneResult(config=cfg, trials=tuple(trials), cached=False,
                      measurements=measured, key=key)
