"""Measurement primitive of the autotuner: interleaved rounds + medians.

Wall-clock on a shared CI box drifts — background load that lands during
candidate A's rounds but not candidate B's would hand B the win for
free.  Every measured comparison in this repo therefore runs
*interleaved rounds* (benchmarks/plan_bench.py introduced the shape):
round r times every candidate once, in a fixed order, so slow minutes
hit all of them equally; the per-candidate score is the **median**
round, which sheds the one-off spikes the mean would keep.

This module is that shape factored into a primitive (ISSUE 6 satellite):
`benchmarks.common` re-exports it for plan_bench / serve_bench, and the
search driver (`repro.tune.search`) uses it as its only way of looking
at a clock.  The clock is injectable — `autotune(clock=...)` threads it
down here — so the search is deterministically testable with a fake
timer (tests/test_tune.py).

Pure stdlib on purpose: no jax, no devices — callers pass closures that
already contain their `block_until_ready`.
"""
from __future__ import annotations

import time
from typing import Callable, List, Sequence


def median(xs: Sequence[float]) -> float:
    """Upper median of a non-empty sequence (the ``sorted[n // 2]``
    convention every benchmark in this repo reports)."""
    if not xs:
        raise ValueError("median of an empty sequence")
    return sorted(xs)[len(xs) // 2]


def interleaved_rounds(fns: Sequence[Callable], rounds: int, *,
                       warmup: int = 1) -> List[list]:
    """Call every fn once per round, in order, for ``rounds`` rounds —
    after ``warmup`` untimed calls each (compile + steady the caches).
    Returns the per-fn list of return values, one per round.  Use this
    form when the measured quantity is the fn's *result* (serve_bench's
    images/s rates); use :func:`interleaved_medians` when it is the
    fn's wall-clock."""
    if rounds < 1:
        raise ValueError(f"need >= 1 round, got {rounds}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    for fn in fns:
        for _ in range(warmup):
            fn()
    outs: List[list] = [[] for _ in fns]
    for _ in range(rounds):
        for out, fn in zip(outs, fns):
            out.append(fn())
    return outs


def interleaved_medians(fns: Sequence[Callable], rounds: int = 5, *,
                        clock: Callable[[], float] = time.perf_counter,
                        warmup: int = 1) -> List[float]:
    """Median wall-clock SECONDS per fn over ``rounds`` interleaved
    rounds (``warmup`` untimed calls each, first).  ``clock`` is the
    timer — injectable, so searches built on this are testable without
    real time (tests/test_tune.py drives it with a fake)."""

    def timed(fn):
        def run():
            t0 = clock()
            fn()
            return clock() - t0
        return run

    return [median(ts) for ts in
            interleaved_rounds([timed(fn) for fn in fns], rounds,
                               warmup=warmup)]
