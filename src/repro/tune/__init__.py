"""Measured-feedback autotuner (ISSUE 6, DESIGN.md §9).

Joint hardware–mapping–executor co-tuning against wall-clock: the
analytical cycle model seeds a shortlist over {per-layer executor
policy, mesh (data, row, col) split, lookahead, sdk block/VMEM, batch
tiers}; interleaved-round medians under successive halving settle it;
winners persist in the schema-versioned disk cache so a cold process
serves tuned with zero re-measurement.

    from repro import tune
    res = tune.autotune(mapping, batch=8)       # measures (or loads)
    cfg = tune.tuned_config(mapping, batch=8)   # peek only, no search

`compile_plan(executor_policy="tuned")` and ``serve_cnn --autotune``
consume the same persisted winners.
"""
from .measure import interleaved_medians, interleaved_rounds, median
from .report import (append_trajectory, trajectory_entry, write_csv,
                     write_json)
from .search import (SMOKE_BUDGET, Trial, TuneBudget, TuneResult,
                     autotune, default_runner, fleet_signature,
                     resolve_tiers, tuned_config, tuning_key)
from .space import (Candidate, TunedConfig, analytic_cost, auto_policy,
                    baseline_candidate, enumerate_space,
                    policy_candidates, shortlist)

__all__ = [
    "median", "interleaved_rounds", "interleaved_medians",
    "Candidate", "TunedConfig", "auto_policy", "policy_candidates",
    "analytic_cost", "enumerate_space", "baseline_candidate", "shortlist",
    "TuneBudget", "SMOKE_BUDGET", "Trial", "TuneResult", "autotune",
    "default_runner", "fleet_signature", "resolve_tiers", "tuned_config",
    "tuning_key",
    "append_trajectory", "trajectory_entry", "write_csv", "write_json",
]
