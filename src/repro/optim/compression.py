"""Int8 error-feedback gradient compression for slow (cross-pod) links.

Per-tensor symmetric int8 quantisation with an error-feedback residual:
the quantisation error of step t is added back to the gradient of step
t+1, which keeps SGD/Adam convergence (Karimireddy et al., 2019).  Used
by launch/train.py around the cross-pod gradient reduction: the 'pod'
axis all-reduce moves 4x fewer bytes (int8 vs fp32); the in-pod
reduction stays full precision.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_update(grad: jnp.ndarray, residual: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(grad, residual) -> (q, scale, new_residual).  The caller reduces q
    across the slow axis, decompresses, and carries new_residual."""
    corrected = grad.astype(jnp.float32) + residual
    q, scale = compress_int8(corrected)
    new_residual = corrected - decompress_int8(q, scale)
    return q, scale, new_residual


def compress_pytree(grads, residuals):
    qs, scales, new_res = {}, {}, {}
    flat, treedef = jax.tree.flatten(grads)
    rflat = treedef.flatten_up_to(residuals)
    out = [ef_compress_update(g, r) for g, r in zip(flat, rflat)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
            treedef.unflatten([o[2] for o in out]))
