from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .schedule import cosine_schedule
from .compression import compress_int8, decompress_int8, ef_compress_update
