"""AdamW with fp32 master state over bf16/fp32 params (pure pytree ops —
no optax offline).  The optimizer state shards exactly like the params
(FSDP x TP), since every op is elementwise."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    def zeros32(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, opt_state, lr, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], jnp.ndarray]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * g32
        v_ = b2 * v + (1 - b2) * g32 * g32
        mh = m_ / (1 - b1 ** step.astype(jnp.float32))
        vh = v_ / (1 - b2 ** step.astype(jnp.float32))
        p32 = p.astype(jnp.float32)
        # decoupled weight decay, applied as its own term: the Adam
        # step ``lr*mh/(sqrt(vh)+eps)`` keeps the textbook association,
        # so with weight_decay=0 the update is bit-identical to a plain
        # Adam implementation (the CNN trainer's regression contract —
        # tests/test_train_plan.py)
        p_new = (p32 - lr * mh / (jnp.sqrt(vh) + cfg.eps)
                 - lr * cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_, v_

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
