"""LR schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * (step + 1.0) / max(1, warmup_steps)   # lr>0 at step 0
    t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio)
                     * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)
