"""Production mesh definition.

Single pod = 16x16 = 256 chips (TPU v5e pod slice), axes (data, model).
Multi-pod = 2 pods = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism over the slow inter-pod links (DCN), which is why
gradient compression (optim/compression.py) targets exactly that axis.

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (smoke tests, CNN
    training, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_macro_mesh(sub_r: int, sub_c: int, devices=None):
    """Device mesh realizing a CIM macro (sub-)grid: axes ("row", "col")
    where "row" carries channel passes and "col" oc passes — the axis
    correspondence of ``TileMapping.cycles`` (DESIGN.md §3).

    The mesh shape maximizes mr*mc over pairs with mr | sub_r,
    mc | sub_c and mr*mc <= len(devices) (shard_map needs the macro axes
    divisible by the mesh axes; leftover macros fold into the per-device
    vmap), preferring taller meshes on ties.  Returns None when only a
    degenerate 1x1 mesh fits — callers then run the pure-vmap
    single-device path.
    """
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices)
    best = (1, 1)
    for mr in (d for d in range(min(sub_r, n), 0, -1) if sub_r % d == 0):
        for mc in (d for d in range(1, min(sub_c, n // mr) + 1)
                   if sub_c % d == 0):
            if mr * mc > best[0] * best[1]:
                best = (mr, mc)
    mr, mc = best
    if mr * mc <= 1:
        return None
    return jax.sharding.Mesh(
        np.asarray(devices[:mr * mc]).reshape(mr, mc), ("row", "col"))


def mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
