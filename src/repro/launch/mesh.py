"""Production mesh definition.

Single pod = 16x16 = 256 chips (TPU v5e pod slice), axes (data, model).
Multi-pod = 2 pods = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism over the slow inter-pod links (DCN), which is why
gradient compression (optim/compression.py) targets exactly that axis.

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import functools
import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (smoke tests, CNN
    training, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_macro_mesh(sub_r: int, sub_c: int, devices=None, *,
                    data: int = 1):
    """Device mesh realizing a CIM macro (sub-)grid: axes ("row", "col")
    where "row" carries channel passes and "col" oc passes — the axis
    correspondence of ``TileMapping.cycles`` (DESIGN.md §3).

    ``data > 1`` prepends a leading "data" axis of that size — ``data``
    replicas of the (row, col) macro grid, each serving a slice of the
    batch (DESIGN.md §7: throughput scaling under a fixed per-replica
    macro budget; the partial-sum reduction stays confined to "row").

    The (row, col) shape maximizes mr*mc over pairs with mr | sub_r,
    mc | sub_c and data*mr*mc <= len(devices) (shard_map needs the macro
    axes divisible by the mesh axes; leftover macros fold into the
    per-device vmap), preferring taller meshes on ties.  Returns None
    when only a degenerate 1x1x1 mesh fits — callers then run the
    pure-vmap single-device path.
    """
    if data < 1:
        raise ValueError(f"data axis must be >= 1, got {data}")
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices) // data
    if n < 1:
        return None
    best = (1, 1)
    for mr in (d for d in range(min(sub_r, n), 0, -1) if sub_r % d == 0):
        for mc in (d for d in range(1, min(sub_c, n // mr) + 1)
                   if sub_c % d == 0):
            if mr * mc > best[0] * best[1]:
                best = (mr, mc)
    mr, mc = best
    if data * mr * mc <= 1:
        return None
    dev = np.asarray(devices[:data * mr * mc])
    if data > 1:
        return jax.sharding.Mesh(dev.reshape(data, mr, mc),
                                 ("data", "row", "col"))
    return jax.sharding.Mesh(dev.reshape(mr, mc), ("row", "col"))


def make_serving_mesh(sub_r: int, sub_c: int, batch: int, devices=None):
    """Macro mesh for throughput serving: spend as many devices as the
    (sub_r, sub_c) macro grid can absorb, then stack the largest "data"
    axis the remaining device budget affords (clamped to ``batch`` — a
    replica with no work is wasted).  The batch need not divide the data
    axis: ragged request batches pad-and-mask to the next multiple
    (:func:`pad_to_data_axis`, launch/serve_cnn.py) instead of silently
    falling back to the single-device vmap path.  Returns None when only
    one device is usable."""
    devices = list(jax.devices() if devices is None else devices)
    base = make_macro_mesh(sub_r, sub_c, devices)
    per_replica = int(np.prod(base.devices.shape)) if base is not None else 1
    d = max(1, min(len(devices) // per_replica, batch))
    best = make_macro_mesh(sub_r, sub_c, devices, data=d)
    return best if best is not None else base


def net_macro_grid(net_mapping) -> tuple:
    """(gr, gc) macro sub-grid every layer of a ``NetworkMapping`` can
    shard onto — the gcd of the per-layer sub-grids (the shape
    `serving_mesh_for` and the autotuner's mesh candidates build from)."""
    gr = gc = 0
    for m in net_mapping.layers:
        gr = math.gcd(gr, m.sub_grid.r)
        gc = math.gcd(gc, m.sub_grid.c)
    return max(gr, 1), max(gc, 1)


def serving_mesh_for(net_mapping, batch: int, devices=None):
    """Largest mesh every layer of a ``NetworkMapping`` can shard onto:
    the mesh macro axes must divide each layer's sub-grid (gcd across
    layers), leftover devices stack along "data"."""
    gr, gc = net_macro_grid(net_mapping)
    return make_serving_mesh(gr, gc, batch, devices=devices)


def mesh_split(mesh) -> tuple | None:
    """Canonical ``(data, row, col)`` device split of a macro/serving
    mesh (``None`` for the single-device vmap path) — the hashable,
    picklable form the autotuner searches over and persists
    (`repro.tune`); :func:`mesh_from_split` rebuilds the live mesh."""
    if mesh is None:
        return None
    shape = dict(mesh.shape)
    return (int(shape.get("data", 1)), int(shape.get("row", 1)),
            int(shape.get("col", 1)))


def mesh_from_split(split, devices=None):
    """Live mesh realizing a ``(data, row, col)`` split, or ``None``
    (vmap path) for ``split=None`` / a degenerate 1x1x1 split / a fleet
    too small to realize it — a tuned split recorded on a bigger fleet
    degrades to the single-device path instead of crashing the server."""
    if split is None:
        return None
    data, mr, mc = (int(s) for s in split)
    if min(data, mr, mc) < 1:
        raise ValueError(f"mesh split must be >= 1 per axis, got {split}")
    if data * mr * mc <= 1:
        return None
    devices = list(jax.devices() if devices is None else devices)
    if data * mr * mc > len(devices):
        return None
    dev = np.asarray(devices[:data * mr * mc])
    if data > 1:
        return jax.sharding.Mesh(dev.reshape(data, mr, mc),
                                 ("data", "row", "col"))
    return jax.sharding.Mesh(dev.reshape(mr, mc), ("row", "col"))


def mesh_split_candidates(net_mapping, batch: int, devices=None) -> tuple:
    """Distinct ``(data, row, col)`` splits of a fixed device budget the
    autotuner measures against each other: for every feasible "data"
    replica count the largest macro realization of the net's common
    sub-grid (:func:`net_macro_grid` x `make_macro_mesh`), plus the pure
    data-parallel split and ``None`` (the single-device vmap path).
    ``data`` is clamped to ``batch`` — a replica with no batch rows is
    wasted.  Always contains at least ``None``; on one device that is
    all there is."""
    devices = list(jax.devices() if devices is None else devices)
    gr, gc = net_macro_grid(net_mapping)
    splits = [None]
    top_data = max(1, min(len(devices), max(batch, 1)))
    for data in range(1, top_data + 1):
        m = make_macro_mesh(gr, gc, devices, data=data)
        s = mesh_split(m)
        if s is not None and s not in splits:
            splits.append(s)
    pure = (top_data, 1, 1)
    if pure[0] > 1 and pure not in splits:
        splits.append(pure)
    return tuple(splits)


def data_axis_size(mesh) -> int:
    """Size of the mesh's "data" axis (1 when absent / no mesh)."""
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return int(mesh.shape["data"])


def pad_to_data_axis(batch: int, mesh) -> int:
    """Smallest batch >= ``batch`` the mesh's "data" axis divides — the
    plan batch a ragged request batch pads to (no-op without a data
    axis)."""
    d = data_axis_size(mesh)
    return -(-batch // d) * d


def _scan_mesh_platform(mesh) -> str | None:
    devices = getattr(mesh, "devices", None)
    if devices is None:
        return None
    plats = {p for p in (getattr(d, "platform", None)
                         for d in np.asarray(devices).ravel())
             if p is not None}
    if not plats:
        return None
    return plats.pop() if len(plats) == 1 else "mixed"


_mesh_platform_cached = functools.lru_cache(maxsize=64)(_scan_mesh_platform)


def mesh_platform(mesh) -> str | None:
    """Platform ("cpu" / "tpu" / "gpu") the mesh's devices live on, or
    None without a mesh / without real devices.  The serving mesh may
    sit on a different platform than ``jax.default_backend()`` (forced
    host meshes in tests, CPU meshes next to an accelerator), so
    platform-dependent decisions — input-buffer donation above all —
    must key on the mesh, not the default backend.  Mixed-platform
    meshes report ``"mixed"`` (callers treat that as unsupported).
    Cached per mesh: `execute_plan(donate=...)` consults this on every
    steady-state forward, and the O(devices) scan must not recur per
    step on a production-size mesh."""
    if mesh is None:
        return None
    try:
        return _mesh_platform_cached(mesh)
    except TypeError:               # unhashable mesh stand-ins (tests)
        return _scan_mesh_platform(mesh)


def mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
