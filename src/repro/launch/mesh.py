"""Production mesh definition.

Single pod = 16x16 = 256 chips (TPU v5e pod slice), axes (data, model).
Multi-pod = 2 pods = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism over the slow inter-pod links (DCN), which is why
gradient compression (optim/compression.py) targets exactly that axis.

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (smoke tests, CNN
    training, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
