"""Serving driver: batched prefill + greedy decode loop (transformer
scaffold).  For batched CNN serving through the macro-parallel mapped
executor — images/s, batch-axis sharding, persistent mapping cache —
see ``repro.launch.serve_cnn`` (DESIGN.md §7).

    python -m repro.launch.serve --arch mixtral_8x7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import transformer as T


def generate(cfg, params, prompts, gen: int, enc_embeds=None):
    """prompts (B, S) -> (B, S+gen) greedy continuation."""
    b, s = prompts.shape
    prefill = jax.jit(make_prefill_step(cfg, cache_len=s + gen))
    serve = jax.jit(make_serve_step(cfg))
    batch = {"tokens": prompts}
    if enc_embeds is not None:
        batch["enc_embeds"] = enc_embeds
    nxt, cache = prefill(params, batch)
    out = [prompts, nxt[:, None]]
    tok = nxt[:, None]
    for i in range(gen - 1):
        tok, cache = serve(params, cache, tok,
                           jnp.array(s + i, jnp.int32))
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    enc = None
    if cfg.kind == "encdec":
        enc = jax.random.normal(key, (args.batch, args.prompt_len,
                                      cfg.d_model), jnp.bfloat16)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.gen, enc_embeds=enc)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {args.gen} tokens x {args.batch} seqs "
          f"in {dt:.1f}s ({args.gen*args.batch/dt:.1f} tok/s)")
    print("sample:", out[0, -args.gen:].tolist())


if __name__ == "__main__":
    main()
