"""Training driver: end-to-end supervised loop on a real mesh.

On this CPU container it drives the smoke-scale configs (examples/
train_lm.py); on hardware the same entry point takes --arch <id> with
the production mesh.  Wires together: config -> sharded init ->
TokenStream pipeline -> train_step -> CheckpointStore + TrainSupervisor
(heartbeats, straggler log, restart-exact resume).

``--plan-net <network>`` switches to the CNN plan trainer instead: the
named bench network (core/networks.py) is mapped, compiled to a chained
NetworkPlan, and its kernels train through `execute_plan` with
rematerialization (`--remat off|auto|<bytes>`) and gradient accumulation
(`--accum K`) — `repro.cnn.train.train_plan`, DESIGN.md §13.

Usage:
    python -m repro.launch.train --arch stablelm_1_6b --smoke \
        --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
    python -m repro.launch.train --plan-net densenet40 --remat auto \
        --steps 10 --batch 8 --accum 2
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import ShardedDataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.steps import (TrainConfig, init_train_state,
                                make_train_step)
from repro.runtime import HeartbeatMonitor, TrainSupervisor


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-net", default=None,
                    help="train this bench network through the plan "
                         "trainer (cnn/train.train_plan) instead of the "
                         "transformer loop")
    ap.add_argument("--remat", default="off",
                    help="plan trainer: off | auto | <peak budget bytes>")
    ap.add_argument("--accum", type=int, default=1,
                    help="plan trainer: microbatches per optimizer step")
    args = ap.parse_args(argv)

    if args.plan_net is not None:
        return _plan_main(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(microbatches=args.microbatches, peak_lr=args.lr,
                     warmup_steps=max(2, args.steps // 20),
                     total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, tc))

    ts = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=args.seed)
    pipe = ShardedDataPipeline(ts)
    store = CheckpointStore(Path(args.ckpt_dir) / cfg.name, keep=2,
                            async_save=True)
    sup = TrainSupervisor(store=store, pipeline=pipe,
                          monitor=HeartbeatMonitor(1),
                          save_every=args.save_every)

    def wrapped(state, tokens):
        state, metrics = step_fn(state, {"tokens": jnp.asarray(tokens)})
        return state, metrics

    t0 = time.time()
    if args.resume:
        like = jax.eval_shape(partial(init_train_state, cfg),
                              jax.random.PRNGKey(args.seed))
        state, last = sup.resume(like, _metric_logger(wrapped, t0),
                                 steps=args.steps)
    else:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        state, last = sup.run(state, _metric_logger(wrapped, t0),
                              steps=args.steps)
    store.wait()
    print(f"done: {last} steps in {time.time()-t0:.1f}s; "
          f"events={sup.events[-3:]}")


def _plan_main(args) -> None:
    """The --plan-net path: map the named network and train its kernels
    through the compiled plan (module docstring)."""
    from repro.cnn.train import train_plan
    from repro.core import ArrayConfig, MacroGrid, map_net, networks
    if args.plan_net not in networks.NETWORKS:
        raise SystemExit(f"unknown network {args.plan_net!r} "
                         f"(have: {sorted(networks.NETWORKS)})")
    remat = None if args.remat == "off" else (
        args.remat if args.remat == "auto" else int(args.remat))
    net = map_net(args.plan_net, networks.NETWORKS[args.plan_net](),
                  ArrayConfig(64, 64), "TetrisG-SDK", MacroGrid(2, 2))
    t0 = time.time()
    losses: list = []
    r = train_plan(net, steps=args.steps, batch=args.batch, lr=args.lr,
                   seed=args.seed, accum=args.accum, remat=remat,
                   losses=losses)
    for i, lv in enumerate(losses):
        if i % 10 == 0 or i == len(losses) - 1:
            print(f"step {i + 1:>5d}  loss {lv:.4f}", flush=True)
    print(f"done: {r.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {r.first_loss:.4f} -> {r.final_loss:.4f}; "
          f"peak~{r.peak_mb:.0f}MB (unremat {r.unremat_peak_mb:.0f}MB, "
          f"{r.segments} segment(s), accum={r.accum}, "
          f"donated={r.donated})")


def _metric_logger(step_fn, t0, every: int = 10):
    counter = {"n": 0}

    def fn(state, batch):
        state, metrics = step_fn(state, batch)
        counter["n"] += 1
        if counter["n"] % every == 0 or counter["n"] == 1:
            print(f"step {counter['n']:>5d}  "
                  f"loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)
        return state, metrics
    return fn


if __name__ == "__main__":
    main()
