"""Training driver: end-to-end supervised loop on a real mesh.

On this CPU container it drives the smoke-scale configs (examples/
train_lm.py); on hardware the same entry point takes --arch <id> with
the production mesh.  Wires together: config -> sharded init ->
TokenStream pipeline -> train_step -> CheckpointStore + TrainSupervisor
(heartbeats, straggler log, restart-exact resume).

Usage:
    python -m repro.launch.train --arch stablelm_1_6b --smoke \
        --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import ShardedDataPipeline
from repro.data.synthetic import TokenStream
from repro.launch.steps import (TrainConfig, init_train_state,
                                make_train_step)
from repro.runtime import HeartbeatMonitor, TrainSupervisor


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(microbatches=args.microbatches, peak_lr=args.lr,
                     warmup_steps=max(2, args.steps // 20),
                     total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, tc))

    ts = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=args.seed)
    pipe = ShardedDataPipeline(ts)
    store = CheckpointStore(Path(args.ckpt_dir) / cfg.name, keep=2,
                            async_save=True)
    sup = TrainSupervisor(store=store, pipeline=pipe,
                          monitor=HeartbeatMonitor(1),
                          save_every=args.save_every)

    def wrapped(state, tokens):
        state, metrics = step_fn(state, {"tokens": jnp.asarray(tokens)})
        return state, metrics

    t0 = time.time()
    if args.resume:
        like = jax.eval_shape(partial(init_train_state, cfg),
                              jax.random.PRNGKey(args.seed))
        state, last = sup.resume(like, _metric_logger(wrapped, t0),
                                 steps=args.steps)
    else:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        state, last = sup.run(state, _metric_logger(wrapped, t0),
                              steps=args.steps)
    store.wait()
    print(f"done: {last} steps in {time.time()-t0:.1f}s; "
          f"events={sup.events[-3:]}")


def _metric_logger(step_fn, t0, every: int = 10):
    counter = {"n": 0}

    def fn(state, batch):
        state, metrics = step_fn(state, batch)
        counter["n"] += 1
        if counter["n"] % every == 0 or counter["n"] == 1:
            print(f"step {counter['n']:>5d}  "
                  f"loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)
        return state, metrics
    return fn


if __name__ == "__main__":
    main()
