"""train/prefill/serve step functions (what the dry-run lowers and the
drivers run).

train_step: microbatch scan (gradient accumulation) with full remat
inside each layer-scan unit; AdamW update; returns (state, metrics).
prefill_step: forward over the full sequence -> (last logits, KV cache).
serve_step: one decode token against the cache -> (next token, cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


def vocab_mask(cfg: ArchConfig) -> jnp.ndarray:
    return (jnp.arange(cfg.padded_vocab) < cfg.vocab)


def _model_inputs(cfg: ArchConfig, batch: Dict[str, Any]) -> Dict[str, Any]:
    kw = {"tokens": batch["tokens"]}
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if "enc_embeds" in batch:
        kw["enc_embeds"] = batch["enc_embeds"]
    return kw


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, Any],
            act_sharding=None) -> jnp.ndarray:
    """Next-token CE over the real (unpadded) vocabulary.  The batch
    carries S+1 tokens; the model sees the first S, logit t predicts
    token t+1."""
    inputs = {**batch, "tokens": batch["tokens"][:, :-1]}
    logits = T.forward(params, cfg, mode="train", act_sharding=act_sharding,
                       **_model_inputs(cfg, inputs))
    prefix = batch.get("prefix_embeds", None)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    logits = logits.astype(jnp.float32)
    labels = batch["tokens"][:, 1:]
    mask = vocab_mask(cfg)
    logits = jnp.where(mask[None, None, :], logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    adamw: AdamWConfig = AdamWConfig()


def init_train_state(cfg: ArchConfig, key) -> Dict[str, Any]:
    params = T.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(cfg: ArchConfig, tc: TrainConfig, act_sharding=None):
    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params, opt = state["params"], state["opt"]
        n_mb = tc.microbatches

        def split_mb(x):
            return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

        mb_batch = {k: split_mb(v) for k, v in batch.items()}

        def one_mb(acc, mb):
            lv, g = jax.value_and_grad(loss_fn)(params, cfg, mb,
                                                act_sharding)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_mb, acc, g)
            return acc, lv

        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, cfg, {k: v[0] for k, v in mb_batch.items()},
                act_sharding)
            losses = loss[None]
        else:
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(one_mb, zeros, mb_batch)

        lr = cosine_schedule(opt["step"], peak_lr=tc.peak_lr,
                             warmup_steps=tc.warmup_steps,
                             total_steps=tc.total_steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, lr,
                                                  tc.adamw)
        metrics = {"loss": losses.mean(), "grad_norm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, cache_len: Optional[int] = None,
                      act_sharding=None):
    def prefill_step(params, batch: Dict[str, Any]):
        logits, cache = T.forward(params, cfg, mode="prefill",
                                  cache_len=cache_len, remat=False,
                                  act_sharding=act_sharding,
                                  **_model_inputs(cfg, batch))
        mask = vocab_mask(cfg)
        last = jnp.where(mask[None, :], logits[:, -1].astype(jnp.float32),
                         -1e30)
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache
    return prefill_step


def make_serve_step(cfg: ArchConfig, act_sharding=None):
    def serve_step(params, cache, token, pos):
        """token (B, 1) int32; pos () int32 — absolute decode position."""
        logits, new_cache = T.forward(params, cfg, mode="decode",
                                      tokens=token, cache=cache, pos=pos,
                                      remat=False,
                                      act_sharding=act_sharding)
        mask = vocab_mask(cfg)
        lg = jnp.where(mask[None, None, :], logits.astype(jnp.float32),
                       -1e30)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache
    return serve_step
