# Launcher layer: production mesh, sharding rules, step functions,
# multi-pod dry-run, roofline analysis, train/serve drivers.
