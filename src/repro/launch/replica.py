"""Multi-replica serving front-end: process-level scale-out (DESIGN.md §12).

Everything below the fleet tier runs ONE Python loop in ONE process —
macro mesh, data axis, and fleet scheduler all scale *inside* that
process, so aggregate throughput is bottlenecked on a single GIL-bound
dispatch thread.  This module applies the paper's inter-macro move at
process level: N worker processes, each running its own plan ladder on
its own mesh, behind one load-aware router.

* **Workers** (:func:`_worker_main`) — one process per replica.  Each
  maps the network (the shared ``REPRO_MAPPING_CACHE`` disk cache makes
  a warm cold-start skip the window search AND the plan compiles),
  builds a `batching.PlanLadder`, warms every tier, and then serves a
  max-delay coalescer fed by its private task queue.  Start-up cost is
  measured per worker and reported (cold vs warm is the disk cache's
  acceptance quantity).
* **Router** (:class:`ReplicaRouter`) — pure-Python load tracking:
  per-replica outstanding rows/requests (queued + in-flight from the
  router's view), least-loaded dispatch, exactly-once accounting on
  `batching.WorkItem.seq`.  Health rides the so-far-unused
  `runtime/recovery.py`: idle heartbeats feed
  `HeartbeatMonitor.beat`, batch completions feed ``report`` (so the
  straggler policy sees real step durations), and a worker that misses
  its deadline — or whose process died — is declared dead ONCE, its
  outstanding items re-queued to the survivors.
* **Transports** — the router speaks to workers only through a
  queue-transport object: :class:`MpTransport` (real spawn-context
  processes + multiprocessing queues) in production, and the
  deterministic `batching.InMemoryTransport` fake in tests, where
  simulated workers run synchronously under a fake clock (the
  kill-a-worker lossless test needs no real processes).

Exactly-once contract: a request is counted served when its first
completion arrives; a completion for an already-served seq increments
``duplicate_serves`` instead of double-counting.  Crash injection
(``CTRL_DIE``) makes the worker flush its acknowledged completions
(queue close + join) before ``os._exit``, so with in-tree kill paths
``duplicate_serves == 0`` deterministically; an external SIGKILL can at
worst lose the flush and degrade to at-least-once, which the counter
makes visible instead of silent.

    python -m repro.launch.serve_cnn --net cnn8 --replicas 2 \
        --max-delay-ms 2 --max-batch 4 --requests 64 \
        --cache-dir /tmp/mapping-cache
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.recovery import HeartbeatMonitor, StragglerPolicy

from . import batching
from .batching import (CTRL_DIE, CTRL_GO, CTRL_STOP, MSG_DONE, MSG_DYING,
                       MSG_HEARTBEAT, MSG_READY, MSG_STATS, WorkItem)


class NoSurvivorsError(RuntimeError):
    """Every replica is dead — there is nobody to re-queue work to."""


@dataclass(frozen=True)
class WorkerConfig:
    """Everything a worker process needs to build its serving stack —
    frozen and picklable (it crosses the spawn boundary).  ``layers``
    optionally serves a prefix of the named net (benchmarks keep CPU
    compile time sane the same way fleet_bench slices densenet40);
    ``xla_host_devices`` forces that many host devices in the worker
    BEFORE jax initializes (each worker owns its mesh, so replicas can
    shard internally too)."""

    net: str = "cnn8"
    array: Tuple[int, int] = (512, 512)
    alg: str = "TetrisG-SDK"
    grid: Optional[Tuple[int, int]] = None
    p_max: Optional[int] = None
    layers: Optional[int] = None
    groups: Tuple[int, ...] = (1, 2, 4)
    max_batch: int = 8
    max_delay_ms: float = 2.0
    adaptive_delay: bool = False
    policy: str = "mapped"
    seed: int = 0
    cache_dir: Optional[str] = None
    warmup: int = 1
    use_mesh: bool = True
    donate: Optional[bool] = None
    heartbeat_s: float = 0.05
    xla_host_devices: Optional[int] = None


# ---------------------------------------------------------------------------
# Router — pure Python, fake-clock testable
# ---------------------------------------------------------------------------


@dataclass
class WorkerView:
    """The router's ledger for one replica: load (outstanding work it
    shipped there), serving stats accumulated from completion messages,
    and the start-up cost the worker reported when it came up."""

    wid: int
    alive: bool = True
    startup_s: float = 0.0
    table_misses: int = 0
    disk_hits: int = 0
    outstanding: Dict[int, WorkItem] = field(default_factory=dict)
    outstanding_rows: int = 0
    served_requests: int = 0
    served_rows: int = 0
    padded_rows: int = 0
    batches: int = 0
    exec_s: float = 0.0
    delays_s: List[float] = field(default_factory=list)


class ReplicaRouter:
    """Least-loaded dispatch + exactly-once completion accounting.

    Pure Python over explicit state — no clocks, no devices, no
    queues — so unit tests drive every dispatch/death/re-queue path
    directly.  The optional ``monitor`` (`runtime.HeartbeatMonitor`)
    carries liveness deadlines and straggler medians; the router feeds
    it (`on_heartbeat` → ``beat``, `on_batch_done` → ``report``) and
    consults it (`deadline_dead`), but death is always declared through
    :meth:`mark_dead`, which retires the worker from the monitor and
    hands back its outstanding items exactly once."""

    def __init__(self, n_replicas: int, *,
                 monitor: Optional[HeartbeatMonitor] = None):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.views = {w: WorkerView(w) for w in range(n_replicas)}
        self.monitor = monitor
        self.served: Dict[int, int] = {}        # seq -> serving wid
        self._owner: Dict[int, int] = {}        # seq -> current assignee
        self._seen: set = set()                 # every seq ever dispatched
        self.dispatched = 0                     # distinct seqs (len _seen)
        self.requeued = 0
        self.duplicate_serves = 0
        self.deaths = 0

    def alive_ids(self) -> List[int]:
        return [w for w, v in self.views.items() if v.alive]

    def load(self, wid: int) -> int:
        """Outstanding rows shipped to ``wid`` (queued + in-flight from
        the router's view — the worker batches them on its own)."""
        return self.views[wid].outstanding_rows

    def dispatch(self, item: WorkItem) -> int:
        """Assign ``item`` to the least-loaded live replica (ties to
        fewer outstanding requests, then lowest wid — deterministic)."""
        alive = self.alive_ids()
        if not alive:
            raise NoSurvivorsError(
                f"request seq={item.seq} has no live replica to go to")
        wid = min(alive, key=lambda w: (self.views[w].outstanding_rows,
                                        len(self.views[w].outstanding), w))
        v = self.views[wid]
        if item.seq not in self._seen:      # re-queues don't count twice
            self._seen.add(item.seq)
            self.dispatched += 1
        v.outstanding[item.seq] = item
        v.outstanding_rows += item.rows
        self._owner[item.seq] = wid
        return wid

    def on_ready(self, wid: int, startup_s: float, table_misses: int = 0,
                 disk_hits: int = 0) -> None:
        v = self.views[wid]
        v.startup_s = startup_s
        v.table_misses, v.disk_hits = table_misses, disk_hits

    def on_heartbeat(self, wid: int) -> None:
        if self.monitor is not None and self.views[wid].alive:
            self.monitor.beat(wid)

    def on_batch_done(self, wid: int, tier: int,
                      entries: Sequence[Tuple[int, int, float]],
                      exec_s: float = 0.0) -> int:
        """Account one completed batch; returns how many of its
        requests were NEW (first completion).  A seq already served —
        possible only when a re-queued item's original owner turned out
        to have served it before dying — bumps ``duplicate_serves``
        and is not double-counted."""
        v = self.views[wid]
        v.batches += 1
        v.padded_rows += tier
        v.exec_s += exec_s
        new = 0
        for seq, rows, delay_s in entries:
            if seq in self.served:
                self.duplicate_serves += 1
                continue
            self.served[seq] = wid
            new += 1
            v.served_requests += 1
            v.served_rows += rows
            v.delays_s.append(delay_s)
            owner = self._owner.pop(seq, None)
            if owner is not None:
                o = self.views[owner]
                it = o.outstanding.pop(seq, None)
                if it is not None:
                    o.outstanding_rows -= it.rows
        if self.monitor is not None and v.alive:
            self.monitor.report(wid, exec_s)
        return new

    def mark_dead(self, wid: int) -> List[WorkItem]:
        """Declare ``wid`` dead (idempotent) and return its outstanding
        items in seq order — the caller re-dispatches them to
        survivors.  Already-served seqs never appear here: completions
        removed them from the ledger."""
        v = self.views[wid]
        if not v.alive:
            return []
        v.alive = False
        self.deaths += 1
        if self.monitor is not None:
            self.monitor.forget(wid)
        items = [v.outstanding[s] for s in sorted(v.outstanding)]
        v.outstanding.clear()
        v.outstanding_rows = 0
        for it in items:
            self._owner.pop(it.seq, None)
        self.requeued += len(items)
        return items

    def deadline_dead(self) -> List[int]:
        """Live workers whose heartbeat deadline has expired per the
        monitor (empty without one)."""
        if self.monitor is None:
            return []
        return [w for w in self.monitor.dead_workers()
                if w in self.views and self.views[w].alive]

    def incomplete(self) -> int:
        return self.dispatched - len(self.served)


# ---------------------------------------------------------------------------
# Aggregate stats
# ---------------------------------------------------------------------------


@dataclass
class ReplicaStats:
    """One multi-replica run: per-worker ledgers plus pooled aggregate
    rates and queue-delay percentiles over the shared wall time."""

    workers: Dict[int, WorkerView]
    wall_s: float
    requeued: int
    duplicate_serves: int
    deaths: int
    stragglers: Dict[int, str] = field(default_factory=dict)

    @property
    def request_images(self) -> int:
        return sum(v.served_rows for v in self.workers.values())

    @property
    def padded_images(self) -> int:
        return sum(v.padded_rows for v in self.workers.values())

    @property
    def images_per_s(self) -> float:
        return self.request_images / max(self.wall_s, 1e-12)

    @property
    def padded_images_per_s(self) -> float:
        return self.padded_images / max(self.wall_s, 1e-12)

    @property
    def delays_s(self) -> List[float]:
        return [d for v in self.workers.values() for d in v.delays_s]

    def delay_ms(self, q: float) -> float:
        """Aggregate queue-delay percentile over the POOLED per-replica
        samples — the same never-average-percentiles contract as
        `batching.DynamicServeStats.delay_ms`."""
        return batching.percentile(self.delays_s, q) * 1e3

    def describe(self) -> str:
        n = len(self.workers)
        lines = [f"replicas: {n} workers ({self.deaths} died), "
                 f"{self.request_images} request images "
                 f"({self.padded_images} padded) in {self.wall_s*1e3:.1f}ms"
                 f" = {self.images_per_s:.1f} images/s "
                 f"({self.padded_images_per_s:.1f} padded), "
                 f"requeued={self.requeued}, "
                 f"duplicate_serves={self.duplicate_serves}"]
        if self.delays_s:
            lines.append(f"  pooled queue-delay p50={self.delay_ms(50):.2f}ms"
                         f" p95={self.delay_ms(95):.2f}ms "
                         f"p99={self.delay_ms(99):.2f}ms")
        for wid in sorted(self.workers):
            v = self.workers[wid]
            state = "" if v.alive else " DEAD"
            strag = (f" straggler={self.stragglers[wid]}"
                     if wid in self.stragglers else "")
            lines.append(
                f"  w{wid}{state}{strag}: startup {v.startup_s*1e3:.0f}ms "
                f"(table_builds={v.table_misses} disk_hits={v.disk_hits}), "
                f"{v.served_requests} requests / {v.served_rows} images "
                f"in {v.batches} batches")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------


def _build_mapping(cfg: WorkerConfig):
    """Map the configured net inside the worker (disk cache warm → no
    table builds); split out so tests can build the single-process
    baseline from the exact same mapping."""
    from repro.core import (ArrayConfig, MacroGrid, grid_search, map_net,
                            networks)
    layers = networks.NETWORKS[cfg.net]()
    if cfg.layers is not None:
        layers = layers[:cfg.layers]
    kw = {"groups": tuple(cfg.groups)} if cfg.alg == "TetrisG-SDK" else {}
    array = ArrayConfig(*cfg.array)
    if cfg.p_max is not None:
        return grid_search(cfg.net, layers, array, cfg.p_max, cfg.alg,
                           **kw).best
    grid = MacroGrid(*cfg.grid) if cfg.grid is not None else MacroGrid()
    return map_net(cfg.net, layers, array, cfg.alg, grid, **kw)


def _worker_main(wid: int, cfg: WorkerConfig, task_q, result_q) -> None:
    """One replica process: build (measured), announce ready, wait for
    GO, serve until STOP.  Runs in a fresh spawn-context interpreter —
    env overrides land before jax initializes its backend."""
    import os
    if cfg.xla_host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count="
            f"{cfg.xla_host_devices}").strip()
    import queue as queue_mod
    t_start = time.perf_counter()
    try:
        from repro.core import memo
        if cfg.cache_dir is not None:
            memo.set_disk_cache(cfg.cache_dir)
        import jax
        import numpy as np
        from repro.exec import donation_supported, execute_plan
        from repro.launch import mesh as meshlib
        from repro.launch.serve_cnn import _serving_kernels

        mapping = _build_mapping(cfg)
        mesh = (meshlib.serving_mesh_for(mapping, cfg.max_batch)
                if cfg.use_mesh else None)
        donate = (donation_supported(mesh) if cfg.donate is None
                  else cfg.donate)
        tiers = batching.batch_tiers(cfg.max_batch, mesh)
        ladder = batching.PlanLadder(mapping, tiers, mesh=mesh,
                                     policy=cfg.policy)
        rng, ks = _serving_kernels(mapping, cfg.seed)
        first = mapping.layers[0].layer
        shape = (first.ic, first.i_h, first.i_w)
        pool = rng.randn(ladder.max_batch, *shape).astype(np.float32)

        def run_tier(tier: int, x_np):
            y = execute_plan(ladder.plans[tier], ks, jax.device_put(x_np),
                             mesh=mesh, donate=donate)
            return jax.block_until_ready(y)

        for _ in range(max(cfg.warmup, 0)):
            for t in ladder.tiers:
                run_tier(t, pool[:t])
        st = memo.snapshot()
        result_q.put((MSG_READY, wid, time.perf_counter() - t_start,
                      int(st["table_misses"]), int(st["disk_hits"])))
    except BaseException as e:          # startup failed: say so, then die
        result_q.put((MSG_DYING, wid, f"startup: {e!r}"))
        raise

    epoch = None                        # the router's shared clock zero
    while epoch is None:
        msg = task_q.get()
        if isinstance(msg, tuple) and msg[0] == CTRL_GO:
            epoch = float(msg[1])
        elif isinstance(msg, tuple) and msg[0] == CTRL_DIE:
            os._exit(1)

    def now_fn() -> float:
        # wall clock relative to the router's epoch: the one clock all
        # processes on this host share, so queue delays (launch minus
        # router-stamped arrival) are measured consistently
        return time.time() - epoch

    delay_policy = (batching.AdaptiveDelay(cfg.max_delay_ms / 1e3,
                                           cfg.max_batch)
                    if cfg.adaptive_delay else None)
    co = batching.Coalescer(cfg.max_batch, cfg.max_delay_ms / 1e3,
                            delay_policy=delay_policy)
    served_rows = padded_rows = batches = 0
    stopping = False
    try:
        while True:
            # how long may the first (blocking) get wait: until the
            # coalescer's deadline, capped by the heartbeat interval
            if len(co):
                dl = co.next_deadline()
                block_s = (0.0 if dl is None else
                           max(0.0, min(cfg.heartbeat_s, dl - now_fn())))
            elif stopping:
                block_s = 0.0
            else:
                block_s = cfg.heartbeat_s
            first_wait = True
            while True:                 # drain everything available now
                try:
                    if first_wait and block_s > 0:
                        msg = task_q.get(timeout=block_s)
                    else:
                        msg = task_q.get_nowait()
                except queue_mod.Empty:
                    break
                first_wait = False
                if isinstance(msg, WorkItem):
                    co.push(msg.rows, msg.arrival_s, payload=msg)
                elif msg[0] == CTRL_STOP:
                    stopping = True
                elif msg[0] == CTRL_DIE:
                    # crash injection: flush acknowledged completions
                    # (so finished work is not replayed), then vanish
                    # WITHOUT draining the coalescer or the task queue
                    result_q.put((MSG_DYING, wid, "killed"))
                    result_q.close()
                    result_q.join_thread()
                    os._exit(1)
            now = now_fn()
            result_q.put((MSG_HEARTBEAT, wid, now))
            batch = co.pop(now, force=stopping)
            if batch:
                rows = sum(r.rows for r in batch)
                tier, _ = ladder.plan_for(rows)
                x_np = np.zeros((tier,) + shape, np.float32)
                x_np[:rows] = pool[:rows]   # padded rows stay zero
                launch = now_fn()
                run_tier(tier, x_np)
                exec_s = now_fn() - launch
                entries = tuple((r.payload.seq, r.rows,
                                 launch - r.arrival_s) for r in batch)
                result_q.put((MSG_DONE, wid, tier, entries, exec_s))
                served_rows += rows
                padded_rows += tier
                batches += 1
            elif stopping and not len(co):
                result_q.put((MSG_STATS, wid, served_rows, padded_rows,
                              batches))
                break
    except BaseException as e:
        result_q.put((MSG_DYING, wid, f"serve: {e!r}"))
        raise


class MpTransport:
    """Real process-level transport: one spawn-context ``Process`` +
    task ``Queue`` per worker, one shared result ``Queue`` back.  Spawn
    (never fork): the parent has long since initialized jax, and each
    worker must come up with its own fresh backend (and its own
    ``XLA_FLAGS``, applied in `_worker_main` before device init)."""

    blocks = True

    def __init__(self, *, ctx: str = "spawn"):
        import multiprocessing as mp
        self._ctx = mp.get_context(ctx)
        self.result_q = self._ctx.Queue()
        self._procs: Dict[int, object] = {}
        self._task_qs: Dict[int, object] = {}

    def start_worker(self, wid: int, cfg: WorkerConfig) -> None:
        q = self._ctx.Queue()
        p = self._ctx.Process(target=_worker_main,
                              args=(wid, cfg, q, self.result_q),
                              daemon=True, name=f"replica-w{wid}")
        p.start()
        self._task_qs[wid] = q
        self._procs[wid] = p

    def send(self, wid: int, msg) -> None:
        self._task_qs[wid].put(msg)

    def poll(self, timeout: float = 0.0):
        import queue as queue_mod
        try:
            if timeout > 0:
                return self.result_q.get(True, timeout)
            return self.result_q.get_nowait()
        except queue_mod.Empty:
            return None

    def alive(self, wid: int) -> bool:
        return self._procs[wid].is_alive()

    def kill(self, wid: int) -> None:
        """Hard-kill a worker (SIGKILL) — the ungraceful death path."""
        self._procs[wid].kill()

    def join(self, timeout: float = 10.0) -> None:
        for p in self._procs.values():
            p.join(timeout)
            if p.is_alive():
                p.terminate()
                p.join(1.0)


# ---------------------------------------------------------------------------
# Serve loop
# ---------------------------------------------------------------------------


def serve_replicas(trace: Sequence[Tuple[float, int]], cfg: WorkerConfig,
                   n_replicas: int, *, transport=None,
                   dead_after_s: float = 5.0,
                   straggler: Optional[StragglerPolicy] = None,
                   kill_worker: Optional[int] = None,
                   kill_after_batches: int = 0,
                   clock=time.time, sleep=time.sleep,
                   tick_s: float = 0.02,
                   ready_timeout_s: float = 600.0) -> ReplicaStats:
    """Serve ``trace`` (``(arrival_s, rows)`` pairs, relative seconds —
    e.g. `serve_cnn.poisson_arrivals`) across ``n_replicas`` workers.

    Phases: spawn every worker and wait until all report READY (their
    measured start-up cost lands in the stats — this is where a warm
    disk cache pays); stamp ``t0`` and broadcast GO with the shared
    epoch; then the dispatch loop pushes each arrival to the
    least-loaded live replica as its time comes, folds completion /
    heartbeat messages into the router, and re-queues the outstanding
    work of any replica that died (process gone, DYING received, or
    heartbeat deadline missed).  ``kill_worker`` injects a crash: once
    that worker has ``kill_after_batches`` batches done and work
    outstanding, it is told to die — the lossless-recovery test path.

    ``transport`` defaults to real processes (:class:`MpTransport`);
    tests pass a `batching.InMemoryTransport` plus fake ``clock`` /
    ``sleep`` and the whole loop runs deterministically in-process."""
    if n_replicas < 1:
        raise ValueError(f"need >= 1 replica, got {n_replicas}")
    trace = tuple(trace)
    big = max((r for _, r in trace), default=0)
    if big > cfg.max_batch:
        raise ValueError(f"request of {big} rows exceeds max_batch="
                         f"{cfg.max_batch} — requests are never split")
    if kill_worker is not None and not 0 <= kill_worker < n_replicas:
        raise ValueError(f"kill_worker={kill_worker} not in "
                         f"[0, {n_replicas})")
    transport = MpTransport() if transport is None else transport

    for wid in range(n_replicas):
        transport.start_worker(wid, cfg)

    # --- phase 1: wait for every worker's READY (startup measured) ---
    ready: Dict[int, Tuple[float, int, int]] = {}
    t_limit = clock() + ready_timeout_s
    while len(ready) < n_replicas:
        msg = transport.poll(tick_s)
        if msg is None:
            if not transport.blocks:
                sleep(tick_s)
            if clock() > t_limit:
                raise RuntimeError(
                    f"only {len(ready)}/{n_replicas} replicas became "
                    f"ready within {ready_timeout_s}s")
            continue
        if msg[0] == MSG_READY:
            ready[msg[1]] = (msg[2], msg[3], msg[4])
        elif msg[0] == MSG_DYING:
            raise RuntimeError(
                f"replica {msg[1]} died during startup: {msg[2]}")

    # --- phase 2: GO — one shared epoch, then dispatch the trace ---
    t0 = clock()
    monitor = HeartbeatMonitor(n_replicas, dead_after_s=dead_after_s,
                               policy=straggler,
                               clock=lambda: clock() - t0)
    router = ReplicaRouter(n_replicas, monitor=monitor)
    for wid, (s, misses, hits) in ready.items():
        router.on_ready(wid, s, misses, hits)
    for wid in range(n_replicas):
        transport.send(wid, (CTRL_GO, t0))

    def requeue(wid: int) -> None:
        for it in router.mark_dead(wid):
            transport.send(router.dispatch(it), it)

    pending = deque(sorted(trace, key=lambda e: e[0]))
    seq = 0
    killed = False
    while pending or router.incomplete():
        now = clock() - t0
        while pending and pending[0][0] <= now:
            arrival, rows = pending.popleft()
            item = WorkItem(seq, rows, arrival)
            seq += 1
            transport.send(router.dispatch(item), item)
        if (kill_worker is not None and not killed
                and router.views[kill_worker].alive
                and router.load(kill_worker) > 0
                and router.views[kill_worker].batches
                >= kill_after_batches):
            transport.send(kill_worker, (CTRL_DIE,))
            killed = True
        timeout = tick_s
        if pending:
            timeout = min(tick_s, max(0.0, pending[0][0] - now))
        progressed = False
        msg = transport.poll(timeout)
        while msg is not None:
            head = msg[0]
            if head == MSG_HEARTBEAT:
                router.on_heartbeat(msg[1])
            elif head == MSG_DONE:
                router.on_batch_done(msg[1], msg[2], msg[3], msg[4])
                progressed = True
            elif head == MSG_DYING:
                # FIFO per producer: all its earlier DONEs are already
                # folded in, so the re-queue set is exact
                requeue(msg[1])
                progressed = True
            elif head == MSG_STATS:
                progressed = True       # late stats from a stopper
            msg = transport.poll(0.0)
        for wid in router.alive_ids():
            if not transport.alive(wid):
                requeue(wid)
                progressed = True
        for wid in router.deadline_dead():
            requeue(wid)
            progressed = True
        if not progressed and not transport.blocks:
            # fake transports never wait in poll: idle time must pass
            # through the injected sleep (advancing the fake clock)
            sleep(timeout if timeout > 0 else tick_s)
    wall = clock() - t0

    # --- phase 3: drain worker-side stats, shut down ---
    stragglers = dict(monitor.stragglers())
    expecting = set(router.alive_ids())
    for wid in expecting:
        transport.send(wid, (CTRL_STOP,))
    t_limit = clock() + ready_timeout_s
    while expecting and clock() <= t_limit:
        msg = transport.poll(tick_s)
        if msg is None:
            if not transport.blocks:
                sleep(tick_s)
            expecting = {w for w in expecting if transport.alive(w)}
            continue
        if msg[0] == MSG_STATS:
            expecting.discard(msg[1])
        elif msg[0] == MSG_DYING:
            expecting.discard(msg[1])
    transport.join()
    return ReplicaStats(workers=router.views, wall_s=wall,
                        requeued=router.requeued,
                        duplicate_serves=router.duplicate_serves,
                        deaths=router.deaths, stragglers=stragglers)
