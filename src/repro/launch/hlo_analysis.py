"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — a scanned
95-layer transformer under-reports flops/bytes/collectives by ~95x.  This
module re-derives the three roofline inputs from the partitioned HLO text
with loop trip-count amplification:

* computations are parsed per-line (ops are indented, computation headers
  and the closing brace are at column 0);
* ``while`` ops contribute body-cost x trip-count; the trip count is the
  largest integer constant in the condition computation (the canonical
  lax.scan lowering compares the induction variable LT a constant —
  validated against known layer counts in tests);
* ``fusion``/``call``/``conditional`` contribute their callee cost once
  (branches: max over branches);
* FLOPs: 2 x |output| x |contracted dims| per ``dot`` (+ batch dims are
  part of the output, so this is exact for dot_general);
* HBM traffic: per top-level op, operand bytes + output bytes at fusion
  boundaries (internal fusion temps never hit HBM — this approximates
  post-fusion HBM traffic; data-movement-only ops (bitcast, tuple, GTE,
  parameter) are free, ``copy`` is counted);
* collective bytes: output bytes per op, per kind, amplified by trips.

All numbers are per-chip (the HLO is the per-partition SPMD module).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLEE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "iota", "after-all", "partition-id", "replica-id"}


def shape_info(type_txt: str) -> Tuple[int, int]:
    """(elements, bytes) summed over shape tokens (handles tuples)."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Op:
    name: str
    kind: str
    type_txt: str
    rest: str

    @property
    def out_bytes(self) -> int:
        return shape_info(self.type_txt)[1]

    @property
    def out_elems(self) -> int:
        return shape_info(self.type_txt)[0]


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)


_KIND_RE = re.compile(r"^((?:[a-z0-9\[\],{}:*() ]|->)+?)\s+([\w\-]+)\(")


def _parse_op(line: str) -> Optional[Op]:
    m = _OP_LINE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    km = _KIND_RE.match(rhs)
    if not km:
        return None
    return Op(name=name, kind=km.group(2), type_txt=km.group(1), rest=rhs)


_OP_START = re.compile(r"^\s+(?:ROOT\s+)?%[\w.\-]+\s*=")


def _joined_lines(hlo: str):
    """Yield logical lines: the HLO printer wraps ops with huge tuple
    types / operand lists — continuation lines (indented, not an op
    start, not a header/brace) are folded into the previous line."""
    buf: Optional[str] = None
    for line in hlo.splitlines():
        if not line:
            continue
        if line[0] not in " }":                  # header or module text
            if buf is not None:
                yield buf
                buf = None
            yield line
            continue
        if line.startswith("}"):
            if buf is not None:
                yield buf
                buf = None
            continue
        if _OP_START.match(line):
            if buf is not None:
                yield buf
            buf = line
        elif buf is not None:
            buf += " " + line.strip()
    if buf is not None:
        yield buf


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    hlo = _COMMENT.sub("", hlo)
    for line in _joined_lines(hlo):
        if line[0] not in " ":
            h = _HEADER.match(line)
            if h and line.rstrip().endswith("{"):
                cur = Computation(h.group(1))
                comps[cur.name] = cur
            continue
        if cur is not None:
            op = _parse_op(line)
            if op is not None:
                cur.ops.append(op)
    return comps


def _dot_flops(op: Op, shapes: Dict[str, Tuple[int, int]],
               dims_by_name: Dict[str, List[int]]) -> float:
    ops = _OPERANDS.findall(op.rest.split("(", 1)[1])
    lhs = ops[0] if ops else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contracted = 1
    if lhs is not None and m and lhs in dims_by_name:
        dims = dims_by_name[lhs]
        for i in m.group(1).split(","):
            if i and int(i) < len(dims):
                contracted *= dims[int(i)]
    return 2.0 * op.out_elems * contracted


def _conv_flops(op: Op, dims_by_name: Dict[str, List[int]]) -> float:
    ops = _OPERANDS.findall(op.rest.split("(", 1)[1])
    if len(ops) < 2 or ops[1] not in dims_by_name:
        return 0.0
    kernel_elems = math.prod(dims_by_name[ops[1]]) or 1
    # flops ~ 2 * out_elems * (kernel elems / out_features)
    return 2.0 * op.out_elems * kernel_elems


@dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    # attribution: jax op_name group -> bytes (for perf debugging)
    hbm_by_group: Dict[str, float] = field(default_factory=dict)
    coll_by_group: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.hbm_by_group.items():
            self.hbm_by_group[k] = self.hbm_by_group.get(k, 0.0) + v * mult
        for k, v in other.coll_by_group.items():
            self.coll_by_group[k] = (self.coll_by_group.get(k, 0.0)
                                     + v * mult)


_METADATA_NAME = re.compile(r'op_name="([^"]*)"')


def _group_of(op: "Op", comps: Optional[Dict[str, "Computation"]] = None
              ) -> str:
    """Coarse attribution group from jax metadata: the most informative
    path segments of op_name.  Fusions without their own metadata are
    labelled by the largest-output op inside their callee."""
    m = _METADATA_NAME.search(op.rest)
    if not m and comps is not None and op.kind == "fusion":
        callee = _CALLEE.search(op.rest)
        if callee and callee.group(1) in comps:
            best, best_b = None, -1
            for sub in comps[callee.group(1)].ops:
                mm = _METADATA_NAME.search(sub.rest)
                if mm and sub.out_bytes > best_b:
                    best, best_b = mm.group(1), sub.out_bytes
            if best:
                segs = [s for s in best.split("/")
                        if s and not s.startswith("jit(")]
                tail = "/".join(segs[-2:]) if segs else best
                return "f:" + re.sub(r"\.\d+", "", tail)[:58]
    if not m:
        return f"<{op.kind}>"
    name = m.group(1)
    segs = [s for s in name.split("/") if s and not s.startswith("jit(")]
    tail = "/".join(segs[-2:]) if segs else name
    return re.sub(r"\.\d+", "", tail)[:60]


def analyze_hlo(hlo: str, entry: Optional[str] = None) -> CostTotals:
    comps = parse_computations(hlo)
    # global maps: op name -> dims (single-shape ops) and -> bytes
    dims_by_name: Dict[str, List[int]] = {}
    bytes_by_name: Dict[str, int] = {}
    for c in comps.values():
        for op in c.ops:
            toks = _SHAPE_TOKEN.findall(op.type_txt)
            if len(toks) == 1:
                dims_by_name[op.name] = [int(d) for d in
                                         toks[0][1].split(",") if d]
            bytes_by_name[op.name] = op.out_bytes

    # --- slice-aware operand accounting -------------------------------
    # A fusion that only *dynamic-slices* a big operand (the canonical
    # scan pattern: read one layer's slice of the stacked params /
    # residuals) touches the slice, not the whole array.  For each
    # fusion callee, find parameters whose only consumers are slice ops
    # and record the actual sliced bytes.  Dually, a fusion whose output
    # is a dynamic-update-slice of a carried buffer (scan ys stacking)
    # writes the *update*, not the buffer (XLA aliases it in place) —
    # record the per-callee update size.
    param_slice_bytes: Dict[Tuple[str, int], int] = {}
    dus_out_bytes: Dict[str, int] = {}
    for cname, comp in comps.items():
        params: Dict[str, int] = {}
        local_bytes: Dict[str, int] = {}
        for op in comp.ops:
            local_bytes[op.name] = op.out_bytes
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.rest)
                if m:
                    params[op.name] = int(m.group(1))
        dus_updates = 0
        for op in comp.ops:
            if op.kind == "dynamic-update-slice":
                names = _OPERANDS.findall(op.rest.split("(", 1)[1]
                                          .split(")")[0])
                if len(names) >= 2:
                    dus_updates += local_bytes.get(names[1], 0)
        if dus_updates:
            dus_out_bytes[cname] = dus_updates
        if not params:
            continue
        consumers: Dict[str, List[Op]] = {p: [] for p in params}
        for op in comp.ops:
            if op.kind == "parameter":
                continue
            args = op.rest.split("(", 1)
            if len(args) != 2:
                continue
            for o2 in _OPERANDS.findall(args[1].split(")")[0]):
                if o2 in consumers:
                    consumers[o2].append(op)
        for pname, idx in params.items():
            cons = consumers[pname]
            if cons and all(c.kind in ("dynamic-slice", "slice", "gather",
                                       "dynamic-update-slice")
                            for c in cons):
                sliced = 0
                for c in cons:
                    if c.kind == "dynamic-update-slice":
                        names = _OPERANDS.findall(
                            c.rest.split("(", 1)[1].split(")")[0])
                        # buffer operand of a DUS: aliased, charge update
                        if names and names[0] == pname and len(names) > 1:
                            sliced += local_bytes.get(names[1], 0)
                        else:
                            sliced += c.out_bytes
                    else:
                        sliced += c.out_bytes
                param_slice_bytes[(cname, idx)] = sliced

    def boundary_bytes(op: Op) -> int:
        """HBM traffic at an op boundary: output written + operands read
        (slice-consumed operands charged at sliced size)."""
        if op.kind in ("dynamic-slice", "slice", "gather"):
            return op.out_bytes * 2            # read slice + write out
        if op.kind == "dynamic-update-slice":
            args = op.rest.split("(", 1)
            upd = 0
            if len(args) == 2:
                names = _OPERANDS.findall(args[1].split(")")[0])
                if len(names) >= 2:
                    upd = bytes_by_name.get(names[1], 0)
            return upd * 2                     # in-place buffer aliasing
        args = op.rest.split("(", 1)
        callee = _CALLEE.search(op.rest) if op.kind == "fusion" else None
        cname = callee.group(1) if callee else None
        # fusion writing via dynamic-update-slice: output is aliased
        # in-place — charge the update size, not the carried buffer
        if cname is not None and cname in dus_out_bytes and \
                dus_out_bytes[cname] * 4 < op.out_bytes:
            total = dus_out_bytes[cname]
        else:
            total = op.out_bytes
        if len(args) != 2:
            return total
        for i, operand in enumerate(
                _OPERANDS.findall(args[1].split(")")[0])):
            full = bytes_by_name.get(operand, 0)
            if cname is not None and (cname, i) in param_slice_bytes:
                total += min(full, param_slice_bytes[(cname, i)])
            else:
                total += full
        return total

    trip_cache: Dict[str, int] = {}

    def trip_count(cond_name: str) -> int:
        if cond_name in trip_cache:
            return trip_cache[cond_name]
        best = 1
        comp = comps.get(cond_name)
        if comp is not None:
            for op in comp.ops:
                for c in _CONST_INT.findall(op.rest):
                    best = max(best, int(c))
        trip_cache[cond_name] = best
        return best

    memo: Dict[Tuple[str, bool], CostTotals] = {}

    def cost_of(name: str, count_hbm: bool, stack=()) -> CostTotals:
        """count_hbm=True for entry/while/conditional bodies (ops hit
        HBM); False inside fusion callees (internal temps are registers —
        only flops/collectives counted there)."""
        key = (name, count_hbm)
        if key in memo:
            return memo[key]
        if name in stack:            # defensive: no recursion in HLO
            return CostTotals()
        total = CostTotals()
        comp = comps.get(name)
        if comp is None:
            return total
        for op in comp.ops:
            if op.kind == "while":
                cond = _COND.search(op.rest)
                body = _CALLEE.search(op.rest)
                if body:
                    trips = trip_count(cond.group(1)) if cond else 1
                    total.add(cost_of(body.group(1), count_hbm,
                                      stack + (name,)), trips)
                continue
            if op.kind == "conditional":
                br = _BRANCHES.search(op.rest)
                if br:
                    subs = [cost_of(b.strip().lstrip("%"), count_hbm,
                                    stack + (name,))
                            for b in br.group(1).split(",") if b.strip()]
                    if subs:
                        total.add(max(subs, key=lambda c: (c.flops,
                                                           c.hbm_bytes)))
                continue
            if op.kind in ("fusion", "call", "async-start", "map"):
                callee = _CALLEE.search(op.rest)
                if callee:
                    total.add(cost_of(callee.group(1), False,
                                      stack + (name,)))
                if count_hbm:
                    bb = boundary_bytes(op)
                    total.hbm_bytes += bb
                    g = _group_of(op, comps)
                    total.hbm_by_group[g] = (total.hbm_by_group.get(g, 0.0)
                                             + bb)
                continue
            if op.kind.replace("-start", "") in COLLECTIVES:
                kind = op.kind.replace("-start", "")
                total.coll_bytes[kind] = (total.coll_bytes.get(kind, 0.0)
                                          + op.out_bytes)
                g = _group_of(op, comps)
                total.coll_by_group[g] = (total.coll_by_group.get(g, 0.0)
                                          + op.out_bytes)
                if count_hbm:
                    total.hbm_bytes += boundary_bytes(op)
                continue
            if op.kind == "dot":
                total.flops += _dot_flops(op, {}, dims_by_name)
                if count_hbm:
                    bb = boundary_bytes(op)
                    total.hbm_bytes += bb
                    g = _group_of(op, comps)
                    total.hbm_by_group[g] = (total.hbm_by_group.get(g, 0.0)
                                             + bb)
                continue
            if op.kind == "convolution":
                total.flops += _conv_flops(op, dims_by_name)
                if count_hbm:
                    total.hbm_bytes += boundary_bytes(op)
                continue
            if op.kind in _FREE_OPS:
                continue
            if count_hbm:
                bb = boundary_bytes(op)
                total.hbm_bytes += bb
                g = _group_of(op, comps)
                total.hbm_by_group[g] = (total.hbm_by_group.get(g, 0.0)
                                         + bb)
        memo[key] = total
        return total

    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    totals = cost_of(entry, True)
    totals.coll_bytes["total"] = sum(totals.coll_bytes.values())
    return totals
