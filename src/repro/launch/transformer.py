"""Lower transformer configs block-by-block into the mapped-serving IR.

``transformer_mapping`` turns an `models.config.ArchConfig` into a
`core.types.NetworkMapping` whose layers are all ``op="matmul"`` specs
(`core.types.matmul_spec`) — qkv / o / w1 / w2 projections — and whose
``glue`` tuple carries everything the mapped matmuls do *not* own:
pre-layernorm, the flash-attention opaque stage between qkv and o,
activations, and the two residual adds per block.  The result flows
through the exact conv path: ``compile_plan -> execute_plan ->
PlanLadder -> FleetScheduler``, with steps==cycles asserted per layer at
compile time.

Serving layout: a request is a frame of precomputed token embeddings
``(B, d_model, seq, 1)`` — d_model on the conv channel axis, tokens on
``i_h`` (`tokens_per_row` recovers seq for tokens/s reporting).
Embedding/vocab lookups stay outside the mapped net, matching the
whisper frontend stub.

Fidelity notes (geometry over weights — this is a *mapping* workload,
not a checkpoint): norms are parameter-free passthroughs (rmsnorm
configs also lower to the layernorm passthrough); the gated-silu
"dense" ffn lowers to single-branch ``w1 -> silu -> w2`` (same mapped
shapes as one gate branch); whisper lowers its encoder self-attention
stack (cross-attention decode has no mapped-matmul chain shape yet);
rotary embeddings are skipped.  Mixers other than gqa (mla/rec/ssd) and
MoE ffns raise — their routing is future work, not silently wrong.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

from repro.core import ArrayConfig, MacroGrid, NetworkMapping, mapper
from repro.core.types import GlueSpec, matmul_spec
from repro.models.config import ArchConfig, BlockSpec


def _arch_blocks(cfg: ArchConfig) -> Tuple[Tuple[str, BlockSpec], ...]:
    """(name_prefix, spec) per lowered block, in execution order."""
    if cfg.kind == "encdec":
        # encoder self-attention stack; bidirectional by construction
        base = cfg.stages[0].unit[0] if cfg.stages else BlockSpec()
        enc = BlockSpec(mixer=base.mixer, ffn=base.ffn, causal=False)
        return tuple((f"enc{i}", enc) for i in range(cfg.n_enc_layers))
    out, i = [], 0
    for stage in cfg.stages:
        for _ in range(stage.n_units):
            for spec in stage.unit:
                out.append((f"blk{i}", spec))
                i += 1
    return tuple(out)


def _lower_block(prefix: str, spec: BlockSpec, cfg: ArchConfig, seq: int):
    """One transformer block -> 4 matmul specs + their glue."""
    if spec.mixer != "gqa":
        raise ValueError(f"{cfg.name}: mixer {spec.mixer!r} has no mapped "
                         "lowering (only gqa/mha)")
    if spec.ffn not in ("dense", "gelu"):
        raise ValueError(f"{cfg.name}: ffn {spec.ffn!r} has no mapped "
                         "lowering (only dense/gelu)")
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads or cfg.n_heads, cfg.head_dim
    d, ff = cfg.d_model, cfg.d_ff
    act = "gelu" if spec.ffn == "gelu" else "silu"
    layers = (
        matmul_spec(f"{prefix}.qkv", seq, d, (hq + 2 * hkv) * hd),
        matmul_spec(f"{prefix}.o", seq, hq * hd, d),
        matmul_spec(f"{prefix}.w1", seq, d, ff),
        matmul_spec(f"{prefix}.w2", seq, ff, d),
    )
    glue = (
        GlueSpec(kind="chain", pre="layernorm", save=True,
                 post="attention", heads=(hq, hkv, hd),
                 causal=spec.causal),
        GlueSpec(kind="residual"),
        GlueSpec(kind="chain", pre="layernorm", save=True, act=act),
        GlueSpec(kind="residual"),
    )
    return layers, glue


def transformer_mapping(config: Union[str, ArchConfig], *,
                        seq: int = 16,
                        array: ArrayConfig = ArrayConfig(),
                        algorithm: str = "TetrisG-SDK",
                        grid: MacroGrid = MacroGrid(),
                        blocks: Optional[int] = None,
                        groups: Sequence[int] = (1, 2, 4),
                        **kw) -> NetworkMapping:
    """Lower ``config`` (an ArchConfig or a `TRANSFORMERS` name) into a
    glue-carrying NetworkMapping of mapped matmul layers, ready for
    ``compile_plan``.  ``blocks`` truncates to the first N blocks."""
    if isinstance(config, str):
        config = TRANSFORMERS[config]()
    arch_blocks = _arch_blocks(config)
    if not arch_blocks:
        raise ValueError(f"{config.name}: no lowerable blocks")
    if blocks is not None:
        arch_blocks = arch_blocks[:blocks]
    layers, glue = [], []
    for prefix, spec in arch_blocks:
        ls, gs = _lower_block(prefix, spec, config, seq)
        layers.extend(ls)
        glue.extend(gs)
    return mapper.map_net(config.name, layers, array, algorithm, grid,
                          glue=tuple(glue), groups=tuple(groups), **kw)


def tokens_per_row(net: NetworkMapping) -> Optional[int]:
    """Tokens carried per batch row (seq) when ``net`` is a lowered
    transformer; None for conv nets (serve paths report images/s)."""
    first = net.layers[0].layer
    return first.i_h if getattr(first, "op", "conv") == "matmul" else None


TRANSFORMERS = {
    "stablelm_smoke": lambda: _smoke("stablelm_1_6b"),
    "whisper_smoke": lambda: _smoke("whisper_base"),
}


def _smoke(module: str) -> ArchConfig:
    import importlib
    return importlib.import_module(f"repro.configs.{module}").smoke_config()
