"""Batched CNN serving driver: mapped-executor throughput (images/s).

The CNN counterpart of ``launch/serve.py`` (which serves the transformer
scaffold): map a benchmark conv stack once — reusing a persistent on-disk
mapping cache so a cold replica skips the window search entirely — then
drive steady-state batched forward passes through the macro-parallel
executor (``cnn/mapped_net.py``, ``executor="mapped"``) and report
images/s.  With multiple devices the batch shards over the "data" axis
of the serving mesh while (row, col) carry the macro grid
(``launch.mesh.make_serving_mesh``; DESIGN.md §7).

    python -m repro.launch.serve_cnn --net cnn8 --batch 8 --steps 20 \
        --p-max 4 --cache-dir /tmp/mapping-cache

Prints one ``serve/...`` CSV row per the benchmark harness contract plus
a human-readable summary (search time, cache stats, mesh, images/s).
"""
from __future__ import annotations

import argparse
import math
import time

from repro.core import (ArrayConfig, MacroGrid, grid_search, map_net, memo,
                        networks)


def _parse_grid(text: str) -> MacroGrid:
    r, c = text.lower().split("x")
    return MacroGrid(int(r), int(c))


def map_for_serving(net: str, array: ArrayConfig, algorithm: str,
                    grid: MacroGrid = None, p_max: int = None,
                    groups=(1, 2, 4)):
    """Map ``net`` for serving (fixed grid or Alg 2 budget sweep) and
    return ``(mapping, search_seconds)``.  With a warm disk cache
    (``memo.set_disk_cache`` / ``REPRO_MAPPING_CACHE``) a cold process
    performs zero search-table builds — asserted in tests/test_serve_cnn.
    """
    layers = networks.NETWORKS[net]()
    kw = {"groups": groups} if algorithm == "TetrisG-SDK" else {}
    t0 = time.perf_counter()
    if p_max is not None:
        mapping = grid_search(net, layers, array, p_max, algorithm,
                              **kw).best
    else:
        mapping = map_net(net, layers, array, algorithm,
                          grid or MacroGrid(), **kw)
    return mapping, time.perf_counter() - t0


def serving_mesh_for(net_mapping, batch: int):
    """Largest mesh every layer of the mapping can shard onto: the mesh
    macro axes must divide each layer's sub-grid (gcd across layers),
    leftover devices stack along "data" when the batch divides."""
    from repro.launch.mesh import make_serving_mesh
    gr = gc = 0
    for m in net_mapping.layers:
        gr = math.gcd(gr, m.sub_grid.r)
        gc = math.gcd(gc, m.sub_grid.c)
    return make_serving_mesh(max(gr, 1), max(gc, 1), batch)


def serve(net_mapping, batch: int, steps: int, warmup: int = 2,
          mesh=None, seed: int = 0):
    """Steady-state batched forward passes; returns (images/s, s/batch)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.cnn.mapped_net import mapped_net_apply, zero_pruned_kernels

    rng = np.random.RandomState(seed)
    ks = zero_pruned_kernels(net_mapping, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net_mapping.layers])
    first = net_mapping.layers[0].layer
    x = jnp.asarray(rng.randn(batch, first.ic, first.i_h, first.i_w),
                    jnp.float32)

    def step():
        return jax.block_until_ready(
            mapped_net_apply(net_mapping, ks, x, mesh=mesh))

    for _ in range(max(1, warmup)):          # compile + steady the caches
        step()
    t0 = time.perf_counter()
    for _ in range(steps):
        step()
    dt = (time.perf_counter() - t0) / steps
    return batch / dt, dt


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="cnn8", choices=sorted(networks.NETWORKS))
    ap.add_argument("--alg", default="TetrisG-SDK")
    ap.add_argument("--ar", type=int, default=512)
    ap.add_argument("--ac", type=int, default=512)
    ap.add_argument("--grid", type=_parse_grid, default=None,
                    help="fixed macro grid RxC (default: 1x1)")
    ap.add_argument("--p-max", type=int, default=None,
                    help="Alg 2 macro-budget sweep instead of --grid")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent mapping cache directory "
                         "(default: $REPRO_MAPPING_CACHE)")
    ap.add_argument("--no-mesh", action="store_true",
                    help="force the single-device vmap path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.cache_dir is not None:
        memo.set_disk_cache(args.cache_dir)

    mapping, search_s = map_for_serving(
        args.net, ArrayConfig(args.ar, args.ac), args.alg,
        grid=args.grid, p_max=args.p_max)
    st = memo.stats
    print(f"{args.net} [{args.alg}] grid={mapping.grid.r}x{mapping.grid.c} "
          f"total_cycles={mapping.total_cycles} search={search_s*1e3:.1f}ms "
          f"(table_builds={st['table_misses']} disk_hits={st['disk_hits']} "
          f"disk_writes={st['disk_writes']})")

    mesh = None if args.no_mesh else serving_mesh_for(mapping, args.batch)
    tag = ("x".join(str(s) for s in mesh.devices.shape)
           if mesh is not None else "vmap")
    ips, dt = serve(mapping, args.batch, args.steps, warmup=args.warmup,
                    mesh=mesh, seed=args.seed)
    print(f"mesh={tag} batch={args.batch}: {ips:.1f} images/s "
          f"({dt*1e3:.1f} ms/batch, executor=mapped)")
    print(f"serve/{args.net}/b{args.batch},{dt*1e6:.1f},"
          f"images_per_s={ips:.1f};mesh={tag};"
          f"search_ms={search_s*1e3:.1f};table_builds={st['table_misses']}")


if __name__ == "__main__":
    main()
