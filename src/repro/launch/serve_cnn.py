"""Batched CNN serving driver: compiled-plan throughput (images/s).

The CNN counterpart of ``launch/serve.py`` (which serves the transformer
scaffold): map a benchmark conv stack once — reusing a persistent
on-disk mapping cache so a cold replica skips the window search entirely
— compile the mapping into :class:`repro.exec.NetworkPlan` programs
(executor choice, schedule, glue, and mesh fitting all fixed at compile
time; DESIGN.md §8), then drive steady-state forward passes through
``execute_plan`` — a single jitted program per forward, never re-fitting
the mesh per request — and report images/s.  With multiple devices the
batch shards over the "data" axis of the serving mesh while (row, col)
carry the macro grid (``launch.mesh.make_serving_mesh``; DESIGN.md §7).

Four serving modes:

* **fixed** (:func:`serve`) — every step serves one fixed request
  batch; ragged request batches are padded-and-masked to the plan batch
  (``mesh.pad_to_data_axis``) instead of silently falling back to the
  single-device vmap path.
* **dynamic** (:func:`serve_dynamic`, ``--max-delay-ms``) — an
  arrival-driven queue + max-delay coalescer (`launch/batching.py`)
  drains ragged arrivals into the largest ready batch, which pads to
  the nearest tier of a power-of-two **plan ladder** (all tiers sharing
  one serving mesh); per-tier effective vs padded images/s and
  queue-delay percentiles are reported.  On platforms that implement
  buffer donation the steady-state loop donates each batch's input
  buffer to the program (``execute_plan(donate=True)``).
* **fleet** (``--fleet cnn8,inception,densenet40``) — several networks
  share ONE serving mesh under mixed Poisson traffic: per-model
  coalescers + plan ladders behind a cross-model drain policy, with
  prepared shifted-weight constants shared across each network's tiers
  (`launch/fleet.py`); per-model and aggregate effective vs padded
  images/s, queue-delay percentiles, and SLO attainment are reported.
* **replicas** (``--replicas N``) — process-level scale-out
  (`launch/replica.py`; DESIGN.md §12): N worker processes, each with
  its own mesh and plan ladder (warm ``--cache-dir`` makes their
  cold-start cheap), behind a least-loaded router with heartbeat-based
  worker recovery; aggregate + per-replica effective images/s and
  pooled queue-delay percentiles are reported.

    python -m repro.launch.serve_cnn --net cnn8 --batch 8 --steps 20 \
        --p-max 4 --cache-dir /tmp/mapping-cache
    python -m repro.launch.serve_cnn --net cnn8 --max-batch 8 \
        --max-delay-ms 2 --arrival-rate 500 --requests 64
    python -m repro.launch.serve_cnn --fleet cnn8,inception,densenet40 \
        --max-batch 4 --arrival-rate 200 --requests 48 --slo-ms 50
    python -m repro.launch.serve_cnn --net cnn8 --replicas 2 \
        --max-batch 4 --max-delay-ms 2 --requests 64 \
        --cache-dir /tmp/mapping-cache

Prints ``serve/...`` (and per-tier ``serve_dyn/...``) CSV rows per the
benchmark harness contract plus a human-readable summary (search time,
cache stats, mesh, plan, images/s).
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.core import (ArrayConfig, MacroGrid, grid_search, map_net, memo,
                        networks)
from repro.launch import batching
from repro.launch import mesh as meshlib


def _parse_grid(text: str) -> MacroGrid:
    r, c = text.lower().split("x")
    return MacroGrid(int(r), int(c))


def map_for_serving(net: str, array: ArrayConfig, algorithm: str,
                    grid: MacroGrid = None, p_max: int = None,
                    groups=(1, 2, 4)):
    """Map ``net`` for serving (fixed grid or Alg 2 budget sweep) and
    return ``(mapping, search_seconds)``.  With a warm disk cache
    (``memo.set_disk_cache`` / ``REPRO_MAPPING_CACHE``) a cold process
    performs zero search-table builds — asserted in tests/test_serve_cnn.
    """
    layers = networks.NETWORKS[net]()
    kw = {"groups": groups} if algorithm == "TetrisG-SDK" else {}
    t0 = time.perf_counter()
    if p_max is not None:
        mapping = grid_search(net, layers, array, p_max, algorithm,
                              **kw).best
    else:
        mapping = map_net(net, layers, array, algorithm,
                          grid or MacroGrid(), **kw)
    return mapping, time.perf_counter() - t0


def serving_mesh_for(net_mapping, batch: int):
    """Largest mesh every layer of the mapping can shard onto — thin
    wrapper over :func:`repro.launch.mesh.serving_mesh_for`."""
    return meshlib.serving_mesh_for(net_mapping, batch)


def _serving_kernels(net_mapping, seed: int):
    import jax.numpy as jnp
    import numpy as np
    from repro.cnn.mapped_net import zero_pruned_kernels
    rng = np.random.RandomState(seed)
    ks = zero_pruned_kernels(net_mapping, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net_mapping.layers])
    return rng, ks


@dataclass
class ServeStats:
    """One steady-state measurement: effective rate counts the images
    the caller asked for; padded counts what the plan executed."""

    images_per_s: float         # request images / batch time (effective)
    padded_images_per_s: float  # plan-batch images / batch time
    s_per_batch: float
    request_batch: int
    plan_batch: int
    plan: object                # the NetworkPlan served from
    warmup_steps: int = 0       # warmup forwards actually executed
    donated: bool = False       # input buffers donated to the program


def serve(net_mapping, batch: int, steps: int, warmup: int = 2,
          mesh=None, seed: int = 0, policy="mapped",
          donate: Optional[bool] = None,
          lookahead: Optional[int] = None, block: Optional[str] = None,
          vmem_budget: Optional[int] = None) -> ServeStats:
    """Steady-state batched forward passes through a compiled plan.

    ``batch`` is the *request* batch; when it does not divide the mesh's
    "data" axis the inputs are zero-padded to the plan batch and the
    padded rows masked off the output (pad-and-mask) — the mesh is never
    silently abandoned for the vmap path.

    ``warmup`` is honored exactly, including 0 — with ``warmup=0`` the
    timed steps include plan compilation (useful for cold-start
    measurements); the count actually executed is reported in
    ``ServeStats.warmup_steps``.  ``donate=None`` donates each step's
    input buffer whenever the plan's platform supports it
    (`repro.exec.donation_supported`; the input ring then re-uploads a
    fresh buffer per step — `launch.batching.InputRing`)."""
    import jax
    import jax.numpy as jnp
    from repro.exec import compile_plan, donation_supported, execute_plan

    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    if donate is None:
        donate = donation_supported(mesh)
    plan_batch = meshlib.pad_to_data_axis(batch, mesh)
    plan = compile_plan(net_mapping, executor_policy=policy, mesh=mesh,
                        batch=plan_batch, lookahead=lookahead,
                        block=block, vmem_budget=vmem_budget)

    rng, ks = _serving_kernels(net_mapping, seed)
    first = net_mapping.layers[0].layer
    x = jnp.asarray(rng.randn(batch, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    if plan_batch != batch:         # ragged: pad to the plan's batch ...
        x = jnp.pad(x, ((0, plan_batch - batch),) + ((0, 0),) * 3)
    ring = batching.InputRing(x, donate=donate)

    def step():
        y = execute_plan(plan, ks, ring.next(), mesh=mesh, donate=donate)
        return jax.block_until_ready(y[:batch])   # ... mask padded rows

    for _ in range(warmup):          # compile + steady the caches
        step()
    t0 = time.perf_counter()
    for _ in range(steps):
        step()
    dt = (time.perf_counter() - t0) / steps
    return ServeStats(images_per_s=batch / dt,
                      padded_images_per_s=plan_batch / dt,
                      s_per_batch=dt, request_batch=batch,
                      plan_batch=plan_batch, plan=plan,
                      warmup_steps=warmup, donated=donate)


def poisson_arrivals(n: int, rate_per_s: float, max_rows: int,
                     seed: int = 0) -> Tuple[Tuple[float, int], ...]:
    """A synthetic ragged arrival schedule: ``n`` requests with
    exponential inter-arrival times at ``rate_per_s`` (0 → a fully
    backlogged queue, everything arrives at t=0) and uniform ragged
    sizes in [1, max_rows]."""
    import numpy as np
    if n < 1:
        raise ValueError(f"need >= 1 request, got {n}")
    if max_rows < 1:
        raise ValueError(f"max_rows must be >= 1, got {max_rows}")
    rng = np.random.RandomState(seed)
    if rate_per_s > 0:
        gaps = rng.exponential(1.0 / rate_per_s, size=n)
        times = np.cumsum(gaps) - gaps[0]       # first request at t=0
    else:
        times = np.zeros(n)
    rows = rng.randint(1, max_rows + 1, size=n)
    return tuple((float(t), int(r)) for t, r in zip(times, rows))


def serve_dynamic(net_mapping, requests: Sequence[Tuple[float, int]], *,
                  max_batch: int, max_delay_ms: float, mesh=None,
                  tiers: Optional[Sequence[int]] = None,
                  policy="mapped", warmup: int = 1, seed: int = 0,
                  donate: Optional[bool] = None,
                  adaptive_delay: bool = False,
                  lookahead: Optional[int] = None,
                  block: Optional[str] = None,
                  vmem_budget: Optional[int] = None,
                  clock=time.perf_counter,
                  sleep=time.sleep) -> batching.DynamicServeStats:
    """Arrival-driven serving through the plan ladder.

    ``requests`` is a schedule of ``(arrival_s, rows)`` pairs (seconds
    relative to measurement start, e.g. :func:`poisson_arrivals`).  The
    loop pushes each arrival into a max-delay :class:`batching.Coalescer`
    as its time comes, sleeps only until the next arrival or the oldest
    request's delay deadline, and serves every coalesced batch through
    the smallest ladder tier that fits (zero-padding the tier's spare
    rows, which the output mask drops — pad-and-mask isolation is
    regression-tested).  Once no future arrival remains the queue is
    force-drained: waiting can no longer grow a batch.

    ``warmup`` forwards per tier run before the clock starts (0 honored:
    compile time then lands in the measurement).  ``donate=None`` →
    donate input buffers whenever the plan's platform supports it.
    ``adaptive_delay`` swaps the fixed coalescing delay for the
    load-proportional `batching.AdaptiveDelay` policy (deep backlog →
    drain immediately; idle → wait up to ``max_delay_ms``)."""
    import jax
    import numpy as np
    from repro.exec import donation_supported, execute_plan

    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    if max_delay_ms < 0:
        raise ValueError(f"max_delay_ms must be >= 0, got {max_delay_ms}")
    if donate is None:
        donate = donation_supported(mesh)
    requests = tuple(requests)      # may be a generator: snapshot once
    big = max((r for _, r in requests), default=0)
    if big > max_batch:             # fail before serving, not mid-drain
        raise ValueError(f"request of {big} rows exceeds max_batch="
                         f"{max_batch} — requests are never split")
    tiers = batching.batch_tiers(max_batch, mesh) if tiers is None \
        else tuple(tiers)
    ladder = batching.PlanLadder(net_mapping, tiers, mesh=mesh,
                                 policy=policy, lookahead=lookahead,
                                 block=block, vmem_budget=vmem_budget)
    if ladder.max_batch < max_batch:
        raise ValueError(
            f"tiers {ladder.tiers} do not cover max_batch={max_batch} — "
            f"a full coalesced batch would have no plan to run on")
    rng, ks = _serving_kernels(net_mapping, seed)
    first = net_mapping.layers[0].layer
    shape = (first.ic, first.i_h, first.i_w)
    pool = rng.randn(ladder.max_batch, *shape).astype(np.float32)

    def run_tier(tier: int, x_np):
        y = execute_plan(ladder.plans[tier], ks, jax.device_put(x_np),
                         mesh=mesh, donate=donate)
        return jax.block_until_ready(y)

    warmup_steps = 0
    for _ in range(warmup):
        for t in ladder.tiers:       # compile every tier up front
            run_tier(t, pool[:t])
            warmup_steps += 1

    # the coalescer caps batches at the CALLER's max_batch (the
    # documented "largest coalesced batch"); the ladder's top tier may
    # sit above it when the mesh data axis pads it up
    delay_policy = (batching.AdaptiveDelay(max_delay_ms / 1e3, max_batch)
                    if adaptive_delay else None)
    co = batching.Coalescer(max_batch, max_delay_ms / 1e3,
                            delay_policy=delay_policy)
    # stable sort on TIME ONLY: a plain sorted() would order tied
    # timestamps (every backlogged stream) by rows, silently reordering
    # the FIFO the coalescer promises to preserve
    pending = deque(sorted(requests, key=lambda tr: tr[0]))
    stats = {t: batching.TierStats(plan_batch=t) for t in ladder.tiers}
    served_rows = padded_rows = 0
    t0 = clock()
    while pending or len(co):
        now = clock() - t0
        while pending and pending[0][0] <= now:
            arrival, rows = pending.popleft()
            co.push(rows, arrival)   # delay measured from scheduled arrival
        batch = co.pop(now, force=not pending)
        if not batch:
            deadline = co.next_deadline()
            horizon = min(pending[0][0] if pending else float("inf"),
                          deadline if deadline is not None else float("inf"))
            if horizon > now:
                sleep(horizon - now)
            continue
        rows = sum(r.rows for r in batch)
        tier, _ = ladder.plan_for(rows)
        x_np = np.zeros((tier,) + shape, np.float32)
        x_np[:rows] = pool[:rows]    # padded rows stay zero (pad-and-mask)
        launch = clock() - t0
        run_tier(tier, x_np)
        stats[tier].record(batch, launch, exec_s=clock() - t0 - launch)
        served_rows += rows
        padded_rows += tier
    wall = clock() - t0
    return batching.DynamicServeStats(
        tiers=stats, request_images=served_rows, padded_images=padded_rows,
        wall_s=wall, warmup_steps=warmup_steps)


def _print_dynamic(net: str, s: batching.DynamicServeStats, *, tag: str,
                   max_batch: int, max_delay_ms: float,
                   compiles: int, st: dict) -> None:
    """Human summary + harness CSV rows (one per served tier, one
    aggregate) for a dynamic run.  ``st`` is the SEARCH-phase stats
    snapshot — never the live dict (plan-ladder cache traffic would
    leak into the search columns)."""
    print(s.describe())
    for t in sorted(s.tiers):
        ts = s.tiers[t]
        if not ts.batches:
            continue
        print(f"serve_dyn/{net}/tier{t},"
              f"{ts.exec_s / ts.batches * 1e6:.1f},"
              f"images_per_s={ts.request_images / max(ts.exec_s, 1e-12):.1f};"
              f"padded_images_per_s="
              f"{ts.padded_images / max(ts.exec_s, 1e-12):.1f};"
              f"batches={ts.batches};"
              f"p50_ms={ts.delay_ms(50):.2f};p95_ms={ts.delay_ms(95):.2f};"
              f"p99_ms={ts.delay_ms(99):.2f}")
    # aggregate percentiles over the POOLED per-tier samples — never an
    # average of the per-tier p50/p95/p99 printed above
    pooled = (f"p50_ms={s.delay_ms(50):.2f};p95_ms={s.delay_ms(95):.2f};"
              f"p99_ms={s.delay_ms(99):.2f};" if s.delays_s else "")
    print(f"serve_dyn/{net}/all,"
          f"{s.wall_s / max(s.request_images, 1) * 1e6:.1f},"
          f"images_per_s={s.images_per_s:.1f};"
          f"padded_images_per_s={s.padded_images_per_s:.1f};"
          f"{pooled}"
          f"tiers={'/'.join(str(t) for t in sorted(s.tiers))};"
          f"plan_compiles={compiles};mesh={tag};"
          f"max_batch={max_batch};max_delay_ms={max_delay_ms};"
          f"warmup_steps={s.warmup_steps};"
          f"table_builds={st['table_misses']};disk_hits={st['disk_hits']}")


def _print_fleet(stats, *, tag: str, max_batch: int, max_delay_ms: float,
                 st: dict) -> None:
    """Human summary + harness CSV rows for a fleet run: one
    ``serve_fleet/<net>`` row per model, one ``serve_fleet/all``
    aggregate."""
    print(stats.describe())
    for name, ms in stats.models.items():
        if not ms.batches:
            continue
        exec_s = sum(t.exec_s for t in ms.tiers.values())
        ds = ms.delays_s
        tok = ""
        if ms.request_tokens is not None:
            tok = (f"tokens_per_s="
                   f"{ms.request_tokens / max(exec_s, 1e-12):.1f};")
        print(f"serve_fleet/{name},"
              f"{exec_s / ms.batches * 1e6:.1f},"
              f"images_per_s={ms.request_images / max(exec_s, 1e-12):.1f};"
              f"padded_images_per_s="
              f"{ms.padded_images / max(exec_s, 1e-12):.1f};"
              f"{tok}"
              f"dropped_layers={ms.dropped_layers};"
              f"batches={ms.batches};"
              f"tiers={'/'.join(str(t) for t in sorted(ms.tiers))};"
              f"p50_ms={batching.percentile(ds, 50)*1e3:.2f};"
              f"p95_ms={batching.percentile(ds, 95)*1e3:.2f};"
              f"p99_ms={batching.percentile(ds, 99)*1e3:.2f};"
              f"slo_attainment={ms.slo_attainment:.3f}")
    # fleet-wide percentiles over the POOLED per-model delay samples —
    # never an average of the per-model percentiles printed above
    pooled = (f"p50_ms={stats.delay_ms(50):.2f};"
              f"p95_ms={stats.delay_ms(95):.2f};"
              f"p99_ms={stats.delay_ms(99):.2f};" if stats.delays_s else "")
    print(f"serve_fleet/all,"
          f"{stats.wall_s / max(stats.request_images, 1) * 1e6:.1f},"
          f"images_per_s={stats.images_per_s:.1f};"
          f"padded_images_per_s={stats.padded_images_per_s:.1f};"
          f"{pooled}"
          f"models={'/'.join(stats.models)};"
          f"slo_attainment={stats.slo_attainment:.3f};mesh={tag};"
          f"max_batch={max_batch};max_delay_ms={max_delay_ms};"
          f"warmup_steps={stats.warmup_steps};"
          f"shared_constants={stats.shared_constants};"
          f"table_builds={st['table_misses']};disk_hits={st['disk_hits']}")


def _main_fleet(args) -> None:
    """``--fleet a,b,c``: mixed Poisson traffic across several models
    on one shared serving mesh (`launch/fleet.serve_fleet`).  Names
    resolve against the conv benchmarks (`core.networks.NETWORKS`) and
    the transformer lowerings (`launch.transformer.TRANSFORMERS`) — a
    mixed CNN+transformer fleet serves both kinds side by side, with
    tokens/s reported next to images/s."""
    from . import fleet, transformer
    names = [n.strip() for n in args.fleet.split(",") if n.strip()]
    unknown = [n for n in names
               if n not in networks.NETWORKS
               and n not in transformer.TRANSFORMERS]
    if unknown:
        raise SystemExit(
            f"unknown fleet nets {unknown} — choose from "
            f"{sorted(networks.NETWORKS)} or "
            f"{sorted(transformer.TRANSFORMERS)}")
    mappings, dropped, search_s = {}, {}, 0.0
    for n in names:
        t0 = time.perf_counter()
        if n in transformer.TRANSFORMERS:
            full = transformer.transformer_mapping(
                n, seq=args.seq, array=ArrayConfig(args.ar, args.ac),
                algorithm=args.alg, grid=args.grid or MacroGrid())
            s = time.perf_counter() - t0
        else:
            full, s = map_for_serving(
                n, ArrayConfig(args.ar, args.ac), args.alg,
                grid=args.grid, p_max=args.p_max)
        search_s += s
        mappings[n] = fleet.chainable_prefix(full)
        dropped[n] = len(full.layers) - len(mappings[n].layers)
        if dropped[n]:
            print(f"{n}: serving the chainable prefix "
                  f"({len(mappings[n].layers)}/{len(full.layers)} layers"
                  f" — the net is a layer set, not a chain)")
    st = memo.snapshot()
    max_batch = args.max_batch or args.batch
    max_delay_ms = 2.0 if args.max_delay_ms is None else args.max_delay_ms
    max_request = args.max_request or min(4, max_batch)
    config = fleet.FleetConfig(models=tuple(
        fleet.ModelSpec(n, max_batch=max_batch,
                        max_delay_s=max_delay_ms / 1e3,
                        slo_ms=args.slo_ms) for n in names))
    trace = fleet.mixed_poisson_trace(names, args.requests,
                                      args.arrival_rate, max_request,
                                      seed=args.seed)
    mesh = None if args.no_mesh else fleet.fleet_mesh_for(mappings,
                                                          max_batch)
    tag = meshlib.mesh_tag(mesh) if mesh is not None else "vmap"
    print(f"fleet [{args.alg}] nets={'/'.join(names)} mesh={tag} "
          f"search={search_s*1e3:.1f}ms "
          f"(table_builds={st['table_misses']} "
          f"disk_hits={st['disk_hits']})")
    stats, _ = fleet.serve_fleet(
        mappings, config, trace, mesh=mesh, policy=args.policy,
        warmup=args.warmup, seed=args.seed,
        donate=False if args.no_donate else None,
        share_constants=not args.no_share_constants,
        dropped_layers=dropped)
    _print_fleet(stats, tag=tag, max_batch=max_batch,
                 max_delay_ms=max_delay_ms, st=st)


def _print_replicas(net: str, rs, *, n: int, max_batch: int,
                    max_delay_ms: float) -> None:
    """Human summary + harness CSV rows for a multi-replica run: one
    ``serve_replica/<net>/w<i>`` row per worker, one aggregate."""
    print(rs.describe())
    for wid in sorted(rs.workers):
        v = rs.workers[wid]
        if not v.batches and v.alive:
            continue
        print(f"serve_replica/{net}/w{wid},"
              f"{v.exec_s / max(v.batches, 1) * 1e6:.1f},"
              f"requests={v.served_requests};images={v.served_rows};"
              f"batches={v.batches};alive={int(v.alive)};"
              f"startup_ms={v.startup_s*1e3:.1f};"
              f"table_builds={v.table_misses};disk_hits={v.disk_hits}")
    pooled = (f"p50_ms={rs.delay_ms(50):.2f};p95_ms={rs.delay_ms(95):.2f};"
              f"p99_ms={rs.delay_ms(99):.2f};" if rs.delays_s else "")
    print(f"serve_replica/{net}/all,"
          f"{rs.wall_s / max(rs.request_images, 1) * 1e6:.1f},"
          f"images_per_s={rs.images_per_s:.1f};"
          f"padded_images_per_s={rs.padded_images_per_s:.1f};"
          f"{pooled}"
          f"replicas={n};deaths={rs.deaths};requeued={rs.requeued};"
          f"duplicate_serves={rs.duplicate_serves};"
          f"max_batch={max_batch};max_delay_ms={max_delay_ms}")


def _main_replicas(args) -> None:
    """``--replicas N``: spawn N worker processes (each mapping and
    compiling behind the shared disk cache), route a Poisson trace
    through the least-loaded dispatcher, report aggregate and
    per-replica rates (`launch/replica.serve_replicas`)."""
    from .replica import WorkerConfig, serve_replicas
    max_batch = args.max_batch or args.batch
    max_delay_ms = 2.0 if args.max_delay_ms is None else args.max_delay_ms
    max_request = args.max_request or min(4, max_batch)
    trace = poisson_arrivals(args.requests, args.arrival_rate, max_request,
                             seed=args.seed)
    cfg = WorkerConfig(
        net=args.net, array=(args.ar, args.ac), alg=args.alg,
        grid=(args.grid.r, args.grid.c) if args.grid is not None else None,
        p_max=args.p_max, max_batch=max_batch, max_delay_ms=max_delay_ms,
        adaptive_delay=args.adaptive_delay, policy=args.policy,
        seed=args.seed, cache_dir=args.cache_dir, warmup=args.warmup,
        use_mesh=not args.no_mesh,
        donate=False if args.no_donate else None,
        xla_host_devices=args.worker_devices)
    print(f"{args.net} [{args.alg}] replicas={args.replicas} "
          f"max_batch={max_batch} max_delay_ms={max_delay_ms} "
          f"requests={args.requests} rate={args.arrival_rate}/s")
    rs = serve_replicas(trace, cfg, args.replicas,
                        dead_after_s=args.dead_after_ms / 1e3,
                        kill_worker=args.kill_worker)
    _print_replicas(args.net, rs, n=args.replicas, max_batch=max_batch,
                    max_delay_ms=max_delay_ms)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="cnn8", choices=sorted(networks.NETWORKS))
    ap.add_argument("--alg", default="TetrisG-SDK")
    ap.add_argument("--ar", type=int, default=512)
    ap.add_argument("--ac", type=int, default=512)
    ap.add_argument("--grid", type=_parse_grid, default=None,
                    help="fixed macro grid RxC (default: 1x1)")
    ap.add_argument("--p-max", type=int, default=None,
                    help="Alg 2 macro-budget sweep instead of --grid")
    ap.add_argument("--batch", type=int, default=8,
                    help="request batch (padded-and-masked to the plan "
                         "batch when the mesh data axis does not divide)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--warmup", type=int, default=2,
                    help="untimed warmup forwards; 0 is honored (timing "
                         "then includes plan compilation)")
    ap.add_argument("--policy", default="mapped",
                    choices=("mapped", "reference", "sdk", "auto",
                             "tuned"),
                    help="plan executor policy (per-layer for 'auto'; "
                         "'tuned' loads the autotuner's persisted "
                         "winner, falling back to 'auto')")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured-feedback autotuner "
                         "(repro.tune) for this net / fleet / batch "
                         "profile first — instant with a warm "
                         "--cache-dir — then serve the winner's full "
                         "config (policy, mesh split, lookahead, sdk "
                         "knobs, tiers)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent mapping/plan cache directory "
                         "(default: $REPRO_MAPPING_CACHE)")
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="mtime-LRU size cap for --cache-dir")
    ap.add_argument("--no-mesh", action="store_true",
                    help="force the single-device vmap path")
    ap.add_argument("--no-donate", action="store_true",
                    help="never donate input buffers (default: donate "
                         "whenever the plan's platform supports it)")
    ap.add_argument("--seed", type=int, default=0)
    dyn = ap.add_argument_group(
        "dynamic batching (arrival-driven; enabled by --max-delay-ms)")
    dyn.add_argument("--max-delay-ms", type=float, default=None,
                     help="coalescer max delay: a queued request is "
                          "served at latest this long after arrival")
    dyn.add_argument("--max-batch", type=int, default=None,
                     help="largest coalesced batch / top ladder tier "
                          "(default: --batch)")
    dyn.add_argument("--arrival-rate", type=float, default=0.0,
                     help="synthetic Poisson arrivals per second "
                          "(0: fully backlogged queue)")
    dyn.add_argument("--requests", type=int, default=32,
                     help="number of synthetic requests to serve")
    dyn.add_argument("--max-request", type=int, default=None,
                     help="largest rows per ragged request (default: "
                          "min(4, max-batch))")
    dyn.add_argument("--adaptive-delay", action="store_true",
                     help="scale the coalescing delay with queue depth "
                          "(deep backlog drains immediately, an idle "
                          "queue waits up to --max-delay-ms)")
    rep = ap.add_argument_group(
        "multi-replica serving (process scale-out; enabled by --replicas)")
    rep.add_argument("--replicas", type=int, default=None,
                     help="spawn this many worker processes, each with "
                          "its own mesh + plan ladder, behind a "
                          "least-loaded router (reuses the dynamic-"
                          "batching knobs per worker)")
    rep.add_argument("--dead-after-ms", type=float, default=5000.0,
                     help="heartbeat deadline: a worker silent this "
                          "long is declared dead and its in-flight "
                          "requests re-queued to survivors")
    rep.add_argument("--kill-worker", type=int, default=None,
                     help="crash-inject: kill this worker id once it "
                          "has work in flight (recovery demo — the run "
                          "must still serve every request exactly once)")
    rep.add_argument("--worker-devices", type=int, default=None,
                     help="force this many XLA host devices in each "
                          "worker (workers own their meshes; parent "
                          "device count does not apply)")
    flt = ap.add_argument_group(
        "fleet serving (multi-model; enabled by --fleet)")
    flt.add_argument("--fleet", default=None,
                     help="comma list of models to serve together on one "
                          "shared mesh under mixed Poisson traffic — conv "
                          "nets (cnn8,inception,densenet40) and transformer "
                          "lowerings (stablelm_smoke,whisper_smoke) mix "
                          "freely; reuses the dynamic-batching knobs per "
                          "model")
    flt.add_argument("--seq", type=int, default=16,
                     help="sequence length (tokens per request row) for "
                          "transformer fleet members")
    flt.add_argument("--slo-ms", type=float, default=None,
                     help="per-request queue-delay SLO target for "
                          "attainment reporting (fleet mode)")
    flt.add_argument("--no-share-constants", action="store_true",
                     help="materialize shifted-weight constants per "
                          "tier instead of once per network")
    args = ap.parse_args(argv)

    if args.cache_dir is not None:
        memo.set_disk_cache(args.cache_dir, max_bytes=args.cache_max_bytes)

    if args.fleet is not None:
        _main_fleet(args)
        return

    if args.replicas is not None:
        _main_replicas(args)
        return

    mapping, search_s = map_for_serving(
        args.net, ArrayConfig(args.ar, args.ac), args.alg,
        grid=args.grid, p_max=args.p_max)
    # snapshot at the measurement boundary: serving traffic (plan-cache
    # lookups, ladder compiles) must not leak into the search stats
    st = memo.snapshot()
    print(f"{args.net} [{args.alg}] grid={mapping.grid.r}x{mapping.grid.c} "
          f"total_cycles={mapping.total_cycles} search={search_s*1e3:.1f}ms "
          f"(table_builds={st['table_misses']} disk_hits={st['disk_hits']} "
          f"disk_writes={st['disk_writes']})")

    donate = False if args.no_donate else None
    if args.max_delay_ms is not None:
        from repro.exec import compile_counts
        max_batch = args.max_batch or args.batch
        max_request = args.max_request or min(4, max_batch)
        reqs = poisson_arrivals(args.requests, args.arrival_rate,
                                max_request, seed=args.seed)
        mesh = None if args.no_mesh else serving_mesh_for(mapping, max_batch)
        policy, tiers = args.policy, None
        lookahead = block = vmem_budget = None
        if args.autotune:
            from repro import tune
            res = tune.autotune(mapping, batch=max_batch,
                                ragged=tuple(r for _, r in reqs),
                                max_delay_ms=args.max_delay_ms,
                                seed=args.seed)
            print(f"autotune: {res.describe()}")
            cand = res.config.candidate
            if not args.no_mesh:
                mesh = meshlib.mesh_from_split(cand.mesh_split)
            policy, lookahead = cand.policy, cand.lookahead
            block, vmem_budget = cand.block, cand.vmem_budget
            tiers = tune.resolve_tiers(cand, max_batch, mesh)
        tag = meshlib.mesh_tag(mesh) if mesh is not None else "vmap"
        s = serve_dynamic(mapping, reqs, max_batch=max_batch,
                          max_delay_ms=args.max_delay_ms, mesh=mesh,
                          tiers=tiers, policy=policy, warmup=args.warmup,
                          seed=args.seed, donate=donate,
                          adaptive_delay=args.adaptive_delay,
                          lookahead=lookahead, block=block,
                          vmem_budget=vmem_budget)
        compiles = sum(compile_counts(net=mapping).values())
        _print_dynamic(args.net, s, tag=tag, max_batch=max_batch,
                       max_delay_ms=args.max_delay_ms, compiles=compiles,
                       st=st)
        return

    mesh = None if args.no_mesh else serving_mesh_for(mapping, args.batch)
    policy = args.policy
    lookahead = block = vmem_budget = None
    if args.autotune:
        from repro import tune
        res = tune.autotune(mapping, batch=args.batch, seed=args.seed)
        print(f"autotune: {res.describe()}")
        cand = res.config.candidate
        if not args.no_mesh:
            mesh = meshlib.mesh_from_split(cand.mesh_split)
        policy, lookahead = cand.policy, cand.lookahead
        block, vmem_budget = cand.block, cand.vmem_budget
    tag = meshlib.mesh_tag(mesh) if mesh is not None else "vmap"
    s = serve(mapping, args.batch, args.steps, warmup=args.warmup,
              mesh=mesh, seed=args.seed, policy=policy, donate=donate,
              lookahead=lookahead, block=block, vmem_budget=vmem_budget)
    print(s.plan.describe())
    pad_note = (f" ({s.padded_images_per_s:.1f} padded images/s at "
                f"plan batch {s.plan_batch})"
                if s.plan_batch != s.request_batch else "")
    pol_tag = args.policy if isinstance(policy, str) else \
        "tuned:" + "/".join(sorted(set(policy)))
    print(f"mesh={tag} batch={args.batch}: {s.images_per_s:.1f} images/s"
          f"{pad_note} ({s.s_per_batch*1e3:.1f} ms/batch, "
          f"executor={pol_tag}, warmup_steps={s.warmup_steps}, "
          f"donated={s.donated})")
    print(f"serve/{args.net}/b{args.batch},{s.s_per_batch*1e6:.1f},"
          f"images_per_s={s.images_per_s:.1f};"
          f"padded_images_per_s={s.padded_images_per_s:.1f};"
          f"plan_batch={s.plan_batch};"
          f"dispatches={s.plan.host_dispatches};mesh={tag};"
          f"search_ms={search_s*1e3:.1f};table_builds={st['table_misses']};"
          f"disk_hits={st['disk_hits']}")


if __name__ == "__main__":
    main()
