"""Batched CNN serving driver: compiled-plan throughput (images/s).

The CNN counterpart of ``launch/serve.py`` (which serves the transformer
scaffold): map a benchmark conv stack once — reusing a persistent
on-disk mapping cache so a cold replica skips the window search entirely
— compile the mapping into ONE :class:`repro.exec.NetworkPlan` (executor
choice, schedule, glue, and mesh fitting all fixed at compile time;
DESIGN.md §8), then drive steady-state batched forward passes through
``execute_plan`` — a single jitted program per forward, never re-fitting
the mesh per request — and report images/s.  With multiple devices the
batch shards over the "data" axis of the serving mesh while (row, col)
carry the macro grid (``launch.mesh.make_serving_mesh``; DESIGN.md §7).

Ragged request batches are **padded and masked** to the plan's batch
(the next multiple of the "data" axis, ``mesh.pad_to_data_axis``)
instead of silently falling back to the single-device vmap path; the
driver reports effective (request) next to padded images/s.

    python -m repro.launch.serve_cnn --net cnn8 --batch 8 --steps 20 \
        --p-max 4 --cache-dir /tmp/mapping-cache

Prints one ``serve/...`` CSV row per the benchmark harness contract plus
a human-readable summary (search time, cache stats, mesh, plan,
images/s).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

from repro.core import (ArrayConfig, MacroGrid, grid_search, map_net, memo,
                        networks)
from repro.launch import mesh as meshlib


def _parse_grid(text: str) -> MacroGrid:
    r, c = text.lower().split("x")
    return MacroGrid(int(r), int(c))


def map_for_serving(net: str, array: ArrayConfig, algorithm: str,
                    grid: MacroGrid = None, p_max: int = None,
                    groups=(1, 2, 4)):
    """Map ``net`` for serving (fixed grid or Alg 2 budget sweep) and
    return ``(mapping, search_seconds)``.  With a warm disk cache
    (``memo.set_disk_cache`` / ``REPRO_MAPPING_CACHE``) a cold process
    performs zero search-table builds — asserted in tests/test_serve_cnn.
    """
    layers = networks.NETWORKS[net]()
    kw = {"groups": groups} if algorithm == "TetrisG-SDK" else {}
    t0 = time.perf_counter()
    if p_max is not None:
        mapping = grid_search(net, layers, array, p_max, algorithm,
                              **kw).best
    else:
        mapping = map_net(net, layers, array, algorithm,
                          grid or MacroGrid(), **kw)
    return mapping, time.perf_counter() - t0


def serving_mesh_for(net_mapping, batch: int):
    """Largest mesh every layer of the mapping can shard onto — thin
    wrapper over :func:`repro.launch.mesh.serving_mesh_for`."""
    return meshlib.serving_mesh_for(net_mapping, batch)


@dataclass
class ServeStats:
    """One steady-state measurement: effective rate counts the images
    the caller asked for; padded counts what the plan executed."""

    images_per_s: float         # request images / batch time (effective)
    padded_images_per_s: float  # plan-batch images / batch time
    s_per_batch: float
    request_batch: int
    plan_batch: int
    plan: object                # the NetworkPlan served from


def serve(net_mapping, batch: int, steps: int, warmup: int = 2,
          mesh=None, seed: int = 0, policy: str = "mapped") -> ServeStats:
    """Steady-state batched forward passes through a compiled plan.

    ``batch`` is the *request* batch; when it does not divide the mesh's
    "data" axis the inputs are zero-padded to the plan batch and the
    padded rows masked off the output (pad-and-mask) — the mesh is never
    silently abandoned for the vmap path."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.cnn.mapped_net import zero_pruned_kernels
    from repro.exec import compile_plan, execute_plan

    plan_batch = meshlib.pad_to_data_axis(batch, mesh)
    plan = compile_plan(net_mapping, executor_policy=policy, mesh=mesh,
                        batch=plan_batch)

    rng = np.random.RandomState(seed)
    ks = zero_pruned_kernels(net_mapping, [
        jnp.asarray(rng.randn(m.layer.k_h, m.layer.k_w,
                              m.layer.ic // m.group, m.layer.oc) * 0.1,
                    jnp.float32) for m in net_mapping.layers])
    first = net_mapping.layers[0].layer
    x = jnp.asarray(rng.randn(batch, first.ic, first.i_h, first.i_w),
                    jnp.float32)
    if plan_batch != batch:         # ragged: pad to the plan's batch ...
        x = jnp.pad(x, ((0, plan_batch - batch),) + ((0, 0),) * 3)

    def step():
        y = execute_plan(plan, ks, x, mesh=mesh)
        return jax.block_until_ready(y[:batch])   # ... mask padded rows

    for _ in range(max(1, warmup)):          # compile + steady the caches
        step()
    t0 = time.perf_counter()
    for _ in range(steps):
        step()
    dt = (time.perf_counter() - t0) / steps
    return ServeStats(images_per_s=batch / dt,
                      padded_images_per_s=plan_batch / dt,
                      s_per_batch=dt, request_batch=batch,
                      plan_batch=plan_batch, plan=plan)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="cnn8", choices=sorted(networks.NETWORKS))
    ap.add_argument("--alg", default="TetrisG-SDK")
    ap.add_argument("--ar", type=int, default=512)
    ap.add_argument("--ac", type=int, default=512)
    ap.add_argument("--grid", type=_parse_grid, default=None,
                    help="fixed macro grid RxC (default: 1x1)")
    ap.add_argument("--p-max", type=int, default=None,
                    help="Alg 2 macro-budget sweep instead of --grid")
    ap.add_argument("--batch", type=int, default=8,
                    help="request batch (padded-and-masked to the plan "
                         "batch when the mesh data axis does not divide)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--policy", default="mapped",
                    choices=("mapped", "reference", "sdk", "auto"),
                    help="plan executor policy (per-layer for 'auto')")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent mapping/plan cache directory "
                         "(default: $REPRO_MAPPING_CACHE)")
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="mtime-LRU size cap for --cache-dir")
    ap.add_argument("--no-mesh", action="store_true",
                    help="force the single-device vmap path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.cache_dir is not None:
        memo.set_disk_cache(args.cache_dir, max_bytes=args.cache_max_bytes)

    mapping, search_s = map_for_serving(
        args.net, ArrayConfig(args.ar, args.ac), args.alg,
        grid=args.grid, p_max=args.p_max)
    st = memo.stats
    print(f"{args.net} [{args.alg}] grid={mapping.grid.r}x{mapping.grid.c} "
          f"total_cycles={mapping.total_cycles} search={search_s*1e3:.1f}ms "
          f"(table_builds={st['table_misses']} disk_hits={st['disk_hits']} "
          f"disk_writes={st['disk_writes']})")

    mesh = None if args.no_mesh else serving_mesh_for(mapping, args.batch)
    tag = meshlib.mesh_tag(mesh) if mesh is not None else "vmap"
    s = serve(mapping, args.batch, args.steps, warmup=args.warmup,
              mesh=mesh, seed=args.seed, policy=args.policy)
    print(s.plan.describe())
    pad_note = (f" ({s.padded_images_per_s:.1f} padded images/s at "
                f"plan batch {s.plan_batch})"
                if s.plan_batch != s.request_batch else "")
    print(f"mesh={tag} batch={args.batch}: {s.images_per_s:.1f} images/s"
          f"{pad_note} ({s.s_per_batch*1e3:.1f} ms/batch, "
          f"executor={args.policy})")
    print(f"serve/{args.net}/b{args.batch},{s.s_per_batch*1e6:.1f},"
          f"images_per_s={s.images_per_s:.1f};"
          f"padded_images_per_s={s.padded_images_per_s:.1f};"
          f"plan_batch={s.plan_batch};"
          f"dispatches={s.plan.host_dispatches};mesh={tag};"
          f"search_ms={search_s*1e3:.1f};table_builds={st['table_misses']}")


if __name__ == "__main__":
    main()
