import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices; record memory/cost/collective analysis.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) —
the XLA_FLAGS line above executes before any other jax import, because
jax locks the device count at first init.

Results are cached as JSON under results/dryrun/ keyed by
(arch, shape, mesh); the sweep is restartable (skips cached cells).

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
        --mesh multi
    python -m repro.launch.dryrun --sweep            # everything missing
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, canon, get_config
from repro.launch import roofline as rl
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_cell, cell_supported
from repro.models import transformer as T

RESULTS = Path(__file__).resolve().parents[3] / "results"


def cell_path(arch: str, shape: str, mesh_name: str,
              tag: str = "dryrun") -> Path:
    return RESULTS / tag / f"{canon(arch)}__{shape}__{mesh_name}.json"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, optimized: bool = True,
             tag: str = "dryrun") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out_path = cell_path(arch, shape_name, mesh_name, tag)
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "?", "ts": time.time()}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        record.update(status="SKIP", reason=reason)
        _write(out_path, record)
        return record

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh,
                                             optimized=optimized)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
        # loop-aware per-chip totals (cost_analysis counts while bodies
        # once — see hlo_analysis.py; raw cost kept below for reference)
        totals = analyze_hlo(hlo)
        coll = {k: v for k, v in totals.coll_bytes.items()}

        n_params = T.count_params(cfg)
        n_active = T.count_params(cfg, active_only=True)
        chips = mesh.devices.size
        terms = rl.RooflineTerms(
            flops_per_chip=totals.flops,
            bytes_per_chip=totals.hbm_bytes,
            coll_bytes_per_chip=float(coll.get("total", 0.0)),
            chips=chips,
            model_flops_total=rl.model_flops(cfg, shape, n_params,
                                             n_active))
        record.update(
            status="OK",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            chips=chips,
            n_params=n_params, n_active_params=n_active,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            cost={k: cost[k] for k in ("flops", "bytes accessed")
                  if k in cost},
            collectives=coll,
            roofline={
                "t_compute": terms.t_compute,
                "t_memory": terms.t_memory,
                "t_collective": terms.t_coll,
                "dominant": terms.dominant,
                "model_flops": terms.model_flops_total,
                "useful_flops_fraction": terms.useful_flops_fraction,
                "roofline_fraction": terms.roofline_fraction,
            },
        )
    except Exception as e:   # record failures — they are bugs to fix
        record.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(out_path, record)
    return record


def _write(path: Path, record: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(record, indent=1, default=str))
    tmp.rename(path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="disable beyond-paper optimizations (SPerf)")
    ap.add_argument("--tag", default=None,
                    help="results subdir (default dryrun_opt/dryrun_base)")
    args = ap.parse_args()
    tag = args.tag or ("dryrun_base" if args.baseline else "dryrun_opt")

    archs = ARCH_IDS if (args.sweep or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.sweep or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                r = run_cell(arch, shape, mp, force=args.force,
                             optimized=not args.baseline, tag=tag)
                dom = r.get("roofline", {}).get("dominant", "-")
                print(f"{arch:22s} {shape:12s} {r['mesh']:8s} "
                      f"{r['status']:4s} dom={dom:10s} "
                      f"({time.time() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
