"""Multi-model fleet serving: tagged request streams over shared plans.

PR 5's serving stack dedicates the whole device fleet to ONE network —
one coalescer, one plan ladder.  A production CIM box serves many
models at once, so this module generalizes `launch/batching.py` into a
fleet tier: a :class:`FleetScheduler` routes a *tagged* request stream
(model name on every `batching.Request`) across several compiled
`NetworkPlan` ladders sharing one serving mesh —

* **per-model queues** — each model owns a max-delay
  :class:`batching.Coalescer` and a :class:`batching.PlanLadder`; the
  single-model latency contract (FIFO, never split, max-delay bound) is
  preserved per model.
* **cross-model drain policy** — weighted-fair by queued rows with a
  deadline override: a model whose oldest request has *expired* (now ≥
  arrival + max_delay) drains first, nearest deadline breaking ties;
  otherwise the model with the largest ``queued_rows x weight`` drains
  (keeping the arrays full), ties resolved by config order.
* **plan-constant sharing** — co-resident ladders of the same network
  reuse one prepared shifted-weight handle across all tiers
  (`exec.constants.prepare_constants` through ``memo.cached_constants``)
  instead of materializing the blocks once per tier.

Determinism invariant (regression-tested in tests/test_fleet.py):
the scheduler core — routing, fairness, deadline override, tier
selection — is pure Python over explicit ``now`` timestamps.  Given the
same :class:`FleetConfig` (or any pickle round-trip of it), the same
arrival trace, and the same clock/sleep pair, :func:`run_fleet` emits a
bit-identical :class:`LaunchRecord` sequence on every run: no wall
clock, no randomness, no dict-iteration order — every tie-break
resolves by the config's model order, and all state lives in per-model
FIFOs.  Device execution happens strictly *after* each decision and
feeds back only through the injected clock.

    python -m repro.launch.serve_cnn --fleet cnn8,inception,densenet40 \
        --max-delay-ms 2 --arrival-rate 500 --requests 96
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from . import batching
from . import mesh as meshlib


# ---------------------------------------------------------------------------
# Configuration — frozen, hashable, picklable (the determinism test
# round-trips it through pickle)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSpec:
    """Per-model serving contract: queueing (``max_batch`` /
    ``max_delay_s`` feed the model's coalescer), fairness ``weight``
    (drain priority scales with queued rows x weight), and the
    reporting SLO ``slo_ms`` (a queue-delay target; attainment = the
    fraction of requests launched within it — None reports 1.0)."""

    name: str
    max_batch: int
    max_delay_s: float
    weight: float = 1.0
    slo_ms: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("model name must be non-empty")
        if self.max_batch < 1:
            raise ValueError(
                f"{self.name}: max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_s < 0:
            raise ValueError(f"{self.name}: max_delay_s must be >= 0, "
                             f"got {self.max_delay_s}")
        if not self.weight > 0:
            raise ValueError(
                f"{self.name}: weight must be > 0, got {self.weight}")
        if self.slo_ms is not None and not self.slo_ms > 0:
            raise ValueError(
                f"{self.name}: slo_ms must be > 0, got {self.slo_ms}")


@dataclass(frozen=True)
class FleetConfig:
    """The fleet: an ordered tuple of :class:`ModelSpec`.  The ORDER is
    semantic — every scheduler tie-break (equal deadlines, equal
    weighted backlogs) resolves to the earliest model in it, which is
    what makes the drain sequence reproducible."""

    models: Tuple[ModelSpec, ...]

    def __post_init__(self):
        if not self.models:
            raise ValueError("fleet needs at least one model")
        names = [m.name for m in self.models]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in fleet: {names}")

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(m.name for m in self.models)

    def spec(self, name: str) -> ModelSpec:
        for m in self.models:
            if m.name == name:
                return m
        raise KeyError(f"model {name!r} not in fleet {self.names}")


# ---------------------------------------------------------------------------
# Scheduler core — pure Python, explicit `now`, fake-clock testable
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Launch:
    """One drain decision: ``requests`` (a FIFO prefix of one model's
    queue, whole requests, arrival order) to serve on ``tier``."""

    model: str
    tier: int
    requests: Tuple[batching.Request, ...]

    @property
    def rows(self) -> int:
        return sum(r.rows for r in self.requests)


@dataclass(frozen=True)
class LaunchRecord:
    """The comparable trace row of one launch — what the determinism
    regression asserts bit-identical across runs: when, which model,
    which tier, and exactly which requests (rows + arrival stamps, in
    served order)."""

    launch_s: float
    model: str
    tier: int
    rows: Tuple[int, ...]
    arrivals_s: Tuple[float, ...]

    @staticmethod
    def of(launch: "Launch", launch_s: float) -> "LaunchRecord":
        return LaunchRecord(
            launch_s=launch_s, model=launch.model, tier=launch.tier,
            rows=tuple(r.rows for r in launch.requests),
            arrivals_s=tuple(r.arrival_s for r in launch.requests))


class FleetScheduler:
    """Route a tagged request stream across per-model coalescers.

    All methods take ``now`` explicitly (the caller owns the clock);
    nothing here touches devices, wall time, or randomness — see the
    module docstring's determinism invariant.  ``tiers`` maps each
    model to its plan-batch ladder (default:
    ``batching.batch_tiers(spec.max_batch, mesh)``), so :meth:`pop`
    can stamp every launch with the tier it will pad to.
    """

    def __init__(self, config: FleetConfig, *, mesh=None,
                 tiers: Optional[Mapping[str, Sequence[int]]] = None):
        self.config = config
        self.tiers: Dict[str, Tuple[int, ...]] = {}
        self._co: Dict[str, batching.Coalescer] = {}
        for spec in config.models:
            self._co[spec.name] = batching.Coalescer(
                spec.max_batch, spec.max_delay_s)
            t = batching.batch_tiers(spec.max_batch, mesh) \
                if tiers is None or spec.name not in tiers \
                else tuple(sorted(set(int(x) for x in tiers[spec.name])))
            if t[-1] < spec.max_batch:
                raise ValueError(
                    f"{spec.name}: tiers {t} do not cover max_batch="
                    f"{spec.max_batch}")
            self.tiers[spec.name] = t

    def __len__(self) -> int:
        """Total queued images across all models."""
        return sum(len(c) for c in self._co.values())

    def queued_rows(self, model: str) -> int:
        return len(self._co[model])

    def push(self, model: str, rows: int, now: float,
             payload: object = None) -> None:
        if model not in self._co:
            raise KeyError(
                f"model {model!r} not in fleet {self.config.names}")
        self._co[model].push(rows, now, payload, model)

    def next_deadline(self) -> Optional[float]:
        """Earliest max-delay expiry across the fleet (None when every
        queue is empty) — the latest moment the server may sleep to."""
        ds = [d for d in (c.next_deadline() for c in self._co.values())
              if d is not None]
        return min(ds) if ds else None

    def ready(self, now: float) -> bool:
        return any(c.ready(now) for c in self._co.values())

    def pop(self, now: float, force: bool = False) -> Optional[Launch]:
        """Drain ONE model per the cross-model policy, or None when no
        model is ready (callers loop until None to drain everything due
        at ``now``).

        Policy, in order (all ties resolve by config order):

        1. **deadline override** — among models whose oldest request has
           expired (``now >= arrival + max_delay``), the nearest (i.e.
           most overdue) deadline drains first: the max-delay latency
           bound outranks fill.
        2. **forced flush** (``force=True``, no future arrival can grow
           any batch) — drain in deadline order, oldest obligation
           first.
        3. **weighted fair** — the model with the largest
           ``queued_rows x weight`` drains: among models that are ready
           anyway, prefer the fullest batch (array fill is throughput).
        """
        order = {m.name: i for i, m in enumerate(self.config.models)}
        cand = [m.name for m in self.config.models
                if len(self._co[m.name])
                and (force or self._co[m.name].ready(now))]
        if not cand:
            return None
        expired = [n for n in cand
                   if now >= self._co[n].next_deadline()]
        if expired:
            name = min(expired, key=lambda n: (self._co[n].next_deadline(),
                                               order[n]))
        elif force:
            name = min(cand, key=lambda n: (self._co[n].next_deadline(),
                                            order[n]))
        else:
            name = max(cand, key=lambda n: (
                len(self._co[n]) * self.config.spec(n).weight, -order[n]))
        batch = self._co[name].pop(now, force=force)
        if not batch:               # not reachable for a ready/forced
            return None             # candidate; kept as a guard
        rows = sum(r.rows for r in batch)
        return Launch(model=name,
                      tier=batching.tier_for(rows, self.tiers[name]),
                      requests=tuple(batch))


TraceEvent = Tuple[float, str, int]     # (arrival_s, model, rows)


def run_fleet(sched: FleetScheduler, trace: Sequence[TraceEvent], *,
              clock: Callable[[], float] = time.perf_counter,
              sleep: Callable[[float], None] = time.sleep,
              execute: Optional[Callable[[Launch, float], None]] = None,
              ) -> List[LaunchRecord]:
    """Replay a tagged arrival trace through the scheduler.

    The loop shape of `serve_cnn.serve_dynamic`, fleet-wide: push each
    arrival as its time comes, drain one launch per pass (``execute``
    runs the device forward and feeds back only through ``clock``),
    sleep to the earliest of next-arrival / earliest-deadline when
    nothing is ready, and force-drain once no future arrival remains.
    Returns the full launch schedule — the determinism regression's
    comparison object."""
    for t, model, rows in trace:
        spec = sched.config.spec(model)     # KeyError -> unknown model
        if rows > spec.max_batch:           # fail before serving
            raise ValueError(
                f"request of {rows} rows exceeds {model}'s max_batch="
                f"{spec.max_batch} — requests are never split")
        if rows < 1:
            raise ValueError(f"request must carry >= 1 row, got {rows}")
        del t
    # stable sort on TIME ONLY (see serve_dynamic): ordering tied
    # timestamps by payload would reorder the FIFO each model expects
    pending = deque(sorted(trace, key=lambda e: e[0]))
    records: List[LaunchRecord] = []
    t0 = clock()
    while pending or len(sched):
        now = clock() - t0
        while pending and pending[0][0] <= now:
            arrival, model, rows = pending.popleft()
            # delay is measured from the SCHEDULED arrival time
            sched.push(model, rows, arrival)
        launch = sched.pop(now, force=not pending)
        if launch is None:
            deadline = sched.next_deadline()
            horizon = min(
                pending[0][0] if pending else float("inf"),
                deadline if deadline is not None else float("inf"))
            if horizon > now:
                sleep(horizon - now)
            continue
        launch_s = clock() - t0
        if execute is not None:
            execute(launch, launch_s)
        records.append(LaunchRecord.of(launch, launch_s))
    return records


# ---------------------------------------------------------------------------
# Synthetic mixed traffic + fleet mesh
# ---------------------------------------------------------------------------


def mixed_poisson_trace(models: Sequence[str], n: int, rate_per_s: float,
                        max_rows: Union[int, Mapping[str, int]],
                        seed: int = 0,
                        weights: Optional[Sequence[float]] = None,
                        ) -> Tuple[TraceEvent, ...]:
    """A tagged Poisson arrival schedule: ``n`` requests with
    exponential inter-arrival gaps at ``rate_per_s`` (0 → fully
    backlogged, everything at t=0), each tagged with a model drawn from
    ``models`` (uniform, or per ``weights``) and a uniform ragged size
    in ``[1, max_rows[model]]`` (``max_rows`` may be one int for
    all)."""
    import numpy as np
    if n < 1:
        raise ValueError(f"need >= 1 request, got {n}")
    models = list(models)
    if not models:
        raise ValueError("need >= 1 model")
    caps = {m: (max_rows if isinstance(max_rows, int)
                else int(max_rows[m])) for m in models}
    for m, cap in caps.items():
        if cap < 1:
            raise ValueError(f"{m}: max_rows must be >= 1, got {cap}")
    if weights is not None:
        if len(weights) != len(models):
            raise ValueError(f"{len(weights)} weights for "
                             f"{len(models)} models")
        p = np.asarray(weights, dtype=float)
        p = p / p.sum()
    else:
        p = None
    rng = np.random.RandomState(seed)
    if rate_per_s > 0:
        gaps = rng.exponential(1.0 / rate_per_s, size=n)
        times = np.cumsum(gaps) - gaps[0]       # first request at t=0
    else:
        times = np.zeros(n)
    picks = rng.choice(len(models), size=n, p=p)
    out = []
    for t, mi in zip(times, picks):
        m = models[int(mi)]
        out.append((float(t), m, int(rng.randint(1, caps[m] + 1))))
    return tuple(out)


def chainable_prefix(net_mapping):
    """Longest chainable PREFIX of a network mapping, as a mapping.

    Some bench networks are representative layer *sets*, not chains
    (inception's two disjoint blocks) — `exec.compile_plan` refuses to
    chain them.  Fleet serving drives whole-forward plans, so such a
    net serves as its longest chainable prefix; the glue arithmetic is
    the same pure channel check `exec.glue.resolve_chain` applies at
    compile time (next ic == oc, or == ic + oc for concat).  Returns
    the mapping unchanged when it already chains end to end; callers
    report the slice as ``ModelStats.dropped_layers``
    (`serve_cnn._main_fleet`, benchmarks/fleet_bench).

    Mappings carrying EXPLICIT glue (transformer lowerings) return
    unchanged: their chaining — residual save/pop stacks, attention
    channel folds — is validated by ``compile_plan`` against the glue
    itself, and the pure oc/ic arithmetic below would mis-slice them
    (a fused qkv's oc never equals the o projection's ic).
    """
    import dataclasses
    if getattr(net_mapping, "glue", None) is not None:
        return net_mapping
    layers = [m.layer for m in net_mapping.layers]
    n = 1
    for a, b in zip(layers, layers[1:]):
        if b.ic not in (a.oc, a.ic + a.oc):
            break
        n += 1
    if n == len(layers):
        return net_mapping
    return dataclasses.replace(net_mapping,
                               layers=net_mapping.layers[:n])


def fleet_mesh_for(mappings: Mapping[str, object], max_batch: int,
                   devices=None):
    """Largest serving mesh EVERY network in the fleet can shard onto:
    the gcd of the per-network macro sub-grids (`mesh.net_macro_grid`),
    leftover devices stacked along "data" — one shared mesh, so every
    model's ladder plans against the same device split."""
    import math
    gr = gc = 0
    for nm in mappings.values():
        r, c = meshlib.net_macro_grid(nm)
        gr, gc = math.gcd(gr, r), math.gcd(gc, c)
    return meshlib.make_serving_mesh(max(gr, 1), max(gc, 1), max_batch,
                                     devices=devices)


# ---------------------------------------------------------------------------
# Stats + device-serving driver
# ---------------------------------------------------------------------------


@dataclass
class ModelStats:
    """One model's slice of a fleet run: per-tier effective vs padded
    accounting plus SLO attainment against the model's queue-delay
    target.

    ``tokens_per_row`` is set for transformer models (the lowered
    sequence length, `launch.transformer.tokens_per_row`) so tokens/s
    reports next to images/s; ``dropped_layers`` surfaces how many
    trailing layers `chainable_prefix` cut from the served mapping
    (0 for an end-to-end chain) — a stats/CSV field, not just a CLI
    print."""

    name: str
    slo_ms: Optional[float]
    tiers: Dict[int, batching.TierStats] = field(default_factory=dict)
    tokens_per_row: Optional[int] = None
    dropped_layers: int = 0

    def record(self, launch: Launch, launch_s: float,
               exec_s: float = 0.0) -> None:
        ts = self.tiers.get(launch.tier)
        if ts is None:
            ts = self.tiers[launch.tier] = batching.TierStats(
                plan_batch=launch.tier)
        ts.record(launch.requests, launch_s, exec_s=exec_s)

    @property
    def request_images(self) -> int:
        return sum(t.request_images for t in self.tiers.values())

    @property
    def request_tokens(self) -> Optional[int]:
        """Tokens served (rows x lowered seq) — None for conv models."""
        if self.tokens_per_row is None:
            return None
        return self.request_images * self.tokens_per_row

    @property
    def padded_images(self) -> int:
        return sum(t.padded_images for t in self.tiers.values())

    @property
    def batches(self) -> int:
        return sum(t.batches for t in self.tiers.values())

    @property
    def delays_s(self) -> List[float]:
        return [d for t in self.tiers.values() for d in t.delays_s]

    @property
    def slo_attainment(self) -> float:
        """Fraction of requests launched within ``slo_ms`` of arrival
        (1.0 with no SLO set, or before anything was served)."""
        ds = self.delays_s
        if self.slo_ms is None or not ds:
            return 1.0
        bound = self.slo_ms / 1e3
        return sum(1 for d in ds if d <= bound) / len(ds)


@dataclass
class FleetStats:
    """One mixed-traffic fleet run: per-model breakdown plus aggregate
    effective / padded rates over the shared wall time."""

    models: Dict[str, ModelStats]
    wall_s: float
    warmup_steps: int
    shared_constants: bool

    @property
    def request_images(self) -> int:
        return sum(m.request_images for m in self.models.values())

    @property
    def padded_images(self) -> int:
        return sum(m.padded_images for m in self.models.values())

    @property
    def images_per_s(self) -> float:
        return self.request_images / max(self.wall_s, 1e-12)

    @property
    def padded_images_per_s(self) -> float:
        return self.padded_images / max(self.wall_s, 1e-12)

    @property
    def delays_s(self) -> List[float]:
        return [d for m in self.models.values() for d in m.delays_s]

    def delay_ms(self, q: float) -> float:
        """Fleet-wide queue-delay percentile over the POOLED per-model
        samples.  Never computed by averaging per-model percentiles —
        that is not a percentile of anything (a model serving 90% of
        the traffic must dominate the fleet tail, not count as one
        vote); the pooled nearest-rank value matches
        ``numpy.percentile(pooled, q, method="inverted_cdf")``."""
        return batching.percentile(self.delays_s, q) * 1e3

    @property
    def slo_attainment(self) -> float:
        """Request-weighted attainment across models with an SLO set
        (1.0 when none is)."""
        num = den = 0
        for m in self.models.values():
            if m.slo_ms is None:
                continue
            ds = m.delays_s
            den += len(ds)
            num += sum(1 for d in ds if d <= m.slo_ms / 1e3)
        return num / den if den else 1.0

    def describe(self) -> str:
        lines = [f"fleet: {self.request_images} request images "
                 f"({self.padded_images} padded) in {self.wall_s*1e3:.1f}ms"
                 f" = {self.images_per_s:.1f} images/s "
                 f"({self.padded_images_per_s:.1f} padded), "
                 f"slo_attainment={self.slo_attainment:.3f}, "
                 f"warmup_steps={self.warmup_steps}, "
                 f"shared_constants={self.shared_constants}"]
        if self.delays_s:
            lines.append(
                f"  all models pooled: queue-delay "
                f"p50={self.delay_ms(50):.2f}ms "
                f"p95={self.delay_ms(95):.2f}ms "
                f"p99={self.delay_ms(99):.2f}ms")
        for name, m in self.models.items():
            if not m.batches:
                continue
            ds = m.delays_s
            toks = ""
            if m.tokens_per_row is not None:
                tps = m.request_tokens / max(self.wall_s, 1e-12)
                toks = (f"{m.request_tokens} tokens "
                        f"({tps:.1f} tokens/s), ")
            dropped = (f"dropped_layers={m.dropped_layers}, "
                       if m.dropped_layers else "")
            lines.append(
                f"  {name}: {m.batches} batches, "
                f"{m.request_images}/{m.padded_images} images, {toks}"
                f"{dropped}"
                f"queue-delay p50={batching.percentile(ds, 50)*1e3:.2f}ms "
                f"p95={batching.percentile(ds, 95)*1e3:.2f}ms, "
                f"slo_attainment={m.slo_attainment:.3f}")
        return "\n".join(lines)


def serve_fleet(mappings: Mapping[str, object], config: FleetConfig,
                trace: Sequence[TraceEvent], *, mesh=None,
                policy="mapped", warmup: int = 1, seed: int = 0,
                donate: Optional[bool] = None,
                share_constants: bool = True,
                lookahead: Optional[int] = None,
                block: Optional[str] = None,
                vmem_budget: Optional[int] = None,
                dropped_layers: Optional[Mapping[str, int]] = None,
                clock: Callable[[], float] = time.perf_counter,
                sleep: Callable[[float], None] = time.sleep,
                ) -> Tuple[FleetStats, List[LaunchRecord]]:
    """Serve a tagged trace across the fleet's plan ladders on ONE
    shared mesh.

    ``mappings`` maps each config model name to its `NetworkMapping` —
    conv nets and transformer lowerings
    (`launch.transformer.transformer_mapping`) mix freely; transformer
    models additionally report tokens/s (their `ModelStats` carry
    ``tokens_per_row``).  ``dropped_layers`` records, per model, how
    many layers `chainable_prefix` cut before serving (surfaced in the
    stats rather than only printed).  Per model: a
    `batching.PlanLadder` (every tier compiled against the shared
    ``mesh``) plus — with ``share_constants`` (default) — one
    `exec.constants.PlanConstants` handle feeding every tier's program
    its pre-materialized shifted-weight blocks
    (`exec.constants.constant_counts` shows one materialization per
    network, not per tier).  ``warmup`` forwards per tier run before
    the clock starts; scheduling itself is :func:`run_fleet` on a
    :class:`FleetScheduler` (see the determinism invariant above)."""
    import jax
    import numpy as np
    from repro.exec import (donation_supported, execute_plan,
                            prepare_constants)
    from .serve_cnn import _serving_kernels
    from .transformer import tokens_per_row

    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    missing = [m.name for m in config.models if m.name not in mappings]
    if missing:
        raise KeyError(f"no mapping for fleet models {missing}")
    if donate is None:
        donate = donation_supported(mesh)

    sched = FleetScheduler(config, mesh=mesh)
    ladders: Dict[str, batching.PlanLadder] = {}
    kernels: Dict[str, list] = {}
    consts: Dict[str, object] = {}
    pools: Dict[str, np.ndarray] = {}
    shapes: Dict[str, tuple] = {}
    for spec in config.models:
        nm = mappings[spec.name]
        ladder = batching.PlanLadder(
            nm, sched.tiers[spec.name], mesh=mesh, policy=policy,
            lookahead=lookahead, block=block, vmem_budget=vmem_budget)
        ladders[spec.name] = ladder
        rng, ks = _serving_kernels(nm, seed)
        kernels[spec.name] = ks
        if share_constants:
            # keyed on (net mapping, executors, kernel token): every
            # tier of every co-resident ladder of this network fetches
            # the SAME handle out of memo.cached_constants
            consts[spec.name] = prepare_constants(
                ladder.plans[ladder.tiers[0]], ks,
                token=("serve_fleet", seed))
        first = nm.layers[0].layer
        shapes[spec.name] = (first.ic, first.i_h, first.i_w)
        pools[spec.name] = rng.randn(
            ladder.max_batch, *shapes[spec.name]).astype(np.float32)

    def run_tier(name: str, tier: int, x_np):
        y = execute_plan(ladders[name].plans[tier], kernels[name],
                         jax.device_put(x_np), mesh=mesh, donate=donate,
                         constants=consts.get(name))
        return jax.block_until_ready(y)

    warmup_steps = 0
    for _ in range(warmup):
        for spec in config.models:       # compile every tier up front
            for t in ladders[spec.name].tiers:
                run_tier(spec.name, t, pools[spec.name][:t])
                warmup_steps += 1

    stats = {m.name: ModelStats(
                 name=m.name, slo_ms=m.slo_ms,
                 tokens_per_row=tokens_per_row(mappings[m.name]),
                 dropped_layers=(dropped_layers or {}).get(m.name, 0))
             for m in config.models}
    t0 = clock()

    def execute(launch: Launch, launch_s: float) -> None:
        rows = launch.rows
        x_np = np.zeros((launch.tier,) + shapes[launch.model], np.float32)
        x_np[:rows] = pools[launch.model][:rows]   # padded rows stay zero
        t_ex = clock()
        run_tier(launch.model, launch.tier, x_np)
        stats[launch.model].record(launch, launch_s,
                                   exec_s=clock() - t_ex)

    records = run_fleet(sched, trace, clock=clock, sleep=sleep,
                        execute=execute)
    wall = clock() - t0
    return (FleetStats(models=stats, wall_s=wall,
                       warmup_steps=warmup_steps,
                       shared_constants=share_constants),
            records)
