"""Roofline term derivation from a compiled dry-run artifact.

Per (arch x shape x mesh) cell:

    t_compute = FLOPs_per_chip / 197e12          (bf16 peak, TPU v5e)
    t_memory  = bytes_per_chip / 819e9           (HBM bw)
    t_coll    = collective_bytes_per_chip / 50e9 (per-link ICI bw)

``compiled.cost_analysis()`` on an SPMD module reports *per-partition*
flops/bytes (verified empirically against a hand-counted matmul), which
is exactly the per-chip view the terms need.  Collective bytes are not in
cost_analysis: we parse the partitioned HLO and sum the OUTPUT buffer
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute — the per-chip received-bytes proxy (ring all-reduce
moves ~2x this; noted in EXPERIMENTS.md).

MODEL_FLOPS uses 6*N*D (train, dense), 6*N_active*D (MoE), 2*N*D
(prefill) and 2*N_active*B (decode, per step) with N from the analytic
param count.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-kind summed output bytes of collective ops (per-chip view)."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_txt)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    chips: int
    model_flops_total: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_coll(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Step-time lower bound (no overlap assumption: max of terms)."""
        return max(self.t_compute, self.t_memory, self.t_coll)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (total) — remat/redundancy waste."""
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        (useful flops / chips / peak) / bound."""
        if self.bound == 0:
            return 0.0
        t_useful = self.model_flops_total / self.chips / PEAK_FLOPS
        return t_useful / self.bound


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """Analytic 'useful' FLOPs for the cell (whole step)."""
    tokens = shape.batch * shape.seq
    if shape.mode == "train":
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.batch      # decode: one token / seq
