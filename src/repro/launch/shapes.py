"""The assigned input-shape grid and per-cell jit assembly.

Every (arch x shape) cell resolves to a concrete (step_fn, abstract args,
in/out shardings) triple via :func:`build_cell` — used identically by the
dry-run (lower+compile only) and by real drivers (with concrete arrays).

Shapes (per the brief):
    train_4k     seq 4096,   global_batch 256   -> train_step
    prefill_32k  seq 32768,  global_batch 32    -> prefill_step
    decode_32k   seq 32768,  global_batch 128   -> serve_step (1 token,
                 cache filled to seq)
    long_500k    seq 524288, global_batch 1     -> serve_step; requires a
                 sub-quadratic arch (cfg.sub_quadratic) — full-attention
                 archs are SKIPped (DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.attention import attention_policy
from repro.models.common import norm_policy
from repro.models.config import ArchConfig
from . import sharding as sh
from .steps import (TrainConfig, init_train_state, make_prefill_step,
                    make_serve_step, make_train_step)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    mode: str           # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: ShapeSpec
                   ) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 500k context — "
                       "skipped per brief; see DESIGN.md")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(abstract batch, shardings) for a train/prefill batch."""
    b, s = shape.batch, shape.seq
    extra = 1 if shape.mode == "train" else 0      # +1 token for labels
    batch: Dict[str, Any] = {}
    shards: Dict[str, Any] = {}
    bd = sh.batch_dim(mesh, b)
    if cfg.frontend == "vision":
        batch["tokens"] = _sds((b, s - cfg.n_prefix + extra), jnp.int32)
        batch["prefix_embeds"] = _sds((b, cfg.n_prefix, cfg.d_model),
                                      jnp.bfloat16)
        shards["tokens"] = NamedSharding(mesh, P(bd, None))
        shards["prefix_embeds"] = NamedSharding(mesh, P(bd, None, None))
    else:
        batch["tokens"] = _sds((b, s + extra), jnp.int32)
        shards["tokens"] = NamedSharding(mesh, P(bd, None))
    if cfg.kind == "encdec":
        batch["enc_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        shards["enc_embeds"] = NamedSharding(mesh, P(bd, None, None))
    return batch, shards


def default_microbatches(cfg: ArchConfig, shape: ShapeSpec, mesh) -> int:
    """Grad-accumulation count: keep ~<=128k tokens per microbatch and
    divide the batch evenly."""
    target = max(1, (shape.batch * shape.seq) // 131072)
    n = 1
    for cand in (1, 2, 4, 8, 16, 32):
        if shape.batch % cand == 0 and cand <= target:
            n = cand
    return n


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               microbatches: Optional[int] = None,
               train_cfg: Optional[TrainConfig] = None,
               optimized: bool = True):
    """-> (fn, args_abstract: tuple, in_shardings, out_shardings)."""
    params_shape = jax.eval_shape(partial(T.init_params, cfg),
                                  jax.random.PRNGKey(0))
    param_sh = sh.param_shardings(cfg, params_shape, mesh)
    rep = sh.replicated(mesh)
    bd_act = sh.batch_dim(mesh, shape.batch)
    act_sh = NamedSharding(mesh, P(bd_act, None, None))

    # context-parallel scores for archs whose head count doesn't divide
    # the model axis (SPerf: the head_dim-sharded fallback all-reduces
    # fp32 score tensors; q-row sharding removes that entirely)
    # Mode-aware optimization policy (SPerf — measured per mode):
    # * train: CP scores for head-indivisible archs, FSDP gather-at-use
    #   for MoE weights, inner-scan remat, bf16 score storage - 1.15-2.3x
    #   on the train cells.
    # * prefill: bf16 scores only (CP/weight-gather measured as
    #   regressions: 0.51x qwen prefill, 0.75x dsv2).
    # * decode: everything off (HBM-floor; weight-gather at batch<=128 is
    #   a 0.03-0.7x regression).
    is_train = optimized and shape.mode == "train"
    # fast_norm measured as a 0.90x regression on RG-LRU stacks (SPerf
    # iteration 14) — gated off for recurrent mixers
    has_rec = any(sp.mixer == "rec" for st in cfg.stages
                  for sp in st.unit)
    scores_sh = None
    cp_axis = None
    if is_train and cfg.n_heads and \
            cfg.n_heads % mesh.shape["model"] != 0:
        scores_sh = NamedSharding(mesh, P(bd_act, None, None, "model",
                                          None))
        cp_axis = (mesh, bd_act)

    def with_policy(fn):
        def wrapped(*a):
            with attention_policy(
                    scores_sharding=scores_sh, cp_axis=cp_axis,
                    scores_dtype=(jnp.bfloat16 if optimized
                                  and shape.mode != "decode" else None),
                    inner_remat=is_train,
                    mesh=mesh if is_train else None), \
                 norm_policy(fast=is_train and not has_rec):
                return fn(*a)
        return wrapped

    if shape.mode == "train":
        n_mb = microbatches or default_microbatches(cfg, shape, mesh)
        tc = train_cfg or TrainConfig(microbatches=n_mb)
        state_shape = jax.eval_shape(partial(init_train_state, cfg),
                                     jax.random.PRNGKey(0))
        state_sh = {"params": param_sh,
                    "opt": sh.opt_shardings(param_sh, mesh)}
        batch, batch_sh = batch_specs(cfg, shape, mesh)
        fn = with_policy(make_train_step(cfg, tc, act_sharding=act_sh))
        metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        return (fn, (state_shape, batch), (state_sh, batch_sh),
                (state_sh, metrics_sh))

    if shape.mode == "prefill":
        batch, batch_sh = batch_specs(cfg, shape, mesh)
        fn = with_policy(make_prefill_step(cfg, cache_len=shape.seq,
                                         act_sharding=act_sh))
        cache_shape = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.batch, shape.seq,
                                 enc_len=shape.seq))
        cache_sh = sh.cache_shardings(cfg, cache_shape, mesh)
        bd = sh.batch_dim(mesh, shape.batch)
        out_sh = (NamedSharding(mesh, P(bd)), cache_sh)
        return fn, (params_shape, batch), (param_sh, batch_sh), out_sh

    # decode
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq,
                             enc_len=min(shape.seq, 32768)))
    cache_sh = sh.cache_shardings(cfg, cache_shape, mesh)
    bd = sh.batch_dim(mesh, shape.batch)
    token = _sds((shape.batch, 1), jnp.int32)
    token_sh = NamedSharding(mesh, P(bd, None))
    pos = _sds((), jnp.int32)
    fn = with_policy(make_serve_step(cfg, act_sharding=act_sh))
    return (fn, (params_shape, cache_shape, token, pos),
            (param_sh, cache_sh, token_sh, rep),
            (token_sh, cache_sh))
