"""Sharding rules: param/optimizer/cache/batch PartitionSpecs per arch.

Policy (baseline — §Perf iterates on it):

* params: 2-D sharded — FSDP over the data axes x TP over 'model'.
  Attention projections shard heads over 'model' when divisible, else
  head_dim (e.g. qwen's 40 heads on a 16-way axis); MoE experts shard
  over 'model' when divisible (EP), else d_ff (TP fallback, mixtral 8e).
* optimizer state: same spec as its param (elementwise ops).
* batch: over the data axes ('pod' folds in); replicated when the batch
  doesn't divide (long_500k's batch=1).
* KV caches: batch over data axes, sequence over 'model'
  (flash-decoding-style SP — softmax/out reductions are the only
  cross-shard traffic); recurrent states shard their widest dim.

Specs derive from pytree *paths*: the block group name ('attn', 'mlp',
'moe', 'rec', 'ssd', 'cross') plus the leaf name are the contract, so the
same rules cover every arch.
"""
from __future__ import annotations

from typing import Optional, TYPE_CHECKING, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import data_axes

if TYPE_CHECKING:       # annotation-only: keep the LLM-arch stack out of
    from repro.models.config import ArchConfig   # CNN/mapped_net imports


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
    return tuple(names)


def _prod(mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_spec(names: Tuple[str, ...], shape: Tuple[int, ...], mesh,
               cfg: ArchConfig) -> P:
    dp = data_axes(mesh)
    name = names[-1]
    group = next((n for n in reversed(names[:-1])
                  if n in ("attn", "cross", "mlp", "moe", "rec", "ssd")),
                 None)
    stacked = "stages" in names or "enc_stages" in names
    off = 1 if stacked else 0
    lead = (None,) * off

    def mdl(i: int):
        return "model" if shape[i] % mesh.shape["model"] == 0 else None

    def fsdp(i: int):
        return dp if shape[i] % _prod(mesh, dp) == 0 else None

    # --- top level ---
    if name == "embed":
        return P(mdl(0), fsdp(1))
    if name == "head":
        return P(fsdp(0), mdl(1))

    # --- attention (incl. cross) ---
    if group in ("attn", "cross"):
        if name in ("wq", "wk", "wv"):          # (L, D, H, dh)
            if mdl(off + 1):
                return P(*lead, fsdp(off), "model", None)
            return P(*lead, fsdp(off), None, mdl(off + 2))
        if name in ("bq", "bk", "bv"):          # (L, H, dh)
            if mdl(off):
                return P(*lead, "model", None)
            return P(*lead, None, mdl(off + 1))
        if name == "wo":                        # (L, H, dh, D)
            if mdl(off):
                return P(*lead, "model", None, fsdp(off + 2))
            return P(*lead, None, mdl(off + 1), fsdp(off + 2))
        if name in ("w_uk", "w_uv"):            # (L, dl, H, dh)
            return P(*lead, fsdp(off), mdl(off + 1), None)
        if name == "w_dkv":                     # (L, D, dl)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name == "w_kr":                      # (L, D, dr)
            return P(*lead, fsdp(off), None)

    # --- MoE ---
    if group == "moe":
        if name in ("wi", "wg"):                # (L, E, D, F)
            if mdl(off):
                return P(*lead, "model", fsdp(off + 1), None)
            return P(*lead, None, fsdp(off + 1), mdl(off + 2))
        if name == "wo":                        # (L, E, F, D)
            if mdl(off):
                return P(*lead, "model", None, fsdp(off + 2))
            return P(*lead, None, mdl(off + 1), fsdp(off + 2))
        if name == "router":                    # (L, D, E)
            return P(*lead, fsdp(off), None)
        if name in ("shared_wi", "shared_wg"):  # (L, D, Fs)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name == "shared_wo":                 # (L, Fs, D)
            return P(*lead, mdl(off), fsdp(off + 1))

    # --- dense MLP ---
    if group == "mlp":
        if name in ("wi", "wg"):                # (L, D, F)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name == "wo":                        # (L, F, D)
            return P(*lead, mdl(off), fsdp(off + 1))

    # --- RG-LRU recurrent block ---
    if group == "rec":
        if name in ("wx", "wgate"):             # (L, D, W)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name in ("wr", "wi"):                # (L, W, W)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name == "wout":                      # (L, W, D)
            return P(*lead, mdl(off), fsdp(off + 1))
        if name == "conv_w":                    # (L, K, W)
            return P(*lead, None, mdl(off + 1))
        if name == "lam":                       # (L, W)
            return P(*lead, mdl(off))

    # --- SSD (mamba2) ---
    if group == "ssd":
        if name in ("wx", "wz", "wbc", "wdt"):  # (L, D, X)
            return P(*lead, fsdp(off), mdl(off + 1))
        if name == "wout":                      # (L, di, D)
            return P(*lead, mdl(off), fsdp(off + 1))
        if name == "conv_w":                    # (L, K, X)
            return P(*lead, None, mdl(off + 1))

    # norms, scalars, small vectors: replicate
    return P(*((None,) * len(shape)))


def param_shardings(cfg: ArchConfig, params_shape, mesh):
    def one(path, leaf):
        names = _path_names(path)
        spec = param_spec(names, leaf.shape, mesh, cfg)
        assert len(spec) <= len(leaf.shape), (names, leaf.shape, spec)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_dim(mesh, b: int):
    dp = data_axes(mesh)
    return dp if b % _prod(mesh, dp) == 0 else None


def batch_spec(mesh, b: int, ndim: int) -> P:
    return P(batch_dim(mesh, b), *((None,) * (ndim - 1)))


def cache_spec(names: Tuple[str, ...], shape, mesh, cfg: ArchConfig) -> P:
    name = names[-1]
    bd = batch_dim(mesh, shape[1])      # dim 0 is the n_units stack

    def mdl(i: int):
        return "model" if shape[i] % mesh.shape["model"] == 0 else None

    if name in ("k", "v"):              # (U, B, L, Hkv, dh)
        return P(None, bd, mdl(2), None, None)
    if name in ("ckv", "kr"):           # (U, B, L, X)
        return P(None, bd, mdl(2), None)
    if name == "state":                 # (U, B, H, P, N)
        return P(None, bd, None, None, mdl(4))
    if name == "h":                     # (U, B, W)
        return P(None, bd, mdl(2))
    if name == "conv":                  # (U, B, K-1, X)
        return P(None, bd, None, mdl(3))
    return P(*((None,) * len(shape)))


def cache_shardings(cfg: ArchConfig, cache_shape, mesh):
    def one(path, leaf):
        names = _path_names(path)
        return NamedSharding(mesh, cache_spec(names, leaf.shape, mesh, cfg))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_shardings(param_sh, mesh):
    rep = NamedSharding(mesh, P())
    return {"m": param_sh, "v": param_sh, "step": rep}


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# CIM macro-grid specs (cnn/mapped_net.py)
# ---------------------------------------------------------------------------

def macro_pass_specs(mesh=None) -> Tuple[P, P, P]:
    """(patch, weight, out) PartitionSpecs for one macro-grid super-step
    of the mapped-network executor on a ("row", "col") — or
    ("data", "row", "col") — mesh (launch.mesh.make_macro_mesh).

    The operands of ``mapped_net._macro_step`` lead with the macro axes:
    patches (sub_r, b, ...) shard over "row" (each macro row holds one
    channel-pass block), weights (sub_r, sub_c, ...) over both macro axes
    (each macro holds its own ic_t x oc_t block), and the output
    (sub_c, b, ...) over "col" after the cross-row partial-sum reduction
    (the shift-and-add accumulation becomes a psum over "row").

    When the mesh carries a leading "data" axis, the batch axis of the
    patches and the output additionally shards over it — each data
    replica of the macro grid serves its own batch slice; weights are
    replicated across "data" and the psum stays confined to "row"."""
    if mesh is not None and "data" in mesh.axis_names:
        return P("row", "data"), P("row", "col"), P("col", "data")
    return P("row"), P("row", "col"), P("col")


def macro_mesh_fits(mesh, sub_r: int, sub_c: int,
                    batch: Optional[int] = None) -> bool:
    """shard_map requires the macro axes to divide the mesh axes — and,
    on a mesh with a "data" axis, the batch to divide that axis."""
    if (mesh is None
            or sub_r % mesh.shape["row"]
            or sub_c % mesh.shape["col"]):
        return False
    if "data" in mesh.axis_names:
        return batch is not None and batch % mesh.shape["data"] == 0
    return True
