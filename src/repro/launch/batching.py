"""Dynamic batching for the compiled-plan serve path (DESIGN.md §7).

PR 3's serving driver ran a *fixed* request batch: every forward served
exactly ``--batch`` images, and a ragged request was padded-and-masked
to the plan batch on its own.  Real PIM serving only realizes the
paper's throughput once arrival-driven batching keeps the arrays full —
so this module turns the fixed-batch driver into an arrival-driven
server while keeping the forward path one jitted program per plan:

* :class:`Coalescer` — a FIFO request queue with **max-delay
  coalescing**: arrivals accumulate until either the queued rows reach
  ``max_batch`` or the *oldest* request has waited ``max_delay_s``; the
  drain then releases the longest FIFO prefix of whole requests that
  fits ``max_batch`` (never split, never reordered — arrival order is
  the latency contract).  The API takes explicit ``now`` timestamps so
  unit tests drive it with a fake clock (tests/test_batching.py).
* :func:`batch_tiers` / :class:`PlanLadder` — a small **power-of-two
  ladder of plan batches**, every tier padded to the one shared serving
  mesh's "data" axis (`mesh.pad_to_data_axis`) and compiled once via
  `repro.exec.compile_plan` (which memoizes through
  ``memo.cached_plan``, so a warm replica compiles no tier at all).  A
  coalesced batch pads to the smallest tier that fits instead of one
  fixed plan batch.
* :class:`TierStats` / :class:`DynamicServeStats` — per-tier effective
  vs padded images/s plus queue-delay percentiles, the report the
  driver (`launch/serve_cnn.serve_dynamic`) prints per tier.
* :class:`InputRing` — feeds the steady-state loop one device input per
  step under **plan-level input donation** (`execute_plan(donate=True)`
  consumes the buffer it is handed, so every step needs a fresh one);
  without donation the single uploaded buffer is reused.
* :class:`AdaptiveDelay` — a load-proportional max-delay policy: the
  effective coalescing delay shrinks as the queue deepens (deep backlog
  → drain immediately; idle → wait up to the cap), plugged into the
  coalescer as ``delay_policy`` and driven by the same explicit-``now``
  API.
* :class:`WorkItem` + :class:`InMemoryTransport` — the queue-transport
  abstraction behind the multi-replica tier (`launch/replica.py`): the
  router ships :class:`WorkItem`s to worker queues and reads tuple
  messages (``MSG_*`` heads) off one shared result channel.  The
  in-memory transport is the injectable fake — same duck-typed surface
  as the real ``replica.MpTransport`` but workers are caller-supplied
  objects stepped synchronously inside :meth:`InMemoryTransport.poll`,
  so a fake clock drives the whole multi-process loop deterministically.

Queue/tier/stats logic is pure Python on purpose: it must be testable
under a fake clock with no devices, and the jit boundary stays exactly
where PR 4 put it (one `execute_plan` program per tier).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

from . import mesh as meshlib


@dataclass(frozen=True)
class Request:
    """One queued arrival: ``rows`` images that arrived at ``arrival_s``
    (seconds on the caller's clock).  ``payload`` is opaque to the
    coalescer (the driver stores host-side image rows there).
    ``model`` tags the request with its target network for fleet serving
    (`launch/fleet.FleetScheduler`); single-model serving leaves it
    None."""

    rows: int
    arrival_s: float
    payload: object = None
    model: Optional[str] = None


@dataclass(frozen=True)
class AdaptiveDelay:
    """Load-proportional coalescing delay (the PR 5 follow-up).

    A fixed ``max_delay_s`` trades the head request's latency for fill
    regardless of load; under a deep backlog that wait buys nothing —
    the next tier is already full — while at idle it is exactly the
    bound that lets a second request share the batch.  This policy
    scales the effective delay linearly DOWN with observed queue depth:

        delay(queued_rows) = max_delay_s * max(0, 1 - queued_rows/ref_rows)

    so an empty-ish queue waits up to the cap and a queue at
    ``ref_rows`` (typically ``max_batch``) drains immediately.  Pure
    and stateless: the coalescer consults it with its current depth
    inside :meth:`Coalescer.next_deadline`, so the same explicit-``now``
    fake-clock tests cover it."""

    max_delay_s: float
    ref_rows: int

    def __post_init__(self):
        if self.max_delay_s < 0:
            raise ValueError(
                f"max_delay_s must be >= 0, got {self.max_delay_s}")
        if self.ref_rows < 1:
            raise ValueError(f"ref_rows must be >= 1, got {self.ref_rows}")

    def __call__(self, queued_rows: int) -> float:
        return self.max_delay_s * max(0.0, 1.0 - queued_rows / self.ref_rows)


class Coalescer:
    """Max-delay request coalescer: drain arrivals into ready batches.

    A batch becomes ready when the queued rows reach ``max_batch``
    (max-batch trigger) or the oldest queued request is ``max_delay_s``
    old (max-delay expiry — bounded worst-case queueing latency).
    Requests are whole units and stay in arrival order: :meth:`pop`
    releases the longest FIFO *prefix* that fits ``max_batch`` — it
    never splits a request, and never skips past a non-fitting request
    to a smaller one behind it (reordering would trade the head
    request's latency bound away for fill).  A request larger than
    ``max_batch`` is refused at :meth:`push`.  All methods take ``now``
    explicitly — the caller owns the clock, which makes the expiry
    logic exactly testable.

    ``delay_policy`` (e.g. :class:`AdaptiveDelay`) makes the delay
    load-proportional: it is called with the current queued rows and
    returns the effective delay, clamped to ``[0, max_delay_s]`` —
    ``max_delay_s`` stays the worst-case latency bound either way.
    """

    def __init__(self, max_batch: int, max_delay_s: float, *,
                 delay_policy: Optional[Callable[[int], float]] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(
                f"max_delay_s must be >= 0, got {max_delay_s}")
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.delay_policy = delay_policy
        self._q: Deque[Request] = deque()
        self._rows = 0

    def effective_delay_s(self) -> float:
        """The delay in force at the current queue depth: the policy's
        answer clamped to ``[0, max_delay_s]``, or ``max_delay_s``
        without a policy."""
        if self.delay_policy is None:
            return self.max_delay_s
        return min(max(float(self.delay_policy(self._rows)), 0.0),
                   self.max_delay_s)

    def __len__(self) -> int:
        """Queued images (rows, not requests)."""
        return self._rows

    @property
    def requests(self) -> int:
        return len(self._q)

    def push(self, rows: int, now: float, payload: object = None,
             model: Optional[str] = None) -> None:
        if rows < 1:
            raise ValueError(f"request must carry >= 1 row, got {rows}")
        if rows > self.max_batch:
            raise ValueError(
                f"request of {rows} rows exceeds max_batch="
                f"{self.max_batch} — requests are never split")
        self._q.append(Request(rows, now, payload, model))
        self._rows += rows

    def next_deadline(self) -> Optional[float]:
        """When the oldest queued request expires (max-delay), or None
        on an empty queue — the latest moment the server may sleep to.
        With a ``delay_policy`` the deadline moves EARLIER as the queue
        deepens (it is re-derived from the live depth on every call, so
        a push can only shrink it — callers that sleep to a stale
        deadline wake late but never starve: the policy is clamped by
        ``max_delay_s``)."""
        if not self._q:
            return None
        return self._q[0].arrival_s + self.effective_delay_s()

    def ready(self, now: float) -> bool:
        if not self._q:
            return False
        return self._rows >= self.max_batch or now >= self.next_deadline()

    def pop(self, now: float, force: bool = False) -> List[Request]:
        """The longest ready FIFO prefix (whole requests, ``<=
        max_batch`` rows, arrival order preserved), or ``[]`` when
        nothing is ready yet.  ``force=True`` drains regardless of the
        delay deadline (the final flush once no further arrival can grow
        the batch); an empty queue drains to ``[]`` either way."""
        if not self._q or not (force or self.ready(now)):
            return []
        batch: List[Request] = []
        rows = 0
        while self._q and rows + self._q[0].rows <= self.max_batch:
            r = self._q.popleft()
            batch.append(r)
            rows += r.rows
        self._rows -= rows
        return batch


def batch_tiers(max_batch: int, mesh=None) -> Tuple[int, ...]:
    """The plan-batch ladder: powers of two up to ``max_batch`` (the top
    tier covers it exactly), each padded to the serving mesh's "data"
    axis and deduplicated — e.g. ``(1, 2, 4, 6)`` for ``max_batch=6``
    without a mesh, ``(2, 4, 8)`` for ``max_batch=8`` on a data=2 mesh.
    Ascending, so :func:`tier_for` is a linear scan."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    tiers: List[int] = []
    b = 1
    while True:
        t = meshlib.pad_to_data_axis(min(b, max_batch), mesh)
        if not tiers or t > tiers[-1]:
            tiers.append(t)
        if b >= max_batch:
            break
        b *= 2
    return tuple(tiers)


def tier_for(rows: int, tiers: Sequence[int]) -> int:
    """Smallest tier that fits ``rows`` (the batch then pads to it)."""
    for t in tiers:
        if rows <= t:
            return t
    raise ValueError(f"{rows} rows exceed the largest tier {max(tiers)}")


class PlanLadder:
    """``compile_plan`` at every tier of the ladder, all sharing ONE
    serving mesh: a coalesced batch pads to ``tier_for(rows)`` instead
    of one fixed plan batch.  Tier plans come out of ``memo.cached_plan``
    (exec/plan.py), so each tier compiles once per process — or never,
    with a warm disk cache; `repro.exec.plan.compile_counts` gives the
    per-key evidence."""

    def __init__(self, net_mapping, tiers: Sequence[int], *, mesh=None,
                 policy="mapped", lookahead: Optional[int] = None,
                 block: Optional[str] = None,
                 vmem_budget: Optional[int] = None):
        from repro.exec import compile_plan
        self.tiers = tuple(sorted(set(int(t) for t in tiers)))
        if not self.tiers:
            raise ValueError("ladder needs at least one tier")
        for t in self.tiers:
            if meshlib.pad_to_data_axis(t, mesh) != t:
                raise ValueError(
                    f"tier {t} does not divide the mesh data axis "
                    f"{meshlib.data_axis_size(mesh)} — build tiers with "
                    f"batch_tiers(max_batch, mesh)")
        self.mesh = mesh
        # policy is any compile_plan PolicyLike (a name, "auto"/"tuned",
        # a per-layer tuple); lookahead / block / vmem_budget pass
        # through unset (None) so "tuned" can fill them per plan
        self.plans = {t: compile_plan(net_mapping, executor_policy=policy,
                                      mesh=mesh, batch=t,
                                      lookahead=lookahead, block=block,
                                      vmem_budget=vmem_budget)
                      for t in self.tiers}

    @property
    def max_batch(self) -> int:
        return self.tiers[-1]

    def plan_for(self, rows: int):
        """``(tier, plan)`` serving a ``rows``-image coalesced batch."""
        t = tier_for(rows, self.tiers)
        return t, self.plans[t]


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty sequence —
    enough for latency reporting without pulling numpy into the queue
    layer."""
    if not xs:
        raise ValueError("percentile of an empty sequence")
    if not 0 <= q <= 100:
        raise ValueError(f"q must be in [0, 100], got {q}")
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, math.ceil(q / 100 * len(s)) - 1))]


@dataclass
class TierStats:
    """Served-batch accounting for ONE tier of the ladder: effective
    (request) vs padded (plan) images, plus per-request queue delays
    (batch launch minus arrival)."""

    plan_batch: int
    batches: int = 0
    request_images: int = 0
    padded_images: int = 0
    exec_s: float = 0.0
    delays_s: List[float] = field(default_factory=list)

    def record(self, batch: Sequence[Request], launch_s: float,
               exec_s: float = 0.0) -> None:
        self.batches += 1
        rows = sum(r.rows for r in batch)
        self.request_images += rows
        self.padded_images += self.plan_batch
        self.exec_s += exec_s
        self.delays_s.extend(launch_s - r.arrival_s for r in batch)

    def delay_ms(self, q: float) -> float:
        return percentile(self.delays_s, q) * 1e3


@dataclass
class DynamicServeStats:
    """One arrival-driven serving run: per-tier breakdown plus the
    aggregate effective / padded rates over the measured wall time."""

    tiers: Dict[int, TierStats]
    request_images: int
    padded_images: int
    wall_s: float
    warmup_steps: int           # actual warmup executions (0 honored)

    @property
    def images_per_s(self) -> float:
        return self.request_images / max(self.wall_s, 1e-12)

    @property
    def padded_images_per_s(self) -> float:
        return self.padded_images / max(self.wall_s, 1e-12)

    @property
    def delays_s(self) -> List[float]:
        return [d for t in self.tiers.values() for d in t.delays_s]

    def delay_ms(self, q: float) -> float:
        """Aggregate queue-delay percentile over the POOLED per-tier
        delay samples — never an average of per-tier percentiles, which
        is not a percentile of anything (a tier with 3 fast batches
        would weigh as much as one with 300 slow ones)."""
        return percentile(self.delays_s, q) * 1e3

    def describe(self) -> str:
        lines = [f"dynamic: {self.request_images} request images "
                 f"({self.padded_images} padded) in {self.wall_s*1e3:.1f}ms"
                 f" = {self.images_per_s:.1f} images/s "
                 f"({self.padded_images_per_s:.1f} padded), "
                 f"warmup_steps={self.warmup_steps}"]
        if self.delays_s:
            lines.append(
                f"  all tiers pooled: queue-delay "
                f"p50={self.delay_ms(50):.2f}ms "
                f"p95={self.delay_ms(95):.2f}ms "
                f"p99={self.delay_ms(99):.2f}ms")
        for t in sorted(self.tiers):
            ts = self.tiers[t]
            if not ts.batches:
                continue
            lines.append(
                f"  tier {t}: {ts.batches} batches, "
                f"{ts.request_images}/{ts.padded_images} images, "
                f"queue-delay p50={ts.delay_ms(50):.2f}ms "
                f"p95={ts.delay_ms(95):.2f}ms p99={ts.delay_ms(99):.2f}ms")
        return "\n".join(lines)


class InputRing:
    """Device-input feeder for the steady-state serve loop.

    With plan-level donation (`execute_plan(donate=True)`) the program
    CONSUMES the input buffer it is handed — reusing it next step is a
    use-after-donate error.  The ring keeps one host-side staging copy
    and re-uploads it per step (`jax.device_put` never consumes the
    host array, so every upload is a fresh donatable device buffer —
    the realistic serving cost: every real request arrives as a new
    buffer, and the donated pages are recycled by the allocator).
    Without donation the single uploaded buffer is reused and
    :meth:`next` is free."""

    def __init__(self, x_host, *, donate: bool):
        import jax
        import numpy as np
        self.donate = bool(donate)
        if self.donate:
            self._host = np.array(x_host)
            self._dev = None
        else:
            self._host = None
            self._dev = jax.device_put(x_host)

    def next(self):
        """The device buffer to feed this step (fresh iff donating)."""
        if not self.donate:
            return self._dev
        import jax
        return jax.device_put(self._host)


# ---------------------------------------------------------------------------
# Queue transport — the multi-replica tier's wire format (launch/replica.py)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkItem:
    """One routed request in the multi-replica tier: what the router
    ships to a worker's task queue.  ``seq`` is the router-assigned
    request id — the exactly-once accounting key: completions dedupe on
    it, and a dead worker's outstanding seqs are re-queued to survivors
    (`launch/replica.ReplicaRouter`).  ``rows``/``arrival_s`` mean what
    they do on :class:`Request`; the payload stays synthetic worker-side
    (no arrays cross the queue)."""

    seq: int
    rows: int
    arrival_s: float
    model: Optional[str] = None


# Message heads on the shared worker->router result channel.  Tuples,
# not classes: they must pickle cheaply across process boundaries and
# stay greppable in both transports.
MSG_READY = "ready"        # (MSG_READY, wid, startup_s, table_misses, disk_hits)
MSG_HEARTBEAT = "hb"       # (MSG_HEARTBEAT, wid, now_s)
MSG_DONE = "done"          # (MSG_DONE, wid, tier, ((seq, rows, delay_s), ...), exec_s)
MSG_DYING = "dying"        # (MSG_DYING, wid, reason) — flushed before death
MSG_STATS = "stats"        # (MSG_STATS, wid, served_rows, padded_rows, batches)

# Router->worker control messages (WorkItems ride the same task queue).
CTRL_GO = "go"             # (CTRL_GO, epoch_s): start serving, shared clock zero
CTRL_STOP = "stop"         # (CTRL_STOP,): drain, report stats, exit
CTRL_DIE = "die"           # (CTRL_DIE,): crash injection — exit WITHOUT draining


class InMemoryTransport:
    """Injectable in-memory fake of the multi-replica queue transport.

    Duck-type twin of `launch/replica.MpTransport` (``start_worker`` /
    ``send`` / ``poll`` / ``alive`` / ``kill`` / ``join``) with nothing
    crossing a process boundary: ``factory(wid, cfg, inbox, emit)``
    builds a caller-supplied worker object whose ``step()`` is run
    synchronously inside :meth:`poll` (return ``False`` to die), so a
    fake clock drives the whole replica serve loop deterministically —
    the kill-a-worker recovery test needs no real processes.
    ``blocks=False`` tells the serve loop that :meth:`poll` never
    waits, so idle time must pass through its injected ``sleep``."""

    blocks = False

    def __init__(self, factory):
        self._factory = factory
        self._inbox: Dict[int, Deque] = {}
        self._results: Deque = deque()
        self._workers: Dict[int, object] = {}
        self._alive: Dict[int, bool] = {}

    def start_worker(self, wid: int, cfg) -> None:
        self._inbox[wid] = deque()
        self._alive[wid] = True
        self._workers[wid] = self._factory(wid, cfg, self._inbox[wid],
                                           self._results.append)

    def send(self, wid: int, msg) -> None:
        # a send to a dead worker vanishes, like a socket to a dead peer
        if self._alive.get(wid):
            self._inbox[wid].append(msg)

    def poll(self, timeout: float = 0.0):
        """Step every live worker once, then pop one result (or None).
        ``timeout`` is ignored — this transport never blocks."""
        for wid in sorted(self._workers):
            if self._alive[wid] and self._workers[wid].step() is False:
                self._alive[wid] = False
                self._inbox[wid].clear()
        return self._results.popleft() if self._results else None

    def alive(self, wid: int) -> bool:
        return self._alive.get(wid, False)

    def kill(self, wid: int) -> None:
        """Simulate an abrupt worker death: it is never stepped again
        and its queued work is lost (the router must re-queue)."""
        self._alive[wid] = False
        self._inbox[wid].clear()

    def join(self, timeout: Optional[float] = None) -> None:
        pass
