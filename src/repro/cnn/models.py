"""Benchmark CNNs in pure JAX (param-pytree modules, NCHW).

The builder consumes the same ``ConvLayerSpec`` stacks the mapping layer
uses, so the *trained* network and the *mapped* network are structurally
identical.  ``group`` applies TetrisG grouped convolutions (Alg 1
training side): every conv's kernel becomes the lax grouped layout
``(k, k, ic/G, oc)``.

Forward paths (``executor=``):
  * ``"reference"`` — lax.conv fast path (default without mappings)
  * ``"cim"``       — the placement-batched reference executor
    (cim_conv2d; default with mappings)
  * ``"mapped"``    — the macro-parallel executor (vmap/shard_map over
    the mapping's macro grid), so training runs through the very path
    whose cycles the tables report (DESIGN.md §3)
  * ``"sdk"``       — the Pallas MXU path (interpret mode off-TPU)

Every mapping-driven path resolves through a compiled execution plan
(``repro.exec.compile_plan`` with ``chained=False`` — the model owns its
own pooling / bias plumbing between convs, so the plan contributes the
per-layer executor dispatch, the compile-time steps==cycles check, and
the mesh-fit decisions; DESIGN.md §8).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ConvLayerSpec, LayerMapping, NetworkMapping
from .cim_conv import reference_conv2d

#: apply_cnn executor -> plan executor policy ("reference" stays the raw
#: lax.conv fast path, outside the plan).
_PLAN_POLICY = {"cim": "reference", "mapped": "mapped", "sdk": "sdk"}


@dataclass(frozen=True)
class CNNConfig:
    name: str
    convs: Tuple[ConvLayerSpec, ...]      # padded specs, in order
    num_classes: int = 10
    group: int = 1                        # TetrisG grouping (1 = off)
    pool_after: Tuple[int, ...] = ()      # conv indices followed by 2x2 pool

    def grouped(self, g: int) -> "CNNConfig":
        for c in self.convs:
            if c.ic % g or c.oc % g:
                raise ValueError(f"{c.name} not divisible by G={g}")
        return CNNConfig(self.name + f"-g{g}", self.convs, self.num_classes,
                         g, self.pool_after)


def cnn8_config(in_size: int = 16, in_ch: int = 8, group: int = 1
                ) -> CNNConfig:
    """CNN8-shaped stack scaled to a trainable-on-CPU geometry: same
    channel progression as the paper's CNN8 (24-32-32-64-64-64-256 after
    the stem), 3x3 convs + one 5x5 head conv."""
    s = in_size + 2
    convs = (
        ConvLayerSpec("c1", s, s, 3, 3, in_ch, 24),
        ConvLayerSpec("c2", s, s, 3, 3, 24, 32),
        ConvLayerSpec("c3", s, s, 3, 3, 32, 32),
        ConvLayerSpec("c4", s // 2 + 1, s // 2 + 1, 3, 3, 32, 64),
        ConvLayerSpec("c5", s // 2 + 1, s // 2 + 1, 3, 3, 64, 64),
    )
    return CNNConfig("cnn8", convs, group=group, pool_after=(2,))


def init_cnn(rng: jax.Array, cfg: CNNConfig) -> Dict:
    params: Dict = {"convs": []}
    g = cfg.group
    keys = jax.random.split(rng, len(cfg.convs) + 1)
    for i, c in enumerate(cfg.convs):
        fan_in = c.k_h * c.k_w * c.ic // g
        w = jax.random.normal(keys[i], (c.k_h, c.k_w, c.ic // g, c.oc),
                              jnp.float32) * math.sqrt(2.0 / fan_in)
        params["convs"].append({"w": w, "b": jnp.zeros((c.oc,))})
    # head dims resolved lazily at first apply via shape; store factory seed
    params["head"] = None
    params["_head_key"] = keys[-1]
    return params


def _pad(x: jnp.ndarray, target: int) -> jnp.ndarray:
    pad = target - x.shape[-1]
    lo, hi = pad // 2, pad - pad // 2
    return jnp.pad(x, ((0, 0), (0, 0), (lo, hi), (lo, hi)))


def apply_cnn(params: Dict, cfg: CNNConfig, x: jnp.ndarray,
              mappings: Optional[Sequence[LayerMapping]] = None,
              executor: Optional[str] = None, mesh=None,
              remat=None) -> jnp.ndarray:
    """x: (b, in_ch, H, W) -> logits (b, num_classes).

    ``executor`` selects the conv path (module docstring); None resolves
    to "cim" when mappings are given, else "reference".  Mapping-driven
    executors resolve to a layerwise execution plan (repro.exec) — one
    compiled dispatch table per (mappings, executor, mesh, batch; the
    batch joins the key so `exec.plan.compile_counts` counts one plan
    per distinct input shape — the train loop's pad-and-mask contract).
    ``mesh`` is an optional ("row", "col") device mesh for the mapped
    executor (launch.mesh.make_macro_mesh).  ``remat`` asks the plan's
    segment pass for checkpoint boundaries (`compile_plan(remat=...)`;
    layerwise plans may cut at any conv) and wraps each segment's convs
    + pooling in `jax.checkpoint` — mapping-driven executors only: the
    lax.conv fast path has no plan to segment."""
    if executor is None:
        executor = "reference" if mappings is None else "cim"
    if executor not in ("reference", "cim", "mapped", "sdk"):
        raise ValueError(f"unknown executor {executor!r}")
    if executor != "reference" and mappings is None:
        raise ValueError(f"executor={executor!r} needs mappings")
    plan = None
    if executor != "reference":
        from repro.exec import compile_plan
        net = NetworkMapping(
            name=cfg.name, algorithm=mappings[0].algorithm,
            array=mappings[0].array, layers=tuple(mappings),
            grid=mappings[0].grid)
        plan = compile_plan(net, executor_policy=_PLAN_POLICY[executor],
                            mesh=mesh, batch=x.shape[0],
                            chained=False, remat=remat)
    elif remat is not None:
        raise ValueError("remat needs a mapping-driven executor — the "
                         "plan's segment pass owns the boundaries")

    def segment(x, seg_params, lo, hi):
        from repro.exec import apply_layer
        for i in range(lo, hi):
            c = cfg.convs[i]
            x = _pad(x, c.i_w)
            w, b = seg_params[i - lo]["w"], seg_params[i - lo]["b"]
            if plan is not None:
                y = apply_layer(plan, i, x, w, mesh=mesh)
            else:
                y = reference_conv2d(c, x, w, groups=cfg.group)
            x = jax.nn.relu(y + b[None, :, None, None])
            if i in cfg.pool_after:
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2),
                    "VALID")
        return x

    spans = plan.spans if plan is not None else ((0, len(cfg.convs)),)
    for lo, hi in spans:
        seg_params = params["convs"][lo:hi]
        if len(spans) > 1:
            # remat: the backward re-runs this conv slice from its
            # boundary carry instead of saving every layer's residuals
            x = jax.checkpoint(functools.partial(segment, lo=lo, hi=hi))(
                x, seg_params)
        else:
            x = segment(x, seg_params, lo, hi)
    feats = x.mean(axis=(2, 3))                       # GAP
    head = params["head"]
    if head is None:
        raise ValueError("call ensure_head(params, cfg, in_ch) first")
    return feats @ head["w"] + head["b"]


def ensure_head(params: Dict, cfg: CNNConfig) -> Dict:
    if params["head"] is None:
        d = cfg.convs[-1].oc
        k = params.pop("_head_key")
        params["head"] = {
            "w": jax.random.normal(k, (d, cfg.num_classes), jnp.float32)
            * math.sqrt(1.0 / d),
            "b": jnp.zeros((cfg.num_classes,)),
        }
    params.pop("_head_key", None)
    return params
