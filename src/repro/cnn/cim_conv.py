"""Execute a convolution *exactly as a LayerMapping prescribes* — the
semantic bridge between the mapping search and real compute.

Every array load of the mapping becomes one (patch-vector @ mapped-weight-
matrix) product: the weight matrix is the shifted-and-duplicated kernel
layout of Fig 5 (rows = window pixels x channel tile, columns = kernel
position x output channel), built by :func:`build_weight_matrix`.  Summing
partial products over channel loads and scattering per-position outputs
reconstructs the OFM exactly (up to float summation order) against
``lax.conv_general_dilated`` — asserted in tests/test_cim_conv.py.

Overlap semantics: border-clamped (ceil-form) and marginal windows may
recompute output positions already produced by a neighbouring window of
the same channel pass; recomputed values are identical, so each channel
pass writes into its own buffer with *set* semantics (idempotent), and
buffers accumulate across channel passes (the partial-sum adds of the
shift-and-add peripheral, Fig 3).

This executor is loop-unrolled host-side (placements are static) and is
the *reference* path; the TPU performance path is kernels/im2win_conv.py.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import (ConvLayerSpec, LayerMapping, TileMapping)


def window_placements(layer: ConvLayerSpec, tile: TileMapping
                      ) -> List[Tuple[int, int, int, int]]:
    """(y, x, pw_h, pw_w) for every window load of a tile: the regular
    floor-grid, then Alg 4 marginal windows (or, for ceil-form baselines,
    border-clamped overhang windows)."""
    s = layer.stride
    w, k_w, k_h = tile.window, layer.k_w, layer.k_h
    step_x = ((w.pw_w - k_w) // s + 1) * s
    step_y = ((w.pw_h - k_h) // s + 1) * s

    n_x = (layer.i_w - w.pw_w) // step_x + 1
    n_y = (layer.i_h - w.pw_h) // step_y + 1
    ceil_x = math.ceil(((layer.i_w - k_w) // s + 1) / (step_x // s))
    ceil_y = math.ceil(((layer.i_h - k_h) // s + 1) / (step_y // s))

    # border clamps must stay on the stride grid so in-window kernel
    # positions align with the global output raster
    def clamp(v: int, limit: int) -> int:
        return min(v, (limit // s) * s)

    out: List[Tuple[int, int, int, int]] = []
    use_ceil = not tile.marginals
    nx, ny = (ceil_x, ceil_y) if use_ceil else (n_x, n_y)
    for iy in range(ny):
        for ix in range(nx):
            y = clamp(iy * step_y, layer.i_h - w.pw_h)
            x = clamp(ix * step_x, layer.i_w - w.pw_w)
            out.append((y, x, w.pw_h, w.pw_w))

    for mw in tile.marginals:
        if mw.edge == "w":          # right strip
            x = clamp(layer.i_w - mw.mw_w, layer.i_w - mw.mw_w)
            step = ((mw.mw_h - k_h) // s + 1) * s
            for i in range(mw.count):
                y = clamp(i * step, layer.i_h - mw.mw_h)
                out.append((y, x, mw.mw_h, mw.mw_w))
        else:                        # bottom strip
            y = clamp(layer.i_h - mw.mw_h, layer.i_h - mw.mw_h)
            step = ((mw.mw_w - k_w) // s + 1) * s
            for i in range(mw.count):
                x = clamp(i * step, layer.i_w - mw.mw_w)
                out.append((y, x, mw.mw_h, mw.mw_w))
    return out


def build_weight_matrix(layer: ConvLayerSpec, kernel: jnp.ndarray,
                        pw_h: int, pw_w: int) -> jnp.ndarray:
    """Shifted-and-duplicated kernel matrix for one window shape (Fig 5).

    kernel: (k_h, k_w, ic_t, oc_t) slice ->
    matrix: (ic_t * pw_h * pw_w, n_pos * oc_t); rows are channel-major
    window pixels, columns enumerate (position, oc).
    """
    s = layer.stride
    k_h, k_w = layer.k_h, layer.k_w
    ic_t, oc_t = kernel.shape[2], kernel.shape[3]
    py = (pw_h - k_h) // s + 1
    px = (pw_w - k_w) // s + 1
    W = jnp.zeros((ic_t, pw_h, pw_w, py * px, oc_t), kernel.dtype)
    kt = jnp.transpose(kernel, (2, 0, 1, 3))   # (ic_t, k_h, k_w, oc_t)
    for iy in range(py):
        for ix in range(px):
            p = iy * px + ix
            W = W.at[:, iy * s:iy * s + k_h, ix * s:ix * s + k_w, p, :].add(kt)
    return W.reshape(ic_t * pw_h * pw_w, py * px * oc_t)


def cim_conv2d(mapping: LayerMapping, x: jnp.ndarray,
               kernel: jnp.ndarray) -> jnp.ndarray:
    """Convolve per the mapping.

    x: (batch, ic, i_h, i_w) pre-padded; kernel in lax grouped layout
    (k_h, k_w, ic // G, oc) with G = mapping.group (for G=1 that is the
    ordinary dense HWIO kernel).  Returns (batch, oc, o_h, o_w).  Pruned
    channels (depth-optimal tiles) are skipped — callers comparing against
    an exact conv must zero the corresponding kernel slices (see tests).
    """
    layer = mapping.layer
    s = layer.stride
    b = x.shape[0]
    o_h, o_w = layer.o_h, layer.o_w
    out = jnp.zeros((b, layer.oc, o_h, o_w), jnp.result_type(x, kernel))

    g = mapping.group
    ic_g, oc_g = layer.ic // g, layer.oc // g

    if kernel.shape != (layer.k_h, layer.k_w, ic_g, layer.oc):
        raise ValueError(f"kernel shape {kernel.shape} != grouped layout "
                         f"{(layer.k_h, layer.k_w, ic_g, layer.oc)}")

    for gi in range(g):
        xg = x[:, gi * ic_g:(gi + 1) * ic_g]
        kg = kernel[:, :, :, gi * oc_g:(gi + 1) * oc_g]
        c_base = 0
        for tile in mapping.tiles:
            kept = tile.depth        # TileMapping.depth is the KEPT channels
            placements = window_placements(layer, tile)
            for c0 in range(c_base, c_base + kept, tile.ic_t):
                ic_t = min(tile.ic_t, c_base + kept - c0)
                for o0 in range(0, oc_g, tile.oc_t):
                    oc_t = min(tile.oc_t, oc_g - o0)
                    # one channel x oc pass: set-semantics buffer
                    buf = jnp.zeros((b, oc_t, o_h, o_w), out.dtype)
                    for (y, x0, pw_h, pw_w) in placements:
                        Wm = build_weight_matrix(
                            layer, kg[:, :, c0:c0 + ic_t, o0:o0 + oc_t],
                            pw_h, pw_w)
                        patch = jax.lax.dynamic_slice(
                            xg, (0, c0, y, x0), (b, ic_t, pw_h, pw_w))
                        flat = patch.reshape(b, ic_t * pw_h * pw_w)
                        prod = flat @ Wm              # (b, n_pos*oc_t)
                        py = (pw_h - layer.k_h) // s + 1
                        px = (pw_w - layer.k_w) // s + 1
                        prod = prod.reshape(b, py, px, oc_t)
                        prod = jnp.transpose(prod, (0, 3, 1, 2))
                        buf = jax.lax.dynamic_update_slice(
                            buf, prod, (0, 0, y // s, x0 // s))
                    out = out.at[:, gi * oc_g + o0:gi * oc_g + o0 + oc_t
                                 ].add(buf)
            c_base += tile.depth
    return out


def reference_conv2d(layer: ConvLayerSpec, x: jnp.ndarray,
                     kernel: jnp.ndarray, groups: int = 1) -> jnp.ndarray:
    """Oracle: lax.conv_general_dilated on the (pre-padded) input; kernel
    in the same grouped layout cim_conv2d consumes."""
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(layer.stride, layer.stride),
        padding="VALID",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
        feature_group_count=groups)
