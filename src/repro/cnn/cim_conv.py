"""Execute a convolution *exactly as a LayerMapping prescribes* — the
semantic bridge between the mapping search and real compute.

Every array load of the mapping becomes one (patch-vector @ mapped-weight-
matrix) product: the weight matrix is the shifted-and-duplicated kernel
layout of Fig 5 (rows = window pixels x channel tile, columns = kernel
position x output channel), built by :func:`build_weight_matrix`.  Summing
partial products over channel loads and scattering per-position outputs
reconstructs the OFM exactly (up to float summation order) against
``lax.conv_general_dilated`` — asserted in tests/test_cim_conv.py.

Overlap semantics: border-clamped (ceil-form) and marginal windows may
recompute output positions already produced by a neighbouring window of
the same channel pass; recomputed values are identical, so each channel
pass writes into its own buffer with *set* semantics (idempotent), and
buffers accumulate across channel passes (the partial-sum adds of the
shift-and-add peripheral, Fig 3).

Execution strategy (DESIGN.md §2): placements are *batched* — all window
loads of one shape in one (channel x oc) pass are gathered into a single
stacked patch tensor and hit the weight matrix as one batched matmul,
followed by one vectorized scatter.  The weight matrix is hoisted out of
the placement loop entirely (it depends only on the window shape).
Placements stay host-side Python ints, so :func:`cim_conv2d` traces to a
small, static op graph and :func:`cim_conv2d_jit` can treat the mapping
as a static argument.  This is the *reference* path; the TPU performance
path is kernels/im2win_conv.py (``sdk_conv`` consumes the same mapping).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (ConvLayerSpec, LayerMapping, TileMapping)


def window_placements(layer: ConvLayerSpec, tile: TileMapping
                      ) -> List[Tuple[int, int, int, int]]:
    """(y, x, pw_h, pw_w) for every window load of a tile: the regular
    floor-grid, then Alg 4 marginal windows (or, for ceil-form baselines,
    border-clamped overhang windows)."""
    s = layer.stride
    w, k_w, k_h = tile.window, layer.k_w, layer.k_h
    step_x = ((w.pw_w - k_w) // s + 1) * s
    step_y = ((w.pw_h - k_h) // s + 1) * s

    n_x = (layer.i_w - w.pw_w) // step_x + 1
    n_y = (layer.i_h - w.pw_h) // step_y + 1
    ceil_x = math.ceil(((layer.i_w - k_w) // s + 1) / (step_x // s))
    ceil_y = math.ceil(((layer.i_h - k_h) // s + 1) / (step_y // s))

    # border clamps must stay on the stride grid so in-window kernel
    # positions align with the global output raster
    def clamp(v: int, limit: int) -> int:
        return min(v, (limit // s) * s)

    out: List[Tuple[int, int, int, int]] = []
    use_ceil = not tile.marginals
    nx, ny = (ceil_x, ceil_y) if use_ceil else (n_x, n_y)
    for iy in range(ny):
        for ix in range(nx):
            y = clamp(iy * step_y, layer.i_h - w.pw_h)
            x = clamp(ix * step_x, layer.i_w - w.pw_w)
            out.append((y, x, w.pw_h, w.pw_w))

    for mw in tile.marginals:
        if mw.edge == "w":          # right strip
            x = clamp(layer.i_w - mw.mw_w, layer.i_w - mw.mw_w)
            step = ((mw.mw_h - k_h) // s + 1) * s
            for i in range(mw.count):
                y = clamp(i * step, layer.i_h - mw.mw_h)
                out.append((y, x, mw.mw_h, mw.mw_w))
        else:                        # bottom strip
            y = clamp(layer.i_h - mw.mw_h, layer.i_h - mw.mw_h)
            step = ((mw.mw_w - k_w) // s + 1) * s
            for i in range(mw.count):
                x = clamp(i * step, layer.i_w - mw.mw_w)
                out.append((y, x, mw.mw_h, mw.mw_w))
    return out


def placement_groups(layer: ConvLayerSpec, tile: TileMapping
                     ) -> Dict[Tuple[int, int], np.ndarray]:
    """Window placements grouped by congruent shape: {(pw_h, pw_w) ->
    (N, 2) int array of (y, x) origins}.  All N loads of one shape share
    one weight matrix and execute as one batched matmul."""
    groups: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for (y, x, ph, pw) in window_placements(layer, tile):
        groups.setdefault((ph, pw), []).append((y, x))
    return {shape: np.asarray(org, np.int32)
            for shape, org in groups.items()}


def gather_patches(xc: jnp.ndarray, origins: np.ndarray, ph: int, pw: int
                   ) -> jnp.ndarray:
    """Stack every congruent placement of one window shape in one gather:
    xc (..., C, H, W), origins (N, 2) of (y, x) -> (..., N, C*ph*pw).
    Row order is channel-major (channel, y, x) — exactly the row order of
    :func:`build_weight_matrix`, so the result multiplies the weight
    matrix directly.  Shared by the reference executor and the
    macro-parallel executor (cnn/mapped_net.py)."""
    ys, xs = origins[:, 0], origins[:, 1]
    Y = ys[:, None, None] + np.arange(ph)[None, :, None]   # (N, ph, 1)
    X = xs[:, None, None] + np.arange(pw)[None, None, :]   # (N, 1, pw)
    p = xc[..., Y, X]                                      # (..., C, N, ph, pw)
    p = jnp.moveaxis(p, -4, -3)                            # (..., N, C, ph, pw)
    return p.reshape(*p.shape[:-3], -1)


def scatter_indices(origins: np.ndarray, py: int, px: int, stride: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Output-raster indices of every placement's (py x px) output tile:
    (OY, OX) broadcastable to (N, py, px), for a vectorized set-semantics
    scatter (overlapping windows recompute identical values)."""
    ys, xs = origins[:, 0], origins[:, 1]
    OY = (ys // stride)[:, None, None] + np.arange(py)[None, :, None]
    OX = (xs // stride)[:, None, None] + np.arange(px)[None, None, :]
    return OY, OX


def build_weight_matrix(layer: ConvLayerSpec, kernel: jnp.ndarray,
                        pw_h: int, pw_w: int) -> jnp.ndarray:
    """Shifted-and-duplicated kernel matrix for one window shape (Fig 5).

    kernel: (k_h, k_w, ic_t, oc_t) slice ->
    matrix: (ic_t * pw_h * pw_w, n_pos * oc_t); rows are channel-major
    window pixels, columns enumerate (position, oc).  Built as a single
    scatter — every (position, kernel-pixel) destination is distinct.
    """
    s = layer.stride
    k_h, k_w = layer.k_h, layer.k_w
    ic_t, oc_t = kernel.shape[2], kernel.shape[3]
    py = (pw_h - k_h) // s + 1
    px = (pw_w - k_w) // s + 1
    kt = jnp.transpose(kernel, (2, 0, 1, 3))   # (ic_t, k_h, k_w, oc_t)

    iy, ix = np.divmod(np.arange(py * px), px)
    ys = (iy * s)[:, None, None] + np.arange(k_h)[None, :, None]  # (P,kh,1)
    xs = (ix * s)[:, None, None] + np.arange(k_w)[None, None, :]  # (P,1,kw)
    p = np.arange(py * px)[:, None, None]
    W = jnp.zeros((ic_t, pw_h, pw_w, py * px, oc_t), kernel.dtype)
    W = W.at[:, ys, xs, p, :].set(
        jnp.broadcast_to(kt[:, None], (ic_t, py * px, k_h, k_w, oc_t)))
    return W.reshape(ic_t * pw_h * pw_w, py * px * oc_t)


def cim_conv2d_traced(mapping: LayerMapping, x: jnp.ndarray,
                      kernel: jnp.ndarray) -> jnp.ndarray:
    """Convolve per the mapping (placement-batched) — the trace-time
    body.  Public plan-consuming entry: `repro.exec.run` inlines it into
    the whole-network program; stand-alone callers use
    :func:`cim_conv2d` / :func:`cim_conv2d_jit`.

    x: (batch, ic, i_h, i_w) pre-padded; kernel in lax grouped layout
    (k_h, k_w, ic // G, oc) with G = mapping.group (for G=1 that is the
    ordinary dense HWIO kernel).  Returns (batch, oc, o_h, o_w).  Pruned
    channels — the trailing slice of each tile's channel range — are
    skipped; callers comparing against an exact conv must zero the
    corresponding kernel slices (see zero_pruned_kernels / tests).
    """
    layer = mapping.layer
    s = layer.stride
    b = x.shape[0]
    o_h, o_w = layer.o_h, layer.o_w

    g = mapping.group
    ic_g, oc_g = layer.ic // g, layer.oc // g

    if kernel.shape != (layer.k_h, layer.k_w, ic_g, layer.oc):
        raise ValueError(f"kernel shape {kernel.shape} != grouped layout "
                         f"{(layer.k_h, layer.k_w, ic_g, layer.oc)}")

    # all G groups are congruent (same tiles, same placements): expose the
    # group axis once and batch it through every gather/matmul/scatter
    xr = x.reshape(b, g, ic_g, layer.i_h, layer.i_w)
    kr = kernel.reshape(layer.k_h, layer.k_w, ic_g, g, oc_g)
    out = jnp.zeros((b, g, oc_g, o_h, o_w), jnp.result_type(x, kernel))

    c_base = 0
    for tile in mapping.tiles:
        kept = tile.depth            # TileMapping.depth is the KEPT channels
        xc = xr[:, :, c_base:c_base + kept]     # (b, g, kept, i_h, i_w)
        ks = kr[:, :, c_base:c_base + kept]     # (kh, kw, kept, g, oc_g)
        # one set-semantics buffer per tile: every window (regular or
        # marginal, any shape) writes the tile's full kept-channel partial
        # sum, so overlapping windows recompute identical values and set
        # is idempotent; tiles accumulate into `out`
        buf = jnp.zeros((b, g, oc_g, o_h, o_w), out.dtype)
        for (ph, pw), origins in placement_groups(layer, tile).items():
            # The tile's (ic_t x oc_t) array loads batch into ONE matmul
            # per group: channel passes stack along the contraction rows
            # (summing partial products over loads == the shift-and-add
            # accumulation), oc passes concatenate along columns.
            Wm = build_weight_matrix(
                layer, ks.reshape(layer.k_h, layer.k_w, kept, g * oc_g),
                ph, pw)
            py = (ph - layer.k_h) // s + 1
            px = (pw - layer.k_w) // s + 1
            Wm = Wm.reshape(kept * ph * pw, py * px, g, oc_g)
            Wm = Wm.transpose(2, 0, 1, 3).reshape(
                g, kept * ph * pw, py * px * oc_g)
            n = len(origins)
            # gather every congruent placement of every group at once
            flat = gather_patches(xc, origins, ph, pw)  # (b,g,N,kept*ph*pw)
            prod = jnp.einsum("bgnr,grp->bgnp", flat, Wm)
            prod = prod.reshape(b, g, n, py, px, oc_g)
            prod = prod.transpose(0, 1, 5, 2, 3, 4)  # (b,g,oc_g,N,py,px)
            # vectorized scatter with set semantics; duplicate indices
            # only occur where the recomputed values are identical
            OY, OX = scatter_indices(origins, py, px, s)
            buf = buf.at[:, :, :, OY, OX].set(prod)
        out = out + buf
        # a tile's nominal channel range is kept + pruned: the pruned
        # trailing slice is skipped here, not shifted into the next tile
        c_base += tile.depth + tile.pruned_channels
    return out.reshape(b, layer.oc, o_h, o_w)


cim_conv2d_jit = functools.partial(jax.jit, static_argnums=0)(
    cim_conv2d_traced)
cim_conv2d_jit.__doc__ = (
    """jit entry point: the mapping (and with it every placement) is a
    static argument — LayerMapping is a frozen, hashable dataclass — so
    each distinct mapping compiles once to a fully fused program.""")


def cim_conv2d(mapping: LayerMapping, x: jnp.ndarray,
               kernel: jnp.ndarray) -> jnp.ndarray:
    """Convolve per the mapping — see :func:`cim_conv2d_traced` for the
    layout contract.  Dispatches through :func:`cim_conv2d_jit`: one XLA
    compile per distinct (mapping, shapes) instead of per-op eager
    dispatch of every gather/matmul/scatter."""
    return cim_conv2d_jit(mapping, x, kernel)


def reference_conv2d(layer: ConvLayerSpec, x: jnp.ndarray,
                     kernel: jnp.ndarray, groups: int = 1) -> jnp.ndarray:
    """Oracle: lax.conv_general_dilated on the (pre-padded) input; kernel
    in the same grouped layout cim_conv2d consumes."""
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(layer.stride, layer.stride),
        padding="VALID",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
        feature_group_count=groups)
