"""Macro-parallel mapped-network executor — the paper's P-macro grid
realized as *executed* parallelism, not just cycle bookkeeping.

``TileMapping.cycles`` assumes a grid (r, c) runs ``r`` channel passes and
``c`` oc passes of every window load concurrently:

    cycles = n_windows * ceil(AR_c / r) * ceil(AC_c / c)

This module executes exactly that schedule (DESIGN.md §3).  Per tile, the
(AR_c x AC_c) pass matrix is covered by ``ceil(AR_c/r) * ceil(AC_c/c)``
sequential *super-steps*; within a super-step the (r x c) block of array
passes runs as one macro-grid step — ``jax.vmap`` over the explicit
(row, col) macro axes on a single device, or ``shard_map`` over a
("row", "col") device mesh (launch.mesh.make_macro_mesh /
launch.sharding.macro_pass_specs) when one is available.  Groups follow
``LayerMapping.group_split``: ``gr*gc`` congruent groups run concurrently
on disjoint sub-grids (batched through the group axis), remaining groups
time-multiplex as ``group_rounds`` sequential rounds.

The *executed* step count is derived from the same host-side structures
the executor iterates (placement lists x super-step trip counts x group
rounds) and is asserted equal to ``LayerMapping.cycles`` for every layer
— the equivalence contract that turns the Fig 20 speed-ups from
accounting into execution.

Whole-network entry points (``mapped_net_apply`` /
``reference_net_apply``) are thin wrappers over the compiled-plan path
(``repro.exec``, DESIGN.md §8): the chain is lowered once by
``compile_plan`` (schedule, glue, sharding, steps==cycles — all at
compile time) and executed as one jitted program.  This module owns the
per-layer executor (``mapped_conv2d`` and its traced body) and the
schedule derivation the plan compiler consumes.

Numerics follow cnn/cim_conv.py: window loads of one congruent shape are
gathered and multiplied in one batch (sequential in hardware, counted as
such); each channel super-step writes a set-semantics buffer (overlapping
border/marginal windows recompute identical partial sums), buffers
accumulate across channel super-steps — the shift-and-add adds of Fig 3,
with the cross-row reduction of a super-step becoming a ``psum`` over the
mesh "row" axis in the sharded path.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import (LayerMapping, MacroGrid, NetworkMapping,
                              TileMapping)
from repro.launch.sharding import macro_mesh_fits, macro_pass_specs
from .cim_conv import (build_weight_matrix, gather_patches,
                       placement_groups, scatter_indices)


# ---------------------------------------------------------------------------
# Execution schedule: the executor's sequential structure, as host ints
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TileSchedule:
    """Sequential structure of one tile's execution (per group round)."""

    window_loads: int          # gathered placements == tile.n_windows
    r_steps: int               # ceil(ar_c / sub_r) channel super-steps
    c_steps: int               # ceil(ac_c / sub_c) oc super-steps

    @property
    def steps(self) -> int:
        return self.window_loads * self.r_steps * self.c_steps


@dataclass(frozen=True)
class LayerSchedule:
    """What :func:`mapped_conv2d` actually executes for one layer."""

    layer: str
    sub: MacroGrid             # macro sub-grid of one group's passes
    group_rounds: int          # sequential rounds of gr*gc-parallel groups
    tiles: Tuple[TileSchedule, ...]

    @property
    def steps(self) -> int:
        """Executed sequential grid steps — the measured counterpart of
        ``LayerMapping.cycles``."""
        return self.group_rounds * sum(t.steps for t in self.tiles)


@functools.lru_cache(maxsize=None)
def layer_schedule(mapping: LayerMapping) -> LayerSchedule:
    """Derive the executor's schedule from the mapping.  ``window_loads``
    counts the *actual* placement list the executor gathers (floor grid +
    marginals, or the ceil-form clamped raster), not the stored
    ``n_windows`` — the equality of the two is part of the contract.
    Cached per mapping (frozen dataclass): the dispatch-time schedule
    assert in :func:`mapped_conv2d` then costs nothing per step."""
    sub = mapping.sub_grid
    tiles = []
    for tile in mapping.tiles:
        _, ar_c, _, ac_c = mapping.tile_passes(tile)
        loads = sum(len(o) for o in
                    placement_groups(mapping.layer, tile).values())
        tiles.append(TileSchedule(
            window_loads=loads,
            r_steps=math.ceil(ar_c / sub.r),
            c_steps=math.ceil(ac_c / sub.c)))
    return LayerSchedule(layer=mapping.layer.name, sub=sub,
                         group_rounds=mapping.group_rounds,
                         tiles=tuple(tiles))


def executed_steps(mapping: LayerMapping) -> int:
    return layer_schedule(mapping).steps


def network_schedule(net: NetworkMapping) -> Tuple[LayerSchedule, ...]:
    return tuple(layer_schedule(m) for m in net.layers)


def check_steps(mapping: LayerMapping) -> None:
    """Raise unless the executor's schedule matches the mapping's cycle
    count — the per-layer half of the DESIGN.md §3 contract."""
    s = layer_schedule(mapping)
    if s.steps != mapping.cycles:
        raise AssertionError(
            f"{mapping.layer.name}: executed steps {s.steps} != "
            f"cycles {mapping.cycles} (sub-grid {s.sub.r}x{s.sub.c}, "
            f"rounds {s.group_rounds})")


def assert_steps_match(net: NetworkMapping) -> None:
    """Executed grid steps == analytical cycle count for every layer —
    the Fig 20 speed-ups are *executed*, not just counted."""
    for m in net.layers:
        check_steps(m)


# ---------------------------------------------------------------------------
# One macro-grid super-step
# ---------------------------------------------------------------------------

def _one_macro(p: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """ONE macro's array pass: patches (b, g, N, K) against this macro's
    (ic_t x oc_t) weight block (g, K, Po) -> (b, g, N, Po)."""
    return jnp.einsum("bgnk,gko->bgno", p, w)


# grid rows share nothing; grid columns share the row's patch block
_macro_cols = jax.vmap(_one_macro, in_axes=(None, 0))      # over sub_c
_macro_grid = jax.vmap(_macro_cols, in_axes=(0, 0))        # over sub_r


def _macro_step(p_blk: jnp.ndarray, w_blk: jnp.ndarray,
                mesh=None) -> jnp.ndarray:
    """One super-step of the macro grid: an (r x c) block of array passes
    runs concurrently.

    p_blk (sub_r, b, g, N, K): each macro row's channel-pass patch block.
    w_blk (sub_r, sub_c, g, K, Po): each macro's weight block.
    Returns (sub_c, b, g, N, Po) — partial products summed over the grid
    rows (the shift-and-add accumulation across macro rows).

    On a ("row", "col") device mesh whose axes divide (sub_r, sub_c) the
    step runs under shard_map — macros become devices and the row
    reduction a psum; otherwise the macro axes are vmapped on one device.
    A mesh with a leading "data" axis (make_macro_mesh(..., data=n))
    additionally shards the batch axis over ``n`` replicas of the macro
    grid — weights replicate across "data", and the psum stays confined
    to "row", so each replica computes its own batch slice independently.
    """
    if macro_mesh_fits(mesh, p_blk.shape[0], w_blk.shape[1],
                       batch=p_blk.shape[1]):
        from jax.experimental.shard_map import shard_map
        p_spec, w_spec, o_spec = macro_pass_specs(mesh)

        def local(p, w):
            part = _macro_grid(p, w).sum(0)          # local rows
            return jax.lax.psum(part, "row")         # cross-device rows

        return shard_map(local, mesh=mesh, in_specs=(p_spec, w_spec),
                         out_specs=o_spec)(p_blk, w_blk)
    return _macro_grid(p_blk, w_blk).sum(0)


# ---------------------------------------------------------------------------
# Layer executor
# ---------------------------------------------------------------------------

def _tile_dims(mapping: LayerMapping, tile: TileMapping
               ) -> Tuple[int, int, int, int]:
    """(R, C, ic_pad, oc_pad) of one tile's super-step blocking — the
    sequential channel/oc super-step counts and the channel paddings
    that make every super-step a full (sub_r x sub_c) macro block."""
    sub = mapping.sub_grid
    ic_t, ar_c, oc_t, ac_c = mapping.tile_passes(tile)
    R = math.ceil(ar_c / sub.r)
    C = math.ceil(ac_c / sub.c)
    return R, C, R * sub.r * ic_t, C * sub.c * oc_t


def _tile_weights(mapping: LayerMapping, tile: TileMapping,
                  ks: jnp.ndarray, R: int, C: int) -> Tuple[jnp.ndarray, ...]:
    """Blocked shifted-weight matrices, one per congruent window shape:
    (R, C, sub_r, sub_c, g, K, npos*oc_t) — the row/oc blocking of the
    Fig 5 shifted-and-duplicated matrix.  Input- and batch-independent:
    co-resident plan tiers can share ONE prepared copy
    (`prepared_layer_weights` / repro.exec.constants)."""
    layer = mapping.layer
    s = layer.stride
    sub = mapping.sub_grid
    ic_t, _, oc_t, _ = mapping.tile_passes(tile)
    g = ks.shape[3]
    ic_pad, oc_pad = ks.shape[2], ks.shape[4]
    out = []
    for (ph, pw), _origins in placement_groups(layer, tile).items():
        py = (ph - layer.k_h) // s + 1
        px = (pw - layer.k_w) // s + 1
        npos = py * px
        K = ic_t * ph * pw
        Wm = build_weight_matrix(
            layer, ks.reshape(layer.k_h, layer.k_w, ic_pad, g * oc_pad),
            ph, pw)                                    # (ic_pad*ph*pw, ...)
        w_all = Wm.reshape(R, sub.r, K, npos, g, C, sub.c, oc_t)
        w_all = w_all.transpose(0, 5, 1, 6, 4, 2, 3, 7).reshape(
            R, C, sub.r, sub.c, g, K, npos * oc_t)
        out.append(w_all)
    return tuple(out)


def _tile_operands(mapping: LayerMapping, tile: TileMapping,
                   xc: jnp.ndarray, ks: Optional[jnp.ndarray],
                   R: int, C: int,
                   prepared: Optional[Sequence[jnp.ndarray]] = None
                   ) -> List[dict]:
    """Pass-blocked operands per congruent window shape.

    xc (b, g, ic_pad, H, W) and ks (k_h, k_w, ic_pad, g, oc_pad) are the
    tile's channel slice zero-padded to whole super-steps.  For each
    shape: patches (R, sub_r, b, g, N, K) with K = ic_t*ph*pw, and
    weights (R, C, sub_r, sub_c, g, K, npos*oc_t) — the row/oc blocking
    of the Fig 5 shifted-and-duplicated matrix.  ``prepared`` substitutes
    pre-materialized weight blocks (`_tile_weights` order) for the
    in-trace build — the plan-constant sharing path; ``ks`` may then be
    None.
    """
    layer = mapping.layer
    s = layer.stride
    sub = mapping.sub_grid
    ic_t, _, _, _ = mapping.tile_passes(tile)
    b, g = xc.shape[0], xc.shape[1]
    if prepared is None:
        weights = _tile_weights(mapping, tile, ks, R, C)
    else:
        weights = tuple(prepared)
    groups = placement_groups(layer, tile)
    if len(weights) != len(groups):
        raise ValueError(f"{layer.name}: {len(weights)} prepared weight "
                         f"blocks for {len(groups)} window shapes")
    out = []
    for (ph, pw), origins in groups.items():
        py = (ph - layer.k_h) // s + 1
        px = (pw - layer.k_w) // s + 1
        K = ic_t * ph * pw
        flat = gather_patches(xc, origins, ph, pw)     # (b,g,N,ic_pad*ph*pw)
        n = flat.shape[2]
        p_all = flat.reshape(b, g, n, R * sub.r, K)
        p_all = p_all.transpose(3, 0, 1, 2, 4).reshape(
            R, sub.r, b, g, n, K)
        OY, OX = scatter_indices(origins, py, px, s)
        out.append(dict(p_all=p_all, w_all=weights[len(out)], OY=OY, OX=OX,
                        py=py, px=px))
    return out


def prepared_layer_weights(mapping: LayerMapping, kernel: jnp.ndarray
                           ) -> Tuple[Tuple[jnp.ndarray, ...], ...]:
    """Materialize one layer's blocked shifted-weight matrices from its
    kernel — per tile, per congruent window shape, in exactly the order
    :func:`mapped_conv2d_traced` consumes them via ``weights=``.

    The blocks depend only on (mapping, kernel), never on the input or
    the batch, so every tier of a plan ladder — and every co-resident
    plan of the same network — can share ONE prepared copy instead of
    re-deriving the matrices inside each tier's program on every forward
    (repro.exec.constants.prepare_constants owns the sharing handle)."""
    layer = mapping.layer
    g = mapping.group
    ic_g, oc_g = layer.ic // g, layer.oc // g
    if kernel.shape != (layer.k_h, layer.k_w, ic_g, layer.oc):
        raise ValueError(f"kernel shape {kernel.shape} != grouped layout "
                         f"{(layer.k_h, layer.k_w, ic_g, layer.oc)}")
    kr = kernel.reshape(layer.k_h, layer.k_w, ic_g, g, oc_g)
    out = []
    c_base = 0
    for tile in mapping.tiles:
        kept = tile.depth
        R, C, ic_pad, oc_pad = _tile_dims(mapping, tile)
        ks = jnp.pad(kr[:, :, c_base:c_base + kept],
                     ((0, 0), (0, 0), (0, ic_pad - kept), (0, 0),
                      (0, oc_pad - oc_g)))
        out.append(_tile_weights(mapping, tile, ks, R, C))
        c_base += kept + tile.pruned_channels
    return tuple(out)


def mapped_conv2d_traced(mapping: LayerMapping, x: jnp.ndarray,
                         kernel: Optional[jnp.ndarray], *, mesh=None,
                         weights=None) -> jnp.ndarray:
    """Macro-parallel convolution per the mapping — the trace-time body.
    Public plan-consuming entry: `repro.exec.run` inlines it into the
    whole-network program; stand-alone callers use :func:`mapped_conv2d`
    / :func:`mapped_conv2d_jit`.  Same layout contract as
    cnn.cim_conv.cim_conv2d: x (batch, ic, i_h, i_w) pre-padded, kernel
    (k_h, k_w, ic // G, oc) in lax grouped layout, output
    (batch, oc, o_h, o_w); pruned channels (the trailing slice of each
    tile's channel range) are skipped.  ``weights`` substitutes this
    layer's pre-materialized shifted-weight blocks
    (:func:`prepared_layer_weights`) for the in-trace build — the
    plan-constant sharing path; ``kernel`` is then only consulted for
    the result dtype (and may be None)."""
    layer = mapping.layer
    b = x.shape[0]
    o_h, o_w = layer.o_h, layer.o_w
    g = mapping.group
    ic_g, oc_g = layer.ic // g, layer.oc // g
    if weights is None:
        if kernel.shape != (layer.k_h, layer.k_w, ic_g, layer.oc):
            raise ValueError(f"kernel shape {kernel.shape} != grouped "
                             f"layout "
                             f"{(layer.k_h, layer.k_w, ic_g, layer.oc)}")
        kr = kernel.reshape(layer.k_h, layer.k_w, ic_g, g, oc_g)
        w_dtype = kernel.dtype
    else:
        if len(weights) != len(mapping.tiles):
            raise ValueError(f"{layer.name}: {len(weights)} prepared "
                             f"weight tiles for {len(mapping.tiles)}")
        kr = None
        w_dtype = weights[0][0].dtype

    # all groups are congruent: the group axis batches the gr*gc-parallel
    # groups; sequential group rounds only multiply the step count
    xr = x.reshape(b, g, ic_g, layer.i_h, layer.i_w)
    out = jnp.zeros((b, g, oc_g, o_h, o_w), jnp.result_type(x.dtype,
                                                            w_dtype))

    sub = mapping.sub_grid
    c_base = 0
    for ti, tile in enumerate(mapping.tiles):
        kept = tile.depth
        oc_t = mapping.tile_passes(tile)[2]
        R, C, ic_pad, oc_pad = _tile_dims(mapping, tile)
        xc = jnp.pad(xr[:, :, c_base:c_base + kept],
                     ((0, 0), (0, 0), (0, ic_pad - kept), (0, 0), (0, 0)))
        if weights is None:
            ks = jnp.pad(kr[:, :, c_base:c_base + kept],
                         ((0, 0), (0, 0), (0, ic_pad - kept), (0, 0),
                          (0, oc_pad - oc_g)))
        else:
            ks = None
        shapes = _tile_operands(mapping, tile, xc, ks, R, C,
                                prepared=None if weights is None
                                else weights[ti])

        acc = jnp.zeros((b, g, oc_pad, o_h, o_w), out.dtype)
        soc = sub.c * oc_t                   # oc columns per super-step
        for ri in range(R):
            # one channel super-step: set semantics within it (every
            # window writes this step's full partial sum), accumulate
            # across steps (shift-and-add)
            buf = jnp.zeros_like(acc)
            for ci in range(C):
                for sh in shapes:
                    res = _macro_step(sh["p_all"][ri],
                                      sh["w_all"][ri, ci], mesh)
                    py, px = sh["py"], sh["px"]
                    n = res.shape[3]
                    vals = res.reshape(sub.c, b, g, n, py, px, oc_t)
                    vals = vals.transpose(1, 2, 0, 6, 3, 4, 5).reshape(
                        b, g, soc, n, py, px)
                    buf = buf.at[:, :, ci * soc:(ci + 1) * soc,
                                 sh["OY"], sh["OX"]].set(vals)
            acc = acc + buf
        out = out + acc[:, :, :oc_g]
        # skip the tile's pruned trailing channels instead of shifting
        # the next tile's range onto them
        c_base += kept + tile.pruned_channels
    return out.reshape(b, layer.oc, o_h, o_w)


mapped_conv2d_jit = functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mesh",))(
    mapped_conv2d_traced)
mapped_conv2d_jit.__doc__ = (
    """jit entry: mapping (frozen dataclass) and mesh are static — one
    XLA program per distinct (mapping, mesh, shapes).""")


def mapped_conv2d(mapping: LayerMapping, x: jnp.ndarray,
                  kernel: jnp.ndarray, *, mesh=None) -> jnp.ndarray:
    """Execute one layer macro-parallel, asserting the executed schedule
    matches the mapping's cycle count (host-side, cached, free under
    jit)."""
    check_steps(mapping)
    return mapped_conv2d_jit(mapping, x, kernel, mesh=mesh)


# ---------------------------------------------------------------------------
# Network forward pass — thin wrappers over the compiled-plan path
# ---------------------------------------------------------------------------
#
# Whole-network execution lives in repro.exec (DESIGN.md §8): a
# NetworkMapping is lowered ONCE by `compile_plan` (executor choice,
# schedule, inter-layer glue, sharding decisions, steps==cycles — all at
# compile time) and `execute_plan` runs the forward as one jitted
# program.  These wrappers keep the original signatures so every
# equivalence test runs unchanged against the plan.  (repro.exec is
# imported lazily: it consumes this module's traced bodies.)

def mapped_net_apply(net: NetworkMapping, kernels: Sequence[jnp.ndarray],
                     x: jnp.ndarray, *, mesh=None,
                     activation=None) -> jnp.ndarray:
    """Forward an entire ``NetworkMapping`` through the macro-parallel
    executor — now a wrapper over ``compile_plan``/``execute_plan`` with
    every layer pinned to ``"mapped"``.  ``kernels[i]`` is layer i's
    kernel in that mapping's grouped layout ``(k_h, k_w, ic // G_i,
    oc)``.  Executed grid steps == ``LayerMapping.cycles`` is checked at
    plan-compile time (memoized, so repeat calls pay nothing).
    ``activation`` is a static jit argument hashed by identity — pass a
    stable callable, not a fresh lambda per call."""
    from repro.exec import compile_plan, execute_plan
    plan = compile_plan(net, executor_policy="mapped", mesh=mesh,
                        batch=x.shape[0] if mesh is not None else None)
    return execute_plan(plan, kernels, x, mesh=mesh, activation=activation)


def reference_net_apply(net: NetworkMapping,
                        kernels: Sequence[jnp.ndarray], x: jnp.ndarray, *,
                        activation=None) -> jnp.ndarray:
    """Oracle composition: the same compiled chain (glue and all),
    lax.conv per layer (pruned channels must be zeroed in ``kernels``,
    see zero_pruned_kernels)."""
    from repro.exec import compile_plan
    from repro.exec.run import execute_oracle
    plan = compile_plan(net, executor_policy="reference")
    return execute_oracle(plan, kernels, x, activation=activation)


def zero_pruned_kernels(net: NetworkMapping,
                        kernels: Sequence[jnp.ndarray]
                        ) -> List[jnp.ndarray]:
    """Zero each tile's pruned input channels — the trailing slice of
    that tile's nominal (kept + pruned) channel range, which is exactly
    what the executors skip (the retrained-network convention of the
    equivalence tests).  One trailing slice per *tile*, not one per
    layer: with several pruned tiles the pruned channels interleave with
    later tiles' kept ranges, and a single layer-trailing slice would
    zero the wrong channels."""
    out = []
    for m, k in zip(net.layers, kernels):
        c_base = 0
        for t in m.tiles:
            c_base += t.depth
            if t.pruned_channels:
                k = k.at[:, :, c_base:c_base + t.pruned_channels, :].set(0.0)
            c_base += t.pruned_channels
        out.append(k)
    return out


def __getattr__(name: str):
    # back-compat: the inter-layer glue moved to repro.exec.glue
    if name in ("fit_spatial", "_center_crop"):
        from repro.exec import glue
        return glue.fit_spatial if name == "fit_spatial" else \
            glue.center_crop
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
