"""Grouped-convolution training study (Table II proxy).

MNIST/CIFAR/TinyImageNet are not available offline; the claim under test —
"grouped convolutions are near-lossless (and sometimes better)" — is
checked on a seeded synthetic image-classification task
(:mod:`repro.data.synthetic`).  We train the CNN8-shaped stack with
G in {1, 2, 4} under identical budgets and report accuracy deltas next to
the mapping cycle counts (benchmarks/table2_grouped.py).

``executor="mapped"`` (or "cim" / "sdk") trains through the
mapping-driven executors instead of lax.conv: the executor name resolves
to a compiled execution-plan policy (``repro.exec.compile_plan`` via
``apply_cnn`` — DESIGN.md §8), so every conv of every training step runs
exactly as its ``LayerMapping`` prescribes (macro-parallel super-steps
for "mapped" — DESIGN.md §3) and the accuracy the study reports is
measured on the same execution path whose cycles the tables count, with
the steps==cycles check paid once at plan-compile time.  Gradients flow
through the executors' gather/matmul/scatter (exact; asserted against
the lax.conv path in tests/test_mapped_net.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.grouped import tetrisg_layer
from repro.core.types import ArrayConfig, LayerMapping, MacroGrid
from repro.data.synthetic import image_task
from .models import CNNConfig, apply_cnn, ensure_head, init_cnn


@dataclass
class TrainResult:
    config: str
    group: int
    steps: int
    final_loss: float
    train_acc: float
    test_acc: float
    executor: str = "reference"


def train_mappings(cfg: CNNConfig, array: ArrayConfig,
                   grid: MacroGrid = MacroGrid()
                   ) -> Tuple[LayerMapping, ...]:
    """Per-conv TetrisG mappings pinned to the config's grouping factor,
    so each mapping's group matches the trained kernels' grouped layout
    ``(k, k, ic/G, oc)``."""
    return tuple(tetrisg_layer(c, array, grid, groups=(cfg.group,))
                 for c in cfg.convs)


def loss_fn(params, cfg: CNNConfig, x, y, mappings=None, executor=None):
    logits = apply_cnn(params, cfg, x, mappings=mappings, executor=executor)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def train_cnn(cfg: CNNConfig, *, steps: int = 300, batch: int = 64,
              lr: float = 3e-3, seed: int = 0,
              n_train: int = 2048, n_test: int = 512,
              executor: str = "reference",
              array: Optional[ArrayConfig] = None,
              grid: MacroGrid = MacroGrid()) -> TrainResult:
    rng = jax.random.PRNGKey(seed)
    k_init, k_data = jax.random.split(rng)
    xs, ys, xt, yt = image_task(k_data, n_train=n_train, n_test=n_test,
                                size=cfg.convs[0].i_w - 2,
                                channels=cfg.convs[0].ic,
                                num_classes=cfg.num_classes)
    params = ensure_head(init_cnn(k_init, cfg), cfg)

    mappings = None
    if executor != "reference":
        mappings = train_mappings(cfg, array or ArrayConfig(512, 512), grid)

    @jax.jit
    def step(params, opt, x, y):
        lval, grads = jax.value_and_grad(loss_fn)(params, cfg, x, y,
                                                  mappings, executor)
        # Adam
        m = jax.tree.map(lambda m_, g: 0.9 * m_ + 0.1 * g, opt["m"], grads)
        v = jax.tree.map(lambda v_, g: 0.999 * v_ + 0.001 * g * g,
                         opt["v"], grads)
        t = opt["t"] + 1
        def upd(p, m_, v_):
            mh = m_ / (1 - 0.9 ** t)
            vh = v_ / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8)
        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}, lval

    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.zeros_like, params), "t": 0}
    n = xs.shape[0]
    loss = float("nan")
    for i in range(steps):
        lo = (i * batch) % max(1, n - batch)
        params, opt, loss = step(params, opt, xs[lo:lo + batch],
                                 ys[lo:lo + batch])

    @jax.jit
    def acc(params, x, y):
        logits = apply_cnn(params, cfg, x, mappings=mappings,
                           executor=executor)
        return (logits.argmax(-1) == y).mean()

    return TrainResult(
        config=cfg.name, group=cfg.group, steps=steps,
        final_loss=float(loss),
        train_acc=float(acc(params, xs[:n_test], ys[:n_test])),
        test_acc=float(acc(params, xt, yt)),
        executor=executor)
