"""Training through the mapping IR: the Table II proxy and the plan
trainer.

MNIST/CIFAR/TinyImageNet are not available offline; the claim under test —
"grouped convolutions are near-lossless (and sometimes better)" — is
checked on a seeded synthetic image-classification task
(:mod:`repro.data.synthetic`).  We train the CNN8-shaped stack with
G in {1, 2, 4} under identical budgets and report accuracy deltas next to
the mapping cycle counts (benchmarks/table2_grouped.py).

``executor="mapped"`` (or "cim" / "sdk") trains through the
mapping-driven executors instead of lax.conv: the executor name resolves
to a compiled execution-plan policy (``repro.exec.compile_plan`` via
``apply_cnn`` — DESIGN.md §8), so every conv of every training step runs
exactly as its ``LayerMapping`` prescribes and the accuracy the study
reports is measured on the same execution path whose cycles the tables
count.  Gradients flow through the executors' gather/matmul/scatter
(exact; asserted against the lax.conv path in tests/test_mapped_net.py).

Both trainers share the step machinery (DESIGN.md §13):

* the **optimizer** is `repro.optim.adamw` with :data:`ADAM` (plain
  Adam: no decay, no clipping) — the update is bit-identical to the
  hand-rolled closure it replaced (tests/test_train_plan.py);
* **gradient accumulation**: ``accum`` microbatches per optimizer step,
  `lax.scan` over the reshaped batch, per-example losses summed and
  divided by the *valid* example count once — so accumulation and
  padding never change the gradient;
* **pad-and-mask**: a ragged tail batch is padded to the compiled
  ``(accum, microbatch)`` shape (`launch.mesh.pad_to_data_axis` when a
  mesh fixes the data axis) with zero-weight masks, so raggedness never
  recompiles the fused program — one compile per *distinct* shape,
  asserted via `exec.plan.compile_counts`;
* **donation**: the step donates the params/optimizer buffers when the
  platform supports it (`exec.run.donation_supported`), halving
  steady-state optimizer-state residency.

`train_plan` is the scale path: it trains the kernels of a **chained**
NetworkMapping through `execute_plan` — the whole forward as one fused
program — with ``remat`` segments from the plan's memory model
(exec/memory.py, exec/remat.py).  When ``REPRO_TRAIN_MEM_BUDGET`` is
set, a plan whose peak estimate exceeds it refuses to train (the
CPU-deterministic stand-in for an accelerator OOM); ``remat="auto"``
segments under that budget and trains.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.grouped import tetrisg_layer
from repro.core.types import (ArrayConfig, LayerMapping, MacroGrid,
                              NetworkMapping)
from repro.data.synthetic import image_task
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from .models import CNNConfig, apply_cnn, ensure_head, init_cnn

#: Plain Adam via the shared AdamW module: the b1/b2/eps the hand-rolled
#: closure used, decay and clipping off.  With these settings
#: `adamw_update` is bit-identical to the classic
#: ``p - lr*mh/(sqrt(vh)+eps)`` update (regression-tested).
ADAM = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                   grad_clip=float("inf"))


@dataclass
class TrainResult:
    config: str
    group: int
    steps: int
    final_loss: float
    train_acc: float
    test_acc: float
    executor: str = "reference"


@dataclass
class PlanTrainResult:
    """`train_plan` outcome + the memory-model facts the frontier
    (benchmarks/train_bench.py) reports next to measured steps/s."""
    name: str
    steps: int
    batch: int
    accum: int
    final_loss: float
    first_loss: float
    peak_mb: float              # estimate of the plan as segmented
    unremat_peak_mb: float      # estimate with remat off
    segments: int
    donated: bool


def train_mappings(cfg: CNNConfig, array: ArrayConfig,
                   grid: MacroGrid = MacroGrid()
                   ) -> Tuple[LayerMapping, ...]:
    """Per-conv TetrisG mappings pinned to the config's grouping factor,
    so each mapping's group matches the trained kernels' grouped layout
    ``(k, k, ic/G, oc)``."""
    return tuple(tetrisg_layer(c, array, grid, groups=(cfg.group,))
                 for c in cfg.convs)


def loss_fn(params, cfg: CNNConfig, x, y, mappings=None, executor=None):
    logits = apply_cnn(params, cfg, x, mappings=mappings, executor=executor)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def _pad_and_mask(x, y, batch: int):
    """Pad a (possibly ragged) tail batch to ``batch`` examples with a
    0/1 validity mask — the compiled step sees ONE shape."""
    k = x.shape[0]
    mask = jnp.ones((k,), jnp.float32)
    if k < batch:
        pad = batch - k
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), jnp.float32)])
    return x, y, mask


def _accum_grads(loss_sum_fn, params, xb, yb, mask):
    """Scan ``accum`` microbatches, summing per-example loss and grads;
    divide by the valid count once at the end — gradients are exactly
    those of the unpadded whole-batch mean (DESIGN.md §13).

    ``loss_sum_fn(params, x, y, mask) -> masked per-example SUM``;
    ``xb``/``yb``/``mask`` are (accum, microbatch, ...)."""
    zeros = jax.tree.map(jnp.zeros_like, params)

    def body(acc, mb):
        x, y, mk = mb
        lv, g = jax.value_and_grad(loss_sum_fn)(params, x, y, mk)
        lsum, gsum = acc
        return (lsum + lv,
                jax.tree.map(jnp.add, gsum, g)), None

    (lsum, gsum), _ = lax.scan(body, (jnp.zeros(()), zeros),
                               (xb, yb, mask))
    count = mask.sum()
    return lsum / count, jax.tree.map(lambda g: g / count, gsum)


def _make_step(loss_sum_fn, lr: float, *, donate: bool):
    """The shared jitted optimizer step: accumulate → adamw.  Donates
    the params/opt buffers when the platform implements donation."""

    def step(params, opt, xb, yb, mask):
        loss, grads = _accum_grads(loss_sum_fn, params, xb, yb, mask)
        params, opt, _ = adamw_update(params, grads, opt, lr, ADAM)
        return params, opt, loss

    if donate:
        return jax.jit(step, donate_argnums=(0, 1))
    return jax.jit(step)


def _microbatched(x, y, mask, accum: int):
    mb = x.shape[0] // accum
    return (x.reshape((accum, mb) + x.shape[1:]),
            y.reshape((accum, mb)),
            mask.reshape((accum, mb)))


def train_cnn(cfg: CNNConfig, *, steps: int = 300, batch: int = 64,
              lr: float = 3e-3, seed: int = 0,
              n_train: int = 2048, n_test: int = 512,
              executor: str = "reference",
              array: Optional[ArrayConfig] = None,
              grid: MacroGrid = MacroGrid(),
              accum: int = 1, remat=None, mesh=None,
              donate: Optional[bool] = None) -> TrainResult:
    """The Table II accuracy study trainer (module docstring).

    ``accum`` splits each ``batch`` into that many scanned microbatches
    per optimizer step (``batch % accum == 0``); ``remat`` forwards to
    the execution plan's segment pass (mapping-driven executors only —
    the lax.conv fast path has no plan to segment).  ``donate=None``
    resolves via `donation_supported`.
    """
    if accum < 1 or batch % accum:
        raise ValueError(f"accum={accum} must divide batch={batch}")
    from repro.exec.run import donation_supported
    from repro.launch.mesh import pad_to_data_axis
    if donate is None:
        donate = donation_supported(mesh)
    # the compiled step shape: microbatches pad up to the mesh data axis
    # when one is bound (plans refuse ragged data-axis batches)
    micro = batch // accum
    micro = pad_to_data_axis(micro, mesh) if mesh is not None else micro
    batch = micro * accum

    rng = jax.random.PRNGKey(seed)
    k_init, k_data = jax.random.split(rng)
    xs, ys, xt, yt = image_task(k_data, n_train=n_train, n_test=n_test,
                                size=cfg.convs[0].i_w - 2,
                                channels=cfg.convs[0].ic,
                                num_classes=cfg.num_classes)
    params = ensure_head(init_cnn(k_init, cfg), cfg)

    mappings = None
    if executor != "reference":
        mappings = train_mappings(cfg, array or ArrayConfig(512, 512), grid)

    def loss_sum(params, x, y, mask):
        logits = apply_cnn(params, cfg, x, mappings=mappings,
                           executor=executor, mesh=mesh, remat=remat)
        logp = jax.nn.log_softmax(logits)
        per = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        return (per * mask).sum()

    step = _make_step(loss_sum, lr, donate=donate)
    opt = adamw_init(params)
    n = xs.shape[0]
    loss = float("nan")
    for i in range(steps):
        lo = (i * batch) % max(1, n - batch)
        xb, yb, mask = _pad_and_mask(xs[lo:lo + batch], ys[lo:lo + batch],
                                     batch)
        params, opt, loss = step(params, opt,
                                 *_microbatched(xb, yb, mask, accum))

    @jax.jit
    def acc(params, x, y):
        logits = apply_cnn(params, cfg, x, mappings=mappings,
                           executor=executor)
        return (logits.argmax(-1) == y).mean()

    return TrainResult(
        config=cfg.name, group=cfg.group, steps=steps,
        final_loss=float(loss),
        train_acc=float(acc(params, xs[:n_test], ys[:n_test])),
        test_acc=float(acc(params, xt, yt)),
        executor=executor)


def init_plan_kernels(net: NetworkMapping, key) -> list:
    """He-init kernels in the executor layout ``(k_h, k_w, ic/G, oc)``,
    pruned channels zeroed to match the mapping."""
    from repro.cnn.mapped_net import zero_pruned_kernels
    ks = []
    for i, m in enumerate(net.layers):
        c = m.layer
        fan_in = c.k_h * c.k_w * c.ic // m.group
        ks.append(jax.random.normal(
            jax.random.fold_in(key, i),
            (c.k_h, c.k_w, c.ic // m.group, c.oc), jnp.float32)
            * (2.0 / fan_in) ** 0.5)
    return zero_pruned_kernels(net, ks)


def train_plan(net: NetworkMapping, *, steps: int = 10, batch: int = 8,
               lr: float = 1e-3, seed: int = 0, accum: int = 1,
               remat=None, executor_policy="reference", mesh=None,
               num_classes: int = 10, n_train: int = 256,
               donate: Optional[bool] = None,
               losses: Optional[list] = None,
               step_times: Optional[list] = None) -> PlanTrainResult:
    """Train a chained NetworkMapping's kernels (+ a linear head on the
    GAP features) through `execute_plan` — the whole fused forward, with
    ``remat`` segments applied per `jax.checkpoint` (module docstring).

    When ``REPRO_TRAIN_MEM_BUDGET`` is set (bytes), a plan whose peak
    live-byte *estimate* exceeds it raises MemoryError before touching
    the device — the deterministic CPU stand-in for an accelerator OOM;
    compile with ``remat="auto"`` to segment under the budget.  Pass a
    list as ``losses`` to collect the per-step loss trajectory, and/or
    one as ``step_times`` for per-step wall seconds (the first entry
    includes the jit compile — benchmarks drop it).
    """
    import time as _time
    from repro.exec import compile_plan, execute_plan
    from repro.exec.remat import ENV_BUDGET
    from repro.exec.run import donation_supported
    from repro.launch.mesh import pad_to_data_axis
    if accum < 1 or batch % accum:
        raise ValueError(f"accum={accum} must divide batch={batch}")
    micro = batch // accum
    micro = pad_to_data_axis(micro, mesh) if mesh is not None else micro
    batch = micro * accum
    if donate is None:
        donate = donation_supported(mesh)

    plan = compile_plan(net, executor_policy=executor_policy, mesh=mesh,
                        batch=micro, remat=remat)
    budget = os.environ.get(ENV_BUDGET)
    if budget and plan.peak_bytes > int(budget):
        raise MemoryError(
            f"{net.name}: plan peak estimate {plan.peak_bytes / 1e6:.1f}MB "
            f"exceeds {ENV_BUDGET}={int(budget) / 1e6:.1f}MB "
            f"(remat={remat!r}, {len(plan.spans)} segment(s)) — compile "
            f"with remat='auto' or a byte budget to segment under it")

    first = net.layers[0].layer
    rng = jax.random.PRNGKey(seed)
    k_init, k_head, k_data = jax.random.split(rng, 3)
    xs, ys, _, _ = image_task(k_data, n_train=n_train, n_test=1,
                              size=max(4, first.i_w - 2),
                              channels=first.ic, num_classes=num_classes)
    last = plan.layers[-1]
    out_c = last.mapping.layer.oc
    if last.glue.kind == "concat":      # DenseNet: carry + final output
        out_c += last.carry_c
    params = {
        "kernels": init_plan_kernels(net, k_init),
        "head": jax.random.normal(k_head, (out_c, num_classes),
                                  jnp.float32) * (1.0 / out_c) ** 0.5,
    }

    def loss_sum(params, x, y, mask):
        feats = execute_plan(plan, params["kernels"], x, mesh=mesh,
                             activation=jax.nn.relu).mean(axis=(2, 3))
        logp = jax.nn.log_softmax(feats @ params["head"])
        per = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        return (per * mask).sum()

    step = _make_step(loss_sum, lr, donate=donate)
    opt = adamw_init(params)
    n = xs.shape[0]
    loss = first_loss = float("nan")
    for i in range(steps):
        lo = (i * batch) % max(1, n - batch)
        xb, yb, mask = _pad_and_mask(xs[lo:lo + batch], ys[lo:lo + batch],
                                     batch)
        t0 = _time.perf_counter()
        params, opt, lval = step(params, opt,
                                 *_microbatched(xb, yb, mask, accum))
        loss = float(lval)               # sync: the step really finished
        if step_times is not None:
            step_times.append(_time.perf_counter() - t0)
        if i == 0:
            first_loss = loss
        if losses is not None:
            losses.append(loss)

    return PlanTrainResult(
        name=net.name, steps=steps, batch=batch, accum=accum,
        final_loss=loss, first_loss=first_loss,
        peak_mb=plan.peak_bytes / 1e6,
        unremat_peak_mb=plan.unremat_peak_bytes / 1e6,
        segments=len(plan.spans), donated=donate)
