# CNN substrate: the paper's benchmark networks in JAX + the CIM-mapped
# convolution executor (semantic bridge mapping -> compute).
from .cim_conv import (build_weight_matrix, cim_conv2d, cim_conv2d_jit,
                       placement_groups, reference_conv2d,
                       window_placements)
