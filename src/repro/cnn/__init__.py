# CNN substrate: the paper's benchmark networks in JAX + the CIM-mapped
# convolution executors (semantic bridge mapping -> compute).
# cim_conv.py    reference placement-batched executor (single implicit macro)
# mapped_net.py  macro-parallel executor: the P-macro grid as vmap/shard_map
from .cim_conv import (build_weight_matrix, cim_conv2d, cim_conv2d_jit,
                       gather_patches, placement_groups, reference_conv2d,
                       scatter_indices, window_placements)
from .mapped_net import (executed_steps, layer_schedule, mapped_conv2d,
                         mapped_conv2d_jit, mapped_net_apply,
                         network_schedule, reference_net_apply,
                         zero_pruned_kernels)
