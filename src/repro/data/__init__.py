from .synthetic import image_task, TokenStream
from .pipeline import ShardedDataPipeline
