"""Sharded, restart-exact data pipeline.

Wraps a :class:`TokenStream` (or any ``batch_at(step, shard, n_shards)``
source) with:

* per-data-shard slicing — each data-parallel rank pulls only its shard;
* a monotone step cursor with ``skip_to(step)`` — restart-exact resume
  (checkpoint stores only the step number);
* host-side double-buffering (prefetch thread) so input generation
  overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class ShardedDataPipeline:
    def __init__(self, source, shard: int = 0, n_shards: int = 1,
                 prefetch: int = 2):
        self.source = source
        self.shard = shard
        self.n_shards = n_shards
        self.step = 0
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- restart-exact resume ------------------------------------------
    def skip_to(self, step: int) -> None:
        if self._thread is not None:
            raise RuntimeError("skip_to before starting prefetch")
        self.step = step

    # -- synchronous path ----------------------------------------------
    def next(self) -> np.ndarray:
        batch = self.source.batch_at(self.step, self.shard, self.n_shards)
        self.step += 1
        return batch

    # -- prefetching path ------------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self.shard, self.n_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self) -> None:
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def next_prefetched(self) -> np.ndarray:
        if self._thread is None:
            return self.next()
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.next_prefetched()
