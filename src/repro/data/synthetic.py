"""Seeded synthetic datasets (offline substitute for MNIST/CIFAR/C4).

* :func:`image_task` — K-class image classification: class prototypes in
  a random low-frequency basis + per-sample noise; learnable but not
  trivial (class separation controls difficulty).
* :class:`TokenStream` — deterministic LM token stream: a mixture of
  order-2 Markov chains (one transition table per "document topic"), so a
  model must learn context-dependent statistics; fully determined by
  (seed, step, shard) — restart-exact for checkpoint/resume tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def image_task(key: jax.Array, *, n_train: int, n_test: int, size: int,
               channels: int, num_classes: int,
               noise: float = 0.6) -> Tuple[jnp.ndarray, ...]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    protos = jax.random.normal(k1, (num_classes, channels, size, size))
    # low-pass the prototypes for spatial structure
    kernel = jnp.ones((1, 1, 3, 3)) / 9.0
    protos = jax.lax.conv_general_dilated(
        protos, jnp.tile(kernel, (channels, 1, 1, 1)),
        (1, 1), "SAME", feature_group_count=channels,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))

    def make(k, n):
        ky, kn = jax.random.split(k)
        y = jax.random.randint(ky, (n,), 0, num_classes)
        x = protos[y] + noise * jax.random.normal(
            kn, (n, channels, size, size))
        return x, y

    xs, ys = make(k3, n_train)
    xt, yt = make(k4, n_test)
    return xs, ys, xt, yt


@dataclass
class TokenStream:
    """Deterministic order-2 Markov LM stream.

    ``batch_at(step, shard, n_shards)`` returns the (local_batch, seq+1)
    token block for that step/shard — pure function of (seed, step,
    shard), which is what makes restart-exact data skipping trivial
    (runtime/recovery.py just replays the step counter).
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_topics: int = 8
    _tables: np.ndarray = None  # lazily built (n_topics, V, V) cumulative

    def __post_init__(self):
        rs = np.random.RandomState(self.seed)
        v = min(self.vocab, 512)      # dense tables over a head vocabulary
        raw = rs.dirichlet(np.ones(v) * 0.05, size=(self.n_topics, v))
        self._tables = np.cumsum(raw, axis=-1)
        self._head_vocab = v

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> np.ndarray:
        if self.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        local = self.global_batch // n_shards
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + step * 977 + shard) % (2 ** 31 - 1))
        topics = rs.randint(0, self.n_topics, size=local)
        u = rs.random_sample((local, self.seq_len + 1))
        tabs = self._tables[topics]               # (local, v, v)
        tok = rs.randint(0, self._head_vocab, size=local)
        out = np.empty((local, self.seq_len + 1), np.int32)
        idx = np.arange(local)
        for i in range(self.seq_len + 1):         # sequential in time only
            rows = tabs[idx, tok]                 # (local, v) cumulative
            tok = np.minimum((rows < u[:, i:i + 1]).sum(-1),
                             self._head_vocab - 1)
            out[:, i] = tok
        return out
