"""Shared model primitives (pure JAX, param pytrees, bf16 compute)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16

# fast_norm (set by the launcher policy for optimized train cells): keep
# norm elementwise chains in bf16, accumulating the variance reduction in
# fp32 *inside the reduce* — avoids materialising two fp32 (B,S,D)
# copies per norm per pass, the dominant HBM term on dense train cells
# (EXPERIMENTS.md §Perf iteration 12).
import contextlib
import contextvars

_FAST_NORM = contextvars.ContextVar("fast_norm", default=False)


@contextlib.contextmanager
def norm_policy(fast: bool):
    tok = _FAST_NORM.set(fast)
    try:
        yield
    finally:
        _FAST_NORM.reset(tok)


def cast(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(COMPUTE_DTYPE)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    if _FAST_NORM.get() and dt == jnp.bfloat16:
        # fp32-accumulated reduction, bf16 elementwise (no fp32 copies)
        var = jnp.mean(x * x, axis=-1, keepdims=True,
                       dtype=jnp.float32)
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return x * inv * cast(scale)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * cast(scale)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * cast(scale) + cast(bias)


def rotary_cos_sin(positions: jnp.ndarray, dim: int,
                   base: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int -> cos/sin (..., dim//2) in fp32."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
                 rot_dim: Optional[int] = None) -> jnp.ndarray:
    """x: (..., seq, heads, dim); cos/sin: (..., seq, dim_rot//2).
    Rotates the first `rot_dim` features (partial rotary supported)."""
    d = x.shape[-1] if rot_dim is None else rot_dim
    xr, xp = x[..., :d], x[..., d:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


def sinusoidal_at(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """positions (n,) (may be traced) -> (n, dim)."""
    inv = 1.0 / (10000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def sinusoidal_positions(n: int, dim: int) -> jnp.ndarray:
    return sinusoidal_at(jnp.arange(n), dim)


def dense_init(key: jax.Array, shape: Tuple[int, ...],
               fan_in: Optional[int] = None) -> jnp.ndarray:
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / math.sqrt(fan_in)))


def embed_init(key: jax.Array, shape: Tuple[int, ...]) -> jnp.ndarray:
    return jax.random.normal(key, shape, jnp.float32) * 0.02


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab so the embedding shards cleanly over the model axis."""
    return ((v + multiple - 1) // multiple) * multiple


def keygen(key: jax.Array):
    """Infinite stream of fresh PRNG keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub
