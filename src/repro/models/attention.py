"""Attention: GQA / MQA / sliding-window / local / MLA, with flash-style
q-block streaming so 32k-prefill activations stay O(S * block) and
sliding-window variants are genuinely sub-quadratic (the kv slice per
q-block is bounded by window + block).

Shapes: q (B, Sq, Hq, Dh); k/v (B, Sk, Hkv, Dh) with Hq % Hkv == 0.
GQA is computed grouped (no kv head materialised expansion).
All masks derive from absolute positions, so the same code serves train
(q_offset=0), prefill, and decode (Sq=1, q_offset=cache position).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# --- tunable attention policy (set by the launcher, read at trace time) ---
# scores_sharding: NamedSharding for the (B,Hkv,G,Bq,Sk) score tensor.
#   Context-parallel q-row sharding rescues archs whose head counts don't
#   divide the model axis (SPerf iteration: qwen 40H on a 16-way axis).
# scores_dtype: jnp.float32 (default) or bf16 softmax storage.
_SCORES_SHARDING = contextvars.ContextVar("scores_sharding", default=None)
_SCORES_DTYPE = contextvars.ContextVar("scores_dtype", default=None)
_CP_AXIS = contextvars.ContextVar("cp_axis", default=None)  # (mesh, bd)
_INNER_REMAT = contextvars.ContextVar("inner_remat", default=False)
_POLICY_MESH = contextvars.ContextVar("policy_mesh", default=None)


def policy_mesh():
    """Mesh registered by the launcher policy (None on host meshes)."""
    return _POLICY_MESH.get()


@contextlib.contextmanager
def attention_policy(scores_sharding=None, scores_dtype=None,
                     cp_axis=None, inner_remat=False, mesh=None):
    """cp_axis: (mesh, batch_dim_name) enables context-parallel q blocks:
    each q block is row-sharded over 'model' and k/v are gathered inside
    attention (cheap: one layer's k/v per chip), so scores, softmax and
    the out-matmul are fully local — the rescue path for head counts
    that don't divide the model axis."""
    t1 = _SCORES_SHARDING.set(scores_sharding)
    t2 = _SCORES_DTYPE.set(scores_dtype)
    t3 = _CP_AXIS.set(cp_axis)
    t4 = _INNER_REMAT.set(inner_remat)
    t5 = _POLICY_MESH.set(mesh)
    try:
        yield
    finally:
        _SCORES_SHARDING.reset(t1)
        _SCORES_DTYPE.reset(t2)
        _CP_AXIS.reset(t3)
        _INNER_REMAT.reset(t4)
        _POLICY_MESH.reset(t5)


def _cp_constrain(qb, k, v):
    """Row-shard a q block over 'model'; replicate k/v heads/dh."""
    cp = _CP_AXIS.get()
    if cp is None:
        return qb, k, v
    mesh, bd = cp
    from jax.sharding import NamedSharding, PartitionSpec as P
    if qb.shape[1] % mesh.shape["model"] == 0:
        qb = jax.lax.with_sharding_constraint(
            qb, NamedSharding(mesh, P(bd, "model", None, None, None)))
        k = jax.lax.with_sharding_constraint(
            k, NamedSharding(mesh, P(bd, None, None, None)))
        v = jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P(bd, None, None, None)))
    return qb, k, v


def _cp_constrain_out(out):
    """Pin the attention output to q-row sharding too: wsc transposes to
    itself, so the *cotangent* of out stays row-sharded in backward —
    without this, d(scores) = dout x v contracts a sharded dv and
    all-reduces a score-sized tensor (measured: 5.5 TB/chip on qwen)."""
    cp = _CP_AXIS.get()
    if cp is None:
        return out
    mesh, bd = cp
    from jax.sharding import NamedSharding, PartitionSpec as P
    if out.shape[1] % mesh.shape["model"] == 0:
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(bd, "model", None, None, None)))
    return out


def _constrain_scores(scores: jnp.ndarray) -> jnp.ndarray:
    ns = _SCORES_SHARDING.get()
    if ns is None:
        return scores
    spec = ns.spec
    # applicable only if every named dim divides (decode q=1 doesn't)
    for dim, name in enumerate(spec):
        if name is not None:
            ax = name if isinstance(name, str) else name[0]
            if scores.shape[dim] % ns.mesh.shape[ax]:
                return scores
    return jax.lax.with_sharding_constraint(scores, ns)


def _attend_block(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  pos_q: jnp.ndarray, pos_k: jnp.ndarray, *,
                  causal: bool, window: Optional[int],
                  kv_len: Optional[jnp.ndarray],
                  scale: float) -> jnp.ndarray:
    """One q-block against one kv-block.  q (B,Bq,Hkv,G,Dh);
    k/v (B,Sk,Hkv,Dh); returns (B,Bq,Hkv,G,Dh)."""
    sdt = _SCORES_DTYPE.get() or jnp.float32
    scores = jnp.einsum("bqhgd,bshd->bhgqs", q, k,
                        preferred_element_type=sdt) * scale
    scores = _constrain_scores(scores.astype(sdt))
    mask = jnp.ones(scores.shape[-2:], bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    if kv_len is not None:        # decode: ignore cache beyond fill level
        mask &= (pos_k < kv_len)[None, :]
    scores = jnp.where(mask, scores, jnp.asarray(NEG_INF, sdt))
    # row stats in fp32 (stable); storage in scores_dtype — the bf16
    # option halves the softmax-chain HBM traffic (bf16 keeps the fp32
    # exponent range, so the -1e30 mask value survives)
    m = jax.lax.stop_gradient(
        jnp.max(scores.astype(jnp.float32), -1, keepdims=True))
    e = jnp.exp(scores - m.astype(sdt))
    denom = jnp.sum(e.astype(jnp.float32), -1, keepdims=True)
    w = (e / denom.astype(sdt)).astype(v.dtype)
    return jnp.einsum("bhgqs,bshd->bqhgd", w, v)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True,
              window: Optional[int] = None,
              q_offset=0,
              kv_len: Optional[jnp.ndarray] = None,
              q_block: int = 512) -> jnp.ndarray:
    """Multi-head attention with q-block streaming.

    window: sliding/local attention width (None = full).
    q_offset: absolute position of q[0] (decode/continuation).
    kv_len: actual fill level of the kv buffer (decode caches).
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]               # may differ from dh (MLA)
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, hkv, g, dh)

    if sq <= q_block:
        pos_q = q_offset + jnp.arange(sq)
        pos_k = jnp.arange(sk)
        qg, k, v = _cp_constrain(qg, k, v)
        out = _attend_block(qg, k, v, pos_q, pos_k, causal=causal,
                            window=window, kv_len=kv_len, scale=scale)
        out = _cp_constrain_out(out)
        return out.reshape(b, sq, hq, dv)

    sq_orig = sq
    if sq % q_block:                 # pad q; padded rows are discarded
        pad = q_block - sq % q_block
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        sq = sq + pad
    n_blocks = sq // q_block

    cp = _CP_AXIS.get()
    if cp is not None:
        # gather q once per layer (cheap) so per-block slicing and the
        # per-block q-row resharding are purely local
        mesh, bd = cp
        from jax.sharding import NamedSharding, PartitionSpec as P
        qg = jax.lax.with_sharding_constraint(
            qg, NamedSharding(mesh, P(bd, None, None, None, None)))

    # sliding window: each q-block only needs a bounded kv slice
    kv_slice = sk if window is None else min(sk, window + q_block)

    def _block(qb, kb, vb, pos_q, pos_k):
        qb, kb, vb = _cp_constrain(qb, kb, vb)
        out = _attend_block(qb, kb, vb, pos_q, pos_k, causal=causal,
                            window=window, kv_len=kv_len, scale=scale)
        return _cp_constrain_out(out)

    if _INNER_REMAT.get():
        # remat: scores/softmax recomputed in backward instead of
        # stacking O(n_blocks) score-sized residuals per layer
        _block = jax.checkpoint(_block)

    def body(carry, qb_idx):
        qb = jax.lax.dynamic_slice_in_dim(qg, qb_idx * q_block, q_block, 1)
        pos_q = q_offset + qb_idx * q_block + jnp.arange(q_block)
        if kv_slice == sk:
            kb, vb = k, v
            kv_start = jnp.array(0, jnp.int32)
        else:
            kv_start = jnp.clip(q_offset + qb_idx * q_block
                                - (kv_slice - q_block), 0, sk - kv_slice)
            kb = jax.lax.dynamic_slice_in_dim(k, kv_start, kv_slice, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, kv_start, kv_slice, 1)
        pos_k = kv_start + jnp.arange(kv_slice)
        out = _block(qb, kb, vb, pos_q, pos_k)
        return carry, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_blocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, dv)
    return out.reshape(b, sq, hq, dv)[:, :sq_orig]


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, length: int, hkv: int, dh: int,
                  dtype=jnp.bfloat16) -> dict:
    return {"k": jnp.zeros((batch, length, hkv, dh), dtype),
            "v": jnp.zeros((batch, length, hkv, dh), dtype)}


def cache_insert(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos) -> dict:
    """Insert (B, S_new, Hkv, Dh) at position `pos` (static or traced).
    For ring (sliding-window) caches pass pos % length."""
    return {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new,
                                                     pos, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new,
                                                     pos, 1)}


def decode_attention_ring(q: jnp.ndarray, cache: dict, step,
                          window: int) -> jnp.ndarray:
    """Decode vs a ring buffer of size `window` (SWA long-context decode).
    Ring entries hold absolute positions step-window+1..step (mod wrap);
    masking by absolute position is wrap-invariant, so plain full
    attention over the ring with kv_len handles it."""
    b, sq, hq, dh = q.shape
    length = cache["k"].shape[1]
    # absolute position of ring slot i: derive from step
    slot = jnp.arange(length)
    cur = step % length
    abs_pos = jnp.where(slot <= cur, step - cur + slot,
                        step - cur + slot - length)
    hkv = cache["k"].shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, cache["k"]) * scale
    scores = scores.astype(jnp.float32)
    valid = (abs_pos >= 0) & (abs_pos <= step) & (abs_pos > step - window)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, -1).astype(cache["v"].dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", w, cache["v"])
    return out.reshape(b, sq, hq, dh)
