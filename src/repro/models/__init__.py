# LM substrate: pure-JAX model definitions for the assigned architectures.
from .config import ArchConfig, BlockSpec, Stage
from .moe import MoEConfig
from .ssm import SSMConfig
from . import transformer
