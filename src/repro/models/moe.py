"""Mixture-of-Experts: token-choice top-k routing with capacity, GShard
one-hot dispatch einsums, computed in sequence chunks.

Chunking matters at 32k context: dispatch/combine tensors are
O(B * chunk * E * capacity) instead of O(B * S * E * capacity), so the
scan keeps MoE activation memory flat in S while the expert matmuls stay
MXU-shaped.  Expert weights are (E, D, F) — sharded E over 'model' (EP)
when E divides the axis, else F over 'model' (TP fallback, e.g. Mixtral's
8 experts on a 16-way axis).

FLOP accounting (for roofline): per token, experts cost
``3 * 2 * D * F * top_k`` (gated MLP) and dispatch overhead is
``O(chunk * cf)`` relative — a few percent at chunk=512.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .attention import policy_mesh
from .common import cast


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0            # shared (always-on) experts, dsv2-style
    capacity_factor: float = 1.25
    chunk: int = 512


def capacity(cfg: MoEConfig, chunk_len: int) -> int:
    return max(1, math.ceil(chunk_len * cfg.top_k * cfg.capacity_factor
                            / cfg.n_experts))


def route(logits: jnp.ndarray, cfg: MoEConfig, cap: int
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits (B, T, E) -> dispatch (B,T,E,cap) one-hot, combine (same,
    prob-weighted).  Top-k per token; overflow beyond expert capacity is
    dropped (standard token-dropping MoE)."""
    b, t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)          # (B,T,K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)        # renormalise

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)     # (B,T,K,E)
    flat = onehot.reshape(b, t * cfg.top_k, e)
    ranks = jnp.cumsum(flat, axis=1) - flat                  # slots used before
    ranks = ranks.reshape(b, t, cfg.top_k, e)
    keep = (ranks < cap) * onehot
    slot = jax.nn.one_hot(jnp.sum(ranks * onehot, -1), cap,
                          dtype=jnp.float32)                 # (B,T,K,cap)
    disp = jnp.einsum("btke,btkc->btec", keep, slot)         # (B,T,E,cap)
    comb = jnp.einsum("btke,btkc,btk->btec", keep, slot, top_p)
    return disp, comb


def expert_ffn(xe: jnp.ndarray, wi, wg, wo) -> jnp.ndarray:
    """xe (B,E,cap,D); weights (E,D,F)/(E,F,D) -> (B,E,cap,D)."""
    h = jnp.einsum("becd,edf->becf", xe, cast(wi))
    g = jnp.einsum("becd,edf->becf", xe, cast(wg))
    return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, cast(wo))


def moe_ffn(x: jnp.ndarray, params: dict, cfg: MoEConfig) -> jnp.ndarray:
    """x (B,S,D) -> (B,S,D).  params: router (D,E), wi/wg (E,D,F),
    wo (E,F,D), optional shared_{wi,wg,wo} ((D,Fs)/(Fs,D))."""
    b, s, d = x.shape
    chunk = min(cfg.chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by moe chunk {chunk}")
    cap = capacity(cfg, chunk)
    n_chunks = s // chunk

    # FSDP gather-at-use: expert weights are 2-D sharded (data x model);
    # contracting a data-sharded dim makes GSPMD all-reduce the (much
    # bigger) activation outputs.  Gathering the weight shards once per
    # layer is the standard FSDP schedule — wsc transposes to a
    # reduce-scatter of the weight grads in backward (SPerf: mixtral
    # train collectives 57s -> measured below).
    mesh = policy_mesh()
    if mesh is not None:
        def gather(w, spec):
            return jax.lax.with_sharding_constraint(
                cast(w), NamedSharding(mesh, spec))
        mdl = ("model" if params["wi"].shape[-1] % mesh.shape["model"] == 0
               else None)
        params = dict(params)
        params["wi"] = gather(params["wi"], P(None, None, mdl))
        params["wg"] = gather(params["wg"], P(None, None, mdl))
        params["wo"] = gather(params["wo"], P(None, mdl, None))
        params["router"] = gather(params["router"], P(None, None))

    @jax.checkpoint
    def one_chunk(xc):
        # remat: dispatch one-hots / expert intermediates are recomputed
        # in backward instead of being stacked across the chunk scan
        logits = jnp.einsum("btd,de->bte", xc, cast(params["router"]))
        disp, comb = route(logits, cfg, cap)
        xe = jnp.einsum("btec,btd->becd", disp.astype(xc.dtype), xc)
        ye = expert_ffn(xe, params["wi"], params["wg"], params["wo"])
        return jnp.einsum("btec,becd->btd", comb.astype(xc.dtype), ye)

    if n_chunks == 1:
        y = one_chunk(x)
    else:
        xcs = x.reshape(b, n_chunks, chunk, d)
        _, ys = jax.lax.scan(lambda c, xc: (c, one_chunk(xc)), None,
                             jnp.moveaxis(xcs, 1, 0))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)

    if cfg.n_shared:
        h = jnp.einsum("bsd,df->bsf", x, cast(params["shared_wi"]))
        g = jnp.einsum("bsd,df->bsf", x, cast(params["shared_wg"]))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h,
                           cast(params["shared_wo"]))
    return y
