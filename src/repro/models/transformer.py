"""Model assembly: param init, train/prefill/decode forward passes.

Layers are scanned (jax.lax.scan over params stacked on an n_units axis)
so HLO size stays flat in depth; the scan unit is the stage's repeating
block pattern (e.g. RecurrentGemma's (rec, rec, attn)).  Training wraps
the scan unit in jax.checkpoint (full remat inside a unit, activations
saved only at unit boundaries).

Caches mirror the stage/param structure: per position-in-unit, a pytree
stacked over n_units.  Sliding-window attention uses ring buffers; MLA
caches the 512-d compressed kv + shared rope key (the paper-faithful
small cache); SSD/RG-LRU cache O(1) recurrent states + conv tails.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from .common import (apply_rotary, cast, dense_init, embed_init, keygen,
                     layer_norm, rms_norm, rotary_cos_sin, sinusoidal_at,
                     sinusoidal_positions)
from .config import ArchConfig, BlockSpec, Stage
from .moe import moe_ffn
from .rglru import rg_lru, rg_lru_step
from .ssm import causal_conv1d, ssd_chunked, ssd_decode_step


def _norm(x, p, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def _norm_params(cfg: ArchConfig, d: int) -> Dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Block param init
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ArchConfig, spec: BlockSpec) -> Dict:
    ks = keygen(key)
    d, dh = cfg.d_model, cfg.head_dim
    p: Dict[str, Any] = {}

    if spec.mixer == "gqa":
        h, hk = cfg.n_heads, cfg.n_kv_heads
        p["attn"] = {
            "ln": _norm_params(cfg, d),
            "wq": dense_init(next(ks), (d, h, dh), d),
            "wk": dense_init(next(ks), (d, hk, dh), d),
            "wv": dense_init(next(ks), (d, hk, dh), d),
            "wo": dense_init(next(ks), (h, dh, d), h * dh),
        }
        if cfg.qkv_bias:
            p["attn"]["bq"] = jnp.zeros((h, dh), jnp.float32)
            p["attn"]["bk"] = jnp.zeros((hk, dh), jnp.float32)
            p["attn"]["bv"] = jnp.zeros((hk, dh), jnp.float32)
    elif spec.mixer == "mla":
        h = cfg.n_heads
        dr, dl = cfg.rope_dim, cfg.kv_lora
        p["attn"] = {
            "ln": _norm_params(cfg, d),
            "wq": dense_init(next(ks), (d, h, dh + dr), d),
            "w_dkv": dense_init(next(ks), (d, dl), d),
            "w_kr": dense_init(next(ks), (d, dr), d),
            "kv_ln": {"scale": jnp.ones((dl,), jnp.float32)},
            "w_uk": dense_init(next(ks), (dl, h, dh), dl),
            "w_uv": dense_init(next(ks), (dl, h, dh), dl),
            "wo": dense_init(next(ks), (h, dh, d), h * dh),
        }
    elif spec.mixer == "rec":
        w = cfg.rnn_width
        p["rec"] = {
            "ln": _norm_params(cfg, d),
            "wx": dense_init(next(ks), (d, w), d),
            "wgate": dense_init(next(ks), (d, w), d),
            "conv_w": dense_init(next(ks), (cfg.conv_width, w), cfg.conv_width),
            "wr": dense_init(next(ks), (w, w), w),
            "wi": dense_init(next(ks), (w, w), w),
            "lam": jnp.linspace(0.5, 4.0, w).astype(jnp.float32),
            "wout": dense_init(next(ks), (w, d), w),
        }
    elif spec.mixer == "ssd":
        s = cfg.ssm
        di, hh, pp = s.d_inner, s.n_heads, s.head_dim
        gn = 2 * s.n_groups * s.d_state
        p["ssd"] = {
            "ln": _norm_params(cfg, d),
            "wx": dense_init(next(ks), (d, di), d),
            "wz": dense_init(next(ks), (d, di), d),
            "wbc": dense_init(next(ks), (d, gn), d),
            "wdt": dense_init(next(ks), (d, hh), d),
            "dt_bias": jnp.zeros((hh,), jnp.float32),
            "a_log": jnp.log(jnp.linspace(1.0, 16.0, hh)).astype(jnp.float32),
            "d_skip": jnp.ones((hh,), jnp.float32),
            "conv_w": dense_init(next(ks), (s.conv_width, di + gn),
                                 s.conv_width),
            "gate_ln": {"scale": jnp.ones((di,), jnp.float32)},
            "wout": dense_init(next(ks), (di, d), di),
        }

    if spec.cross:
        h = cfg.n_heads
        p["cross"] = {
            "ln": _norm_params(cfg, d),
            "wq": dense_init(next(ks), (d, h, dh), d),
            "wk": dense_init(next(ks), (d, h, dh), d),
            "wv": dense_init(next(ks), (d, h, dh), d),
            "wo": dense_init(next(ks), (h, dh, d), h * dh),
        }

    if spec.ffn in ("dense", "gelu"):
        f = cfg.d_ff
        p["mlp"] = {
            "ln": _norm_params(cfg, d),
            "wi": dense_init(next(ks), (d, f), d),
            "wo": dense_init(next(ks), (f, d), f),
        }
        if spec.ffn == "dense":
            p["mlp"]["wg"] = dense_init(next(ks), (d, f), d)
    elif spec.ffn == "moe":
        m = cfg.moe
        e, f = m.n_experts, m.d_ff
        p["moe"] = {
            "ln": _norm_params(cfg, d),
            "router": dense_init(next(ks), (d, e), d),
            "wi": dense_init(next(ks), (e, d, f), d),
            "wg": dense_init(next(ks), (e, d, f), d),
            "wo": dense_init(next(ks), (e, f, d), f),
        }
        if m.n_shared:
            fs = m.n_shared * f
            p["moe"]["shared_wi"] = dense_init(next(ks), (d, fs), d)
            p["moe"]["shared_wg"] = dense_init(next(ks), (d, fs), d)
            p["moe"]["shared_wo"] = dense_init(next(ks), (fs, d), fs)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict:
    ks = keygen(key)
    d, v = cfg.d_model, cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": embed_init(next(ks), (v, d)),
        "final_norm": _norm_params(cfg, d),
    }
    if not cfg.tied_embeddings:
        params["head"] = dense_init(next(ks), (d, v), d)

    def stage_params(stages):
        out = []
        for st in stages:
            unit = []
            for spec in st.unit:
                sub = jax.random.split(next(ks), st.n_units)
                unit.append(jax.vmap(lambda k: init_block(k, cfg, spec))(sub))
            out.append(tuple(unit))
        return tuple(out)

    params["stages"] = stage_params(cfg.stages)
    if cfg.kind == "encdec":
        enc_spec = Stage((BlockSpec(mixer="gqa", ffn="gelu", causal=False),),
                         cfg.n_enc_layers)
        params["enc_stages"] = stage_params((enc_spec,))
        params["enc_norm"] = _norm_params(cfg, d)
    return params


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    total = sum(math.prod(leaf.shape) for leaf in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        n_moe = sum(st.n_units * sum(1 for sp in st.unit if sp.ffn == "moe")
                    for st in cfg.stages)
        per_expert = 3 * cfg.d_model * m.d_ff
        total -= n_moe * per_expert * (m.n_experts - m.top_k)
    return total


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, spec: BlockSpec, batch: int,
                     length: int, enc_len: int = 0,
                     dtype=jnp.bfloat16) -> Dict:
    p: Dict[str, Any] = {}
    if spec.mixer == "gqa":
        lc = min(length, spec.window) if spec.window else length
        p["attn"] = attn_lib.init_kv_cache(batch, lc, cfg.n_kv_heads,
                                           cfg.head_dim, dtype)
    elif spec.mixer == "mla":
        p["attn"] = {"ckv": jnp.zeros((batch, length, cfg.kv_lora), dtype),
                     "kr": jnp.zeros((batch, length, cfg.rope_dim), dtype)}
    elif spec.mixer == "rec":
        w = cfg.rnn_width
        p["rec"] = {"h": jnp.zeros((batch, w), dtype),
                    "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}
    elif spec.mixer == "ssd":
        s = cfg.ssm
        p["ssd"] = {
            "state": jnp.zeros((batch, s.n_heads, s.head_dim, s.d_state),
                               dtype),
            "conv": jnp.zeros((batch, s.conv_width - 1,
                               s.d_inner + 2 * s.n_groups * s.d_state),
                              dtype)}
    if spec.cross:
        p["cross"] = attn_lib.init_kv_cache(batch, enc_len, cfg.n_heads,
                                            cfg.head_dim, dtype)
    return p


def init_cache(cfg: ArchConfig, batch: int, length: int,
               enc_len: int = 0, dtype=jnp.bfloat16):
    out = []
    for st in cfg.stages:
        unit = []
        for spec in st.unit:
            one = init_block_cache(cfg, spec, batch, length, enc_len, dtype)
            unit.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (st.n_units,) + x.shape), one))
        out.append(tuple(unit))
    return tuple(out)


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _rope_dims(cfg: ArchConfig) -> int:
    rd = int(cfg.head_dim * cfg.rope_frac)
    return rd - rd % 2


def _pad_seq(a: jnp.ndarray, target: int) -> jnp.ndarray:
    """Pad dim 1 (sequence) with zeros up to `target`."""
    if a.shape[1] >= target:
        return a
    pad = [(0, 0)] * a.ndim
    pad[1] = (0, target - a.shape[1])
    return jnp.pad(a, pad)


def _gqa_block(x, p, spec, cfg, mode, cache, pos, cache_len=None):
    h = _norm(x, p["ln"], cfg)
    q = jnp.einsum("bsd,dhe->bshe", h, cast(p["wq"]))
    k = jnp.einsum("bsd,dhe->bshe", h, cast(p["wk"]))
    v = jnp.einsum("bsd,dhe->bshe", h, cast(p["wv"]))
    if "bq" in p:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    rd = _rope_dims(cfg)
    if rd and spec.causal:
        if mode == "decode":
            positions = jnp.full((1,), pos)
        else:
            positions = jnp.arange(x.shape[1])
        cos, sin = rotary_cos_sin(positions, rd, cfg.rope_base)
        q = apply_rotary(q, cos, sin, rd)
        k = apply_rotary(k, cos, sin, rd)

    new_cache = None
    if mode == "decode":
        lc = cache["attn"]["k"].shape[1]
        ring = spec.window is not None and lc == spec.window
        slot = pos % lc if ring else pos
        c = attn_lib.cache_insert(cache["attn"], k, v, slot)
        new_cache = {"attn": c}
        if ring:
            out = attn_lib.decode_attention_ring(q, c, pos, spec.window)
        else:
            out = attn_lib.attention(q, c["k"], c["v"], causal=True,
                                     window=spec.window, q_offset=pos,
                                     kv_len=pos + 1)
    else:
        out = attn_lib.attention(q, k, v, causal=spec.causal,
                                 window=spec.window)
        if mode == "prefill":
            s = x.shape[1]
            horizon = max(cache_len or s, s)
            lc = min(spec.window, horizon) if spec.window else horizon
            if s >= lc:                      # keep last lc, ring-aligned
                kk, vv = k[:, -lc:], v[:, -lc:]
                shift = s % lc
                if shift:
                    kk = jnp.roll(kk, shift, axis=1)
                    vv = jnp.roll(vv, shift, axis=1)
            else:                            # room for future decode steps
                kk, vv = _pad_seq(k, lc), _pad_seq(v, lc)
            new_cache = {"attn": {"k": kk, "v": vv}}
    return x + jnp.einsum("bshe,hed->bsd", out, cast(p["wo"])), new_cache


def _mla_block(x, p, spec, cfg, mode, cache, pos, cache_len=None):
    h = _norm(x, p["ln"], cfg)
    dh, dr = cfg.head_dim, cfg.rope_dim
    q = jnp.einsum("bsd,dhe->bshe", h, cast(p["wq"]))
    qn, qr = q[..., :dh], q[..., dh:]
    ckv = jnp.einsum("bsd,dl->bsl", h, cast(p["w_dkv"]))
    ckv = rms_norm(ckv, p["kv_ln"]["scale"])
    kr = jnp.einsum("bsd,dr->bsr", h, cast(p["w_kr"]))

    if mode == "decode":
        positions = jnp.full((1,), pos)
    else:
        positions = jnp.arange(x.shape[1])
    cos, sin = rotary_cos_sin(positions, dr, cfg.rope_base)
    qr = apply_rotary(qr, cos, sin)
    kr = apply_rotary(kr[:, :, None, :], cos, sin)[:, :, 0]

    new_cache = None
    if mode == "decode":
        c = {"ckv": jax.lax.dynamic_update_slice_in_dim(
                 cache["attn"]["ckv"], ckv, pos, 1),
             "kr": jax.lax.dynamic_update_slice_in_dim(
                 cache["attn"]["kr"], kr, pos, 1)}
        new_cache = {"attn": c}
        ckv_all, kr_all = c["ckv"], c["kr"]
        kv_len = pos + 1
    else:
        ckv_all, kr_all = ckv, kr
        kv_len = None
        if mode == "prefill":
            horizon = max(cache_len or x.shape[1], x.shape[1])
            new_cache = {"attn": {"ckv": _pad_seq(ckv, horizon),
                                  "kr": _pad_seq(kr, horizon)}}

    k_nope = jnp.einsum("bsl,lhe->bshe", ckv_all, cast(p["w_uk"]))
    val = jnp.einsum("bsl,lhe->bshe", ckv_all, cast(p["w_uv"]))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  k_nope.shape[:3] + (dr,))], -1)
    qq = jnp.concatenate([qn, qr], -1)
    out = attn_lib.attention(qq, k, val, causal=True,
                             q_offset=pos if mode == "decode" else 0,
                             kv_len=kv_len)
    return x + jnp.einsum("bshe,hed->bsd", out, cast(p["wo"])), new_cache


def _rec_block(x, p, cfg, mode, cache, pos):
    h = _norm(x, p["ln"], cfg)
    xb = jnp.einsum("bsd,dw->bsw", h, cast(p["wx"]))
    gate = jnp.einsum("bsd,dw->bsw", h, cast(p["wgate"]))
    conv_state = cache["rec"]["conv"] if mode == "decode" else None
    xc, conv_new = causal_conv1d(xb, p["conv_w"], conv_state)
    r = jnp.einsum("bsw,wv->bsv", xc, cast(p["wr"]))
    i = jnp.einsum("bsw,wv->bsv", xc, cast(p["wi"]))
    if mode == "decode":
        y, h_last = rg_lru_step(xc, r, i, p["lam"], cache["rec"]["h"])
    else:
        y, h_last = rg_lru(xc, r, i, p["lam"])
    out = jnp.einsum("bsw,wd->bsd", jax.nn.gelu(gate) * y, cast(p["wout"]))
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"rec": {"h": h_last, "conv": conv_new.astype(
            cache["rec"]["conv"].dtype if cache else jnp.bfloat16)}}
    return x + out, new_cache


def _ssd_block(x, p, cfg, mode, cache, pos):
    s = cfg.ssm
    h = _norm(x, p["ln"], cfg)
    xs = jnp.einsum("bsd,di->bsi", h, cast(p["wx"]))
    z = jnp.einsum("bsd,di->bsi", h, cast(p["wz"]))
    bc = jnp.einsum("bsd,dg->bsg", h, cast(p["wbc"]))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, cast(p["wdt"])).astype(jnp.float32)
        + p["dt_bias"]).astype(x.dtype)

    conv_in = jnp.concatenate([xs, bc], -1)
    conv_state = cache["ssd"]["conv"] if mode == "decode" else None
    conv_out, conv_new = causal_conv1d(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    di = s.d_inner
    gn = s.n_groups * s.d_state
    xss = conv_out[..., :di].reshape(x.shape[0], x.shape[1],
                                     s.n_heads, s.head_dim)
    b = conv_out[..., di:di + gn].reshape(x.shape[0], x.shape[1],
                                          s.n_groups, s.d_state)
    c = conv_out[..., di + gn:].reshape(x.shape[0], x.shape[1],
                                        s.n_groups, s.d_state)
    if mode == "decode":
        y, state = ssd_decode_step(xss, dt, p["a_log"], b, c, p["d_skip"],
                                   cache["ssd"]["state"])
    else:
        y, state = ssd_chunked(xss, dt, p["a_log"], b, c, p["d_skip"], s)
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"]["scale"])
    out = jnp.einsum("bsi,id->bsd", y, cast(p["wout"]))
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"ssd": {"state": state,
                             "conv": conv_new.astype(jnp.bfloat16)}}
    return x + out, new_cache


def _cross_block(x, p, cfg, mode, cache, enc_out):
    h = _norm(x, p["ln"], cfg)
    q = jnp.einsum("bsd,dhe->bshe", h, cast(p["wq"]))
    if mode == "decode":
        k, v = cache["cross"]["k"], cache["cross"]["v"]
        new_cache = {"cross": cache["cross"]}
    else:
        k = jnp.einsum("bsd,dhe->bshe", enc_out, cast(p["wk"]))
        v = jnp.einsum("bsd,dhe->bshe", enc_out, cast(p["wv"]))
        new_cache = ({"cross": {"k": k, "v": v}} if mode == "prefill"
                     else None)
    out = attn_lib.attention(q, k, v, causal=False)
    return x + jnp.einsum("bshe,hed->bsd", out, cast(p["wo"])), new_cache


def _ffn(x, p, kind, cfg):
    h = _norm(x, p["ln"], cfg)
    if kind == "gelu":
        y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, cast(p["wi"])))
    else:
        y = (jax.nn.silu(jnp.einsum("bsd,df->bsf", h, cast(p["wg"])))
             * jnp.einsum("bsd,df->bsf", h, cast(p["wi"])))
    return x + jnp.einsum("bsf,fd->bsd", y, cast(p["wo"]))


def apply_block(x, p, spec: BlockSpec, cfg: ArchConfig, *, mode: str,
                cache=None, pos=None, enc_out=None, cache_len=None):
    new_cache: Dict[str, Any] = {}
    if spec.mixer == "gqa":
        x, nc = _gqa_block(x, p["attn"], spec, cfg, mode, cache, pos,
                           cache_len)
    elif spec.mixer == "mla":
        x, nc = _mla_block(x, p["attn"], spec, cfg, mode, cache, pos,
                           cache_len)
    elif spec.mixer == "rec":
        x, nc = _rec_block(x, p["rec"], cfg, mode, cache, pos)
    elif spec.mixer == "ssd":
        x, nc = _ssd_block(x, p["ssd"], cfg, mode, cache, pos)
    else:
        nc = None
    if nc:
        new_cache.update(nc)
    if spec.cross:
        x, nc = _cross_block(x, p["cross"], cfg, mode, cache, enc_out)
        if nc:
            new_cache.update(nc)
    if spec.ffn == "moe":
        h = _norm(x, p["moe"]["ln"], cfg)
        x = x + moe_ffn(h, p["moe"], cfg.moe)
    elif spec.ffn in ("dense", "gelu"):
        x = _ffn(x, p["mlp"], spec.ffn, cfg)
    return x, (new_cache or None)


# ---------------------------------------------------------------------------
# Stage / model forward
# ---------------------------------------------------------------------------

def _constrain(x, act_sharding):
    if act_sharding is not None:
        return jax.lax.with_sharding_constraint(x, act_sharding)
    return x


def run_stage(x, stage_p, stage: Stage, cfg: ArchConfig, *, mode: str,
              cache=None, pos=None, enc_out=None, remat: bool = True,
              cache_len=None, act_sharding=None):
    def unit_fn(x, per_unit):
        p_unit, c_unit = per_unit
        ncs = []
        for i, spec in enumerate(stage.unit):
            x, nc = apply_block(x, p_unit[i], spec, cfg, mode=mode,
                                cache=None if c_unit is None else c_unit[i],
                                pos=pos, enc_out=enc_out,
                                cache_len=cache_len)
            x = _constrain(x, act_sharding)
            ncs.append(nc)
        return x, tuple(ncs)

    fn = jax.checkpoint(unit_fn) if (mode == "train" and remat) else unit_fn
    xs = (stage_p, cache)
    x, new_caches = jax.lax.scan(fn, x, xs)
    return x, new_caches


def _embed(params, cfg, tokens):
    return cast(params["embed"])[tokens]


def _logits(params, cfg, x):
    x = _norm(x, params["final_norm"], cfg)
    w = params["embed"] if cfg.tied_embeddings else params["head"]
    if cfg.tied_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, cast(w))
    return jnp.einsum("bsd,dv->bsv", x, cast(w))


def _run_encoder(params, cfg, enc_embeds):
    x = enc_embeds + cast(sinusoidal_positions(enc_embeds.shape[1],
                                               cfg.d_model))[None]
    enc_spec = Stage((BlockSpec(mixer="gqa", ffn="gelu", causal=False),),
                     cfg.n_enc_layers)
    x, _ = run_stage(x, params["enc_stages"][0], enc_spec, cfg,
                     mode="encode", remat=False)
    return _norm(x, params["enc_norm"], cfg)


def forward(params, cfg: ArchConfig, *, tokens=None, prefix_embeds=None,
            enc_embeds=None, mode: str = "train", cache=None, pos=None,
            remat: bool = True, cache_len=None, act_sharding=None):
    """Unified forward.

    train:   tokens (B,S[-P]) [+ prefix/enc embeds] -> logits (B,S,Vp)
    prefill: same inputs -> (logits, cache)
    decode:  tokens (B,1), cache, pos -> (logits (B,1,Vp), cache)

    act_sharding: optional NamedSharding for (B,S,D) activations,
    re-asserted at every block boundary (keeps GSPMD from drifting to
    batch-replicated layouts inside the layer scan).
    """
    enc_out = None
    if cfg.kind == "encdec" and mode != "decode":
        enc_out = _run_encoder(params, cfg, cast(enc_embeds))

    x = _embed(params, cfg, tokens)
    x = _constrain(x, act_sharding)
    if prefix_embeds is not None and mode != "decode":
        x = jnp.concatenate([cast(prefix_embeds), x], axis=1)
    if cfg.kind == "encdec":
        if mode == "decode":
            posv = jnp.full((1,), pos)
        else:
            posv = jnp.arange(x.shape[1])
        x = x + cast(sinusoidal_at(posv, cfg.d_model))[None]

    new_caches = []
    for si, st in enumerate(cfg.stages):
        x, nc = run_stage(
            x, params["stages"][si], st, cfg, mode=mode,
            cache=None if cache is None else cache[si], pos=pos,
            enc_out=enc_out, remat=remat, cache_len=cache_len,
            act_sharding=act_sharding)
        new_caches.append(nc)

    if mode == "prefill":
        # only the last position's logits are consumed (next-token);
        # skipping the full (B,S,V) head matmul saves 2*S*D*V flops and
        # the matching HBM traffic per prefill (SPerf global fix)
        return _logits(params, cfg, x[:, -1:]), tuple(new_caches)
    logits = _logits(params, cfg, x)
    if mode == "train":
        return logits
    return logits, tuple(new_caches)
