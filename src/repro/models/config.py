"""Unified architecture configuration covering the 10 assigned archs.

A model is a sequence of *stages*; each stage is a repeating unit of block
specs scanned ``n_units`` times (jax.lax.scan over stacked params keeps
HLO size flat in depth).  A block spec is (mixer, ffn):

mixer: 'gqa' (incl. MQA/MHA/SWA/local via window), 'mla', 'rec' (RG-LRU),
       'ssd' (Mamba-2), 'none'
ffn:   'dense' (gated silu), 'gelu' (whisper), 'moe', 'none'
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from .moe import MoEConfig
from .ssm import SSMConfig
from .common import pad_vocab


@dataclass(frozen=True)
class BlockSpec:
    mixer: str = "gqa"
    ffn: str = "dense"
    window: Optional[int] = None        # SWA / local attention width
    causal: bool = True                 # False = bidirectional (encoder)
    cross: bool = False                 # cross-attention (encdec decoder)


@dataclass(frozen=True)
class Stage:
    unit: Tuple[BlockSpec, ...]
    n_units: int

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.n_units


@dataclass(frozen=True)
class ArchConfig:
    name: str
    d_model: int
    vocab: int
    stages: Tuple[Stage, ...]
    kind: str = "decoder"               # decoder | encdec
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    rope_frac: float = 1.0
    rope_base: float = 10000.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    # MLA (deepseek-v2)
    kv_lora: int = 0
    rope_dim: int = 64
    # MoE
    moe: Optional[MoEConfig] = None
    # SSM / recurrent
    ssm: Optional[SSMConfig] = None
    rnn_width: int = 0
    conv_width: int = 4
    # encoder (encdec) — mirrors decoder dims unless overridden
    n_enc_layers: int = 0
    # frontends (stubs per the brief)
    frontend: Optional[str] = None      # 'vision' | 'audio'
    n_prefix: int = 0                   # vision prefix embedding positions
    tied_embeddings: bool = True
    # bookkeeping
    sub_quadratic: bool = False         # eligible for long_500k
    notes: str = ""

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline math)."""
        from . import transformer
        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from . import transformer
        return transformer.count_params(self, active_only=True)
