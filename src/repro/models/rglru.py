"""RG-LRU recurrent mixer (RecurrentGemma / Griffin, arXiv:2402.19427).

r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
log a_t = -c * softplus(Lambda) * r_t          (c = 8)
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill use jax.lax.associative_scan over the sequence (log-depth,
collective-free along batch/width shards); decode is the O(1) update.
The full recurrent block is: linear-in -> causal conv1d(w=4) -> RG-LRU ->
gated linear-out, matching the Griffin recurrent block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


C_FACTOR = 8.0


def rg_lru(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
           lam: jnp.ndarray, h0: Optional[jnp.ndarray] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, r, i: (B,S,W); lam (W,).  Returns (y (B,S,W), h_last (B,W))."""
    f32 = jnp.float32
    log_a = (-C_FACTOR * jax.nn.softplus(lam.astype(f32))
             * jax.nn.sigmoid(r.astype(f32)))               # (B,S,W)
    a = jnp.exp(log_a)
    gated = (jax.nn.sigmoid(i.astype(f32)) * x.astype(f32)
             * jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)))

    if h0 is not None:
        # fold the carried state into the first step
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(f32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def rg_lru_step(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
                lam: jnp.ndarray, h: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token: x,r,i (B,1,W); h (B,W)."""
    f32 = jnp.float32
    log_a = (-C_FACTOR * jax.nn.softplus(lam.astype(f32))
             * jax.nn.sigmoid(r[:, 0].astype(f32)))
    a = jnp.exp(log_a)
    gated = (jax.nn.sigmoid(i[:, 0].astype(f32)) * x[:, 0].astype(f32)
             * jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)))
    h_new = a * h.astype(f32) + gated
    return h_new.astype(x.dtype)[:, None], h_new.astype(x.dtype)
