"""Mamba-2 SSD (state-space duality) mixer, chunked matmul form
[arXiv:2405.21060].

Train/prefill run the chunked algorithm: intra-chunk quadratic (masked
decay matmul, MXU-shaped) + inter-chunk state recurrence (scan over
chunks) — O(S * chunk) memory and O(S * chunk + S * ds * dh) compute.
Decode is the O(1) recurrent update.  kernels/ssd_chunk.py provides the
Pallas intra-chunk kernel; this module is the pure-JAX reference used by
the models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import cast


@dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int = 128
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., l) -> (..., l, l) with out[i,j] = sum a[j+1..i], -inf above
    the diagonal (decay matrix exponent)."""
    n = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((n, n), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                cfg: SSMConfig,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H) post-softplus; a_log (H,) with A=-exp(a_log);
    b,c (B,S,G,N); d_skip (H,).  Returns (y (B,S,H,P), state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    L = min(cfg.chunk, S)
    S_orig = S
    if S % L:
        # pad with dt=0 tokens: decay exp(0)=1 and contribution dt*x=0,
        # so padding is exact for both outputs and the final state
        pad = L - S % L
        def zpad(a):
            return jnp.pad(a, [(0, 0), (0, pad)]
                           + [(0, 0)] * (a.ndim - 2))
        x, dt, b, c = zpad(x), zpad(dt), zpad(b), zpad(c)
        S = S + pad
    nc = S // L
    rep = H // G

    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))                        # (H,)
    dA = dt.astype(f32) * A                                # (B,S,H)
    xdt = x * dt[..., None].astype(x.dtype)                # (B,S,H,P)

    # chunked views
    dA_c = dA.reshape(B, nc, L, H)
    x_c = xdt.reshape(B, nc, L, H, P)
    b_c = b.reshape(B, nc, L, G, N)
    c_c = c.reshape(B, nc, L, G, N)

    dA_cs = jnp.cumsum(dA_c, axis=2)                       # (B,nc,L,H)
    # intra-chunk: y[i] = sum_j<=i C_i . B_j exp(sum dA (j,i]) xdt[j]
    Ldec = jnp.exp(segsum(jnp.moveaxis(dA_c, -1, -2)))     # (B,nc,H,L,L)
    cb = jnp.einsum("bnigs,bnjgs->bngij", c_c, b_c)        # (B,nc,G,L,L)
    cb = jnp.repeat(cb, rep, axis=2)                       # (B,nc,H,L,L)
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp",
                         (cb * Ldec).astype(x.dtype), x_c)

    # chunk-final states: S_n = sum_j B_j exp(dA_total - dA_cs[j]) xdt[j]
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (B,nc,L,H)
    b_rep = jnp.repeat(b_c, rep, axis=3)                    # (B,nc,L,H,N)
    states = jnp.einsum("bnjhs,bnjh,bnjhp->bnhps", b_rep,
                        decay_to_end.astype(x.dtype), x_c)  # (B,nc,H,P,N)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (B,nc,H)
    h0 = (jnp.zeros((B, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(h, inp):
        dec, s_new = inp
        h_out = h                                          # state BEFORE chunk
        h = h * dec[..., None, None] + s_new.astype(f32)
        return h, h_out

    decs = jnp.moveaxis(chunk_decay, 1, 0)                  # (nc,B,H)
    snews = jnp.moveaxis(states, 1, 0)                      # (nc,B,H,P,N)
    h_final, h_prevs = jax.lax.scan(step, h0, (decs, snews))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nc,H,P,N)

    # inter-chunk contribution: C_i exp(dA_cs[i]) h_prev
    in_decay = jnp.exp(dA_cs)                               # (B,nc,L,H)
    c_rep = jnp.repeat(c_c, rep, axis=3)                    # (B,nc,L,H,N)
    y_inter = jnp.einsum("bnihs,bnih,bnhps->bnihp", c_rep,
                         in_decay.astype(x.dtype),
                         h_prevs.astype(x.dtype))

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + x * d_skip.astype(x.dtype)[None, None, :, None]
    return y[:, :S_orig], h_final.astype(x.dtype)


def ssd_decode_step(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                    state: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token: x (B,1,H,P); b,c (B,1,G,N); state (B,H,P,N)."""
    B, _, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    dA = jnp.exp(dt[:, 0].astype(f32) * A)                  # (B,H)
    b_rep = jnp.repeat(b[:, 0], rep, axis=1)                # (B,H,N)
    c_rep = jnp.repeat(c[:, 0], rep, axis=1)
    xdt = (x[:, 0] * dt[:, 0, :, None].astype(x.dtype)).astype(f32)
    new_state = (state.astype(f32) * dA[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xdt, b_rep.astype(f32)))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_rep.astype(f32))
    y = y.astype(x.dtype) + x[:, 0] * d_skip.astype(x.dtype)[None, :, None]
    return y[:, None], new_state.astype(state.dtype)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x (B,S,D); w (K,D); state (B,K-1,D) holds
    the trailing inputs of the previous segment.  Returns (y, new_state)."""
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * cast(w[i])[None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return y, new_state
