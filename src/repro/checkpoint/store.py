"""Sharded, atomic, restart-exact checkpointing (no orbax offline).

Layout:  <dir>/step_<N>/
            shard_<k>.npz        flat param/opt arrays owned by host k
            MANIFEST.json        tree structure + leaf->shard map + step
                                 + data cursor + mesh signature
Writes are crash-safe: everything lands in step_<N>.tmp/, the MANIFEST is
written last, then the directory is atomically renamed.  ``restore`` can
reshard onto a *different* mesh (elastic restart): leaves are loaded full
and re-placed under the new sharding — resharding correctness is tested
in tests/test_checkpoint.py.

Async mode: ``CheckpointStore(async_save=True)`` snapshots to host RAM
synchronously (device->host copy) and writes files on a worker thread —
training continues during the fsync.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", "?"))) for e in path)
        out.append((key, leaf))
    return out, jax.tree_util.tree_structure(tree)


def save_checkpoint(directory, step: int, state, *, extra: Optional[Dict]
                    = None, n_shards: int = 4) -> Path:
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": {},
                "n_shards": n_shards}
    shards: List[Dict[str, np.ndarray]] = [{} for _ in range(n_shards)]
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        shard = i % n_shards
        name = f"a{i}"
        shards[shard][name] = arr
        manifest["leaves"][key] = {"shard": shard, "name": name,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    for k, data in enumerate(shards):
        np.savez(tmp / f"shard_{k}.npz", **data)
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish
    return final


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and \
                (p / "MANIFEST.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory, like, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings` (optional pytree of NamedSharding)
    re-places leaves for the *current* mesh — elastic resharding."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    files = {k: np.load(d / f"shard_{k}.npz")
             for k in range(manifest["n_shards"])}

    leaves, _ = _flatten(like)
    out_leaves = []
    flat_sh = (None if shardings is None
               else [s for _, s in _flatten(shardings)[0]])
    for i, (key, leaf) in enumerate(leaves):
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = files[meta["shard"]][meta["name"]]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if flat_sh is not None and flat_sh[i] is not None:
            out_leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_structure(like)
    return (jax.tree_util.tree_unflatten(tree, out_leaves), step,
            manifest["extra"])


class CheckpointStore:
    """Keeps the last `keep` checkpoints; optional async writes."""

    def __init__(self, directory, keep: int = 3, async_save: bool = False):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state, extra: Optional[Dict] = None) -> None:
        # snapshot to host synchronously (cheap), write async if asked
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, extra)

    def _write(self, step, state, extra):
        save_checkpoint(self.directory, step, state, extra=extra)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        return restore_checkpoint(self.directory, like,
                                  shardings=shardings)
