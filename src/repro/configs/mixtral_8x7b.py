"""mixtral-8x7b [moe] — 32L d=4096 32H (GQA kv=8) 8 experts top-2
(d_ff 14336), sliding-window attention 4096 [arXiv:2401.04088; hf]."""
from repro.models import ArchConfig, BlockSpec, MoEConfig, Stage

_WINDOW = 4096


def config() -> ArchConfig:
    blk = BlockSpec(mixer="gqa", ffn="moe", window=_WINDOW)
    return ArchConfig(
        name="mixtral-8x7b",
        d_model=4096, vocab=32000,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336),
        stages=(Stage((blk,), 32),),
        tied_embeddings=False,
        sub_quadratic=True,
        notes="SWA -> long_500k RUNS with 4096-ring KV cache",
    )


def smoke_config() -> ArchConfig:
    blk = BlockSpec(mixer="gqa", ffn="moe", window=16)
    return ArchConfig(
        name="mixtral-8x7b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, chunk=64,
                      capacity_factor=2.0),   # no-drop for exact decode parity
        stages=(Stage((blk,), 3),),
        tied_embeddings=False,
        sub_quadratic=True,
    )
