"""Paper benchmark: CNN8 conv stack (Table I) + default 512x512 macro."""
from repro.core import ArrayConfig, networks

def config():
    return {"layers": networks.cnn8(), "array": ArrayConfig(512, 512)}
