"""Paper benchmark: DenseNet-40 (k=12) conv stack."""
from repro.core import ArrayConfig, networks

def config():
    return {"layers": networks.densenet40(), "array": ArrayConfig(512, 512)}
