"""deepseek-67b [dense] — llama-arch 95L d=8192 64H (GQA kv=8) ff=22016
vocab=102400 [arXiv:2401.02954; hf]."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b",
        d_model=8192, vocab=102400,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 95),),
        tied_embeddings=False,
        notes="full attention -> long_500k SKIP",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=352,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 3),),
        tied_embeddings=False,
    )
