"""Paper benchmark: MobileNetV1 depthwise-separable stack (SIV-C3)."""
from repro.core import ArrayConfig, networks

def config():
    return {"layers": networks.mobilenet(), "array": ArrayConfig(512, 512)}
