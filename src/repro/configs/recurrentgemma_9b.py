"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, pattern
(rec, rec, attn) [arXiv:2402.19427; unverified].  38 blocks = 12 x
(rec, rec, attn) + (rec, rec); local window 2048; MQA (kv=1);
d=4096 16H ff=12288 vocab=256000; temporal conv width 4."""
from repro.models import ArchConfig, BlockSpec, Stage

_WINDOW = 2048


def config() -> ArchConfig:
    rec = BlockSpec(mixer="rec", ffn="dense")
    attn = BlockSpec(mixer="gqa", ffn="dense", window=_WINDOW)
    return ArchConfig(
        name="recurrentgemma-9b",
        d_model=4096, vocab=256000,
        n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288,
        rnn_width=4096, conv_width=4,
        stages=(Stage((rec, rec, attn), 12), Stage((rec, rec), 1)),
        sub_quadratic=True,
        notes="long_500k RUNS (RG-LRU state + 2048-window ring cache)",
    )


def smoke_config() -> ArchConfig:
    rec = BlockSpec(mixer="rec", ffn="dense")
    attn = BlockSpec(mixer="gqa", ffn="dense", window=16)
    return ArchConfig(
        name="recurrentgemma-9b-smoke",
        d_model=64, vocab=512,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
        rnn_width=64, conv_width=4,
        stages=(Stage((rec, rec, attn), 2), Stage((rec, rec), 1)),
        sub_quadratic=True,
    )
