"""Architecture registry: one module per assigned arch (+ the paper's own
CNN benchmarks).  ``get_config(arch_id)`` returns the full ArchConfig;
``get_config(arch_id, smoke=True)`` the reduced same-family config used by
CPU smoke tests."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "internvl2_26b",
    "deepseek_67b",
    "mistral_large_123b",
    "stablelm_1_6b",
    "qwen1_5_32b",
    "whisper_base",
    "recurrentgemma_9b",
    "deepseek_v2_lite_16b",
    "mixtral_8x7b",
    "mamba2_130m",
)

CNN_IDS = ("cnn8", "inception", "densenet40", "mobilenet")


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.smoke_config() if smoke else mod.config()


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
