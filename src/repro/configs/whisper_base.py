"""whisper-base [audio] — enc-dec, 6+6L d=512 8H ff=2048 vocab=51865
[arXiv:2212.04356; unverified].  The conv frontend is a STUB per the
brief: input_specs provide precomputed frame embeddings (B, S, 512); the
mapping benchmarks expose the stubbed conv1d shapes to the paper's
technique separately (DESIGN.md SArch-applicability)."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        kind="encdec", n_enc_layers=6,
        d_model=512, vocab=51865,
        n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
        norm="layernorm", rope_frac=0.0,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="gelu", cross=True),),
                      6),),
        tied_embeddings=True,
        notes="enc-dec full attention -> long_500k SKIP; decode runs "
              "(self cache + cross attention)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base-smoke",
        kind="encdec", n_enc_layers=2,
        d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        norm="layernorm", rope_frac=0.0,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="gelu", cross=True),),
                      2),),
        tied_embeddings=True,
    )
