"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512, rope 64) + MoE
[arXiv:2405.04434; hf].  The brief's shape line is internally
inconsistent ("64e top-6" vs "160 routed"); we follow the actual V2-Lite:
27L, d=2048, 16H MLA, 64 routed experts (d_ff 1408) top-6 + 2 shared,
first layer dense (d_ff 10944) — noted in DESIGN.md."""
from repro.models import ArchConfig, BlockSpec, MoEConfig, Stage


def config() -> ArchConfig:
    dense = BlockSpec(mixer="mla", ffn="dense")
    moe = BlockSpec(mixer="mla", ffn="moe")
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        d_model=2048, vocab=102400,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=10944,
        kv_lora=512, rope_dim=64,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
        stages=(Stage((dense,), 1), Stage((moe,), 26)),
        tied_embeddings=False,
        notes="MLA full softmax -> long_500k SKIP per the brief's rule "
              "(compressed cache would fit)",
    )


def smoke_config() -> ArchConfig:
    dense = BlockSpec(mixer="mla", ffn="dense")
    moe = BlockSpec(mixer="mla", ffn="moe")
    return ArchConfig(
        name="deepseek-v2-lite-16b-smoke",
        d_model=128, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
        kv_lora=64, rope_dim=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, n_shared=1, chunk=64,
                      capacity_factor=2.0),   # no-drop for exact decode parity
        stages=(Stage((dense,), 1), Stage((moe,), 2)),
        tied_embeddings=False,
    )
