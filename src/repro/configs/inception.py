"""Paper benchmark: GoogLeNet Inception 5x5 branches (Table I)."""
from repro.core import ArrayConfig, networks

def config():
    return {"layers": networks.inception(), "array": ArrayConfig(512, 512)}
