"""mistral-large-123b [dense] — 88L d=12288 96H (GQA kv=8) ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b",
        d_model=12288, vocab=32768,
        n_heads=96, n_kv_heads=8, head_dim=128, d_ff=28672,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 88),),
        tied_embeddings=False,
        notes="full attention -> long_500k SKIP",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=288,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 3),),
        tied_embeddings=False,
    )
