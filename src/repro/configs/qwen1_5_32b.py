"""qwen1.5-32b [dense] — 64L d=5120 40H (MHA) ff=27392 vocab=152064, QKV
bias [hf:Qwen/Qwen1.5-*; hf].  40 heads don't divide a 16-way model axis:
sharding falls back to head_dim partitioning (launch/sharding.py)."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b",
        d_model=5120, vocab=152064,
        n_heads=40, n_kv_heads=40, head_dim=128, d_ff=27392,
        qkv_bias=True,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 64),),
        tied_embeddings=False,
        notes="full attention -> long_500k SKIP; heads=40 -> head_dim TP",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=8, head_dim=16, d_ff=352,
        qkv_bias=True,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 3),),
        tied_embeddings=False,
    )
