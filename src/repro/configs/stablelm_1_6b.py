"""stablelm-1.6b [dense] — 24L d=2048 32H (MHA) ff=5632 vocab=100352,
LayerNorm + partial rotary 25 % [hf:stabilityai/stablelm-2-1_6b;
unverified]."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b",
        d_model=2048, vocab=100352,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632,
        rope_frac=0.25, norm="layernorm",
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 24),),
        tied_embeddings=False,
        notes="full attention -> long_500k SKIP",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=8, head_dim=16, d_ff=352,
        rope_frac=0.25, norm="layernorm",
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 3),),
        tied_embeddings=False,
    )
