"""internvl2-26b [vlm] — InternViT + InternLM2 backbone
[arXiv:2404.16821; hf].  Per the brief the modality frontend is a STUB:
input_specs provide precomputed patch embeddings for a 256-token visual
prefix; the transformer backbone below is the InternLM2-26B-shaped
decoder (48L, d=6144, 48H GQA kv=8, ff=16384, vocab=92553)."""
from repro.models import ArchConfig, BlockSpec, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b",
        d_model=6144, vocab=92553,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 48),),
        frontend="vision", n_prefix=256,
        tied_embeddings=False,
        notes="full attention -> long_500k SKIP (DESIGN.md)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b-smoke",
        d_model=128, vocab=512,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=256,
        stages=(Stage((BlockSpec(mixer="gqa", ffn="dense"),), 3),),
        frontend="vision", n_prefix=8,
        tied_embeddings=False,
    )
