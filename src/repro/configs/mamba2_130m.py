"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].  24L d=768 vocab=50280; d_inner=1536
(expand 2), 24 heads x head_dim 64, d_state=128, chunk 256, causal conv
width 4 — the conv is a depthwise temporal conv, the one sublayer where
the paper's mapping technique applies (DESIGN.md SArch-applicability)."""
from repro.models import ArchConfig, BlockSpec, SSMConfig, Stage


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        d_model=768, vocab=50280,
        ssm=SSMConfig(d_inner=1536, n_heads=24, head_dim=64, d_state=128,
                      n_groups=1, conv_width=4, chunk=256),
        stages=(Stage((BlockSpec(mixer="ssd", ffn="none"),), 24),),
        tied_embeddings=True,
        sub_quadratic=True,
        notes="long_500k RUNS (O(1) SSD state)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m-smoke",
        d_model=64, vocab=512,
        ssm=SSMConfig(d_inner=128, n_heads=4, head_dim=32, d_state=32,
                      n_groups=1, conv_width=4, chunk=32),
        stages=(Stage((BlockSpec(mixer="ssd", ffn="none"),), 3),),
        tied_embeddings=True,
        sub_quadratic=True,
    )
