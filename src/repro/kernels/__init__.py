# Pallas TPU kernels for the compute hot-spots (validated in interpret
# mode on CPU; see EXAMPLE.md convention):
#   tetris_matmul.py  - square-inclined blocked matmul (Alg 3 on the MXU)
#   grouped_matmul.py - block-diagonal grouped/expert matmul (SIII-B)
#   im2win_conv.py    - SDK parallel-window convolution (grid = cycles)
#   ops.py            - jit'd wrappers; ref.py - pure-jnp oracles
from . import ops, ref
