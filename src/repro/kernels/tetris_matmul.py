"""Tetris-tiled matmul — the MXU adaptation of the paper's window search.

The CIM macro analogy (DESIGN.md §2): an MXU pass consumes a (bm x bk)
activation tile against a (bk x bn) weight tile — the 'array' is the
(bm, bn, bk) block, VMEM is the constraint (AR x AC -> VMEM budget), and
the number of grid steps is the computing-cycle count.  The paper's
square-inclined rule (Alg 3, AM-GM) picks bm ~ bn (for a fixed number of
output elements per block, a square block minimises operand traffic
(bm+bn)*bk — same argument as minimising window rows); ragged edges are
the marginal-window case, handled on TPU by clamped overlapping edge
blocks (recompute instead of reshape — uniform tiles are what the MXU
wants; the count matches the ceil form).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tetris import factor_pairs_square_first

VMEM_BUDGET = 8 * 1024 * 1024      # bytes per core we allow operands


def select_block_shape(m: int, n: int, k: int, dtype_bytes: int = 2,
                       vmem_budget: int = VMEM_BUDGET
                       ) -> Tuple[int, int, int]:
    """Square-inclined (bm, bn, bk) under the VMEM constraint.

    Mirrors Alg 3: enumerate near-square factor pairs of the per-block
    output element count (largest first), require MXU alignment (128
    multiples where the dim allows) and the operand working set
    (bm*bk + bk*bn) * bytes + bm*bn*4 <= budget."""
    def align(v: int, d: int) -> int:
        a = 128 if d >= 128 else max(8, d)
        return max(a, (v // a) * a)

    best = None
    for target in (1 << 16, 1 << 15, 1 << 14, 1 << 13, 1 << 12):
        for a, b in factor_pairs_square_first(target):
            bm, bn = align(min(a, m), m), align(min(b, n), n)
            if bm > m or bn > n:
                continue
            bk = align(min(k, vmem_budget // ((bm + bn) * dtype_bytes)), k)
            bk = min(bk, k)
            if bk < min(128, k):
                continue
            ws = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
            if ws > vmem_budget:
                continue
            cand = (bm, bn, bk)
            # prefer bigger blocks (fewer grid steps), then squarer
            key = (bm * bn * bk, -abs(bm - bn))
            if best is None or key > best[0]:
                best = (key, cand)
        if best is not None:
            break
    if best is None:
        return (min(m, 128), min(n, 128), min(k, 128))
    return best[1]


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tetris_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                  block: Tuple[int, int, int] = None,
                  interpret: bool = False) -> jnp.ndarray:
    """x (M, K) @ w (K, N); grid = ceil tiles with clamped edge blocks."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = block or select_block_shape(m, n, k, x.dtype.itemsize)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # K must tile exactly (a clamped K block would double-accumulate);
    # M/N edge tiles are clamped — overlapping rewrites of identical
    # values, the marginal-window analogue.
    while k % bk:
        bk -= 1
    gm, gn, gk = (pl.cdiv(m, bm), pl.cdiv(n, bn), k // bk)

    def xi(i, j, ki):
        return (jnp.minimum(i, _last(m, bm)), ki)

    def wi(i, j, ki):
        return (ki, jnp.minimum(j, _last(n, bn)))

    def oi(i, j, ki):
        return (jnp.minimum(i, _last(m, bm)), jnp.minimum(j, _last(n, bn)))

    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[pl.BlockSpec((bm, bk), xi),
                  pl.BlockSpec((bk, bn), wi)],
        out_specs=pl.BlockSpec((bm, bn), oi),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _last(dim: int, block: int) -> int:
    return (dim - 1) // block if dim % block else dim // block - 1
