"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, w, preferred_element_type=jnp.float32
                   ).astype(x.dtype)


def grouped_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (G, M, D), w (G, D, F) -> (G, M, F): block-diagonal matmul."""
    return jnp.einsum("gmd,gdf->gmf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (B, H, W, C) pre-padded, w (kh, kw, C, O), stride 1, VALID."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32).astype(x.dtype)


def ssd_intra_chunk_ref(x, dt, a_log, b, c) -> jnp.ndarray:
    """Intra-chunk SSD (no inter-chunk state): x (B,L,H,P); dt (B,L,H);
    a_log (H,); b,c (B,L,H,N).  y[i] = sum_{j<=i} C_i.B_j exp(dA(j,i]) x_j dt_j."""
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    dA = dt.astype(f32) * A                                   # (B,L,H)
    cs = jnp.cumsum(dA, axis=1)
    seg = cs[:, :, None, :] - cs[:, None, :, :]               # (B,L,L,H)
    L = x.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bihn,bjhn->bijh", c.astype(f32), b.astype(f32))
    xdt = x.astype(f32) * dt.astype(f32)[..., None]
    y = jnp.einsum("bijh,bijh,bjhp->bihp", cb, dec, xdt)
    return y.astype(x.dtype)


def flash_attention_ref(q, k, v, causal: bool = True,
                        q_offset: int = 0) -> "jnp.ndarray":
    """q (BH, Sq, D); k/v (BH, Sk, D): plain softmax attention oracle."""
    import math
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        pos_q = q_offset + jnp.arange(sq)[:, None]
        pos_k = jnp.arange(sk)[None, :]
        s = jnp.where(pos_k <= pos_q, s, -1e30)
    w = jax.nn.softmax(s, -1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
