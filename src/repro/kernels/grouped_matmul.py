"""Block-diagonal grouped matmul — the TPU form of the paper's grouped
convolution (§III-B) and of MoE expert compute.

A dense layer computes x (M, G*D) @ W (G*D, G*F); grouping zeroes the
off-diagonal blocks, and the paper's cycle win is exactly *not touching*
them.  On TPU the same win is a grid that iterates only the G diagonal
blocks: flops drop G-fold vs the dense equivalent, and each block is an
MXU-shaped (bm x D x bf) matmul.  The per-group (bm, bf) tiles follow
the same square-inclined rule as tetris_matmul.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[0], w_ref[0],
        preferred_element_type=jnp.float32).astype(o_ref.dtype)[None]


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                   bm: Optional[int] = None, bf: Optional[int] = None,
                   interpret: bool = False) -> jnp.ndarray:
    """x (G, M, D) @ w (G, D, F) -> (G, M, F), diagonal blocks only."""
    g, m, d = x.shape
    g2, d2, f = w.shape
    assert (g, d) == (g2, d2)
    bm = min(bm or max(8, min(m, 512)), m)
    bf = min(bf or max(8, min(f, 512)), f)
    gm, gf = pl.cdiv(m, bm), pl.cdiv(f, bf)

    def last(dim, blk):
        return (dim - 1) // blk if dim % blk else dim // blk - 1

    return pl.pallas_call(
        _gmm_kernel,
        grid=(g, gm, gf),
        in_specs=[
            pl.BlockSpec((1, bm, d),
                         lambda gi, i, j: (gi, jnp.minimum(i, last(m, bm)),
                                           0)),
            pl.BlockSpec((1, d, bf),
                         lambda gi, i, j: (gi, 0,
                                           jnp.minimum(j, last(f, bf)))),
        ],
        out_specs=pl.BlockSpec(
            (1, bm, bf),
            lambda gi, i, j: (gi, jnp.minimum(i, last(m, bm)),
                              jnp.minimum(j, last(f, bf)))),
        out_shape=jax.ShapeDtypeStruct((g, m, f), x.dtype),
        interpret=interpret,
    )(x, w)
