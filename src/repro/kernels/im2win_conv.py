"""im2win / SDK convolution — the paper's parallel window executed as a
Pallas kernel (DESIGN.md §2 table).

Two entry points:

* :func:`im2win_conv` — mapping-free NHWC path: picks its own square-
  inclined window (Alg 3) and runs stride-1 VALID convolution.
* :func:`sdk_conv` — mapping-*driven* NCHW path: consumes a
  :class:`LayerMapping` directly.  Per (group, tile) one ``pallas_call``
  whose grid is ``(AR_c, AC_c, n_windows)`` — the grid size IS the
  tile's computing-cycle count (ceil form): every grid step is one
  parallel-window load of one ``ic_t x oc_t`` array pass.  Marginal /
  border windows execute as border-clamped reads of the regular window
  shape (overlap-recompute, Alg 4's hardware analogue).  It therefore
  executes the *same* mapping as the reference executor
  (cnn/cim_conv.py) and is cross-checked against it in
  tests/test_sdk_conv.py.

One grid step == one parallel-window load == one computing cycle: the
grid size IS the paper's cycle count for the layer.  Each step covers a
(th x tw) tile of output positions (the 'kernel computations inside the
parallel window', Fig 9a) against the full kernel stack, computed as
k_h*k_w shift-matmuls on the MXU — the shifted-and-duplicated kernel
matrix of Fig 5 realised as shifted *reads* instead of duplicated
*weights* (VMEM holds one kernel copy; the crossbar had to duplicate).

The window tile (th, tw) should come from the square-inclined rule
(Alg 3): for fixed th*tw outputs the input patch (th+K-1)(tw+K-1) is
minimal at th==tw.  Border windows are clamped (overlap-recompute), the
marginal-window analogue; the step count matches the ceil form.
"""
from __future__ import annotations

import collections
import functools
import math
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tetris import factor_pairs_square_first
from repro.core.types import LayerMapping

#: Fallback ``block="auto"`` VMEM budget (bytes) when the environment
#: does not override it.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024
_VMEM_ENV_VAR = "REPRO_SDK_VMEM_BUDGET"


def default_vmem_budget() -> int:
    """The sdk executor's ``block="auto"`` VMEM budget in bytes when the
    caller passes ``vmem_budget=None``: the ``REPRO_SDK_VMEM_BUDGET``
    environment variable, else :data:`DEFAULT_VMEM_BUDGET` (8 MiB).  An
    explicit byte parameter threaded through `compile_plan` / `sdk_conv`
    — the autotuner sweeps it — with the env var as the deploy-time
    default.  Read per call (not cached at import) so tests and drivers
    can re-point it."""
    env = os.environ.get(_VMEM_ENV_VAR)
    if not env:
        return DEFAULT_VMEM_BUDGET
    try:
        budget = int(env)
    except ValueError:
        raise ValueError(
            f"{_VMEM_ENV_VAR}={env!r} is not an integer byte count "
            f"(suffixes like '8M' are not supported)") from None
    if budget <= 0:
        raise ValueError(f"{_VMEM_ENV_VAR}={env!r} must be > 0 "
                         f"(unset it for the {DEFAULT_VMEM_BUDGET}-byte "
                         f"default)")
    return budget


def select_window(o_h: int, o_w: int, k: int, c: int, oc: int,
                  vmem_budget: int = 4 * 1024 * 1024,
                  dtype_bytes: int = 4) -> Tuple[int, int]:
    """Square-inclined (th, tw) output tile per window (Alg 3 on TPU)."""
    best = (min(o_h, 8), min(o_w, 8))
    for target in (4096, 1024, 256, 64, 16, 4):
        for a, b in factor_pairs_square_first(target):
            th, tw = min(a, o_h), min(b, o_w)
            patch = (th + k - 1) * (tw + k - 1) * c
            ws = (patch + th * tw * oc) * dtype_bytes + k * k * c * oc \
                * dtype_bytes
            if ws <= vmem_budget:
                return th, tw
    return best


def _conv_kernel(x_ref, w_ref, o_ref, *, k_h, k_w, th, tw, o_h, o_w):
    i = pl.program_id(1)
    j = pl.program_id(2)
    y0 = jnp.minimum(i * th, o_h - th)
    x0 = jnp.minimum(j * tw, o_w - tw)
    # leading batch index as a unit slice: interpret-mode load/store
    # discharge rejects bare int indices mixed with dynamic slices
    win = pl.load(x_ref, (pl.ds(0, 1), pl.ds(y0, th + k_h - 1),
                          pl.ds(x0, tw + k_w - 1), slice(None)))[0]
    c = win.shape[-1]
    oc = w_ref.shape[-1]
    acc = jnp.zeros((th * tw, oc), jnp.float32)
    for dy in range(k_h):            # unrolled shift-matmuls (MXU passes)
        for dx in range(k_w):
            patch = win[dy:dy + th, dx:dx + tw, :].reshape(th * tw, c)
            acc += jnp.dot(patch, w_ref[dy, dx],
                           preferred_element_type=jnp.float32)
    pl.store(o_ref, (pl.ds(0, 1), pl.ds(y0, th), pl.ds(x0, tw),
                     slice(None)),
             acc.reshape(1, th, tw, oc).astype(o_ref.dtype))


def im2win_conv(x: jnp.ndarray, w: jnp.ndarray, *,
                window: Optional[Tuple[int, int]] = None,
                interpret: bool = False) -> jnp.ndarray:
    """x (B, H, W, C) pre-padded; w (kh, kw, C, O); stride 1 VALID."""
    b, h, ww, c = x.shape
    k_h, k_w, c2, oc = w.shape
    assert c == c2
    o_h, o_w = h - k_h + 1, ww - k_w + 1
    th, tw = window or select_window(o_h, o_w, max(k_h, k_w), c, oc)
    th, tw = min(th, o_h), min(tw, o_w)
    grid = (b, pl.cdiv(o_h, th), pl.cdiv(o_w, tw))

    return pl.pallas_call(
        functools.partial(_conv_kernel, k_h=k_h, k_w=k_w, th=th, tw=tw,
                          o_h=o_h, o_w=o_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, ww, c), lambda bi, i, j: (bi, 0, 0, 0)),
            pl.BlockSpec((k_h, k_w, c, oc), lambda bi, i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o_h, o_w, oc),
                               lambda bi, i, j: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o_h, o_w, oc), x.dtype),
        interpret=interpret,
    )(x, w)


def n_cycles(o_h: int, o_w: int, th: int, tw: int, batch: int = 1) -> int:
    """Grid steps == the mapping's computing-cycle count (ceil form)."""
    return batch * pl.cdiv(o_h, th) * pl.cdiv(o_w, tw)


# ---------------------------------------------------------------------------
# Mapping-driven SDK kernel
# ---------------------------------------------------------------------------

def _tile_passes(mapping: LayerMapping, tile) -> Tuple[int, int, int, int]:
    """(ic_t, ar_c, oc_t, ac_c) of a tile's sequential array passes, per
    group — now shared executor logic on the mapping itself (the
    macro-parallel executor blocks the same passes over the grid)."""
    return mapping.tile_passes(tile)


def _tile_grid(layer, tile) -> Tuple[int, int, int, int, int, int]:
    """(step_y, step_x, ny, nx, lim_y, lim_x) of a tile's ceil-form window
    raster: `n = ny*nx` border-clamped loads of the regular window shape
    cover every output position (clamps stay on the stride grid)."""
    s = layer.stride
    w = tile.window
    step_y = ((w.pw_h - layer.k_h) // s + 1) * s
    step_x = ((w.pw_w - layer.k_w) // s + 1) * s
    ny = math.ceil(((layer.i_h - layer.k_h) // s + 1) / (step_y // s))
    nx = math.ceil(((layer.i_w - layer.k_w) // s + 1) / (step_x // s))
    lim_y = ((layer.i_h - w.pw_h) // s) * s
    lim_x = ((layer.i_w - w.pw_w) // s) * s
    return step_y, step_x, ny, nx, lim_y, lim_x


def _window_origin(wi, *, step_y, step_x, nx, lim_y, lim_x):
    """Border-clamped (y0, x0) of window `wi` in the ceil-form raster."""
    y0 = jnp.minimum((wi // nx) * step_y, lim_y)
    x0 = jnp.minimum((wi % nx) * step_x, lim_x)
    return y0, x0


def _window_matmuls(win, w_ref, *, s, k_h, k_w, py, px):
    """The window's k_h*k_w unrolled shift-matmuls (MXU passes): win
    (b, ic_t, pw_h, pw_w) x kernel block -> (b, oc_t, py, px) f32.
    Shared by the whole-array and window-blocked kernels so the two
    tilings cannot drift."""
    b, oc_t = win.shape[0], w_ref.shape[3]
    acc = jnp.zeros((b * py * px, oc_t), jnp.float32)
    for dy in range(k_h):
        for dx in range(k_w):
            patch = win[:, :, dy:dy + (py - 1) * s + 1:s,
                        dx:dx + (px - 1) * s + 1:s]
            patch = patch.transpose(0, 2, 3, 1).reshape(b * py * px, -1)
            acc += jnp.dot(patch, w_ref[dy, dx],
                           preferred_element_type=jnp.float32)
    return acc.reshape(b, py, px, oc_t).transpose(0, 3, 1, 2)


def _sdk_kernel(x_ref, w_ref, o_ref, *, s, k_h, k_w, pw_h, pw_w, py, px,
                step_y, step_x, nx, lim_y, lim_x):
    """One grid step == one window load of one (ic_t x oc_t) array pass."""
    wi = pl.program_id(2)
    y0, x0 = _window_origin(wi, step_y=step_y, step_x=step_x, nx=nx,
                            lim_y=lim_y, lim_x=lim_x)

    @pl.when(wi == 0)
    def _init():                     # o block is revisited across windows
        o_ref[...] = jnp.zeros_like(o_ref)

    win = x_ref[:, :, pl.ds(y0, pw_h), pl.ds(x0, pw_w)]
    vals = _window_matmuls(win, w_ref, s=s, k_h=k_h, k_w=k_w, py=py, px=px)
    o_ref[0, :, :, pl.ds(y0 // s, py), pl.ds(x0 // s, px)] = \
        vals.astype(o_ref.dtype)


def _sdk_kernel_blocked(x_hbm, w_ref, o_hbm, xwin, ovals, in_sem, out_sem,
                        *, s, k_h, k_w, pw_h, pw_w, py, px, step_y, step_x,
                        ac_c, nw, nx, lim_y, lim_x, ic_t, oc_t):
    """Window-blocked variant of :func:`_sdk_kernel`: x and the output
    stay in HBM (``pl.ANY``); each grid step DMAs exactly one window
    patch (b, ic_t, pw_h, pw_w) into VMEM scratch and one output tile
    (b, oc_t, py, px) back out.  VMEM per step is the window working set
    — independent of the feature-map size, so big Inception / DenseNet
    layers fit where whole-array blocks would not.  Window origins are
    border-clamped to the stride grid, which BlockSpec index maps cannot
    express (blocks overlap); the DMA path is the general form.

    The DMAs are **double-buffered** (two scratch slots + paired
    semaphores, slot = flat step parity): step t prefetches window
    patch t+1 into the idle slot before waiting on its own patch, so the
    next load overlaps this step's MXU shift-matmuls; the output-tile
    store is likewise left in flight and only drained when its slot is
    about to be reused (t+2) or the grid ends.  The grid — and therefore
    the steps==cycles contract — is unchanged: pipelining shortens the
    step, it does not add or remove steps."""
    ci = pl.program_id(0)
    oi = pl.program_id(1)
    wi = pl.program_id(2)
    total = pl.num_programs(0) * ac_c * nw
    t = (ci * ac_c + oi) * nw + wi          # flat sequential step

    def in_copy(step, slot):
        """Async copy of window patch `step` into scratch slot `slot`."""
        ci_s = step // (ac_c * nw)
        wi_s = step % nw
        y0, x0 = _window_origin(wi_s, step_y=step_y, step_x=step_x,
                                nx=nx, lim_y=lim_y, lim_x=lim_x)
        return pltpu.make_async_copy(
            x_hbm.at[:, pl.ds(ci_s * ic_t, ic_t), pl.ds(y0, pw_h),
                     pl.ds(x0, pw_w)],
            xwin.at[slot], in_sem.at[slot])

    def out_copy(step, slot):
        """Async copy of output tile `step` out of scratch slot `slot`."""
        ci_s = step // (ac_c * nw)
        oi_s = step % (ac_c * nw) // nw
        wi_s = step % nw
        y0, x0 = _window_origin(wi_s, step_y=step_y, step_x=step_x,
                                nx=nx, lim_y=lim_y, lim_x=lim_x)
        return pltpu.make_async_copy(
            ovals.at[slot],
            o_hbm.at[ci_s, :, pl.ds(oi_s * oc_t, oc_t),
                     pl.ds(y0 // s, py), pl.ds(x0 // s, px)],
            out_sem.at[slot])

    @pl.when(t == 0)
    def _warmup():                          # pipeline prologue
        in_copy(t, t % 2).start()

    @pl.when(t + 1 < total)
    def _prefetch():                        # overlap next load with compute
        in_copy(t + 1, (t + 1) % 2).start()

    in_copy(t, t % 2).wait()

    @pl.when(t >= 2)
    def _reclaim():                         # slot reused: drain store t-2
        out_copy(t - 2, t % 2).wait()

    ovals[t % 2] = _window_matmuls(xwin[t % 2], w_ref, s=s, k_h=k_h,
                                   k_w=k_w, py=py, px=px)
    out_copy(t, t % 2).start()

    if total >= 2:                          # static: grid has a t-1 step
        @pl.when(t == total - 1)
        def _drain_prev():
            out_copy(t - 1, (t - 1) % 2).wait()

    @pl.when(t == total - 1)
    def _drain_last():                      # pipeline epilogue
        out_copy(t, t % 2).wait()


def _vmem_bytes_whole(b, ic_t, oc_t, layer) -> int:
    """f32 VMEM working set of one whole-array-block grid step."""
    return 4 * (b * ic_t * layer.i_h * layer.i_w
                + layer.k_h * layer.k_w * ic_t * oc_t
                + b * oc_t * layer.o_h * layer.o_w)


def sdk_conv_traced(mapping: LayerMapping, x: jnp.ndarray,
                    kernel: jnp.ndarray, *, interpret: bool = False,
                    block: str = "auto",
                    vmem_budget: Optional[int] = None) -> jnp.ndarray:
    """Trace-time body of :func:`sdk_conv` — see it for the contract.
    Public plan-consuming entry: `repro.exec.run` inlines it into the
    whole-network program.  Builds one pallas_call per (group, tile);
    stand-alone dispatch goes through :func:`sdk_conv_jit` so the
    closures are built once per static (mapping, shapes, flags)
    signature, not once per call."""
    if vmem_budget is None:     # trace-time resolution (static argument)
        vmem_budget = default_vmem_budget()
    _trace_counts[_trace_key(mapping, x, kernel, interpret=interpret,
                             block=block, vmem_budget=vmem_budget)] += 1
    layer = mapping.layer
    s = layer.stride
    b = x.shape[0]
    o_h, o_w = layer.o_h, layer.o_w
    g = mapping.group
    ic_g, oc_g = layer.ic // g, layer.oc // g
    if kernel.shape != (layer.k_h, layer.k_w, ic_g, layer.oc):
        raise ValueError(f"kernel shape {kernel.shape} != grouped layout "
                         f"{(layer.k_h, layer.k_w, ic_g, layer.oc)}")
    if block not in ("auto", "whole", "window"):
        raise ValueError(f"unknown block mode {block!r}")

    outs = []
    for gi in range(g):
        xg = x[:, gi * ic_g:(gi + 1) * ic_g]
        kg = kernel[:, :, :, gi * oc_g:(gi + 1) * oc_g]
        acc = jnp.zeros((b, oc_g, o_h, o_w), jnp.float32)
        c_base = 0
        for tile in mapping.tiles:
            kept = tile.depth
            ic_t, ar_c, oc_t, ac_c = _tile_passes(mapping, tile)
            ic_pad, oc_pad = ar_c * ic_t, ac_c * oc_t

            xt = jnp.pad(xg[:, c_base:c_base + kept],
                         ((0, 0), (0, ic_pad - kept), (0, 0), (0, 0)))
            kt = jnp.pad(kg[:, :, c_base:c_base + kept],
                         ((0, 0), (0, 0), (0, ic_pad - kept),
                          (0, oc_pad - oc_g)))

            w = tile.window
            py = (w.pw_h - layer.k_h) // s + 1
            px = (w.pw_w - layer.k_w) // s + 1
            step_y, step_x, ny, nx, lim_y, lim_x = _tile_grid(layer, tile)

            mode = block
            if mode == "auto":
                mode = ("window"
                        if _vmem_bytes_whole(b, ic_t, oc_t, layer)
                        > vmem_budget else "whole")
            if mode == "window":
                res = pl.pallas_call(
                    functools.partial(
                        _sdk_kernel_blocked, s=s, k_h=layer.k_h,
                        k_w=layer.k_w, pw_h=w.pw_h, pw_w=w.pw_w,
                        py=py, px=px, step_y=step_y, step_x=step_x,
                        ac_c=ac_c, nw=ny * nx, nx=nx,
                        lim_y=lim_y, lim_x=lim_x, ic_t=ic_t, oc_t=oc_t),
                    grid=(ar_c, ac_c, ny * nx),
                    in_specs=[
                        pl.BlockSpec(memory_space=pl.ANY),
                        pl.BlockSpec((layer.k_h, layer.k_w, ic_t, oc_t),
                                     lambda ci, oi, wi: (0, 0, ci, oi)),
                    ],
                    out_specs=pl.BlockSpec(memory_space=pl.ANY),
                    out_shape=jax.ShapeDtypeStruct(
                        (ar_c, b, oc_pad, o_h, o_w), jnp.float32),
                    scratch_shapes=[       # two slots: double-buffered DMA
                        pltpu.VMEM((2, b, ic_t, w.pw_h, w.pw_w),
                                   jnp.float32),
                        pltpu.VMEM((2, b, oc_t, py, px), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,)),
                    ],
                    interpret=interpret,
                )(xt, kt)
            else:
                res = pl.pallas_call(
                    functools.partial(
                        _sdk_kernel, s=s, k_h=layer.k_h, k_w=layer.k_w,
                        pw_h=w.pw_h, pw_w=w.pw_w, py=py, px=px,
                        step_y=step_y, step_x=step_x, nx=nx,
                        lim_y=lim_y, lim_x=lim_x),
                    grid=(ar_c, ac_c, ny * nx),
                    in_specs=[
                        pl.BlockSpec((b, ic_t, layer.i_h, layer.i_w),
                                     lambda ci, oi, wi: (0, ci, 0, 0)),
                        pl.BlockSpec((layer.k_h, layer.k_w, ic_t, oc_t),
                                     lambda ci, oi, wi: (0, 0, ci, oi)),
                    ],
                    out_specs=pl.BlockSpec(
                        (1, b, oc_t, o_h, o_w),
                        lambda ci, oi, wi: (ci, 0, oi, 0, 0)),
                    out_shape=jax.ShapeDtypeStruct(
                        (ar_c, b, oc_pad, o_h, o_w), jnp.float32),
                    interpret=interpret,
                )(xt, kt)
            acc = acc + res.sum(axis=0)[:, :oc_g]
            # the tile's pruned trailing channels are skipped, not
            # shifted into the next tile's range
            c_base += kept + tile.pruned_channels
        outs.append(acc)
    return jnp.concatenate(outs, axis=1).astype(
        jnp.result_type(x, kernel))


#: Host-side trace counter keyed by the static signature — retracing
#: regressions are asserted in tests/test_sdk_conv.py.  Bounded like the
#: memo caches: oldest signatures drop first (jit itself keeps its own
#: cache, so the counter is diagnostics, not correctness).
_trace_counts: Dict[Tuple, int] = collections.defaultdict(int)
_TRACE_COUNT_LIMIT = 1024


def _trace_key(mapping, x, kernel, **flags) -> Tuple:
    while len(_trace_counts) >= _TRACE_COUNT_LIMIT:
        del _trace_counts[next(iter(_trace_counts))]
    return (mapping, x.shape, x.dtype, kernel.shape, kernel.dtype,
            tuple(sorted(flags.items())))


sdk_conv_jit = functools.partial(
    jax.jit, static_argnums=(0,),
    static_argnames=("interpret", "block", "vmem_budget"))(sdk_conv_traced)
sdk_conv_jit.__doc__ = (
    """jit entry mirroring ``cim_conv2d_jit``: mapping (frozen dataclass)
    and the tiling flags are static — the per-(group, tile) pallas_call
    closures are built once per distinct (mapping, shapes, flags)
    signature instead of on every call.""")


def sdk_conv(mapping: LayerMapping, x: jnp.ndarray, kernel: jnp.ndarray,
             *, interpret: bool = False, block: str = "auto",
             vmem_budget: Optional[int] = None) -> jnp.ndarray:
    """Execute a convolution exactly as `mapping` prescribes, on the MXU.

    Same contract as cnn.cim_conv2d: x (batch, ic, i_h, i_w) pre-padded,
    kernel (k_h, k_w, ic // G, oc) in lax grouped layout, output
    (batch, oc, o_h, o_w); pruned channels are skipped.  One pallas_call
    per (group, tile); within it the grid enumerates the mapping's
    (channel pass, oc pass, window) loads, so total grid steps ==
    the mapping's ceil-form cycle count (see sdk_conv_cycles).  Channel /
    oc passes are padded to whole ``ic_t`` / ``oc_t`` blocks with zero
    weights (zero partial products), and each channel pass writes its own
    slot of a leading accumulator axis that is summed on the host — the
    shift-and-add partial-sum accumulation of Fig 3.

    ``block`` picks the tiling: "whole" keeps the full feature map and
    OFM as VMEM blocks (fastest when they fit), "window" DMAs one
    window patch / output tile per grid step with the loads and stores
    double-buffered against the MXU (:func:`_sdk_kernel_blocked` — VMEM
    use independent of layer size), "auto" chooses "window" whenever the
    whole-array working set exceeds ``vmem_budget`` (``None`` —
    :func:`default_vmem_budget`, i.e. ``REPRO_SDK_VMEM_BUDGET`` or
    8 MiB).

    Dispatches through :func:`sdk_conv_jit` (mapping and flags static):
    repeat calls with the same shapes reuse the compiled program instead
    of rebuilding every pallas_call closure.
    """
    if vmem_budget is None:     # resolve before dispatch: None and the
        vmem_budget = default_vmem_budget()  # explicit default share a
    return sdk_conv_jit(mapping, x, kernel, interpret=interpret,  # cache
                        block=block, vmem_budget=vmem_budget)     # entry


def sdk_conv_cycles(mapping: LayerMapping) -> int:
    """Total grid steps sdk_conv executes == the mapping's cycle count in
    the ceil-form convention (tiles with marginal sets run their border
    loads as clamped regular windows, so floor+marginal counts map to the
    equivalent ceil raster), times the sequential group count."""
    total = 0
    for tile in mapping.tiles:
        _, _, ny, nx, _, _ = _tile_grid(mapping.layer, tile)
        _, ar_c, _, ac_c = _tile_passes(mapping, tile)
        total += ar_c * ac_c * ny * nx
    return total * mapping.group
