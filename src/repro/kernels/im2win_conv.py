"""im2win convolution — the paper's SDK parallel window executed as a
Pallas kernel (DESIGN.md §2 table).

One grid step == one parallel-window load == one computing cycle: the
grid size IS the paper's cycle count for the layer.  Each step covers a
(th x tw) tile of output positions (the 'kernel computations inside the
parallel window', Fig 9a) against the full kernel stack, computed as
k_h*k_w shift-matmuls on the MXU — the shifted-and-duplicated kernel
matrix of Fig 5 realised as shifted *reads* instead of duplicated
*weights* (VMEM holds one kernel copy; the crossbar had to duplicate).

The window tile (th, tw) should come from the square-inclined rule
(Alg 3): for fixed th*tw outputs the input patch (th+K-1)(tw+K-1) is
minimal at th==tw.  Border windows are clamped (overlap-recompute), the
marginal-window analogue; the step count matches the ceil form.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tetris import factor_pairs_square_first


def select_window(o_h: int, o_w: int, k: int, c: int, oc: int,
                  vmem_budget: int = 4 * 1024 * 1024,
                  dtype_bytes: int = 4) -> Tuple[int, int]:
    """Square-inclined (th, tw) output tile per window (Alg 3 on TPU)."""
    best = (min(o_h, 8), min(o_w, 8))
    for target in (4096, 1024, 256, 64, 16, 4):
        for a, b in factor_pairs_square_first(target):
            th, tw = min(a, o_h), min(b, o_w)
            patch = (th + k - 1) * (tw + k - 1) * c
            ws = (patch + th * tw * oc) * dtype_bytes + k * k * c * oc \
                * dtype_bytes
            if ws <= vmem_budget:
                return th, tw
    return best


def _conv_kernel(x_ref, w_ref, o_ref, *, k_h, k_w, th, tw, o_h, o_w):
    i = pl.program_id(1)
    j = pl.program_id(2)
    y0 = jnp.minimum(i * th, o_h - th)
    x0 = jnp.minimum(j * tw, o_w - tw)
    win = pl.load(x_ref, (0, pl.ds(y0, th + k_h - 1),
                          pl.ds(x0, tw + k_w - 1), slice(None)))
    c = win.shape[-1]
    oc = w_ref.shape[-1]
    acc = jnp.zeros((th * tw, oc), jnp.float32)
    for dy in range(k_h):            # unrolled shift-matmuls (MXU passes)
        for dx in range(k_w):
            patch = win[dy:dy + th, dx:dx + tw, :].reshape(th * tw, c)
            acc += jnp.dot(patch, w_ref[dy, dx],
                           preferred_element_type=jnp.float32)
    pl.store(o_ref, (0, pl.ds(y0, th), pl.ds(x0, tw), slice(None)),
             acc.reshape(th, tw, oc).astype(o_ref.dtype))


def im2win_conv(x: jnp.ndarray, w: jnp.ndarray, *,
                window: Optional[Tuple[int, int]] = None,
                interpret: bool = False) -> jnp.ndarray:
    """x (B, H, W, C) pre-padded; w (kh, kw, C, O); stride 1 VALID."""
    b, h, ww, c = x.shape
    k_h, k_w, c2, oc = w.shape
    assert c == c2
    o_h, o_w = h - k_h + 1, ww - k_w + 1
    th, tw = window or select_window(o_h, o_w, max(k_h, k_w), c, oc)
    th, tw = min(th, o_h), min(tw, o_w)
    grid = (b, pl.cdiv(o_h, th), pl.cdiv(o_w, tw))

    return pl.pallas_call(
        functools.partial(_conv_kernel, k_h=k_h, k_w=k_w, th=th, tw=tw,
                          o_h=o_h, o_w=o_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, ww, c), lambda bi, i, j: (bi, 0, 0, 0)),
            pl.BlockSpec((k_h, k_w, c, oc), lambda bi, i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o_h, o_w, oc),
                               lambda bi, i, j: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o_h, o_w, oc), x.dtype),
        interpret=interpret,
    )(x, w)


def n_cycles(o_h: int, o_w: int, th: int, tw: int, batch: int = 1) -> int:
    """Grid steps == the mapping's computing-cycle count (ceil form)."""
    return batch * pl.cdiv(o_h, th) * pl.cdiv(o_w, tw)
