"""The ``"matmul"`` plan executor: mapped-IR matmul layers on the MXU
kernels.

A layer spec with ``op == "matmul"`` is the degenerate 1x1 conv
(``core.types.matmul_spec``): x carries M token positions along the
``i_h`` spatial axis and the D feature channels along the channel axis,
so the plan-level layout contract is unchanged — x ``(B, ic, M, 1)``,
kernel ``(1, 1, ic // G, oc)`` in the grouped conv layout every other
executor consumes (oc group-major, matching
``lax.conv feature_group_count`` semantics).  This module adapts that
layout onto the Pallas matmul kernels:

* ``G == 1`` — tokens flatten to one ``(B*M, D)`` operand for
  `kernels.tetris_matmul` (square-inclined block selection, the paper's
  Alg 3 analogue);
* ``G > 1`` — the block-diagonal `kernels.grouped_matmul` grid iterates
  exactly the G diagonal blocks, the paper's §III-B grouped-convolution
  win in MXU form.

Like the sdk executor, this is an MXU stand-in for the mapped schedule:
cycle accounting stays with the ``LayerMapping`` (steps==cycles is
asserted at plan-compile time via `cnn.mapped_net.check_steps`), and
pruned channels follow the reference-executor convention — zero them in
the kernel (`cnn.mapped_net.zero_pruned_kernels`); a dense matmul over
zeroed rows equals the skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grouped_matmul import grouped_matmul
from .tetris_matmul import tetris_matmul


def matmul_layer_traced(mapping, x: jnp.ndarray, kernel: jnp.ndarray, *,
                        interpret: bool = False) -> jnp.ndarray:
    """One mapped matmul layer: x (B, ic, M, 1), kernel
    (1, 1, ic//G, oc) -> (B, oc, M, 1), G = ``mapping.group`` (native
    groups composed with the searched TetrisG grouping)."""
    layer = mapping.layer
    if getattr(layer, "op", "conv") != "matmul":
        raise ValueError(
            f"{layer.name}: executor 'matmul' needs op='matmul' "
            f"(got op={getattr(layer, 'op', 'conv')!r})")
    g = mapping.group
    b = x.shape[0]
    m = layer.i_h
    d_g, f_g = layer.ic // g, layer.oc // g
    if kernel.shape != (1, 1, d_g, layer.oc):
        raise ValueError(
            f"{layer.name}: kernel {kernel.shape} != (1, 1, {d_g}, "
            f"{layer.oc}) — grouped conv layout, G={g}")
    tok = x[..., 0]                                     # (B, ic, M)
    if g == 1:
        xm = tok.transpose(0, 2, 1).reshape(b * m, layer.ic)
        y = tetris_matmul(xm, kernel[0, 0], interpret=interpret)
        return y.reshape(b, m, layer.oc).transpose(0, 2, 1)[..., None]
    # channels are group-major on both sides: ic = (g, d_g) in x,
    # oc = (g, f_g) along the kernel's last axis
    xg = (tok.reshape(b, g, d_g, m).transpose(1, 0, 3, 2)
          .reshape(g, b * m, d_g))
    wg = kernel[0, 0].reshape(d_g, g, f_g).transpose(1, 0, 2)
    y = grouped_matmul(xg, wg, interpret=interpret)     # (g, B*M, f_g)
    return (y.reshape(g, b, m, f_g).transpose(1, 0, 3, 2)
            .reshape(b, layer.oc, m)[..., None])


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("interpret",))
def matmul_layer_jit(mapping, x, kernel, *, interpret=False):
    return matmul_layer_traced(mapping, x, kernel, interpret=interpret)


def matmul_layer_ref(mapping, x: jnp.ndarray,
                     kernel: jnp.ndarray) -> jnp.ndarray:
    """Einsum oracle of :func:`matmul_layer_traced` — same layout, pure
    jnp (the allclose target of the executor equivalence tests)."""
    layer = mapping.layer
    g = mapping.group
    d_g, f_g = layer.ic // g, layer.oc // g
    tok = x[..., 0].transpose(0, 2, 1)                  # (B, M, ic)
    xg = tok.reshape(*tok.shape[:2], g, d_g)
    wg = kernel[0, 0].reshape(d_g, g, f_g).transpose(1, 0, 2)
    y = jnp.einsum("bmgd,gdf->bmgf", xg, wg,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return (y.reshape(*tok.shape[:2], layer.oc)
            .transpose(0, 2, 1)[..., None])
