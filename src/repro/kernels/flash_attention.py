"""Fused (flash) attention Pallas kernel — the TPU fix for the #1
bottleneck the roofline analysis identified (EXPERIMENTS.md §Perf): the
HLO attention path materialises score/softmax chains to HBM; fused
attention keeps them in VMEM, reducing attention HBM traffic from
O(S^2) to O(S * d).

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost so the online-
softmax running state (m, l, acc) lives in VMEM scratch across kv steps:

    m_new = max(m, rowmax(s));  alpha = exp(m - m_new)
    l     = alpha * l + rowsum(exp(s - m_new))
    acc   = alpha * acc + exp(s - m_new) @ v

Causal masking by absolute positions (q_offset for decode/continuation);
the epilogue normalises by l on the last kv step.  Validated in
interpret mode against ref.flash_attention_ref for shapes/dtypes in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (bq, d)
    k = k_ref[0]                                    # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        pos_q = q_offset + qi * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        pos_k = ki * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 1)
        s = jnp.where(pos_k <= pos_q, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                          # (bq, bk)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = (alpha * acc_ref[...]
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_offset: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (BH, Sq, D); k/v (BH, Sk, D) — heads pre-folded into the leading
    dim (callers vmap/reshape GQA groups).  Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq {sq}/{sk} must tile by {bq}/{bk}")
    n_kv = sk // bk
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, n_kv=n_kv, q_offset=q_offset),
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # running max
            pltpu.VMEM((bq, 1), jnp.float32),       # running denom
            pltpu.VMEM((bq, d), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def mha_flash(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, interpret: bool = False) -> jnp.ndarray:
    """Convenience wrapper: q (B, S, H, D), k/v (B, S, Hkv, D) with GQA
    head expansion folded into the flash grid."""
    b, sq, hq, dd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * hq, k.shape[1], dd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1
                    ).reshape(b * hq, v.shape[1], dd)
    of = flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return of.reshape(b, hq, sq, dd).transpose(0, 2, 1, 3)
