"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel bodies in Python) — TPU is the target.
``INTERPRET`` flips globally; callers can override per call.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax

from .flash_attention import flash_attention
from .grouped_matmul import grouped_matmul
from .im2win_conv import im2win_conv
from .tetris_matmul import tetris_matmul

INTERPRET = jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("block", "interpret"))
def matmul(x, w, block: Optional[Tuple[int, int, int]] = None,
           interpret: Optional[bool] = None):
    return tetris_matmul(x, w, block=block,
                         interpret=INTERPRET if interpret is None
                         else interpret)


@partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def gmm(x, w, bm: Optional[int] = None, bf: Optional[int] = None,
        interpret: Optional[bool] = None):
    return grouped_matmul(x, w, bm=bm, bf=bf,
                          interpret=INTERPRET if interpret is None
                          else interpret)


@partial(jax.jit, static_argnames=("window", "interpret"))
def conv2d(x, w, window: Optional[Tuple[int, int]] = None,
           interpret: Optional[bool] = None):
    return im2win_conv(x, w, window=window,
                       interpret=INTERPRET if interpret is None
                       else interpret)


@partial(jax.jit, static_argnames=("causal", "q_offset", "interpret"))
def attention(q, k, v, causal: bool = True, q_offset: int = 0,
              interpret: Optional[bool] = None):
    return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                           interpret=INTERPRET if interpret is None
                           else interpret)
