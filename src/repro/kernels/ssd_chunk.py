"""SSD intra-chunk Pallas kernel (mamba2 hot spot, arXiv:2405.21060).

One grid cell = one (batch, chunk): computes the chunk's masked-decay
attention form entirely in VMEM —

    y[i] = sum_{j<=i} (C_i . B_j) * exp(cumsum dA (j, i]) * dt_j * x[j]

plus the chunk-final state S = sum_j B_j exp(dA_end - dA_j) dt_j x[j]
that the host-side inter-chunk scan consumes (repro.models.ssm does the
O(n_chunks) recurrence; the quadratic work lives here).  The decay matrix
and segment sums never touch HBM — the same traffic argument as flash
attention, applied to the SSD dual form.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)          # (L, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, H)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (H,)
    b = b_ref[0].astype(jnp.float32)          # (L, H, N)
    c = c_ref[0].astype(jnp.float32)          # (L, H, N)
    L = x.shape[0]

    dA = dt * a[None, :]                      # (L, H)
    cs = jnp.cumsum(dA, axis=0)               # (L, H)
    seg = cs[:, None, :] - cs[None, :, :]     # (L, L, H): sum (j, i]
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.where(mask[..., None], jnp.exp(seg), 0.0)   # (L, L, H)

    cb = jnp.einsum("ihn,jhn->ijh", c, b)     # (L, L, H)
    xdt = x * dt[..., None]                   # (L, H, P)
    y = jnp.einsum("ijh,jhp->ihp", cb * dec, xdt)
    y_ref[0] = y.astype(y_ref.dtype)

    # chunk-final state for the host-side recurrence
    dec_end = jnp.exp(cs[-1][None, :] - cs)   # (L, H)
    s = jnp.einsum("jhn,jh,jhp->hpn", b, dec_end, xdt)
    s_ref[0] = s.astype(s_ref.dtype)


def ssd_chunk(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
              b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 128,
              interpret: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, H, P); dt (B, S, H) post-softplus; a_log (H,);
    b/c (B, S, H, N) (groups pre-repeated).  S % chunk == 0.
    Returns (y_intra (B,S,H,P), states (B, n_chunks, H, P, N))."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    if S % chunk:
        raise ValueError(f"S {S} % chunk {chunk} != 0")
    nc = S // chunk

    xr = x.reshape(B * nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H).reshape(B * nc, chunk, H)
    br = b.reshape(B, nc, chunk, H, N).reshape(B * nc, chunk, H, N)
    cr = c.reshape(B, nc, chunk, H, N).reshape(B * nc, chunk, H, N)

    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=(B * nc,),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda g: (g, 0, 0)),
            pl.BlockSpec((H,), lambda g: (0,)),
            pl.BlockSpec((1, chunk, H, N), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, chunk, H, N), lambda g: (g, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda g: (g, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B * nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, a_log, br, cr)
    return (y.reshape(B, S, H, P),
            s.reshape(B, nc, H, P, N))
