"""Compiled execution plans: one operator-generic IR for all executors.

`compile_plan` lowers a `NetworkMapping` once — executor choice per
layer (conv executors plus the ``"matmul"`` MXU path for op="matmul"
layers), super-step schedule (steps==cycles checked at compile time),
inter-layer glue (inferred chain/concat for CNNs, or the mapping's
explicit `GlueSpec` tuple for transformer lowerings), sharding decisions
— and `execute_plan` runs the whole forward as a single jitted program
with cross-layer overlap.  See DESIGN.md §8/§11 and the module
docstrings of exec/plan.py / exec/run.py.

    from repro.exec import compile_plan, execute_plan
    plan = compile_plan(net_mapping, executor_policy="auto",
                        mesh=mesh, batch=8)
    y = execute_plan(plan, kernels, x, mesh=mesh)
"""
from .constants import PlanConstants, constant_counts, prepare_constants
from .glue import (ACTIVATIONS, GLUE_KINDS, GlueSpec, attention_stage,
                   center_crop, fit_spatial, layernorm, resolve_chain)
from .memory import LayerMemory, network_memory, peak_bytes, total_bytes
from .plan import (EXECUTORS, PASSES, LayerPlan, NetworkPlan, PlanDraft,
                   PolicyLike, compile_counts, compile_plan)
from .remat import allowed_cuts, canonical_remat, plan_segments
from .run import (apply_layer, donation_supported, execute_layerwise,
                  execute_looped, execute_oracle, execute_plan)

__all__ = [
    "ACTIVATIONS", "GLUE_KINDS", "GlueSpec", "EXECUTORS", "LayerMemory",
    "LayerPlan", "NetworkPlan", "PASSES", "PlanConstants", "PlanDraft",
    "PolicyLike", "allowed_cuts", "apply_layer", "attention_stage",
    "canonical_remat", "center_crop", "compile_counts", "compile_plan",
    "constant_counts", "donation_supported", "execute_layerwise",
    "execute_looped", "execute_oracle", "execute_plan", "fit_spatial",
    "layernorm", "network_memory", "peak_bytes", "plan_segments",
    "prepare_constants", "resolve_chain", "total_bytes",
]
