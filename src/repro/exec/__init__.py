"""Compiled execution plans: one IR for all three executors.

`compile_plan` lowers a `NetworkMapping` once — executor choice per
layer, super-step schedule (steps==cycles checked at compile time),
inter-layer glue, sharding decisions — and `execute_plan` runs the whole
forward as a single jitted program with cross-layer overlap.  See
DESIGN.md §8 and the module docstrings of exec/plan.py / exec/run.py.

    from repro.exec import compile_plan, execute_plan
    plan = compile_plan(net_mapping, executor_policy="auto",
                        mesh=mesh, batch=8)
    y = execute_plan(plan, kernels, x, mesh=mesh)
"""
from .constants import PlanConstants, constant_counts, prepare_constants
from .glue import GLUE_KINDS, center_crop, fit_spatial, resolve_chain
from .plan import (EXECUTORS, LayerPlan, NetworkPlan, PolicyLike,
                   compile_counts, compile_plan)
from .run import (apply_layer, donation_supported, execute_layerwise,
                  execute_looped, execute_oracle, execute_plan)

__all__ = [
    "GLUE_KINDS", "EXECUTORS", "LayerPlan", "NetworkPlan",
    "PlanConstants", "PolicyLike", "apply_layer", "center_crop",
    "compile_counts", "compile_plan", "constant_counts",
    "donation_supported", "execute_layerwise", "execute_looped",
    "execute_oracle", "execute_plan", "fit_spatial", "prepare_constants",
    "resolve_chain",
]
