"""Execute a :class:`NetworkPlan` — the single dispatch path behind
`mapped_net_apply`, `train_cnn(executor=...)`, and `serve_cnn`.

`execute_plan` runs the whole forward as **one jitted XLA program**: the
plan (frozen, hashable) is a static argument, so the per-layer Python
loops — super-steps, placement groups, glue — unroll at trace time and
the runtime sees a single launch per forward instead of one per layer.
Cross-layer overlap is *bounded, one layer deep*: each layer boundary
threads the carry and the still-unconsumed kernels through
`lax.optimization_barrier`, leaving exactly the next layer's
kernel-side work (its shifted-weight-matrix blocks, its patch-gather
indices) free to issue while this layer's cross-row `psum` drains.
Without the barrier XLA hoists EVERY layer's kernel-derived tensors to
the program start — all shifted weight matrices live at once — which
measurably loses to the per-layer loop on deep concat stacks
(benchmarks/plan_bench.py tracks both).  Inter-layer carry buffers live
inside the program (reused/donated by the compiler rather than
round-tripping through host dispatch); Python-loop dispatch survives
only *between* forwards — within one, nothing serializes on the host.

`execute_looped` keeps the pre-plan behavior — one jit launch per layer
with eager glue between — as the measurement baseline for
benchmarks/plan_bench.py's dispatch-count and wall-clock comparison.

`apply_layer` dispatches ONE layer of a (possibly layerwise) plan
through the per-executor jit entries — the `cnn/models.apply_cnn` path,
which owns its own pooling/bias plumbing between convs.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.cnn.cim_conv import cim_conv2d_jit, cim_conv2d_traced
from repro.cnn.mapped_net import mapped_conv2d_jit, mapped_conv2d_traced
from .glue import (ACTIVATIONS, attention_stage, center_crop, fit_spatial,
                   layernorm)
from .plan import LayerPlan, NetworkPlan, mesh_axes


def _layer_conv(lp: LayerPlan, x: jnp.ndarray, kernel: jnp.ndarray,
                mesh, *, jitted: bool, prepared=None) -> jnp.ndarray:
    """Dispatch one layer to its planned executor — traced bodies when
    inlining into the whole-forward program, jit entries when launched
    stand-alone (`execute_looped` / `apply_layer`).  ``prepared`` is the
    layer's pre-materialized shifted-weight blocks
    (exec/constants.PlanConstants), consumed by the mapped executor in
    place of the in-trace build."""
    m = lp.mapping
    mesh = mesh if lp.use_mesh else None
    if lp.executor == "mapped":
        fn = mapped_conv2d_jit if jitted else mapped_conv2d_traced
        return fn(m, x, kernel, mesh=mesh, weights=prepared)
    if lp.executor == "matmul":
        from repro.kernels.matmul_exec import (matmul_layer_jit,
                                               matmul_layer_traced)
        fn = matmul_layer_jit if jitted else matmul_layer_traced
        return fn(m, x, kernel, interpret=lp.interpret)
    if lp.executor == "sdk":
        from repro.kernels.im2win_conv import sdk_conv_jit, sdk_conv_traced
        fn = sdk_conv_jit if jitted else sdk_conv_traced
        return fn(m, x, kernel, interpret=lp.interpret, block=lp.block,
                  vmem_budget=lp.vmem_budget)
    fn = cim_conv2d_jit if jitted else cim_conv2d_traced
    return fn(m, x, kernel)


#: Fused-forward trace counter: `_forward` with ``jitted=False`` runs
#: only while `_execute_jit` / `_execute_jit_donated` traces (jit caches
#: replays), so this counts whole-program recompiles — the lookahead
#: regression test asserts exactly one per distinct plan.lookahead.
#: Diagnostics only; reset freely in tests.
fused_trace_count: int = 0


@jax.custom_jvp
def _fence(operands):
    """`lax.optimization_barrier` with a differentiation rule: the fence
    shapes the forward schedule only, so its tangent/cotangent is the
    identity (this jax version implements no rule of its own)."""
    return lax.optimization_barrier(operands)


@_fence.defjvp
def _fence_jvp(primals, tangents):
    (operands,), (dots,) = primals, tangents
    return _fence(operands), dots


def _forward(plan: NetworkPlan, kernels, x: jnp.ndarray, mesh,
             activation, *, jitted: bool, conv=None,
             consts=None) -> jnp.ndarray:
    """The planned forward chain.  Glue kinds were classified at compile
    time (exec/glue.py); this only replays them.  ``conv`` overrides the
    per-layer executor (the lax.conv oracle of `execute_oracle`).
    ``consts`` is PlanConstants.weights — per-layer pre-materialized
    shifted-weight blocks.  Deliberately NOT threaded through the
    lookahead fence below: the fence bounds *in-program* kernel-side
    prep, and a pre-materialized buffer has none — XLA hoisting a plain
    program input to the start is free."""
    lay0 = plan.layers[0].mapping.layer
    if x.shape[1] != lay0.ic:
        raise ValueError(f"{lay0.name}: input has {x.shape[1]} channels,"
                         f" layer expects {lay0.ic}")
    fused = not jitted and conv is None     # one program: fence hoisting
    if fused:
        global fused_trace_count
        fused_trace_count += 1
    # with explicit glue (transformer lowerings) the glue owns every
    # nonlinearity — the network-global activation applies only to
    # inferred-glue (CNN) plans, where no GlueSpec.act is ever set
    explicit = plan.net.glue is not None

    def _segment(s, e, x, seg_kernels, seg_consts):
        """Layers [s, e) on carry ``x`` — the whole net in one call for
        unsegmented plans, one `jax.checkpoint` body per plan segment
        otherwise.  The saved-residual stack is segment-local: the
        segment pass only cuts where it is empty (exec/remat.py)."""
        seg_kernels = list(seg_kernels)
        saved = []                  # GlueSpec.save stack (residual bases)
        for i in range(s, e):
            lp = plan.layers[i]
            lay = lp.mapping.layer
            spec = lp.glue
            xp = fit_spatial(x, lay.i_h, lay.i_w)
            if spec.save:           # residual base: the pre-norm input
                saved.append(xp)
            xin = layernorm(xp) if spec.pre == "layernorm" else xp
            y = conv(lp, xin, seg_kernels[i - s]) if conv is not None \
                else _layer_conv(
                    lp, xin, seg_kernels[i - s], mesh, jitted=jitted,
                    prepared=None if seg_consts is None
                    else seg_consts[i - s])
            if spec.act != "none":
                y = ACTIVATIONS[spec.act](y)
            elif activation is not None and not explicit:
                y = activation(y)
            if spec.post == "attention":
                # the opaque stage between mapped qkv and o projections —
                # glue, not a mapped layer, so cycle accounting is
                # untouched
                y = attention_stage(y, spec.heads, spec.causal,
                                    interpret=lp.interpret)
            if spec.kind == "concat":
                skip = center_crop(xp, y.shape[-2], y.shape[-1])
                x = jnp.concatenate([skip, y], axis=1)
            elif spec.kind == "residual":
                # channel match was validated at compile time; saved
                # bases are deliberately NOT threaded through the
                # lookahead fence — they are live carries, not
                # kernel-side prep
                x = saved.pop() + y
            else:                   # "chain" / "last"
                x = y
            # cross-layer pipeline depth (plan.lookahead, a compile_plan
            # argument since ISSUE 6): kernels of layers beyond
            # ``i + 1 + lookahead`` stay fenced behind this carry, so
            # that many layers of kernel-side prep (weight-matrix
            # blocks, gather indices) may overlap the current psum
            # drain while the live working set stays bounded.  The
            # window is clamped to the segment (``j < e``): pipelining
            # never reaches across a checkpoint boundary
            j = i + 1 + plan.lookahead
            if fused and j < e:
                # bounded pipelining (module docstring): layers past the
                # lookahead window cannot start until this carry exists
                x, *rest = _fence((x, *seg_kernels[j - s:]))
                seg_kernels[j - s:] = rest
        return x

    kernels = list(kernels)
    consts_l = None if consts is None else list(consts)
    spans = plan.spans
    if not fused or len(spans) == 1:
        # unsegmented (or per-layer/oracle dispatch, which never
        # checkpoints): the PR-4 program shape, bit for bit
        return _segment(0, len(plan.layers), x, kernels, consts_l)
    for s, e in spans:
        seg_c = None if consts_l is None else tuple(consts_l[s:e])
        # jax.checkpoint per segment: the backward re-runs the segment
        # from its boundary carry instead of saving every layer's
        # residuals — the memory model DESIGN.md §13 prices
        x = jax.checkpoint(functools.partial(_segment, s, e))(
            x, tuple(kernels[s:e]), seg_c)
        j = e + plan.lookahead
        if j < len(plan.layers):
            # the boundary acts as the fence for later segments: their
            # kernel-side prep (beyond the lookahead window) waits on
            # the carry crossing the boundary, exactly as it would have
            # at a plain layer boundary
            x, *rest = _fence((x, *kernels[j:]))
            kernels[j:] = rest
    return x


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("mesh", "activation"))
def _execute_jit(plan, kernels, x, consts=None, *, mesh=None,
                 activation=None):
    return _forward(plan, kernels, x, mesh, activation, jitted=False,
                    consts=consts)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,),
                   static_argnames=("mesh", "activation"))
def _execute_jit_donated(plan, kernels, x, consts=None, *, mesh=None,
                        activation=None):
    return _forward(plan, kernels, x, mesh, activation, jitted=False,
                    consts=consts)


def donation_supported(mesh=None) -> bool:
    """Whether XLA implements input-buffer donation where the plan will
    actually run.  With a mesh bound, that is the mesh's device platform
    — which may differ from ``jax.default_backend()`` (forced host
    meshes, a CPU mesh next to an accelerator) — else the default
    backend.  CPU (and mixed-platform meshes) never donate; callers fall
    back cleanly to the non-donating entry."""
    from repro.launch.mesh import mesh_platform
    platform = mesh_platform(mesh)
    if platform is None:
        platform = jax.default_backend()
    return platform not in ("cpu", "mixed")


def _check_call(plan: NetworkPlan, kernels, x, mesh) -> None:
    if not plan.chained:
        raise ValueError(
            "execute_plan needs a chained plan; this one was compiled "
            "with chained=False (per-layer dispatch via apply_layer)")
    if len(kernels) != len(plan.layers):
        raise ValueError(f"{len(kernels)} kernels for "
                         f"{len(plan.layers)} planned layers")
    axes = mesh_axes(mesh)
    if axes != plan.mesh_axes:
        raise ValueError(
            f"mesh {axes} does not match the plan's compile mesh "
            f"{plan.mesh_axes} — recompile the plan for this mesh")
    if plan.batch is not None and x.shape[0] != plan.batch:
        raise ValueError(
            f"batch {x.shape[0]} != plan batch {plan.batch} — pad the "
            f"request (launch/serve_cnn pad-and-mask) or recompile")
    lay0 = plan.layers[0].mapping.layer
    if x.shape[1] != lay0.ic:
        raise ValueError(f"{lay0.name}: input has {x.shape[1]} channels,"
                         f" layer expects {lay0.ic}")


def execute_plan(plan: NetworkPlan, kernels: Sequence[jnp.ndarray],
                 x: jnp.ndarray, *, mesh=None, activation=None,
                 donate: bool = False, constants=None) -> jnp.ndarray:
    """Run the planned forward as one jitted program.

    ``mesh`` must be the live mesh matching ``plan.mesh_axes`` (the Mesh
    object stays out of the cached IR).  ``activation`` is a STATIC jit
    argument hashed by identity — pass a stable callable
    (``jax.nn.relu``, a module-level function), never a fresh
    lambda/partial per call, or every call recompiles the whole fused
    program.  ``donate=True`` donates the input batch buffer to the
    program (streaming serving: the carry can reuse it, and the caller
    must hand a FRESH buffer to every call — `launch.batching.InputRing`);
    ignored when the platform the plan actually runs on — the mesh's
    devices when a mesh is bound, else the default backend
    (`donation_supported`) — does not implement donation (CPU).
    ``constants`` is a shared `exec.constants.PlanConstants` handle for
    this plan's network: its pre-materialized shifted-weight blocks feed
    the mapped layers as program inputs, shared across every tier/ladder
    of the network (``prepare_constants``).
    """
    _check_call(plan, kernels, x, mesh)
    consts = None
    if constants is not None:
        if constants.net != plan.net:
            raise ValueError("constants were prepared for a different "
                             "network mapping than this plan")
        if constants.executors != plan.executors:
            raise ValueError(
                f"constants were prepared for executors "
                f"{constants.executors}, plan resolved {plan.executors}")
        if len(constants.weights) != len(plan.layers):
            raise ValueError(f"{len(constants.weights)} constant entries "
                             f"for {len(plan.layers)} planned layers")
        consts = constants.weights
    fn = _execute_jit_donated if donate and donation_supported(mesh) \
        else _execute_jit
    return fn(plan, tuple(kernels), x, consts, mesh=mesh,
              activation=activation)


def execute_looped(plan: NetworkPlan, kernels: Sequence[jnp.ndarray],
                   x: jnp.ndarray, *, mesh=None,
                   activation=None) -> jnp.ndarray:
    """The pre-plan dispatch shape — one jit launch per layer, eager glue
    between — kept as the benchmark baseline `execute_plan` is measured
    against (same numerics, `len(plan.layers)` host dispatches per
    forward instead of one)."""
    _check_call(plan, kernels, x, mesh)
    return _forward(plan, tuple(kernels), x, mesh, activation, jitted=True)


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("mesh",))
def _execute_layerwise_jit(plan, kernels, xs, *, mesh=None):
    return tuple(_layer_conv(lp, x, k, mesh, jitted=False)
                 for lp, k, x in zip(plan.layers, kernels, xs))


def execute_layerwise(plan: NetworkPlan, kernels: Sequence[jnp.ndarray],
                      xs: Sequence[jnp.ndarray], *, mesh=None):
    """Every layer on its OWN input, fused into one jitted program — the
    plan counterpart of looping `apply_layer` over a stack that does not
    chain (several bench networks are representative layer *sets*, not
    chains).  One host dispatch instead of ``len(plan.layers)``."""
    if len(kernels) != len(plan.layers) or len(xs) != len(plan.layers):
        raise ValueError(f"{len(kernels)} kernels / {len(xs)} inputs for "
                         f"{len(plan.layers)} planned layers")
    return _execute_layerwise_jit(plan, tuple(kernels), tuple(xs),
                                  mesh=mesh)


def execute_oracle(plan: NetworkPlan, kernels: Sequence[jnp.ndarray],
                   x: jnp.ndarray, *, activation=None) -> jnp.ndarray:
    """`lax.conv_general_dilated` composed over the SAME compiled chain
    — the DESIGN.md §5 oracle the plan executors are cross-checked
    against (pruned channels must be zeroed in ``kernels``)."""
    from repro.cnn.cim_conv import reference_conv2d
    if not plan.chained:
        raise ValueError("execute_oracle needs a chained plan")
    return _forward(
        plan, tuple(kernels), x, None, activation, jitted=True,
        conv=lambda lp, xp, k: reference_conv2d(
            lp.mapping.layer, xp, k, groups=lp.mapping.group))


def apply_layer(plan: NetworkPlan, i: int, x: jnp.ndarray,
                kernel: jnp.ndarray, *, mesh=None) -> jnp.ndarray:
    """Execute layer ``i`` of the plan stand-alone (jit entry per
    executor) — the `apply_cnn` path, where pooling / bias / activation
    plumbing between convs belongs to the model, not the plan."""
    return _layer_conv(plan.layers[i], x, kernel, mesh, jitted=True)
