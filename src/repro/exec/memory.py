"""Memory-model pass: per-layer live-byte estimates from the mapping IR.

Training differentiates through the whole fused forward (exec/run.py),
so without rematerialization every layer's saved input activation AND
its in-trace shifted-weight prep (the Fig-5 weight matrix blocks the
mapped/reference executors build from the kernel) are live at once for
the backward pass.  This pass prices both per layer, **from the
`LayerMapping` itself** — no tracing, no device allocation — so the
segmentation pass (exec/remat.py) can choose checkpoint boundaries and
`NetworkPlan.describe()` / the benches can report a peak estimate
without ever running the trainer.

Two numbers per layer (:class:`LayerMemory`):

* ``act_bytes`` — the input activation saved for the layer's backward:
  ``batch * carry_c * i_h * i_w * itemsize`` (``carry_c`` is the carry
  entering the layer — for DenseNet concat layers that is the full
  concatenated width, which is exactly why deep concat stacks blow up).
* ``weight_bytes`` — the layer's shifted-weight constant prep: the full
  Fig-5 matrix across every channel/oc pass of every tile, times the
  group count (groups are congruent but each has its own weights).  Per
  tile that is ``(ic_t*ar_c * pw_h*pw_w) x (positions * oc_t*ac_c)``
  floats — the executed pass structure (`LayerMapping.tile_passes`),
  not the stored one, so the estimate follows what the executor
  actually materializes.  Marginal-window matrices (strictly smaller
  than the regular placement's) are not added: this is an estimate used
  to *rank* boundaries, not an allocator.

The peak model (:func:`peak_bytes`) is the classic checkpointing one:
each segment boundary stores its carry activation for the whole
backward, and within the backward exactly one segment's layers are
re-materialized at a time —

    peak = max_over_segments(sum of layer bytes) + sum(boundary carries)

With one segment (remat off) this degenerates to the plain sum: every
layer live at once, the ``unremat_peak`` the ROADMAP item set out to
break.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

#: Activations / shifted-weight blocks are float32 throughout the
#: executors (cnn/cim_conv.py builds f32 matrices from f32 kernels).
ITEMSIZE = 4


@dataclass(frozen=True)
class LayerMemory:
    """Live-byte estimate of one planned layer (see module docstring)."""

    name: str
    act_bytes: int          # saved input activation (backward residual)
    weight_bytes: int       # shifted-weight constant prep (Fig 5 blocks)

    @property
    def total_bytes(self) -> int:
        return self.act_bytes + self.weight_bytes


def activation_bytes(mapping, carry_c: int, batch: int) -> int:
    """Input-activation bytes entering a layer: the tensor its backward
    needs saved (or rematerialized)."""
    lay = mapping.layer
    return batch * carry_c * lay.i_h * lay.i_w * ITEMSIZE


def weight_prep_bytes(mapping) -> int:
    """Shifted-weight-matrix bytes of one layer, from the executed pass
    structure: per tile ``rows = ic_t*ar_c * pw_h*pw_w`` and
    ``cols = positions * oc_t*ac_c`` (build_weight_matrix's shape,
    summed over passes), times the group count."""
    lay = mapping.layer
    total = 0
    for tile in mapping.tiles:
        ic_t, ar_c, oc_t, ac_c = mapping.tile_passes(tile)
        w = tile.window
        pos = w.positions(lay.k_w, lay.k_h, lay.stride)
        rows = ic_t * ar_c * w.pw_w * w.pw_h
        cols = pos * oc_t * ac_c
        total += rows * cols
    return total * mapping.group * ITEMSIZE


def layer_memory(mapping, carry_c: int, batch: int) -> LayerMemory:
    return LayerMemory(name=mapping.layer.name,
                       act_bytes=activation_bytes(mapping, carry_c, batch),
                       weight_bytes=weight_prep_bytes(mapping))


def network_memory(net, carries: Sequence[int],
                   batch: int) -> Tuple[LayerMemory, ...]:
    """Per-layer estimates for a whole mapping; ``carries`` is the
    carry channel count entering each layer (the glue pass's output)."""
    return tuple(layer_memory(m, c, batch)
                 for m, c in zip(net.layers, carries))


def peak_bytes(mem: Sequence[LayerMemory],
               segments: Sequence[Tuple[int, int]]) -> int:
    """Peak-byte estimate of a segmented plan (module docstring): the
    heaviest segment's layer bytes plus every boundary's stored carry —
    the carry entering a segment is the first layer's input activation,
    held live for the whole backward."""
    segs = list(segments)
    heaviest = max(sum(m.total_bytes for m in mem[s:e]) for s, e in segs)
    boundaries = sum(mem[s].act_bytes for s, _ in segs[1:])
    return heaviest + boundaries


def total_bytes(mem: Sequence[LayerMemory]) -> int:
    """The unremat'd peak: every layer's saved bytes live at once."""
    return sum(m.total_bytes for m in mem)
