"""`NetworkPlan` — one compiled execution-plan IR for all three executors.

The paper's speedups come from *scheduling*: window configs are searched
per layer, then executed across a fixed macro budget.  Before this module
every call site re-derived that schedule ad hoc — `mapped_net_apply`
walked a Python loop of per-layer super-steps, `serve_cnn` re-planned
mesh fitting per layer per request, and the reference / mapped / Pallas
executors each owned a private copy of the chaining + steps==cycles
logic.  `compile_plan` lowers a `NetworkMapping` **once** into a static
per-layer plan; `execute_plan` (exec/run.py) then runs the whole forward
as ONE jitted program.

Per layer the plan fixes, at compile time:

* the **executor** — ``"reference"`` (cnn/cim_conv.py, placement-batched
  oracle), ``"mapped"`` (cnn/mapped_net.py, macro-parallel super-steps),
  or ``"sdk"`` (kernels/im2win_conv.py, Pallas MXU path) — selectable
  per layer by a size/VMEM heuristic (``"auto"``) or explicit override;
* the **super-step schedule** (`LayerSchedule`) with the steps==cycles
  assertion evaluated here, at compile time, instead of on every
  dispatch;
* the **inter-layer glue** — plain chain / DenseNet concat classified
  from channel arithmetic (exec/glue.py), so a mis-chained network fails
  at compile, not mid-forward;
* the **sharding decision** — whether the layer's sub-grid fits the
  compile mesh (`macro_mesh_fits`), so dispatch never re-fits.

Plans are frozen, hashable (static jit arguments) and picklable; they
join the memo result/disk cache keyed on mapping + resolved policy +
mesh shape + batch (`core/memo.cached_plan`), so a serving replica
compiles each distinct (network, mesh, batch) once per process — or
never, with a warm disk cache.  See DESIGN.md §8.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple, Union

import jax

from repro.core import memo
from repro.core.types import GlueSpec, NetworkMapping
from repro.cnn.mapped_net import LayerSchedule, check_steps, layer_schedule
from repro.launch.sharding import macro_mesh_fits
from .glue import resolve_chain

#: Executors a plan can dispatch a layer to.  "matmul" is the MXU path
#: for ``op="matmul"`` layers (kernels/matmul_exec.py:
#: tetris_matmul / grouped_matmul); the conv executors also accept
#: matmul layers as the degenerate 1x1 conv they are.
EXECUTORS = ("reference", "mapped", "sdk", "matmul")

#: Anything compile_plan accepts as a policy: one name (or "auto") for
#: every layer, a per-layer sequence of names, or a callable
#: ``LayerMapping -> name``.
PolicyLike = Union[str, Sequence[str], Callable]


@dataclass(frozen=True)
class LayerPlan:
    """Compiled execution of ONE layer — everything dispatch used to
    re-derive, fixed at compile time."""

    mapping: object             # LayerMapping (frozen, hashable)
    executor: str               # "reference" | "mapped" | "sdk" | "matmul"
    schedule: LayerSchedule     # steps==cycles evidence (compile-time)
    glue: GlueSpec              # structured inter-layer glue (core.types)
    carry_c: int                # channels entering this layer
    use_mesh: bool              # shard_map vs vmap, decided at compile
    interpret: bool = False     # sdk: pallas interpret mode (off-TPU)
    block: str = "auto"         # sdk: tiling mode
    vmem_budget: int = 8 * 1024 * 1024  # sdk: resolved byte budget


@dataclass(frozen=True)
class NetworkPlan:
    """Static whole-network execution plan (a hashable jit argument).

    ``mesh_axes`` records the compile mesh's (name, size) shape — the
    Mesh object itself stays out of the IR so plans hash, pickle, and
    disk-cache; `execute_plan` re-binds the live mesh and validates it
    against these axes.  ``batch`` is the batch the sharding decisions
    were made for (None: no data-axis sharding was requested).
    """

    net: NetworkMapping
    layers: Tuple[LayerPlan, ...]
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]]
    batch: Optional[int]
    chained: bool = True
    #: cross-layer pipeline depth of the fused program (exec/run.py):
    #: kernels of layers beyond ``i + 1 + lookahead`` are fenced behind
    #: layer i's carry.  A compile-time field (formerly the module
    #: constant ``_LOOKAHEAD``) so the autotuner — and users — can sweep
    #: it without monkeypatching; each value is its own plan, so
    #: changing it recompiles the fused program exactly once per value.
    lookahead: int = 1

    @property
    def executors(self) -> Tuple[str, ...]:
        return tuple(lp.executor for lp in self.layers)

    @property
    def total_steps(self) -> int:
        """Compile-time super-step total == NetworkMapping.total_cycles."""
        return sum(lp.schedule.steps for lp in self.layers)

    @property
    def host_dispatches(self) -> int:
        """jit program launches per forward through the fused entries
        (`execute_plan` for chains, `execute_layerwise` for layer sets):
        always one — the per-layer loop (`execute_looped` /
        `apply_layer`) launched ``len(self.layers)``."""
        return 1

    def describe(self) -> str:
        execs = ",".join(f"{lp.mapping.layer.name}:{lp.executor}"
                         for lp in self.layers)
        tag = ("x".join(f"{n}={s}" for n, s in self.mesh_axes)
               if self.mesh_axes else "vmap")
        return (f"plan[{self.net.name}] layers={len(self.layers)} "
                f"steps={self.total_steps} mesh={tag} "
                f"lookahead={self.lookahead} "
                f"dispatches/forward={self.host_dispatches} ({execs})")


def mesh_axes(mesh) -> Optional[Tuple[Tuple[str, int], ...]]:
    """Canonical (name, size) shape of a mesh — the form stored in the
    IR, used in the plan cache key, and validated at execute time (one
    definition so the three cannot drift)."""
    if mesh is None:
        return None
    return tuple((str(n), int(s)) for n, s in mesh.shape.items())


def _sdk_realizable(mapping) -> bool:
    """sdk runs every pass and every group sequentially — it can only
    stand in for the mapping when no macro/group parallelism is owed."""
    from repro.kernels.im2win_conv import sdk_conv_cycles
    return sdk_conv_cycles(mapping) == mapping.cycles


def _auto_executor(mapping, *, backend: str) -> str:
    """Per-layer heuristic: the Pallas MXU paths on TPU — ``"matmul"``
    for op="matmul" layers (tetris_matmul / grouped_matmul own the
    tiling), ``"sdk"`` for conv layers owing no macro/group parallelism
    (its ``block="auto"`` tiling handles the VMEM budget per layer
    size); the macro-parallel executor whenever a non-degenerate
    sub-grid must be realized; otherwise the placement-batched reference
    path (fewest ops — fastest off-accelerator)."""
    if backend == "tpu":
        if getattr(mapping.layer, "op", "conv") == "matmul":
            return "matmul"
        if _sdk_realizable(mapping):
            return "sdk"
    if mapping.sub_grid.p > 1 or mapping.group_rounds < mapping.group:
        return "mapped"
    return "reference"


def _resolve_policy(policy: PolicyLike, net: NetworkMapping, *,
                    backend: str) -> Tuple[str, ...]:
    if callable(policy):
        per_layer = [policy(m) for m in net.layers]
    elif isinstance(policy, str):
        per_layer = [policy] * len(net.layers)
    else:
        per_layer = list(policy)
        if len(per_layer) != len(net.layers):
            raise ValueError(
                f"policy lists {len(per_layer)} executors for "
                f"{len(net.layers)} layers")
    out = []
    for name, m in zip(per_layer, net.layers):
        if name == "auto":
            name = _auto_executor(m, backend=backend)
        if name not in EXECUTORS:
            raise ValueError(f"unknown executor {name!r} "
                             f"(expected one of {EXECUTORS} or 'auto')")
        out.append(name)
    return tuple(out)


def _compile(net: NetworkMapping, execs: Tuple[str, ...], mesh,
             batch: Optional[int], chained: bool, interpret: bool,
             block: str, vmem_budget: int, lookahead: int) -> NetworkPlan:
    if (mesh is not None and "data" in mesh.axis_names
            and batch is not None and batch % mesh.shape["data"]):
        # refuse rather than silently vmap the whole net: ragged batches
        # must pad to the data axis (launch.mesh.pad_to_data_axis /
        # serve_cnn pad-and-mask)
        raise ValueError(
            f"batch {batch} does not divide the mesh data axis "
            f"{mesh.shape['data']} — pad the batch to "
            f"pad_to_data_axis(batch, mesh) or drop the data axis")
    layers = []
    carry_c = net.layers[0].layer.ic
    saved: list = []                # channel widths of GlueSpec.save stack
    for i, (m, ex) in enumerate(zip(net.layers, execs)):
        lay = m.layer
        check_steps(m)                      # steps==cycles, at compile time
        if ex == "sdk" and not _sdk_realizable(m):
            raise ValueError(
                f"{lay.name}: executor 'sdk' runs passes/groups "
                f"sequentially and cannot realize sub-grid "
                f"{m.sub_grid.r}x{m.sub_grid.c} / {m.group_rounds} group "
                f"rounds — use 'mapped'")
        if ex == "matmul" and getattr(lay, "op", "conv") != "matmul":
            raise ValueError(
                f"{lay.name}: executor 'matmul' requires op='matmul' "
                f"(this layer is op={getattr(lay, 'op', 'conv')!r})")
        use_mesh = (ex == "mapped"
                    and macro_mesh_fits(mesh, m.sub_grid.r, m.sub_grid.c,
                                        batch=batch))
        if not chained:
            glue = GlueSpec(kind="layerwise")
        elif net.glue is not None:
            glue = net.glue[i]
            carry_c, saved = _check_explicit_glue(net, i, glue, carry_c,
                                                  saved)
        else:
            if i + 1 < len(net.layers):
                nxt = net.layers[i + 1].layer
                glue = GlueSpec(kind=resolve_chain(
                    lay.name, lay.oc, carry_c, nxt.name, nxt.ic))
            else:
                glue = GlueSpec(kind="last")
        layers.append(LayerPlan(
            mapping=m, executor=ex, schedule=layer_schedule(m),
            glue=glue, carry_c=carry_c if net.glue is None or not chained
            else lay.ic, use_mesh=use_mesh,
            interpret=interpret, block=block, vmem_budget=vmem_budget))
        if net.glue is None or not chained:
            carry_c = net.layers[i + 1].layer.ic \
                if i + 1 < len(net.layers) else lay.oc
    if chained and net.glue is not None and saved:
        raise ValueError(
            f"{net.name}: {len(saved)} saved residual input(s) never "
            f"consumed by a kind='residual' glue")
    return NetworkPlan(net=net, layers=tuple(layers),
                       mesh_axes=mesh_axes(mesh), batch=batch,
                       chained=chained, lookahead=lookahead)


def _check_explicit_glue(net: NetworkMapping, i: int, spec: GlueSpec,
                         carry_c: int, saved: list):
    """Compile-time channel simulation of one explicit-glue step: what
    `resolve_chain` does for inferred CNN glue, generalized to the
    save/residual stack and the attention stage.  Returns the carry
    channel count entering layer i+1 and the updated saved stack —
    raising the mis-chaining error here, never mid-forward."""
    lay = net.layers[i].layer
    last = i + 1 == len(net.layers)
    if lay.ic != carry_c:
        raise ValueError(
            f"{lay.name}: glue carries {carry_c} channels into a layer "
            f"with ic={lay.ic}")
    if spec.kind == "layerwise" or (spec.kind == "last" and not last):
        raise ValueError(
            f"{lay.name}: glue kind {spec.kind!r} is invalid for chained "
            f"layer {i} of {len(net.layers)}")
    out_c = lay.oc
    if spec.post == "attention":
        hq, hkv, hd = spec.heads
        if getattr(lay, "op", "conv") != "matmul" \
                or lay.oc != (hq + 2 * hkv) * hd:
            raise ValueError(
                f"{lay.name}: post='attention' with heads "
                f"({hq}q, {hkv}kv, {hd}d) needs an op='matmul' layer "
                f"with oc={(hq + 2 * hkv) * hd}, got op="
                f"{getattr(lay, 'op', 'conv')!r} oc={lay.oc}")
        out_c = hq * hd
    saved = list(saved)
    if spec.save:
        saved.append(carry_c)
    if spec.kind == "residual":
        if not saved:
            raise ValueError(f"{lay.name}: kind='residual' with no saved "
                             f"input (no earlier glue set save=True)")
        res_c = saved.pop()
        if res_c != out_c:
            raise ValueError(
                f"{lay.name}: residual add of {res_c} saved channels "
                f"onto {out_c} output channels")
        nxt_c = out_c
    elif spec.kind == "concat":
        nxt_c = carry_c + out_c
    else:                               # "chain" or final "last"
        nxt_c = out_c
    if not last and net.layers[i + 1].layer.ic != nxt_c:
        nxt = net.layers[i + 1].layer
        raise ValueError(
            f"cannot chain {lay.name} ({spec.kind}, {nxt_c} carry "
            f"channels) into {nxt.name} (ic={nxt.ic})")
    return nxt_c, saved


def compile_plan(net: NetworkMapping, *,
                 executor_policy: PolicyLike = "auto",
                 mesh=None, batch: Optional[int] = None,
                 chained: bool = True,
                 interpret: Optional[bool] = None,
                 block: Optional[str] = None,
                 vmem_budget: Optional[int] = None,
                 lookahead: Optional[int] = None) -> NetworkPlan:
    """Lower ``net`` once into a :class:`NetworkPlan`.

    ``executor_policy`` — ``"auto"`` (per-layer heuristic, see
    `_auto_executor`), ``"tuned"`` (the measured-feedback autotuner's
    persisted winner for this net / device fleet / batch — see
    `repro.tune`; falls back to ``"auto"`` when nothing has been tuned),
    one executor name for every layer, a per-layer sequence, or a
    callable ``LayerMapping -> name``.  ``mesh``/``batch``
    fix the sharding decisions (`macro_mesh_fits` per layer, evaluated
    here, never at dispatch); a batch that does not divide the mesh's
    data axis is refused here — pad it first (`mesh.pad_to_data_axis`).
    ``chained=False`` compiles a *layerwise* plan — per-layer executor
    dispatch without inter-layer glue (the `apply_cnn` path, which owns
    its own pooling/bias plumbing); such plans cannot be passed to
    `execute_plan`.

    ``lookahead`` (default 1) is the fused program's cross-layer
    pipeline depth; ``vmem_budget`` (default: the
    ``REPRO_SDK_VMEM_BUDGET`` environment variable, else 8 MiB) bounds
    the sdk executor's ``block="auto"`` whole-array working set.  With
    ``executor_policy="tuned"`` any of ``lookahead`` / ``block`` /
    ``vmem_budget`` left unset take the tuned values.

    Every layer's executed schedule is asserted equal to its
    ``LayerMapping.cycles`` here (compile time), and a mis-chained
    network raises the chaining error here too.  Results are memoized —
    in memory and, when a disk cache is configured, across processes —
    keyed on (net, resolved policy, mesh shape, batch, flags).
    """
    from repro.kernels.im2win_conv import default_vmem_budget
    if not net.layers:
        raise ValueError(f"{net.name}: cannot plan an empty network")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if executor_policy == "tuned":
        # lazy import: repro.tune compiles plans, so the dependency
        # must point tune -> exec at module scope, not both ways
        from repro.tune import tuned_config
        cfg = tuned_config(net, batch=batch)
        if cfg is None:
            executor_policy = "auto"
        else:
            executor_policy = cfg.candidate.policy
            if lookahead is None:
                lookahead = cfg.candidate.lookahead
            if block is None:
                block = cfg.candidate.block
            if vmem_budget is None:
                vmem_budget = cfg.candidate.vmem_budget
    if lookahead is None:
        lookahead = 1
    if lookahead < 0:
        raise ValueError(f"lookahead must be >= 0, got {lookahead}")
    if block is None:
        block = "auto"
    if vmem_budget is None:
        vmem_budget = default_vmem_budget()
    execs = _resolve_policy(executor_policy, net,
                            backend=jax.default_backend())
    key = (net, execs, mesh_axes(mesh), batch, chained, interpret, block,
           vmem_budget, lookahead)

    def _compile_counted():
        _note_compile(key)
        return _compile(net, execs, mesh, batch, chained, interpret,
                        block, vmem_budget, lookahead)

    return memo.cached_plan(key, _compile_counted)


#: Actual `_compile` lowerings per cache key — cache hits (in-memory or
#: disk) do NOT count.  The serving acceptance tests assert every tier
#: of a plan ladder compiles exactly once per process
#: (tests/test_serve_cnn.py); bounded like im2win_conv._trace_counts so
#: a long-lived process cannot grow it without limit.
_compile_counts: dict = {}
_COMPILE_COUNT_LIMIT = 512


def _note_compile(key) -> None:
    if key not in _compile_counts:
        while len(_compile_counts) >= _COMPILE_COUNT_LIMIT:
            del _compile_counts[next(iter(_compile_counts))]
        _compile_counts[key] = 0
    _compile_counts[key] += 1


def compile_counts(*, net: Optional[NetworkMapping] = None,
                   batch: Optional[int] = None) -> dict:
    """Copy of the per-key compile counters, optionally filtered to one
    network mapping and/or plan batch — ``compile_counts(net=nm)``
    values of all 1 prove each (policy, mesh, batch) lowered once."""
    out = {}
    for key, n in _compile_counts.items():
        if net is not None and key[0] != net:
            continue
        if batch is not None and key[3] != batch:
            continue
        out[key] = n
    return out


# a cleared memo cache recompiles, so the counters reset with it —
# "each tier compiled once" stays meaningful per cache generation
memo.register_cache_clear(_compile_counts.clear)
