"""`NetworkPlan` — one compiled execution-plan IR for all three executors.

The paper's speedups come from *scheduling*: window configs are searched
per layer, then executed across a fixed macro budget.  Before this module
every call site re-derived that schedule ad hoc — `mapped_net_apply`
walked a Python loop of per-layer super-steps, `serve_cnn` re-planned
mesh fitting per layer per request, and the reference / mapped / Pallas
executors each owned a private copy of the chaining + steps==cycles
logic.  `compile_plan` lowers a `NetworkMapping` **once** into a static
per-layer plan; `execute_plan` (exec/run.py) then runs the whole forward
as ONE jitted program.

Compilation is a staged **pass pipeline** over a `PlanDraft` — each
pass takes the draft and returns an updated one, so new analyses slot
in without touching dispatch::

    validate ─ resolve_executors ─ check_glue ─ estimate_memory
             ─ segment ─ schedule ─ (freeze → NetworkPlan)

* **validate** — batch/mesh divisibility (a ragged batch is refused
  here: pad it first);
* **resolve_executors** — per-layer executor legality (sdk
  realizability, matmul op match) and the sharding decision
  (`macro_mesh_fits`), so dispatch never re-fits;
* **check_glue** — inter-layer glue: plain chain / DenseNet concat
  classified from channel arithmetic (exec/glue.py) for CNNs, or the
  mapping's explicit `GlueSpec` tuple validated by carry simulation —
  a mis-chained network fails at compile, not mid-forward;
* **estimate_memory** — per-layer live-activation + shifted-weight
  byte estimates from the LayerMapping itself (exec/memory.py);
* **segment** — rematerialization boundaries under the requested
  peak-memory budget (exec/remat.py; concat groups never split);
* **schedule** — the super-step schedule (`LayerSchedule`) with the
  steps==cycles assertion evaluated here, at compile time, instead of
  on every dispatch.

Plans are frozen, hashable (static jit arguments) and picklable; they
join the memo result/disk cache keyed on mapping + resolved policy +
mesh shape + batch + remat spec (`core/memo.cached_plan`), so a serving
replica compiles each distinct (network, mesh, batch) once per process
— or never, with a warm disk cache.  See DESIGN.md §8 and §13.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional, Sequence, Tuple, Union

import jax

from repro.core import memo
from repro.core.types import GlueSpec, NetworkMapping
from repro.cnn.mapped_net import LayerSchedule, check_steps, layer_schedule
from repro.launch.sharding import macro_mesh_fits
from . import memory as memlib
from . import remat as rematlib
from .glue import resolve_chain

#: Executors a plan can dispatch a layer to.  "matmul" is the MXU path
#: for ``op="matmul"`` layers (kernels/matmul_exec.py:
#: tetris_matmul / grouped_matmul); the conv executors also accept
#: matmul layers as the degenerate 1x1 conv they are.
EXECUTORS = ("reference", "mapped", "sdk", "matmul")

#: Anything compile_plan accepts as a policy: one name (or "auto") for
#: every layer, a per-layer sequence of names, or a callable
#: ``LayerMapping -> name``.
PolicyLike = Union[str, Sequence[str], Callable]


@dataclass(frozen=True)
class LayerPlan:
    """Compiled execution of ONE layer — everything dispatch used to
    re-derive, fixed at compile time."""

    mapping: object             # LayerMapping (frozen, hashable)
    executor: str               # "reference" | "mapped" | "sdk" | "matmul"
    schedule: LayerSchedule     # steps==cycles evidence (compile-time)
    glue: GlueSpec              # structured inter-layer glue (core.types)
    carry_c: int                # channels entering this layer
    use_mesh: bool              # shard_map vs vmap, decided at compile
    interpret: bool = False     # sdk: pallas interpret mode (off-TPU)
    block: str = "auto"         # sdk: tiling mode
    vmem_budget: int = 8 * 1024 * 1024  # sdk: resolved byte budget
    act_bytes: int = 0          # memory pass: saved input activation
    weight_bytes: int = 0       # memory pass: shifted-weight prep

    @property
    def mem_bytes(self) -> int:
        """Live bytes this layer pins during an unremat'd backward."""
        return self.act_bytes + self.weight_bytes


@dataclass(frozen=True)
class NetworkPlan:
    """Static whole-network execution plan (a hashable jit argument).

    ``mesh_axes`` records the compile mesh's (name, size) shape — the
    Mesh object itself stays out of the IR so plans hash, pickle, and
    disk-cache; `execute_plan` re-binds the live mesh and validates it
    against these axes.  ``batch`` is the batch the sharding decisions
    were made for (None: no data-axis sharding was requested).
    """

    net: NetworkMapping
    layers: Tuple[LayerPlan, ...]
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]]
    batch: Optional[int]
    chained: bool = True
    #: cross-layer pipeline depth of the fused program (exec/run.py):
    #: kernels of layers beyond ``i + 1 + lookahead`` are fenced behind
    #: layer i's carry.  A compile-time field (formerly the module
    #: constant ``_LOOKAHEAD``) so the autotuner — and users — can sweep
    #: it without monkeypatching; each value is its own plan, so
    #: changing it recompiles the fused program exactly once per value.
    lookahead: int = 1
    #: rematerialization segments — half-open (start, end) layer ranges
    #: chosen by the segment pass; None when remat was off (the PR-4-era
    #: single-program shape).  `execute_plan` wraps each segment in
    #: `jax.checkpoint` when there is more than one.
    segments: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def executors(self) -> Tuple[str, ...]:
        return tuple(lp.executor for lp in self.layers)

    @property
    def total_steps(self) -> int:
        """Compile-time super-step total == NetworkMapping.total_cycles."""
        return sum(lp.schedule.steps for lp in self.layers)

    @property
    def host_dispatches(self) -> int:
        """jit program launches per forward through the fused entries
        (`execute_plan` for chains, `execute_layerwise` for layer sets):
        always one — the per-layer loop (`execute_looped` /
        `apply_layer`) launched ``len(self.layers)``."""
        return 1

    @property
    def spans(self) -> Tuple[Tuple[int, int], ...]:
        """The segment ranges dispatch iterates — one whole-net span
        when the segment pass did not run / remat is off."""
        if self.segments is not None:
            return self.segments
        return ((0, len(self.layers)),)

    @property
    def layer_memory(self) -> Tuple[memlib.LayerMemory, ...]:
        return tuple(memlib.LayerMemory(lp.mapping.layer.name,
                                        lp.act_bytes, lp.weight_bytes)
                     for lp in self.layers)

    @property
    def peak_bytes(self) -> int:
        """Peak live-byte estimate of training through this plan *as
        segmented* (exec/memory.py peak model)."""
        return memlib.peak_bytes(self.layer_memory, self.spans)

    @property
    def unremat_peak_bytes(self) -> int:
        """What the peak would be with every layer's residuals live at
        once — the remat-off baseline the frontier is measured against."""
        return memlib.total_bytes(self.layer_memory)

    def describe(self) -> str:
        execs = ",".join(f"{lp.mapping.layer.name}:{lp.executor}"
                         for lp in self.layers)
        tag = ("x".join(f"{n}={s}" for n, s in self.mesh_axes)
               if self.mesh_axes else "vmap")
        seg = f" segments={len(self.segments)}" if self.segments else ""
        return (f"plan[{self.net.name}] layers={len(self.layers)} "
                f"steps={self.total_steps} mesh={tag} "
                f"lookahead={self.lookahead} "
                f"peak_mem={self.peak_bytes / 1e6:.1f}MB{seg} "
                f"dispatches/forward={self.host_dispatches} ({execs})")

    def describe_memory(self) -> str:
        """Per-layer memory-pass estimates, one line per layer, with
        segment boundaries marked — the frontier, inspectable without
        running the trainer."""
        starts = {s for s, _ in self.spans[1:]}
        lines = [f"plan[{self.net.name}] "
                 f"peak={self.peak_bytes / 1e6:.1f}MB "
                 f"unremat={self.unremat_peak_bytes / 1e6:.1f}MB "
                 f"segments={len(self.spans)}"]
        for i, lp in enumerate(self.layers):
            cut = " <- segment" if i in starts else ""
            lines.append(
                f"  {lp.mapping.layer.name}: act="
                f"{lp.act_bytes / 1e6:.2f}MB weights="
                f"{lp.weight_bytes / 1e6:.2f}MB{cut}")
        return "\n".join(lines)


def mesh_axes(mesh) -> Optional[Tuple[Tuple[str, int], ...]]:
    """Canonical (name, size) shape of a mesh — the form stored in the
    IR, used in the plan cache key, and validated at execute time (one
    definition so the three cannot drift)."""
    if mesh is None:
        return None
    return tuple((str(n), int(s)) for n, s in mesh.shape.items())


def _sdk_realizable(mapping) -> bool:
    """sdk runs every pass and every group sequentially — it can only
    stand in for the mapping when no macro/group parallelism is owed."""
    from repro.kernels.im2win_conv import sdk_conv_cycles
    return sdk_conv_cycles(mapping) == mapping.cycles


def _auto_executor(mapping, *, backend: str) -> str:
    """Per-layer heuristic: the Pallas MXU paths on TPU — ``"matmul"``
    for op="matmul" layers (tetris_matmul / grouped_matmul own the
    tiling), ``"sdk"`` for conv layers owing no macro/group parallelism
    (its ``block="auto"`` tiling handles the VMEM budget per layer
    size); the macro-parallel executor whenever a non-degenerate
    sub-grid must be realized; otherwise the placement-batched reference
    path (fewest ops — fastest off-accelerator)."""
    if backend == "tpu":
        if getattr(mapping.layer, "op", "conv") == "matmul":
            return "matmul"
        if _sdk_realizable(mapping):
            return "sdk"
    if mapping.sub_grid.p > 1 or mapping.group_rounds < mapping.group:
        return "mapped"
    return "reference"


def _resolve_policy(policy: PolicyLike, net: NetworkMapping, *,
                    backend: str) -> Tuple[str, ...]:
    if callable(policy):
        per_layer = [policy(m) for m in net.layers]
    elif isinstance(policy, str):
        per_layer = [policy] * len(net.layers)
    else:
        per_layer = list(policy)
        if len(per_layer) != len(net.layers):
            raise ValueError(
                f"policy lists {len(per_layer)} executors for "
                f"{len(net.layers)} layers")
    out = []
    for name, m in zip(per_layer, net.layers):
        if name == "auto":
            name = _auto_executor(m, backend=backend)
        if name not in EXECUTORS:
            raise ValueError(f"unknown executor {name!r} "
                             f"(expected one of {EXECUTORS} or 'auto')")
        out.append(name)
    return tuple(out)


# ---------------------------------------------------------------------------
# the pass pipeline


@dataclass(frozen=True)
class PlanDraft:
    """The intermediate the compile passes thread — compile_plan's
    resolved inputs plus one field per analysis, each filled by its
    pass and read by later ones.  Frozen: passes return an updated copy
    (`dataclasses.replace`), never mutate."""

    net: NetworkMapping
    execs: Tuple[str, ...]
    mesh: object                    # the LIVE mesh (not in the final IR)
    batch: Optional[int]
    chained: bool
    interpret: bool
    block: str
    vmem_budget: int
    lookahead: int
    remat: object                   # canonical spec (exec.remat)
    # pass products
    use_mesh: Optional[Tuple[bool, ...]] = None        # resolve_executors
    glue: Optional[Tuple[GlueSpec, ...]] = None        # check_glue
    carries: Optional[Tuple[int, ...]] = None          # check_glue
    mem: Optional[Tuple[memlib.LayerMemory, ...]] = None  # estimate_memory
    segments: Optional[Tuple[Tuple[int, int], ...]] = None  # segment
    schedules: Optional[Tuple[LayerSchedule, ...]] = None   # schedule


def pass_validate(d: PlanDraft) -> PlanDraft:
    """Whole-plan input legality (per-layer legality lives with the
    passes that own the facts)."""
    if (d.mesh is not None and "data" in d.mesh.axis_names
            and d.batch is not None and d.batch % d.mesh.shape["data"]):
        # refuse rather than silently vmap the whole net: ragged batches
        # must pad to the data axis (launch.mesh.pad_to_data_axis /
        # serve_cnn pad-and-mask)
        raise ValueError(
            f"batch {d.batch} does not divide the mesh data axis "
            f"{d.mesh.shape['data']} — pad the batch to "
            f"pad_to_data_axis(batch, mesh) or drop the data axis")
    return d


def pass_resolve_executors(d: PlanDraft) -> PlanDraft:
    """Executor legality per layer + the sharding decision."""
    use = []
    for m, ex in zip(d.net.layers, d.execs):
        lay = m.layer
        if ex == "sdk" and not _sdk_realizable(m):
            raise ValueError(
                f"{lay.name}: executor 'sdk' runs passes/groups "
                f"sequentially and cannot realize sub-grid "
                f"{m.sub_grid.r}x{m.sub_grid.c} / {m.group_rounds} group "
                f"rounds — use 'mapped'")
        if ex == "matmul" and getattr(lay, "op", "conv") != "matmul":
            raise ValueError(
                f"{lay.name}: executor 'matmul' requires op='matmul' "
                f"(this layer is op={getattr(lay, 'op', 'conv')!r})")
        use.append(ex == "mapped"
                   and macro_mesh_fits(d.mesh, m.sub_grid.r, m.sub_grid.c,
                                       batch=d.batch))
    return replace(d, use_mesh=tuple(use))


def pass_check_glue(d: PlanDraft) -> PlanDraft:
    """Classify / validate inter-layer glue and the carry channel count
    entering each layer."""
    net = d.net
    n = len(net.layers)
    if not d.chained:
        return replace(
            d, glue=tuple(GlueSpec(kind="layerwise") for _ in range(n)),
            carries=tuple(m.layer.ic for m in net.layers))
    glue, carries = [], []
    carry_c = net.layers[0].layer.ic
    saved: list = []                # channel widths of GlueSpec.save stack
    for i, m in enumerate(net.layers):
        lay = m.layer
        carries.append(carry_c)
        if net.glue is not None:
            spec = net.glue[i]
            carry_c, saved = _check_explicit_glue(net, i, spec, carry_c,
                                                  saved)
        else:
            if i + 1 < n:
                nxt = net.layers[i + 1].layer
                spec = GlueSpec(kind=resolve_chain(
                    lay.name, lay.oc, carry_c, nxt.name, nxt.ic))
            else:
                spec = GlueSpec(kind="last")
            carry_c = net.layers[i + 1].layer.ic if i + 1 < n else lay.oc
        glue.append(spec)
    if net.glue is not None and saved:
        raise ValueError(
            f"{net.name}: {len(saved)} saved residual input(s) never "
            f"consumed by a kind='residual' glue")
    # carries[i] == layers[i].ic in every valid plan (the simulation
    # above raises otherwise) — recorded explicitly so later passes
    # read the glue pass's product, not channel arithmetic of their own
    return replace(d, glue=tuple(glue), carries=tuple(carries))


def pass_estimate_memory(d: PlanDraft) -> PlanDraft:
    """Per-layer live-byte estimates (exec/memory.py).  ``batch=None``
    plans price a single example — the estimate scales linearly, and
    the segment boundaries it drives depend only on the ratios."""
    mem = memlib.network_memory(d.net, d.carries,
                                d.batch if d.batch else 1)
    return replace(d, mem=mem)


def pass_segment(d: PlanDraft) -> PlanDraft:
    """Choose rematerialization boundaries (exec/remat.py).  Chained
    plans cut only at the glue pass's legal boundaries; layerwise plans
    (`apply_cnn`, which owns its own glue) may cut anywhere."""
    if d.remat is None:
        return d                    # remat off: segments stays None
    if d.chained:
        allowed = rematlib.allowed_cuts(d.glue)
    else:
        allowed = tuple(range(len(d.net.layers) - 1))
    return replace(d, segments=rematlib.plan_segments(d.mem, allowed,
                                                      d.remat))


def pass_schedule(d: PlanDraft) -> PlanDraft:
    """Super-step schedules, with steps==cycles asserted per layer —
    at compile time, never at dispatch."""
    scheds = []
    for m in d.net.layers:
        check_steps(m)
        scheds.append(layer_schedule(m))
    return replace(d, schedules=tuple(scheds))


#: The pipeline, in order.  Each pass is PlanDraft -> PlanDraft; new
#: analyses insert here without touching dispatch or the freeze step.
PASSES: Tuple[Callable[[PlanDraft], PlanDraft], ...] = (
    pass_validate, pass_resolve_executors, pass_check_glue,
    pass_estimate_memory, pass_segment, pass_schedule)


def _freeze(d: PlanDraft) -> NetworkPlan:
    """Assemble the frozen IR from a fully-analyzed draft."""
    layers = tuple(
        LayerPlan(mapping=m, executor=ex, schedule=sch, glue=g,
                  carry_c=c, use_mesh=um, interpret=d.interpret,
                  block=d.block, vmem_budget=d.vmem_budget,
                  act_bytes=mm.act_bytes, weight_bytes=mm.weight_bytes)
        for m, ex, sch, g, c, um, mm in zip(
            d.net.layers, d.execs, d.schedules, d.glue, d.carries,
            d.use_mesh, d.mem))
    return NetworkPlan(net=d.net, layers=layers,
                       mesh_axes=mesh_axes(d.mesh), batch=d.batch,
                       chained=d.chained, lookahead=d.lookahead,
                       segments=d.segments)


def _compile(net: NetworkMapping, execs: Tuple[str, ...], mesh,
             batch: Optional[int], chained: bool, interpret: bool,
             block: str, vmem_budget: int, lookahead: int,
             remat_spec=None) -> NetworkPlan:
    draft = PlanDraft(net=net, execs=execs, mesh=mesh, batch=batch,
                      chained=chained, interpret=interpret, block=block,
                      vmem_budget=vmem_budget, lookahead=lookahead,
                      remat=remat_spec)
    for p in PASSES:
        draft = p(draft)
    return _freeze(draft)


def _check_explicit_glue(net: NetworkMapping, i: int, spec: GlueSpec,
                         carry_c: int, saved: list):
    """Compile-time channel simulation of one explicit-glue step: what
    `resolve_chain` does for inferred CNN glue, generalized to the
    save/residual stack and the attention stage.  Returns the carry
    channel count entering layer i+1 and the updated saved stack —
    raising the mis-chaining error here, never mid-forward."""
    lay = net.layers[i].layer
    last = i + 1 == len(net.layers)
    if lay.ic != carry_c:
        raise ValueError(
            f"{lay.name}: glue carries {carry_c} channels into a layer "
            f"with ic={lay.ic}")
    if spec.kind == "layerwise" or (spec.kind == "last" and not last):
        raise ValueError(
            f"{lay.name}: glue kind {spec.kind!r} is invalid for chained "
            f"layer {i} of {len(net.layers)}")
    out_c = lay.oc
    if spec.post == "attention":
        hq, hkv, hd = spec.heads
        if getattr(lay, "op", "conv") != "matmul" \
                or lay.oc != (hq + 2 * hkv) * hd:
            raise ValueError(
                f"{lay.name}: post='attention' with heads "
                f"({hq}q, {hkv}kv, {hd}d) needs an op='matmul' layer "
                f"with oc={(hq + 2 * hkv) * hd}, got op="
                f"{getattr(lay, 'op', 'conv')!r} oc={lay.oc}")
        out_c = hq * hd
    saved = list(saved)
    if spec.save:
        saved.append(carry_c)
    if spec.kind == "residual":
        if not saved:
            raise ValueError(f"{lay.name}: kind='residual' with no saved "
                             f"input (no earlier glue set save=True)")
        res_c = saved.pop()
        if res_c != out_c:
            raise ValueError(
                f"{lay.name}: residual add of {res_c} saved channels "
                f"onto {out_c} output channels")
        nxt_c = out_c
    elif spec.kind == "concat":
        nxt_c = carry_c + out_c
    else:                               # "chain" or final "last"
        nxt_c = out_c
    if not last and net.layers[i + 1].layer.ic != nxt_c:
        nxt = net.layers[i + 1].layer
        raise ValueError(
            f"cannot chain {lay.name} ({spec.kind}, {nxt_c} carry "
            f"channels) into {nxt.name} (ic={nxt.ic})")
    return nxt_c, saved


def compile_plan(net: NetworkMapping, *,
                 executor_policy: PolicyLike = "auto",
                 mesh=None, batch: Optional[int] = None,
                 chained: bool = True,
                 interpret: Optional[bool] = None,
                 block: Optional[str] = None,
                 vmem_budget: Optional[int] = None,
                 lookahead: Optional[int] = None,
                 remat: rematlib.RematSpec = None) -> NetworkPlan:
    """Lower ``net`` once into a :class:`NetworkPlan`.

    ``executor_policy`` — ``"auto"`` (per-layer heuristic, see
    `_auto_executor`), ``"tuned"`` (the measured-feedback autotuner's
    persisted winner for this net / device fleet / batch — see
    `repro.tune`; falls back to ``"auto"`` when nothing has been tuned),
    one executor name for every layer, a per-layer sequence, or a
    callable ``LayerMapping -> name``.  ``mesh``/``batch``
    fix the sharding decisions (`macro_mesh_fits` per layer, evaluated
    here, never at dispatch); a batch that does not divide the mesh's
    data axis is refused here — pad it first (`mesh.pad_to_data_axis`).
    ``chained=False`` compiles a *layerwise* plan — per-layer executor
    dispatch without inter-layer glue (the `apply_cnn` path, which owns
    its own pooling/bias plumbing); such plans cannot be passed to
    `execute_plan`.

    ``lookahead`` (default 1) is the fused program's cross-layer
    pipeline depth; ``vmem_budget`` (default: the
    ``REPRO_SDK_VMEM_BUDGET`` environment variable, else 8 MiB) bounds
    the sdk executor's ``block="auto"`` whole-array working set.

    ``remat`` asks the segment pass for rematerialization boundaries:
    ``None``/``"off"`` (no segmentation — the default), ``"auto"``
    (budget from ``REPRO_TRAIN_MEM_BUDGET`` bytes if set, else the
    sqrt-segments heuristic), an ``int`` peak-byte budget, or an
    explicit sequence of boundary layer indices (cut *after* each;
    illegal cuts — mid concat group, over an outstanding residual —
    raise).  `execute_plan` then wraps each segment in `jax.checkpoint`
    (exec/remat.py).

    With ``executor_policy="tuned"`` any of ``lookahead`` / ``block`` /
    ``vmem_budget`` / ``remat`` left unset take the tuned values (pass
    ``remat="off"`` to force remat off under a tuned policy).

    Every layer's executed schedule is asserted equal to its
    ``LayerMapping.cycles`` here (compile time), and a mis-chained
    network raises the chaining error here too.  Results are memoized —
    in memory and, when a disk cache is configured, across processes —
    keyed on (net, resolved policy, mesh shape, batch, flags, remat).
    """
    from repro.kernels.im2win_conv import default_vmem_budget
    if not net.layers:
        raise ValueError(f"{net.name}: cannot plan an empty network")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if executor_policy == "tuned":
        # lazy import: repro.tune compiles plans, so the dependency
        # must point tune -> exec at module scope, not both ways
        from repro.tune import tuned_config
        cfg = tuned_config(net, batch=batch)
        if cfg is None:
            executor_policy = "auto"
        else:
            executor_policy = cfg.candidate.policy
            if lookahead is None:
                lookahead = cfg.candidate.lookahead
            if block is None:
                block = cfg.candidate.block
            if vmem_budget is None:
                vmem_budget = cfg.candidate.vmem_budget
            if remat is None:
                remat = getattr(cfg.candidate, "remat", None)
    if lookahead is None:
        lookahead = 1
    if lookahead < 0:
        raise ValueError(f"lookahead must be >= 0, got {lookahead}")
    if block is None:
        block = "auto"
    if vmem_budget is None:
        vmem_budget = default_vmem_budget()
    remat_spec = rematlib.canonical_remat(remat)
    execs = _resolve_policy(executor_policy, net,
                            backend=jax.default_backend())
    key = (net, execs, mesh_axes(mesh), batch, chained, interpret, block,
           vmem_budget, lookahead, remat_spec)

    def _compile_counted():
        _note_compile(key)
        return _compile(net, execs, mesh, batch, chained, interpret,
                        block, vmem_budget, lookahead, remat_spec)

    return memo.cached_plan(key, _compile_counted)


#: Actual `_compile` lowerings per cache key — cache hits (in-memory or
#: disk) do NOT count.  The serving acceptance tests assert every tier
#: of a plan ladder compiles exactly once per process
#: (tests/test_serve_cnn.py); bounded like im2win_conv._trace_counts so
#: a long-lived process cannot grow it without limit.
_compile_counts: dict = {}
_COMPILE_COUNT_LIMIT = 512


def _note_compile(key) -> None:
    if key not in _compile_counts:
        while len(_compile_counts) >= _COMPILE_COUNT_LIMIT:
            del _compile_counts[next(iter(_compile_counts))]
        _compile_counts[key] = 0
    _compile_counts[key] += 1


def compile_counts(*, net: Optional[NetworkMapping] = None,
                   batch: Optional[int] = None) -> dict:
    """Copy of the per-key compile counters, optionally filtered to one
    network mapping and/or plan batch — ``compile_counts(net=nm)``
    values of all 1 prove each (policy, mesh, batch) lowered once."""
    out = {}
    for key, n in _compile_counts.items():
        if net is not None and key[0] != net:
            continue
        if batch is not None and key[3] != batch:
            continue
        out[key] = n
    return out


# a cleared memo cache recompiles, so the counters reset with it —
# "each tier compiled once" stays meaningful per cache generation
memo.register_cache_clear(_compile_counts.clear)
