"""Segmentation pass: choose rematerialization boundaries for a plan.

A plan segment is a half-open layer range ``(s, e)``; `execute_plan`
wraps each segment's forward in `jax.checkpoint` so only the segment
boundary carries are saved for backward and everything inside is
recomputed (exec/run.py keeps the §8 lookahead fence *intra*-segment —
pipelining never leaks a constant across a checkpoint boundary).

**Boundary rule.**  A cut is allowed after layer ``i`` only where the
carry is a plain chain: ``glue[i].kind == "chain"`` *and* no saved
residual/concat source is outstanding (the running ``save`` stack from
the glue pass is empty).  This is exactly the ISSUE's
concat-groups-never-split rule: inside a DenseNet block every layer's
output is saved for downstream concats, so the save stack only drains
at the 1x1 transitions — the block is atomic.  Cutting mid-group would
force a saved tensor to cross a checkpoint boundary, which
`jax.checkpoint` cannot express over our single-carry segment
interface.

**Selection.**  Greedy, in the style of chainer-compiler's
``recompute.cc`` (pick recompute sets from the graph's own per-node
memory estimates): walk the layers accumulating the memory-model bytes
(exec/memory.py) and cut at the *last allowed* boundary whenever the
running segment exceeds the budget.  Greedy-last keeps segments as
large as the budget allows, which minimizes recompute work; it can
only fail to meet the budget when a single atomic group already
exceeds it, in which case we cut as tight as legality allows and
report the achievable peak (callers decide whether a best-effort plan
is acceptable — `train_cnn` raises, the autotuner just measures it).

The ``remat`` argument accepted by `compile_plan` canonicalizes as:

* ``None`` / ``"off"`` — no segmentation (single segment, plan field
  stays ``None`` so PR-4-era plan hashes/describe output are
  unchanged).
* ``"auto"`` — budget from ``REPRO_TRAIN_MEM_BUDGET`` (bytes) if set,
  else ``sqrt``-style: aim for ~``ceil(sqrt(n_cuttable))`` segments,
  the classic O(sqrt n) checkpointing sweet spot.
* ``int`` — explicit peak budget in bytes.
* sequence of ints — explicit boundary layer indices (cut *after*
  each index); validated against the boundary rule, ValueError on an
  illegal cut.
"""
from __future__ import annotations

import math
import os
from typing import Optional, Sequence, Tuple, Union

from . import memory as memlib

ENV_BUDGET = "REPRO_TRAIN_MEM_BUDGET"

RematSpec = Union[None, str, int, Sequence[int]]
Segments = Tuple[Tuple[int, int], ...]


def canonical_remat(remat: RematSpec):
    """Normalize a user remat spec to a hashable cache-key form:
    ``None`` (off), ``("auto", env_budget_or_None)``, ``("budget", n)``
    or ``("cuts", (i, ...))``.  The env budget is folded into the key
    so flipping REPRO_TRAIN_MEM_BUDGET never serves a stale plan."""
    if remat is None or remat == "off" or remat is False:
        return None
    if remat == "auto":
        env = os.environ.get(ENV_BUDGET)
        return ("auto", int(env) if env else None)
    if isinstance(remat, bool):  # guard True before int check
        raise ValueError("remat=True is ambiguous; use 'auto' or a budget")
    if isinstance(remat, int):
        if remat <= 0:
            raise ValueError(f"remat budget must be positive, got {remat}")
        return ("budget", remat)
    try:
        cuts = tuple(sorted(int(i) for i in remat))
    except TypeError:
        raise ValueError(f"bad remat spec: {remat!r}") from None
    return ("cuts", cuts)


def allowed_cuts(glue) -> Tuple[int, ...]:
    """Indices i where cutting after layer i is legal (boundary rule
    above): chain glue with an empty outstanding residual-save stack —
    mirroring `_check_explicit_glue`'s carry simulation, ``save=True``
    pushes and ``kind='residual'`` pops.  Concat glue never cuts (the
    never-split rule: the carry there is the concatenated block stack,
    the worst possible boundary).  The last layer is never a cut (a
    trailing empty segment is meaningless)."""
    saved = 0
    out = []
    for i, g in enumerate(glue[:-1] if glue else []):
        if g.save:
            saved += 1
        if g.kind == "residual":
            saved -= 1
        if g.kind == "chain" and saved == 0:
            out.append(i)
    return tuple(out)


def _segments_from_cuts(cuts: Sequence[int], n: int) -> Segments:
    segs, s = [], 0
    for c in cuts:
        segs.append((s, c + 1))
        s = c + 1
    segs.append((s, n))
    return tuple(segs)


def greedy_segments(mem, allowed: Sequence[int],
                    budget: int) -> Segments:
    """Greedy-last-cut segmentation under ``budget`` (module doc)."""
    n = len(mem)
    allowed = set(allowed)
    cuts = []
    start = 0
    running = 0
    last_ok: Optional[int] = None
    for i, m in enumerate(mem):
        running += m.total_bytes
        if running > budget and last_ok is not None and last_ok >= start:
            cuts.append(last_ok)
            start = last_ok + 1
            running = sum(x.total_bytes for x in mem[start:i + 1])
            last_ok = None
        if i in allowed:
            last_ok = i
    return _segments_from_cuts(cuts, n)


def _auto_budget(mem, allowed) -> int:
    """No env budget: target ~sqrt(n_layers) segments — the classic
    O(sqrt n) checkpointing sweet spot — by sizing the budget as
    total/ceil(sqrt(n)).  With fewer legal cuts than that (DenseNet:
    only the transitions), greedy simply uses every cut it has."""
    total = memlib.total_bytes(mem)
    want = max(2, math.ceil(math.sqrt(len(mem))))
    return max(1, total // want)


def plan_segments(mem, allowed: Sequence[int],
                  spec) -> Optional[Segments]:
    """Run the segmentation pass.  ``spec`` is `canonical_remat` output
    and ``allowed`` the legal cut indices (`allowed_cuts` for chained
    plans; every boundary for layerwise ones, where the model owns the
    glue); returns None for remat-off, else the segment tuple."""
    if spec is None:
        return None
    n = len(mem)
    allowed = tuple(allowed)
    kind = spec[0]
    if kind == "cuts":
        bad = [c for c in spec[1] if c not in allowed]
        if bad:
            raise ValueError(
                f"illegal remat boundaries {bad}: cuts are only allowed "
                f"after chain layers with no outstanding concat/residual "
                f"saves (allowed: {list(allowed)})")
        return _segments_from_cuts(spec[1], n)
    if kind == "auto":
        budget = spec[1] if spec[1] else _auto_budget(mem, allowed)
    else:
        budget = spec[1]
    return greedy_segments(mem, allowed, budget)
