"""Shared plan constants: prepared shifted-weight buffers across tiers.

Every tier of a plan ladder — and, in fleet serving
(launch/fleet.py), every co-resident plan of the same network —
executes the SAME ``NetworkMapping``.  Yet each tier's fused program
re-derives the identical shifted-and-duplicated weight matrices
(`cnn/mapped_net._tile_weights`, the Fig 5 blocks) from the raw kernels
on every forward: the prep is batch-independent, so a three-tier ladder
pays for it three programs over, once per forward each.

:func:`prepare_constants` materializes those blocks ONCE per network —
per tile, per congruent window shape, for every layer the plan
dispatches to the ``"mapped"`` executor — into a :class:`PlanConstants`
handle, memoized through ``core/memo.cached_constants`` keyed on the net
mapping (plus resolved executors and the caller's kernel token).
``execute_plan(constants=...)`` then feeds the blocks to any tier of any
co-resident ladder of that network as ordinary program inputs: the
in-trace weight prep disappears from every tier's forward, and all tiers
share one device copy instead of duplicating it per tier.

The blocks arrive as program *inputs*, so the cross-layer lookahead
fence in exec/run.py deliberately does not thread them: hoisting an
already-materialized buffer costs nothing — the fence exists to stop
XLA from computing every layer's prep up front, and with constants there
is no in-program prep left to hoist.

``constant_counts`` mirrors ``exec/plan.compile_counts``: actual
materializations per cache key (hits do NOT count), the evidence the
fleet tests use to assert constants materialize once per network, not
once per tier (tests/test_fleet.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.core import memo
from repro.core.types import NetworkMapping

from .plan import NetworkPlan


@dataclass(frozen=True)
class PlanConstants:
    """Prepared constants for every plan compiled from one network
    mapping: ``weights[i]`` is layer i's per-tile/per-shape blocked
    shifted-weight matrices (`cnn/mapped_net.prepared_layer_weights`)
    when the plan runs that layer on the ``"mapped"`` executor, else
    ``None`` (the reference/sdk/matmul executors consume raw kernels —
    an op="matmul" layer's weight matrix needs no shifted duplication).
    Valid for ANY batch/tier of the network — the blocks are input- and
    batch-independent."""

    net: NetworkMapping
    executors: Tuple[str, ...]
    weights: Tuple[Optional[Tuple], ...]


def _materialize(plan: NetworkPlan, kernels: Sequence) -> PlanConstants:
    from repro.cnn.mapped_net import prepared_layer_weights
    if len(kernels) != len(plan.layers):
        raise ValueError(f"{len(kernels)} kernels for "
                         f"{len(plan.layers)} planned layers")
    weights = tuple(
        prepared_layer_weights(lp.mapping, k) if lp.executor == "mapped"
        else None
        for lp, k in zip(plan.layers, kernels))
    return PlanConstants(net=plan.net, executors=plan.executors,
                         weights=weights)


def prepare_constants(plan: NetworkPlan, kernels: Sequence, *,
                      token=None) -> PlanConstants:
    """Materialize (or fetch) the shared constants for ``plan``'s
    network.

    ``token`` identifies the kernel values (arrays are unhashable): with
    a token the handle is memoized in ``memo.cached_constants`` keyed on
    ``(net, resolved executors, token)``, so every tier of every ladder
    asking for the same network's constants gets the SAME handle and the
    blocks materialize once per network (``constant_counts`` is the
    per-key evidence).  ``token=None`` builds an unshared handle — the
    caller owns its lifetime.  The returned handle serves ANY plan
    compiled from the same mapping with the same resolved executors,
    whatever its batch/tier/mesh."""
    def build():
        if token is not None:
            _note_materialize((plan.net, plan.executors, token))
        return _materialize(plan, kernels)

    if token is None:
        return build()
    return memo.cached_constants(("consts", plan.net, plan.executors,
                                  token), build)


#: Actual materializations per (net, executors, token) — cache hits do
#: NOT count.  The fleet tests assert one materialization per network
#: however many tiers consume the handle; bounded like
#: exec/plan._compile_counts so a long-lived process cannot grow it.
_constant_counts: dict = {}
_CONSTANT_COUNT_LIMIT = 256


def _note_materialize(key) -> None:
    if key not in _constant_counts:
        while len(_constant_counts) >= _CONSTANT_COUNT_LIMIT:
            del _constant_counts[next(iter(_constant_counts))]
        _constant_counts[key] = 0
    _constant_counts[key] += 1


def constant_counts(*, net: Optional[NetworkMapping] = None) -> dict:
    """Copy of the per-key materialization counters, optionally filtered
    to one network mapping — ``constant_counts(net=nm)`` of length 1
    with value 1 proves the network's constants were prepared once and
    shared across every tier that used them."""
    out = {}
    for key, n in _constant_counts.items():
        if net is not None and key[0] != net:
            continue
        out[key] = n
    return out


# a cleared memo cache re-materializes, so the counters reset with it
memo.register_cache_clear(_constant_counts.clear)
