"""Inter-layer glue: the deterministic adapters between mapped layers.

A `NetworkMapping` chains layers whose padded specs rarely line up
exactly; the glue closes the gap in two orthogonal directions:

* **spatial** — :func:`fit_spatial` 2x2-max-pools while the carry is
  >= 2x the next layer's (padded) input, then center-pads / center-crops
  to the exact size.  Deterministic in the *shapes* only, so it is
  resolvable at plan-compile time and traces to a static op chain.
* **channel** — :func:`resolve_chain` classifies how layer i feeds
  layer i+1 from pure channel arithmetic: ``"chain"`` when the next
  layer's ic equals this layer's oc, ``"concat"`` (DenseNet-style: the
  layer's unpadded input is concatenated with its output) when it
  equals their sum, and a clear error otherwise.

Both are mirrored by the reference composition (`reference_net_apply`)
so equivalence tests compare executors, not plumbing.

Since the operator-generic refactor (ISSUE 8, DESIGN.md §11) glue is a
structured `repro.core.GlueSpec` — ``kind`` is the carry rule below,
plus optional per-layer stages the CIM macros do not execute: ``pre``
layernorm passthrough (:func:`layernorm`), ``act`` activations
(:data:`ACTIVATIONS`), ``save``/``kind="residual"`` for transformer
residual adds, and the ``post="attention"`` opaque stage
(:func:`attention_stage`) that turns a fused qkv projection's output
into attention context via `kernels.flash_attention` between two mapped
matmuls.  This module stays a leaf — jax + kernels only — so every
executor layer can import it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import GlueSpec  # noqa: F401  (re-export)

#: Post-layer carry updates a plan can prescribe (LayerPlan.glue.kind):
#: "chain" — carry becomes the layer's output; "concat" — carry becomes
#: concat(center-cropped layer input, output); "residual" — carry becomes
#: saved input + output (transformer skip); "last" — final layer, the
#: output IS the result.
GLUE_KINDS = ("chain", "concat", "residual", "last")

#: Per-layer glue activations (GlueSpec.act).  A layer whose glue names
#: one overrides any network-global ``activation`` for that layer.
ACTIVATIONS = {"relu": jax.nn.relu, "gelu": jax.nn.gelu,
               "silu": jax.nn.silu}


def layernorm(x: jnp.ndarray, axis: int = 1,
              eps: float = 1e-5) -> jnp.ndarray:
    """Parameter-free layernorm over the channel axis (GlueSpec.pre).
    The mapped-serving lowering keeps norms outside the CIM macros as
    passthrough stages (geometry over weights — rmsnorm configs lower
    here too); learned scale/bias would fold into the next matmul's
    mapped weights, not into glue."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    var = jnp.var(x, axis=axis, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def attention_stage(y: jnp.ndarray, heads, causal: bool, *,
                    interpret: bool = False) -> jnp.ndarray:
    """The opaque attention stage (GlueSpec.post="attention"): consume a
    fused qkv projection's output ``y (B, (hq+2*hkv)*hd, M, 1)`` and
    return context ``(B, hq*hd, M, 1)`` for the mapped O projection.

    Runs `kernels.flash_attention.mha_flash` when M tiles by its block
    constraint (any M <= 128, or M % 128 == 0), else falls back to the
    plain-softmax oracle — the stage is glue, not a mapped layer, so
    cycle accounting is unaffected either way."""
    from repro.kernels import flash_attention as fa
    from repro.kernels import ref
    hq, hkv, hd = heads
    b, c, m, w = y.shape
    if w != 1 or c != (hq + 2 * hkv) * hd:
        raise ValueError(f"attention_stage: qkv output {y.shape} != "
                         f"(B, {(hq + 2 * hkv) * hd}, M, 1) for "
                         f"heads={heads}")
    tok = y[..., 0].transpose(0, 2, 1)                   # (B, M, C)
    q = tok[..., :hq * hd].reshape(b, m, hq, hd)
    k = tok[..., hq * hd:(hq + hkv) * hd].reshape(b, m, hkv, hd)
    v = tok[..., (hq + hkv) * hd:].reshape(b, m, hkv, hd)
    if m <= 128 or m % 128 == 0:
        o = fa.mha_flash(q, k, v, causal=causal, interpret=interpret)
    else:                                   # ragged long seq: oracle path
        qf = q.transpose(0, 2, 1, 3).reshape(b * hq, m, hd)
        rep = hq // hkv
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
        o = ref.flash_attention_ref(
            qf, kf.reshape(b * hq, m, hd), vf.reshape(b * hq, m, hd),
            causal=causal).reshape(b, hq, m, hd).transpose(0, 2, 1, 3)
    return o.reshape(b, m, hq * hd).transpose(0, 2, 1)[..., None]


def fit_spatial(x: jnp.ndarray, i_h: int, i_w: int) -> jnp.ndarray:
    """Deterministic inter-layer adapter: 2x2 max-pool while the feature
    map is >= 2x the next layer's (padded) input, then center pad / crop
    to the exact size.  Mirrored by the reference composition so the
    cross-check compares executors, not plumbing."""
    while x.shape[-2] >= 2 * i_h and x.shape[-1] >= 2 * i_w:
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    for ax, tgt in ((-2, i_h), (-1, i_w)):
        d = tgt - x.shape[ax]
        if d > 0:
            pad = [(0, 0)] * x.ndim
            pad[ax] = (d // 2, d - d // 2)
            x = jnp.pad(x, pad)
        elif d < 0:
            lo = (-d) // 2
            x = jax.lax.slice_in_dim(x, lo, lo + tgt, axis=x.ndim + ax)
    return x


def center_crop(x: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    """Center (h, w) spatial slice of x (..., H, W) with H >= h, W >= w."""
    y0 = (x.shape[-2] - h) // 2
    x0 = (x.shape[-1] - w) // 2
    return x[..., y0:y0 + h, x0:x0 + w]


def resolve_chain(name: str, oc: int, carry_c: int,
                  nxt_name: str, nxt_ic: int) -> str:
    """Classify how a layer with ``oc`` output channels (and ``carry_c``
    carried input channels) feeds the next layer: ``"chain"`` or
    ``"concat"``.  Raises the chaining error on any other arithmetic —
    at plan-compile time, not mid-forward."""
    if nxt_ic == oc:
        return "chain"
    if nxt_ic == carry_c + oc:
        return "concat"
    raise ValueError(
        f"cannot chain {name} (oc={oc}, carry={carry_c}) into "
        f"{nxt_name} (ic={nxt_ic})")
