"""Inter-layer glue: the deterministic adapters between mapped layers.

A `NetworkMapping` chains layers whose padded specs rarely line up
exactly; the glue closes the gap in two orthogonal directions:

* **spatial** — :func:`fit_spatial` 2x2-max-pools while the carry is
  >= 2x the next layer's (padded) input, then center-pads / center-crops
  to the exact size.  Deterministic in the *shapes* only, so it is
  resolvable at plan-compile time and traces to a static op chain.
* **channel** — :func:`resolve_chain` classifies how layer i feeds
  layer i+1 from pure channel arithmetic: ``"chain"`` when the next
  layer's ic equals this layer's oc, ``"concat"`` (DenseNet-style: the
  layer's unpadded input is concatenated with its output) when it
  equals their sum, and a clear error otherwise.

Both are mirrored by the reference composition (`reference_net_apply`)
so equivalence tests compare executors, not plumbing.  This module is a
leaf — pure jax + stdlib — so every executor layer can import it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Post-layer carry updates a plan can prescribe (LayerPlan.glue):
#: "chain" — carry becomes the layer's output; "concat" — carry becomes
#: concat(center-cropped layer input, output); "last" — final layer,
#: the output IS the result.
GLUE_KINDS = ("chain", "concat", "last")


def fit_spatial(x: jnp.ndarray, i_h: int, i_w: int) -> jnp.ndarray:
    """Deterministic inter-layer adapter: 2x2 max-pool while the feature
    map is >= 2x the next layer's (padded) input, then center pad / crop
    to the exact size.  Mirrored by the reference composition so the
    cross-check compares executors, not plumbing."""
    while x.shape[-2] >= 2 * i_h and x.shape[-1] >= 2 * i_w:
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    for ax, tgt in ((-2, i_h), (-1, i_w)):
        d = tgt - x.shape[ax]
        if d > 0:
            pad = [(0, 0)] * x.ndim
            pad[ax] = (d // 2, d - d // 2)
            x = jnp.pad(x, pad)
        elif d < 0:
            lo = (-d) // 2
            x = jax.lax.slice_in_dim(x, lo, lo + tgt, axis=x.ndim + ax)
    return x


def center_crop(x: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    """Center (h, w) spatial slice of x (..., H, W) with H >= h, W >= w."""
    y0 = (x.shape[-2] - h) // 2
    x0 = (x.shape[-1] - w) // 2
    return x[..., y0:y0 + h, x0:x0 + w]


def resolve_chain(name: str, oc: int, carry_c: int,
                  nxt_name: str, nxt_ic: int) -> str:
    """Classify how a layer with ``oc`` output channels (and ``carry_c``
    carried input channels) feeds the next layer: ``"chain"`` or
    ``"concat"``.  Raises the chaining error on any other arithmetic —
    at plan-compile time, not mid-forward."""
    if nxt_ic == oc:
        return "chain"
    if nxt_ic == carry_c + oc:
        return "concat"
    raise ValueError(
        f"cannot chain {name} (oc={oc}, carry={carry_c}) into "
        f"{nxt_name} (ic={nxt_ic})")
