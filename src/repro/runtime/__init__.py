from .recovery import (ElasticPlan, HeartbeatMonitor, StragglerPolicy,
                       TrainSupervisor, derive_elastic_mesh)
