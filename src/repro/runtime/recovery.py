"""Fault-tolerance runtime: heartbeats, straggler mitigation, elastic
re-meshing, supervised train loop with checkpoint/restart.

On a real multi-host deployment the heartbeat source is the coordination
service (jax.distributed / GCS liveness); here the transport is an
injectable callable so the logic is fully testable on one host.  The
design targets 1000+ nodes: O(1) state per worker, deadline-based
detection, and restart decisions that only depend on the surviving
device count.

Recovery model (standard TPU-pod practice):
  * worker misses `dead_after` heartbeats      -> declared dead
  * any dead worker                            -> stop, re-mesh on the
    surviving hosts (derive_elastic_mesh), restore latest checkpoint
    (checkpoint.store reshards onto the new mesh), replay the data
    cursor (pipeline.skip_to) — sample-exact resume
  * straggler (slow but alive)                 -> policy: warn (log),
    or demote (treat as dead at the next re-mesh window)
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class StragglerPolicy:
    warn_factor: float = 1.5       # step slower than median x this -> warn
    demote_factor: float = 3.0     # -> treat as failed at next window
    window: int = 20               # steps of history


class HeartbeatMonitor:
    """Deadline-based liveness + straggler detection over step reports.

    Two kinds of signal: :meth:`beat` is liveness only (the serving
    tier's idle heartbeats — they must not dilute the straggler step
    statistics with zero-length samples), :meth:`report` is a completed
    step with its duration (feeds both liveness and the straggler
    medians).  :meth:`forget` retires a worker that was declared dead
    so it stops being re-reported — the replica router
    (`launch/replica.py`) re-queues its work exactly once."""

    def __init__(self, n_workers: int, *, dead_after_s: float = 60.0,
                 policy: Optional[StragglerPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.n = n_workers
        self.dead_after = dead_after_s
        self.policy = policy or StragglerPolicy()
        self.clock = clock
        self.last_seen = {w: clock() for w in range(n_workers)}
        self.durations: Dict[int, List[float]] = {w: []
                                                  for w in range(n_workers)}

    def beat(self, worker: int) -> None:
        """Liveness-only heartbeat: refresh the deadline, record no
        step duration."""
        self.last_seen[worker] = self.clock()

    def report(self, worker: int, step_duration_s: float) -> None:
        self.last_seen[worker] = self.clock()
        d = self.durations.setdefault(worker, [])
        d.append(step_duration_s)
        if len(d) > self.policy.window:
            d.pop(0)

    def forget(self, worker: int) -> None:
        """Retire a worker (declared dead and handled): it no longer
        appears in :meth:`dead_workers` or the straggler scan."""
        self.last_seen.pop(worker, None)
        self.durations.pop(worker, None)

    def dead_workers(self) -> List[int]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.dead_after]

    def stragglers(self) -> Dict[int, str]:
        med = self._median_all()
        if med is None:
            return {}
        out = {}
        for w, d in self.durations.items():
            if not d:
                continue
            mine = sorted(d)[len(d) // 2]
            if mine > self.policy.demote_factor * med:
                out[w] = "demote"
            elif mine > self.policy.warn_factor * med:
                out[w] = "warn"
        return out

    def _median_all(self) -> Optional[float]:
        alld = [x for d in self.durations.values() for x in d]
        if not alld:
            return None
        return sorted(alld)[len(alld) // 2]


@dataclass(frozen=True)
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped: int


def derive_elastic_mesh(n_alive: int, *, model_parallel: int,
                        prefer_pods: bool = True) -> ElasticPlan:
    """Largest coherent (data, model) mesh on the surviving devices.

    Model parallel size is preserved (params are sharded that way);
    the data axis shrinks to floor(n_alive / model_parallel).  With
    prefer_pods, whole multiples of a pod's data extent are kept so the
    slow-link topology stays clean."""
    if n_alive < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{n_alive} devices")
    data = n_alive // model_parallel
    # keep the data extent a power of two (collective-friendly)
    data = 2 ** int(math.log2(data))
    used = data * model_parallel
    return ElasticPlan(shape=(data, model_parallel),
                       axes=("data", "model"),
                       dropped=n_alive - used)


class TrainSupervisor:
    """Orchestrates the train loop: periodic checkpoints, heartbeat
    scanning, restart-from-checkpoint on failure.  Deliberately
    framework-thin so tests can drive it with fake steps/clocks."""

    def __init__(self, *, store, pipeline, monitor: HeartbeatMonitor,
                 save_every: int = 100):
        self.store = store
        self.pipeline = pipeline
        self.monitor = monitor
        self.save_every = save_every
        self.events: List[str] = []

    def run(self, state, step_fn, *, start_step: int = 0, steps: int = 100,
            inject_failure_at: Optional[int] = None):
        """Returns (state, last_step).  ``inject_failure_at`` simulates a
        worker loss mid-run (used by tests and the fault-tolerance
        example)."""
        step = start_step
        self.pipeline.skip_to(step)
        while step < steps:
            if inject_failure_at is not None and step == inject_failure_at:
                self.events.append(f"FAILURE injected at step {step}")
                raise WorkerLost(step)
            t0 = time.monotonic()
            batch = self.pipeline.next()
            state, metrics = step_fn(state, batch)
            self.monitor.report(0, time.monotonic() - t0)
            step += 1
            if step % self.save_every == 0 or step == steps:
                self.store.save(step, state,
                                extra={"data_step": self.pipeline.step})
                self.events.append(f"checkpoint at {step}")
            for w, action in self.monitor.stragglers().items():
                self.events.append(f"straggler worker={w} action={action}")
        return state, step

    def resume(self, like, step_fn, *, steps: int, shardings=None):
        state, step, extra = self.store.restore_latest(like, shardings)
        self.pipeline.skip_to(extra.get("data_step", step))
        self.events.append(f"resumed from step {step}")
        return self.run(state, step_fn, start_step=step, steps=steps)


class WorkerLost(RuntimeError):
    def __init__(self, step: int):
        super().__init__(f"worker lost at step {step}")
        self.step = step
