"""Perf probe: compile one cell and print the top HBM/collective
contributors by jax op-name group (hypothesis-forming tool for SPerf)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_cell
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch import roofline as rl

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--top", type=int, default=14)
args = ap.parse_args()

cfg = get_config(args.arch)
mesh = make_production_mesh()
fn, a, in_sh, out_sh = build_cell(cfg, SHAPES[args.shape], mesh)
with mesh:
    comp = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*a).compile()
t = analyze_hlo(comp.as_text())
print(f"flops/chip {t.flops/1e12:.2f} TF | hbm/chip {t.hbm_bytes/1e12:.2f} TB"
      f" | coll/chip {t.coll_bytes.get('total',0)/1e9:.1f} GB")
print(f"t_comp {t.flops/rl.PEAK_FLOPS:.2f}s t_mem {t.hbm_bytes/rl.HBM_BW:.2f}s "
      f"t_coll {t.coll_bytes.get('total',0)/rl.LINK_BW:.2f}s")
print("\n-- top HBM groups --")
for g, b in sorted(t.hbm_by_group.items(), key=lambda kv: -kv[1])[:args.top]:
    print(f"  {b/1e12:8.3f} TB  {g}")
print("\n-- top collective groups --")
for g, b in sorted(t.coll_by_group.items(), key=lambda kv: -kv[1])[:args.top]:
    print(f"  {b/1e9:8.2f} GB  {g}")
