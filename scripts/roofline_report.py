"""Render EXPERIMENTS.md tables from results/dryrun_{base,opt}/*.json.

    python scripts/roofline_report.py roofline [tag]   # per-cell terms
    python scripts/roofline_report.py compare          # base vs opt
    python scripts/roofline_report.py dryrun [tag]     # compile summary
"""
import glob
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def fmt_t(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(tag, mesh=None):
    recs = [json.loads(Path(p).read_text())
            for p in glob.glob(str(ROOT / "results" / tag / "*.json"))]
    if mesh:
        recs = [r for r in recs if r["mesh"] == mesh]
    recs.sort(key=lambda r: (r["arch"], ORDER[r["shape"]]))
    return recs


def roofline_table(tag="dryrun_opt", mesh="16x16"):
    print(f"\n### Roofline — {tag}, mesh {mesh} (per-chip, v5e constants)\n")
    print("| arch | shape | status | t_comp | t_mem | t_coll | dominant "
          "| useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load(tag, mesh):
        if r["status"] != "OK":
            reason = r.get("reason", r.get("error", ""))[:38]
            print(f"| {r['arch']} | {r['shape']} | {r['status']} "
                  f"({reason}) | | | | | | |")
            continue
        f = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | OK | "
              f"{fmt_t(f['t_compute'])} | {fmt_t(f['t_memory'])} | "
              f"{fmt_t(f['t_collective'])} | {f['dominant']} | "
              f"{f['useful_flops_fraction']:.3f} | "
              f"{f['roofline_fraction']:.4f} |")


def compare(mesh="16x16"):
    base = {(r["arch"], r["shape"]): r for r in load("dryrun_base", mesh)}
    opt = {(r["arch"], r["shape"]): r for r in load("dryrun_opt", mesh)}
    print(f"\n### Baseline vs optimized — mesh {mesh} "
          f"(bound = max roofline term, s/chip)\n")
    print("| arch | shape | base bound (dom) | opt bound (dom) | speedup "
          "| base frac | opt frac |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(base, key=lambda k: (k[0], ORDER[k[1]])):
        b, o = base[key], opt.get(key)
        if b["status"] != "OK" or not o or o["status"] != "OK":
            continue
        fb, fo = b["roofline"], o["roofline"]
        bb = max(fb["t_compute"], fb["t_memory"], fb["t_collective"])
        ob = max(fo["t_compute"], fo["t_memory"], fo["t_collective"])
        print(f"| {key[0]} | {key[1]} | {fmt_t(bb)} ({fb['dominant'][:4]}) "
              f"| {fmt_t(ob)} ({fo['dominant'][:4]}) | "
              f"{bb/ob if ob else 0:.2f}x | "
              f"{fb['roofline_fraction']:.4f} | "
              f"{fo['roofline_fraction']:.4f} |")


def dryrun_table(tag="dryrun_opt"):
    print(f"\n### Dry-run compile summary — {tag} (both meshes)\n")
    print("| arch | shape | mesh | compile_s | temp GB/chip | "
          "coll GB/chip (AG/AR/RS/A2A/CP) |")
    print("|---|---|---|---|---|---|")
    for r in load(tag):
        if r["status"] != "OK":
            continue
        c = r["collectives"]
        parts = "/".join(
            f"{c.get(k, 0)/1e9:.1f}" for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute"))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r.get('compile_s', 0):.0f} | "
              f"{r['memory']['temp_bytes']/1e9:.2f} | {parts} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    tag = sys.argv[2] if len(sys.argv) > 2 else "dryrun_opt"
    if which in ("roofline", "all"):
        roofline_table(tag)
    if which == "multi":
        roofline_table(tag, "2x16x16")
    if which in ("compare", "all"):
        compare()
    if which in ("dryrun",):
        dryrun_table(tag)
