"""Dump the top individual HBM-traffic ops (with loop amplification)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse, sys, re
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_cell
from repro.launch import hlo_analysis as H

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--top", type=int, default=12)
a = ap.parse_args()
cfg = get_config(a.arch)
mesh = make_production_mesh()
fn, args, in_sh, out_sh = build_cell(cfg, SHAPES[a.shape], mesh)
with mesh:
    comp = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
txt = comp.as_text()
comps = H.parse_computations(txt)

# compute trip multiplier per computation by walking from entry
trips = {}
def walk(name, mult, stack=()):
    if name in stack: return
    c = comps.get(name)
    if c is None: return
    trips[name] = trips.get(name, 0) + mult
    for op in c.ops:
        if op.kind == "while":
            cond = H._COND.search(op.rest); body = H._CALLEE.search(op.rest)
            t = 1
            if cond:
                for o2 in comps.get(cond.group(1), H.Computation("x")).ops:
                    for cc in H._CONST_INT.findall(o2.rest):
                        t = max(t, int(cc))
            if body: walk(body.group(1), mult*t, stack+(name,))
        elif op.kind in ("fusion","call","conditional","map"):
            pass  # fusion internals not HBM
entry = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M).group(1)
walk(entry, 1)

rows = []
bytes_by_name = {}
for c in comps.values():
    for op in c.ops:
        bytes_by_name[op.name] = op.out_bytes
for cname, mult in trips.items():
    for op in comps[cname].ops:
        if op.kind in H._FREE_OPS or op.kind in ("while",):
            continue
        bb = op.out_bytes
        args_txt = op.rest.split("(", 1)
        if len(args_txt) == 2:
            for o2 in H._OPERANDS.findall(args_txt[1].split(")")[0]):
                bb += bytes_by_name.get(o2, 0)
        rows.append((bb*mult, mult, cname, op))
rows.sort(key=lambda r: -r[0])
for bb, mult, cname, op in rows[:a.top]:
    md = H._METADATA_NAME.search(op.rest)
    print(f"{bb/1e12:7.2f} TB x{mult:<5d} {op.kind:18s} {op.type_txt[:44]:44s} "
          f"{(md.group(1)[-70:] if md else cname[:40])}")
